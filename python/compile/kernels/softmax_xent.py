"""L1 Pallas kernel: fused row-wise softmax + cross-entropy.

Produces both the per-row loss and the gradient w.r.t. the logits in one
pass over VMEM-resident tiles (labels may be soft/unnormalized; the
gradient uses the exact ``(sum(label) * p - label)`` form, matching the
Rust engine's loss layer).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BR = 128  # row tile


def _kernel(logits_ref, labels_ref, loss_ref, dlogits_ref):
    z = logits_ref[...]
    y = labels_ref[...]
    zmax = jnp.max(z, axis=-1, keepdims=True)
    ez = jnp.exp(z - zmax)
    denom = jnp.sum(ez, axis=-1, keepdims=True)
    logp = z - zmax - jnp.log(denom)
    p = ez / denom
    loss_ref[...] = -jnp.sum(y * logp, axis=-1)
    lsum = jnp.sum(y, axis=-1, keepdims=True)
    dlogits_ref[...] = lsum * p - y


@jax.jit
def softmax_xent(logits, labels):
    """Per-row loss + dlogits. logits/labels: [R, C] -> ([R], [R, C])."""
    r, c = logits.shape
    br = BR if r % BR == 0 else r
    grid = (r // br,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, c), lambda i: (i, 0)),
            pl.BlockSpec((br, c), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((br,), lambda i: (i,)),
            pl.BlockSpec((br, c), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((r,), logits.dtype),
            jax.ShapeDtypeStruct((r, c), logits.dtype),
        ],
        interpret=True,
    )(logits, labels)


del functools
