"""L1 Pallas kernel: tiled fused ``matmul + bias + activation``.

This is the training hot-spot of the reproduced system (every linear /
im2col-conv layer is a matmul). The kernel is written the TPU way:

* the grid tiles M and N; each program instance owns one ``(BM, BN)``
  output tile resident in VMEM,
* the full K dimension is streamed through the MXU per tile (f32
  accumulation; on real TPU the inputs would be bf16 into the 128x128
  systolic array),
* bias add + activation are fused into the epilogue so the tile never
  round-trips to HBM between ops.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so TPU lowering is compile-only; correctness is validated
against ``ref.py`` by pytest/hypothesis (see DESIGN.md
§Hardware-Adaptation for the VMEM/MXU estimate).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes, MXU-oriented (128x128 systolic array). Shapes that
# are not multiples fall back to one-tile blocks.
BM = 128
BN = 128


def _act(x, kind):
    if kind == "none":
        return x
    if kind == "sigmoid":
        return jax.nn.sigmoid(x)
    if kind == "relu":
        return jnp.maximum(x, 0.0)
    if kind == "tanh":
        return jnp.tanh(x)
    raise ValueError(f"unknown activation {kind!r}")


def _kernel(x_ref, w_ref, b_ref, o_ref, *, act):
    # One (BM, BN) output tile: stream full K through the MXU, accumulate
    # in f32, fuse bias + activation in the epilogue.
    acc = jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )
    acc = acc + b_ref[...][None, :]
    o_ref[...] = _act(acc, act).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("act",))
def fused_matmul(x, w, b, act="none"):
    """``act(x @ w + b)`` via a tiled Pallas kernel.

    x: [M, K], w: [K, N], b: [N] -> [M, N]
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    assert b.shape == (n,)
    bm = BM if m % BM == 0 else m
    bn = BN if n % BN == 0 else n
    grid = (m // bm, n // bn)
    return pl.pallas_call(
        functools.partial(_kernel, act=act),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=True,
    )(x, w, b)


def vmem_bytes(m, k, n, dtype_bytes=4, bm=BM, bn=BN):
    """Estimated VMEM footprint per grid step (perf model for DESIGN.md):
    x tile + w tile + bias + out tile + f32 accumulator."""
    bm = bm if m % bm == 0 else m
    bn = bn if n % bn == 0 else n
    return dtype_bytes * (bm * k + k * bn + bn + bm * bn) + 4 * bm * bn


def mxu_utilization(m, k, n, bm=BM, bn=BN):
    """Fraction of MXU-issue slots doing useful work for this shape
    (edge-tile padding waste only; assumes weight-stationary scheduling)."""
    bm = bm if m % bm == 0 else m
    bn = bn if n % bn == 0 else n
    tiles = (m // bm) * (n // bn)
    useful = m * k * n
    issued = tiles * bm * bn * k
    return useful / issued if issued else 0.0
