"""Pure-jnp oracles for the L1 Pallas kernels (the paper's
"equivalence at 1e-4" correctness discipline, applied at build time)."""

import jax
import jax.numpy as jnp


def fused_matmul_ref(x, w, b, act="none"):
    y = x @ w + b[None, :]
    if act == "none":
        return y
    if act == "sigmoid":
        return jax.nn.sigmoid(y)
    if act == "relu":
        return jnp.maximum(y, 0.0)
    if act == "tanh":
        return jnp.tanh(y)
    raise ValueError(act)


def softmax_xent_ref(logits, labels):
    logp = jax.nn.log_softmax(logits, axis=-1)
    loss = -jnp.sum(labels * logp, axis=-1)
    p = jnp.exp(logp)
    lsum = jnp.sum(labels, axis=-1, keepdims=True)
    dlogits = lsum * p - labels
    return loss, dlogits


def lstm_ref(x, wx, wh, b):
    """Full-sequence LSTM oracle matching the Rust layer's layout.

    x: [B, T, I]; wx: [I, 4H]; wh: [H, 4H]; b: [4H] with gate order
    (i, f, g, o). Returns h sequence [B, T, H].
    """
    bsz, t, _ = x.shape
    h4 = wx.shape[1]
    hdim = h4 // 4

    def step(carry, xt):
        h, c = carry
        gates = xt @ wx + h @ wh + b
        i = jax.nn.sigmoid(gates[:, :hdim])
        f = jax.nn.sigmoid(gates[:, hdim : 2 * hdim])
        g = jnp.tanh(gates[:, 2 * hdim : 3 * hdim])
        o = jax.nn.sigmoid(gates[:, 3 * hdim :])
        c = f * c + i * g
        h = o * jnp.tanh(c)
        return (h, c), h

    init = (jnp.zeros((bsz, hdim), x.dtype), jnp.zeros((bsz, hdim), x.dtype))
    _, hs = jax.lax.scan(step, init, jnp.transpose(x, (1, 0, 2)))
    return jnp.transpose(hs, (1, 0, 2))


def conv2d_ref(x, w, stride=1, pad="SAME"):
    """x: [B, C, H, W]; w: [OC, C, KH, KW] -> [B, OC, H', W']."""
    return jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding=pad,
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )
