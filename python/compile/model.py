"""L2: the end-to-end demo model's forward/backward in JAX, calling the
L1 Pallas kernels.

The MLP here mirrors ``rust/src/model/zoo.rs::mlp_e2e`` exactly
(256 → 64 sigmoid → 10, softmax-cross-entropy): the Rust coordinator
drives training through the AOT-compiled ``train_step`` while the same
architecture runs on the native engine — the two must agree to 1e-4
(paper §5.1's equivalence methodology, with this module as the oracle).

The backward pass is written explicitly (the layer-op discipline of the
paper) rather than via ``jax.grad``: forward calls the Pallas kernels,
backward reuses their saved activations.
"""

import jax
import jax.numpy as jnp

from .kernels.fused_matmul import fused_matmul
from .kernels.softmax_xent import softmax_xent
from .kernels import ref

# ---- demo-model spec (keep in sync with zoo::mlp_e2e + examples) ----
MLP_IN = 256
MLP_HIDDEN = 64
MLP_OUT = 10
MLP_BATCH = 32
MLP_LR = 0.5


def mlp_init(seed=42):
    k0, k1 = jax.random.split(jax.random.PRNGKey(seed))
    a0 = (6.0 / (MLP_IN + MLP_HIDDEN)) ** 0.5
    a1 = (6.0 / (MLP_HIDDEN + MLP_OUT)) ** 0.5
    return (
        jax.random.uniform(k0, (MLP_IN, MLP_HIDDEN), jnp.float32, -a0, a0),
        jnp.zeros((MLP_HIDDEN,), jnp.float32),
        jax.random.uniform(k1, (MLP_HIDDEN, MLP_OUT), jnp.float32, -a1, a1),
        jnp.zeros((MLP_OUT,), jnp.float32),
    )


def mlp_forward(w0, b0, w1, b1, x):
    """Logits for a batch. Pallas kernels on the linear hot path."""
    h = fused_matmul(x, w0, b0, act="sigmoid")
    return fused_matmul(h, w1, b1, act="none")


def mlp_train_step(w0, b0, w1, b1, x, y):
    """One SGD step; returns updated params + scalar loss.

    Forward through the Pallas kernels; backward written out layer-op
    style (dW = Xᵀ·ΔD etc.) with activations saved from forward.
    """
    bsz = x.shape[0]
    h = fused_matmul(x, w0, b0, act="sigmoid")
    logits = fused_matmul(h, w1, b1, act="none")
    loss_rows, dlogits = softmax_xent(logits, y)
    loss = jnp.mean(loss_rows)
    dlogits = dlogits / bsz
    # fc1 backward
    dw1 = h.T @ dlogits
    db1 = jnp.sum(dlogits, axis=0)
    dh = dlogits @ w1.T
    # sigmoid backward (uses the saved output, the paper's in-place case)
    dpre = dh * h * (1.0 - h)
    # fc0 backward
    dw0 = x.T @ dpre
    db0 = jnp.sum(dpre, axis=0)
    return (
        w0 - MLP_LR * dw0,
        b0 - MLP_LR * db0,
        w1 - MLP_LR * dw1,
        b1 - MLP_LR * db1,
        loss,
    )


def mlp_forward_ref(w0, b0, w1, b1, x):
    """Pure-jnp oracle of the forward path."""
    h = ref.fused_matmul_ref(x, w0, b0, act="sigmoid")
    return ref.fused_matmul_ref(h, w1, b1, act="none")


# ---- per-layer oracle catalog (shapes the Rust tests execute) ----
ORACLE_LINEAR = dict(m=8, k=32, n=16)
ORACLE_CONV = dict(b=2, c=3, h=8, w=8, oc=4, kk=3)
ORACLE_LSTM = dict(b=2, t=5, i=4, h=6)
ORACLE_XENT = dict(r=8, c=10)


def oracle_linear_fwd(x, w, b):
    return fused_matmul(x, w, b, act="none")


def oracle_linear_sigmoid_fwd(x, w, b):
    return fused_matmul(x, w, b, act="sigmoid")


def oracle_conv2d_fwd(x, w):
    return ref.conv2d_ref(x, w, stride=1, pad="SAME")


def oracle_lstm_fwd(x, wx, wh, b):
    return ref.lstm_ref(x, wx, wh, b)


def oracle_softmax_xent(logits, labels):
    return softmax_xent(logits, labels)
