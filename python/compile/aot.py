"""AOT lowering: jax → HLO *text* artifacts for the Rust PJRT runtime.

Run once by ``make artifacts``; Python never touches the training path
afterwards. HLO text (not serialized HloModuleProto) is the interchange
format: jax ≥ 0.5 emits protos with 64-bit instruction ids that the
crate's xla_extension 0.5.1 rejects; the text parser reassigns ids (see
/opt/xla-example/README.md).
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def catalog():
    """name -> (fn, example_args). Every entry lowers to one artifact."""
    B, I, H, O = M.MLP_BATCH, M.MLP_IN, M.MLP_HIDDEN, M.MLP_OUT
    lin = M.ORACLE_LINEAR
    cv = M.ORACLE_CONV
    ls = M.ORACLE_LSTM
    xe = M.ORACLE_XENT
    return {
        "mlp_train_step": (
            lambda *a: M.mlp_train_step(*a),
            (spec(I, H), spec(H), spec(H, O), spec(O), spec(B, I), spec(B, O)),
        ),
        "mlp_forward": (
            lambda *a: (M.mlp_forward(*a),),
            (spec(I, H), spec(H), spec(H, O), spec(O), spec(B, I)),
        ),
        "oracle_linear_fwd": (
            lambda x, w, b: (M.oracle_linear_fwd(x, w, b),),
            (spec(lin["m"], lin["k"]), spec(lin["k"], lin["n"]), spec(lin["n"])),
        ),
        "oracle_linear_sigmoid_fwd": (
            lambda x, w, b: (M.oracle_linear_sigmoid_fwd(x, w, b),),
            (spec(lin["m"], lin["k"]), spec(lin["k"], lin["n"]), spec(lin["n"])),
        ),
        "oracle_conv2d_fwd": (
            lambda x, w: (M.oracle_conv2d_fwd(x, w),),
            (
                spec(cv["b"], cv["c"], cv["h"], cv["w"]),
                spec(cv["oc"], cv["c"], cv["kk"], cv["kk"]),
            ),
        ),
        "oracle_lstm_fwd": (
            lambda x, wx, wh, b: (M.oracle_lstm_fwd(x, wx, wh, b),),
            (
                spec(ls["b"], ls["t"], ls["i"]),
                spec(ls["i"], 4 * ls["h"]),
                spec(ls["h"], 4 * ls["h"]),
                spec(4 * ls["h"]),
            ),
        ),
        "oracle_softmax_xent": (
            lambda z, y: M.oracle_softmax_xent(z, y),
            (spec(xe["r"], xe["c"]), spec(xe["r"], xe["c"])),
        ),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--only", default=None, help="comma-separated subset")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    only = set(args.only.split(",")) if args.only else None
    manifest = {}
    for name, (fn, example) in catalog().items():
        if only and name not in only:
            continue
        lowered = jax.jit(fn).lower(*example)
        text = to_hlo_text(lowered)
        path = os.path.join(args.out, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest[name] = {
            "file": f"{name}.hlo.txt",
            "args": [list(s.shape) for s in example],
            "chars": len(text),
        }
        print(f"  {name}: {len(text)} chars")
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote {len(manifest)} artifacts to {args.out}")


if __name__ == "__main__":
    main()
