"""Behavioral simulation of the memory-pool & spill-store overhaul.

The container has no Rust toolchain (see .claude/skills/verify/SKILL.md),
so the pure algorithms added by the pool/store PR are ported line-by-line
to Python and fuzzed here:

* ``SkylineTree`` — the lazy-propagation chmax/range-max segment tree
  (rust/src/planner/placer.rs) vs a brute-force array oracle.
* The three placers (first-fit, best-fit, skyline with EO coordinate
  compression) — layout validity (no two time-overlapping items overlap
  in space) over randomized segmented-liveness topologies.
* The portfolio tiers (rust/src/planner/gapfit.rs) — nested candidate
  sets make the peak ordering skyline <= best-fit <= first-fit a
  structural guarantee; asserted per random topology.
* ``plan_compaction`` + ``frag_gauge`` (rust/src/planner/compact.rs) —
  slide-down relocation maps over fragmented committed layouts:
  downward monotone moves, relocated-layout validity, memmove safety
  for persistent (every-EO-live) tensors under in-order application,
  and the gauge vs a cell-counting oracle.
* The byte-shuffle + PackBits codec (rust/src/runtime/store.rs) —
  bitwise round-trip over random/adversarial payloads, run-length
  boundaries at 128/129/130, and loud errors on truncation.
* The ``FileStore`` extent/wear/coalescing state machine — ported over
  a bytearray "file" and driven with random put/get/free sequences
  against a naive dict oracle, plus directed wear-rotation and
  write-coalescing cases.

This checks the *logic*, not the Rust build — tier-1 (cargo build/test)
runs driver/CI-side only.
"""

import random

import pytest

EO_MAX = 40

# ---------------------------------------------------------------------
# Ports: interval algebra + placers (placer.rs / gapfit.rs)
# ---------------------------------------------------------------------


def intervals_overlap(a, b):
    i = j = 0
    while i < len(a) and j < len(b):
        a0, a1 = a[i]
        b0, b1 = b[j]
        if a0 <= b1 and b0 <= a1:
            return True
        if a1 < b1:
            i += 1
        else:
            j += 1
    return False


def blocked_ranges(placed, intervals):
    forbidden = [
        (off, off + ln) for iv, off, ln in placed if intervals_overlap(iv, intervals)
    ]
    forbidden.sort()
    return forbidden


def first_fit_place(items):
    placed, regions, pool_len = [], [], 0
    for iid, need, intervals in items:
        forbidden = blocked_ranges(placed, intervals)
        offset = 0
        for a, b in forbidden:
            if offset + need <= a:
                break
            offset = max(offset, b)
        regions.append((iid, offset, need))
        pool_len = max(pool_len, offset + need)
        placed.append((intervals, offset, need))
    return pool_len, regions


def best_fit_place(items):
    placed, regions, pool_len = [], [], 0
    for iid, need, intervals in items:
        forbidden = blocked_ranges(placed, intervals)
        best = None  # (offset, waste)
        cursor = 0
        for a, b in forbidden:
            if a > cursor:
                hole = a - cursor
                if hole >= need:
                    waste = hole - need
                    if best is None or waste < best[1]:
                        best = (cursor, waste)
            cursor = max(cursor, b)
        offset = best[0] if best is not None else cursor
        regions.append((iid, offset, need))
        pool_len = max(pool_len, offset + need)
        placed.append((intervals, offset, need))
    return pool_len, regions


class SkylineTree:
    def __init__(self, length):
        n = max(length, 1)
        self.len = n
        self.max_v = [0] * (4 * n)
        self.lazy = [0] * (4 * n)

    def _push(self, node):
        pend = self.lazy[node]
        if pend > 0:
            for child in (2 * node, 2 * node + 1):
                self.max_v[child] = max(self.max_v[child], pend)
                self.lazy[child] = max(self.lazy[child], pend)
            self.lazy[node] = 0

    def _raise_rec(self, node, l, r, a, b, h):
        if b < l or r < a:
            return
        if a <= l and r <= b:
            self.max_v[node] = max(self.max_v[node], h)
            self.lazy[node] = max(self.lazy[node], h)
            return
        self._push(node)
        mid = (l + r) // 2
        self._raise_rec(2 * node, l, mid, a, b, h)
        self._raise_rec(2 * node + 1, mid + 1, r, a, b, h)
        self.max_v[node] = max(self.max_v[2 * node], self.max_v[2 * node + 1])

    def _query_rec(self, node, l, r, a, b):
        if b < l or r < a:
            return 0
        if a <= l and r <= b:
            return self.max_v[node]
        self._push(node)
        mid = (l + r) // 2
        return max(
            self._query_rec(2 * node, l, mid, a, b),
            self._query_rec(2 * node + 1, mid + 1, r, a, b),
        )

    def raise_(self, a, b, h):
        b = min(b, self.len - 1)
        self._raise_rec(1, 0, self.len - 1, a, b, h)

    def query(self, a, b):
        b = min(b, self.len - 1)
        return self._query_rec(1, 0, self.len - 1, a, b)


def skyline_place(items):
    coords = sorted({e for _, _, ivs in items for a, z in ivs for e in (a, z)})
    index = {e: i for i, e in enumerate(coords)}
    tree = SkylineTree(len(coords))
    regions, pool_len = [], 0
    for iid, need, intervals in items:
        offset = 0
        for a, z in intervals:
            offset = max(offset, tree.query(index[a], index[z]))
        top = offset + need
        for a, z in intervals:
            tree.raise_(index[a], index[z], top)
        regions.append((iid, offset, need))
        pool_len = max(pool_len, top)
    return pool_len, regions


def ordered(items, order):
    if order == "schedule":
        key = lambda it: (it[2][0][0], -it[2][-1][1], it[0])
    elif order == "size":
        key = lambda it: (-it[1], it[2][0][0], it[0])
    else:  # area
        key = lambda it: (
            -it[1] * sum(z - a + 1 for a, z in it[2]),
            it[2][0][0],
            it[0],
        )
    return sorted(items, key=key)


FF_TIER = [(first_fit_place, o) for o in ("schedule", "size")]
BF_TIER = [(best_fit_place, o) for o in ("schedule", "size")] + FF_TIER
SKY_TIER = [
    (p, o)
    for p in (skyline_place, best_fit_place, first_fit_place)
    for o in ("schedule", "size", "area")
]


def portfolio(items, candidates):
    best = None
    for placer, order in candidates:
        length, regions = placer(ordered(items, order))
        if best is None or length < best[0]:
            best = (length, regions)
    return best


def assert_valid(items, regions):
    by_id = {iid: (off, ln) for iid, off, ln in regions}
    for i in range(len(items)):
        for j in range(i + 1, len(items)):
            if intervals_overlap(items[i][2], items[j][2]):
                ao, al = by_id[items[i][0]]
                bo, bl = by_id[items[j][0]]
                assert ao + al <= bo or bo + bl <= ao, (
                    f"items {items[i][0]} and {items[j][0]} overlap in "
                    f"time and space: ({ao},{al}) vs ({bo},{bl})"
                )


def gen_items(rng, n):
    """Random segmented-liveness items; ~25% persistent (live at every EO)."""
    items = []
    for i in range(n):
        need = rng.randint(1, 50)
        if rng.random() < 0.25:
            intervals = [(0, EO_MAX)]
            persistent = True
        else:
            k = rng.randint(1, 3)
            pts = sorted(rng.sample(range(EO_MAX + 1), 2 * k))
            intervals = [(pts[2 * s], pts[2 * s + 1]) for s in range(k)]
            persistent = False
        items.append((i, need, intervals, persistent))
    return items


# ---------------------------------------------------------------------
# Segment tree vs brute force
# ---------------------------------------------------------------------


def test_skyline_tree_matches_brute_force():
    for seed in range(40):
        rng = random.Random(1000 + seed)
        n = rng.randint(1, 60)
        tree = SkylineTree(n)
        brute = [0] * n
        for _ in range(200):
            a = rng.randrange(n)
            b = rng.randrange(a, n)
            if rng.random() < 0.5:
                h = rng.randint(0, 1000)
                tree.raise_(a, b, h)
                for k in range(a, b + 1):
                    brute[k] = max(brute[k], h)
            else:
                assert tree.query(a, b) == max(brute[a : b + 1]), (seed, a, b)


# ---------------------------------------------------------------------
# Placer validity + portfolio nesting
# ---------------------------------------------------------------------


def test_placers_valid_and_tier_peaks_nested():
    for seed in range(400):
        rng = random.Random(2000 + seed)
        items = [(i, n, iv) for i, n, iv, _ in gen_items(rng, rng.randint(2, 14))]
        for placer in (first_fit_place, best_fit_place, skyline_place):
            length, regions = placer(items)
            assert_valid(items, regions)
            assert length == max(off + ln for _, off, ln in regions)
        ff, ff_regions = portfolio(items, FF_TIER)
        bf, bf_regions = portfolio(items, BF_TIER)
        sky, sky_regions = portfolio(items, SKY_TIER)
        assert sky <= bf <= ff, (seed, sky, bf, ff)
        for regions in (ff_regions, bf_regions, sky_regions):
            assert_valid(items, regions)


def test_skyline_reuses_dead_time():
    # b lives strictly inside a's idle gap -> same address (placer.rs
    # unit fixture)
    items = [(0, 100, [(0, 1), (8, 9)]), (1, 100, [(3, 5)])]
    length, regions = skyline_place(items)
    assert length == 100
    assert regions[0][1] == 0 and regions[1][1] == 0


# ---------------------------------------------------------------------
# Compaction (compact.rs)
# ---------------------------------------------------------------------


def frag_gauge(regions, pool_len):
    spans = sorted((off, off + ln) for _, off, ln in regions)
    unused = largest = cursor = 0
    for a, b in spans:
        if a > cursor:
            hole = a - cursor
            unused += hole
            largest = max(largest, hole)
        cursor = max(cursor, b)
    if pool_len > cursor:
        tail = pool_len - cursor
        unused += tail
        largest = max(largest, tail)
    return unused, largest


def plan_compaction(items, committed, pool_len):
    """Port of planner/compact.rs::plan_compaction.

    ``items``: (id, need, intervals, persistent); ``committed``: id ->
    offset. Returns (moves, new_len) or None; a move is
    (id, from_off, to_off, need, persistent).
    """
    order = sorted(items, key=lambda it: (committed[it[0]], it[0]))
    placed = []  # (intervals, offset, len)
    moves = []
    new_len = 0
    for iid, need, intervals, persistent in order:
        src = committed[iid]
        forbidden = blocked_ranges(placed, intervals)
        offset = 0
        for a, b in forbidden:
            if offset + need <= a:
                break
            offset = max(offset, b)
        assert offset <= src, f"slide-down moved {iid} up: {src} -> {offset}"
        if offset != src:
            moves.append((iid, src, offset, need, persistent))
        new_len = max(new_len, offset + need)
        placed.append((intervals, offset, need))
    if not moves and new_len >= pool_len:
        return None
    return moves, new_len


def gen_fragmented_layout(rng, items):
    """Commit a valid-but-holey layout: place with padded sizes, keep
    the true sizes -- every hole is pure padding, validity preserved."""
    padded = [(i, need + rng.randint(0, 20), iv) for i, need, iv, _ in items]
    _, regions = first_fit_place(ordered(padded, rng.choice(["schedule", "size"])))
    committed = {iid: off for iid, off, _ in regions}
    top = max(committed[i] + need for i, need, _, _ in items)
    return committed, top + rng.randint(0, 15)


def test_compaction_is_valid_monotone_and_memmove_safe():
    compacted = 0
    for seed in range(300):
        rng = random.Random(3000 + seed)
        items = gen_items(rng, rng.randint(2, 12))
        committed, pool_len = gen_fragmented_layout(rng, items)
        plan = plan_compaction(items, committed, pool_len)
        if plan is None:
            # already compact: nothing can slide down
            continue
        compacted += 1
        moves, new_len = plan
        assert new_len <= pool_len

        # moves ascend by source offset; every move is strictly downward
        assert moves == sorted(moves, key=lambda m: (m[1], m[0]))
        for _, src, dst, _, _ in moves:
            assert dst < src

        # a persistent move's destination never overlaps a *later*
        # persistent move's source (the memmove-order property)
        pmoves = [m for m in moves if m[4]]
        for i, (_, _, dst_i, len_i, _) in enumerate(pmoves):
            for _, src_j, _, len_j, _ in pmoves[i + 1 :]:
                assert dst_i + len_i <= src_j or src_j + len_j <= dst_i

        # relocated layout stays valid under the same liveness
        relocated = dict(committed)
        for iid, _, dst, _, _ in moves:
            relocated[iid] = dst
        assert_valid(
            [(i, n, iv) for i, n, iv, _ in items],
            [(i, relocated[i], n) for i, n, _, _ in items],
        )

        # simulate the epoch-barrier application: persistent tensors
        # carry unique tags; in-order forward copies must preserve all
        # of them (transients only get their table regions rewritten)
        pool = [None] * pool_len
        for iid, need, _, persistent in items:
            if persistent:
                off = committed[iid]
                for k in range(need):
                    pool[off + k] = (iid, k)
        for iid, src, dst, need, persistent in moves:
            if persistent:
                for k in range(need):  # forward copy == memmove down
                    pool[dst + k] = pool[src + k]
        for iid, need, _, persistent in items:
            if persistent:
                off = relocated[iid]
                assert all(pool[off + k] == (iid, k) for k in range(need)), iid
    assert compacted > 100, "generator failed to produce fragmented layouts"


def test_frag_gauge_matches_cell_oracle():
    for seed in range(200):
        rng = random.Random(4000 + seed)
        items = gen_items(rng, rng.randint(1, 10))
        committed, pool_len = gen_fragmented_layout(rng, items)
        regions = [(i, committed[i], n) for i, n, _, _ in items]
        unused, largest = frag_gauge(regions, pool_len)
        covered = [False] * pool_len
        for _, off, ln in regions:
            for k in range(off, off + ln):
                covered[k] = True
        assert unused == covered.count(False)
        run = best = 0
        for c in covered:
            run = 0 if c else run + 1
            best = max(best, run)
        assert largest == best


def test_frag_gauge_hand_case():
    # compact.rs::frag_gauge_counts_holes_and_tail (element units)
    regions = [(0, 0, 10), (1, 14, 5)]
    unused, largest = frag_gauge(regions, 25)
    assert unused == 10  # hole of 4 + tail of 6
    assert largest == 6


# ---------------------------------------------------------------------
# Byte-shuffle + PackBits codec (store.rs)
# ---------------------------------------------------------------------


def packbits(src):
    out = bytearray()
    i = 0
    n = len(src)
    while i < n:
        b = src[i]
        run = 1
        while i + run < n and src[i + run] == b and run < 129:
            run += 1
        if run >= 3:
            out.append(128 + run - 2)
            out.append(b)
            i += run
        else:
            start = i
            i += run
            while i < n and i - start < 128:
                c = src[i]
                r = 1
                while i + r < n and src[i + r] == c and r < 3:
                    r += 1
                if r >= 3:
                    break
                i += r
            length = i - start
            if length > 128:
                length = 128
                i = start + length
            out.append(length - 1)
            out += src[start : start + length]
    return bytes(out)


def unpackbits(src):
    out = bytearray()
    i = 0
    n = len(src)
    while i < n:
        c = src[i]
        i += 1
        if c < 128:
            length = c + 1
            if i + length > n:
                raise ValueError("corrupt RLE literal run")
            out += src[i : i + length]
            i += length
        else:
            length = (c - 128) + 2
            if i >= n:
                raise ValueError("corrupt RLE repeat run")
            out += bytes([src[i]]) * length
            i += 1
    return bytes(out)


def shuffle_rle_encode(data):
    """``data``: raw LE f32 bytes (len % 4 == 0)."""
    n = len(data) // 4
    out = bytearray()
    for p in range(4):
        plane = data[p::4]
        coded = packbits(plane)
        out += len(coded).to_bytes(4, "little")
        out += coded
    return bytes(out)


def shuffle_rle_decode(enc, n):
    planes = []
    cur = 0
    for p in range(4):
        if cur + 4 > len(enc):
            raise ValueError("truncated RLE plane header")
        coded = int.from_bytes(enc[cur : cur + 4], "little")
        cur += 4
        if cur + coded > len(enc):
            raise ValueError("truncated RLE plane stream")
        plane = unpackbits(enc[cur : cur + coded])
        cur += coded
        if len(plane) != n:
            raise ValueError(f"RLE plane {p} decoded {len(plane)} bytes, expected {n}")
        planes.append(plane)
    out = bytearray(4 * n)
    for p in range(4):
        out[p::4] = planes[p]
    return bytes(out)


def _payloads(rng):
    n = rng.randint(1, 200)
    kind = rng.randrange(5)
    if kind == 0:  # pure random bytes (worst case, often incompressible)
        return rng.randbytes(4 * n)
    if kind == 1:  # constant f32 pattern (best case)
        return bytes([rng.randrange(256)] * 4) * n
    if kind == 2:  # run boundaries around the 127/128/129/130 edges
        out = bytearray()
        while len(out) < 4 * n:
            out += bytes([rng.randrange(256)]) * rng.choice([126, 127, 128, 129, 130, 131])
        return bytes(out[: 4 * n])
    if kind == 3:  # alternating pair (defeats RLE, stresses literals)
        return (bytes([rng.randrange(256), rng.randrange(256)]) * (2 * n))[: 4 * n]
    # realistic activations: same exponent byte, noisy mantissa
    exp = rng.randrange(256)
    return b"".join(
        bytes([rng.randrange(256), rng.randrange(256), rng.randrange(64), exp])
        for _ in range(n)
    )


def test_codec_roundtrip_bitwise_exact():
    for seed in range(500):
        rng = random.Random(5000 + seed)
        data = _payloads(rng)
        enc = shuffle_rle_encode(data)
        assert shuffle_rle_decode(enc, len(data) // 4) == data, seed


def test_packbits_run_edges_roundtrip():
    for run in (1, 2, 3, 127, 128, 129, 130, 257, 258, 259):
        src = bytes([7] * run + [1, 2, 3])
        assert unpackbits(packbits(src)) == src, run


def test_codec_truncation_errors_loudly():
    rng = random.Random(99)
    data = _payloads(rng)
    enc = shuffle_rle_encode(data)
    n = len(data) // 4
    for cut in range(0, len(enc), max(1, len(enc) // 37)):
        if cut == len(enc):
            continue
        with pytest.raises(ValueError):
            shuffle_rle_decode(enc[:cut], n)


def test_constant_payload_compresses():
    data = bytes([0x3F, 0x80, 0x00, 0x00]) * 1000  # 1000 x 1.0f
    enc = shuffle_rle_encode(data)
    assert len(enc) < len(data) // 10


# ---------------------------------------------------------------------
# FileStore extent / wear / coalescing state machine (store.rs)
# ---------------------------------------------------------------------

ROTATE_WRITES = 64
COALESCE_MAX_GAP = 256
COALESCE_MAX_PENDING = 4 << 20


class FileStoreSim:
    """Line-by-line port of FileStore over a bytearray file."""

    def __init__(self, compress):
        self.file = bytearray()
        self.compress = compress
        self.slots = {}  # key -> (extent, byte_len, enc, enc_len)
        self.extents = []  # [off, cap, writes, free]
        self.end = 0
        self.pending = bytearray()
        self.pending_off = 0
        self.stats = dict.fromkeys(
            "puts gets rewrites rotations coalesced_puts logical physical live peak".split(),
            0,
        )

    def _encode(self, data):
        if self.compress:
            enc = shuffle_rle_encode(data)
            if len(enc) < len(data):
                return "rle", enc
        return "raw", data

    def _pick_free(self, need, cooler_than=None):
        cands = [
            (e[2], e[1], i)
            for i, e in enumerate(self.extents)
            if e[3] and e[1] >= need and (cooler_than is None or e[2] < cooler_than)
        ]
        return min(cands)[2] if cands else None

    def _claim(self, idx):
        assert self.extents[idx][3]
        self.extents[idx][3] = False
        self.stats["live"] += self.extents[idx][1]
        self.stats["peak"] = max(self.stats["peak"], self.stats["live"])

    def _alloc(self, cap):
        i = self._pick_free(cap)
        if i is not None:
            self._claim(i)
            return i
        off = self.end
        self.end += cap
        self.extents.append([off, cap, 0, True])
        i = len(self.extents) - 1
        self._claim(i)
        return i

    def _release(self, idx):
        self.extents[idx][3] = True
        self.stats["live"] -= self.extents[idx][1]
        while self.extents:
            last = self.extents[-1]
            if last[3] and last[0] + last[1] == self.end:
                self.end = last[0]
                self.extents.pop()
            else:
                break

    def _queue_write(self, off, payload):
        if not self.pending:
            self.pending_off = off
            self.pending = bytearray(payload)
            return
        pend_end = self.pending_off + len(self.pending)
        mergeable = (
            off >= self.pending_off
            and off <= pend_end + COALESCE_MAX_GAP
            and len(self.pending) + len(payload) <= COALESCE_MAX_PENDING
        )
        if mergeable:
            if off + len(payload) <= pend_end:
                s = off - self.pending_off
                self.pending[s : s + len(payload)] = payload
            elif off >= pend_end:
                # bridge the hole with the file's current bytes (zeros
                # past EOF) -- zero-filling would clobber a live extent
                # inside the hole at flush time
                hole = self.file[pend_end : off]
                self.pending += hole + bytes(off - pend_end - len(hole))
                self.pending += payload
            else:
                del self.pending[off - self.pending_off :]
                self.pending += payload
            self.stats["coalesced_puts"] += 1
            return
        self._flush()
        self.pending_off = off
        self.pending = bytearray(payload)

    def _flush(self):
        if not self.pending:
            return
        end = self.pending_off + len(self.pending)
        if len(self.file) < end:
            self.file += bytes(end - len(self.file))
        self.file[self.pending_off : end] = self.pending
        self.stats["physical"] += len(self.pending)
        self.pending = bytearray()

    def put(self, key, data):
        raw_len = len(data)
        enc, payload = self._encode(data)
        slot = self.slots.get(key)
        if slot is not None and slot[1] == raw_len:
            ei = slot[0]
            if self.extents[ei][2] >= ROTATE_WRITES:
                ni = self._pick_free(raw_len, cooler_than=self.extents[ei][2])
                if ni is not None:
                    self._claim(ni)
                    self._release(ei)
                    self.stats["rotations"] += 1
                    ei = ni
            extent = ei
        elif slot is not None:
            self._release(slot[0])
            extent = self._alloc(raw_len)
        else:
            extent = self._alloc(raw_len)
        if self.extents[extent][2] > 0:
            self.stats["rewrites"] += 1
        self.extents[extent][2] += 1
        off = self.extents[extent][0]
        self.slots[key] = (extent, raw_len, enc, len(payload))
        self._queue_write(off, payload)
        self.stats["puts"] += 1
        self.stats["logical"] += raw_len

    def get(self, key):
        self._flush()
        extent, raw_len, enc, enc_len = self.slots[key]
        off = self.extents[extent][0]
        blob = bytes(self.file[off : off + enc_len])
        assert len(blob) == enc_len, "read past file end"
        if enc == "raw":
            out = blob
        else:
            out = shuffle_rle_decode(blob, raw_len // 4)
        self.stats["gets"] += 1
        return out

    def free(self, key):
        slot = self.slots.pop(key, None)
        if slot is not None:
            self._release(slot[0])

    def check_invariants(self):
        claimed = [e for e in self.extents if not e[3]]
        spans = sorted((e[0], e[0] + e[1]) for e in self.extents)
        for (a0, a1), (b0, b1) in zip(spans, spans[1:]):
            assert a1 <= b0, "extents overlap"
        assert self.stats["live"] == sum(e[1] for e in claimed)
        assert self.end == max((e[0] + e[1] for e in self.extents), default=0)
        for extent, _, _, _ in self.slots.values():
            assert extent < len(self.extents)
            assert not self.extents[extent][3], "slot references a free extent"


@pytest.mark.parametrize("compress", [False, True])
def test_file_store_state_machine_vs_oracle(compress):
    for seed in range(60):
        rng = random.Random(6000 + seed)
        store = FileStoreSim(compress)
        oracle = {}
        sizes = {k: 4 * rng.randint(1, 64) for k in range(8)}
        for _ in range(200):
            op = rng.random()
            key = rng.randrange(8)
            if op < 0.55:
                if rng.random() < 0.05:  # occasional resize
                    sizes[key] = 4 * rng.randint(1, 64)
                data = (
                    _payloads(rng)[: sizes[key]].ljust(sizes[key], b"\x42")
                    if rng.random() < 0.5
                    else rng.randbytes(sizes[key])
                )
                store.put(key, data)
                oracle[key] = data
            elif op < 0.85:
                if key in oracle:
                    assert store.get(key) == oracle[key], (seed, key)
            else:
                store.free(key)
                oracle.pop(key, None)
            store.check_invariants()
        for key in list(oracle):
            assert store.get(key) == oracle[key]
            store.free(key)
        store.check_invariants()
        assert store.end == 0, "freeing every slot must roll the file back"
        assert store.stats["puts"] >= store.stats["rewrites"]


def test_wear_rotation_hands_hot_slot_to_cool_extent():
    store = FileStoreSim(compress=False)
    a = bytes(range(64))  # 64 bytes
    store.put(0, a)
    store.put(1, a)  # the future cool extent (middle of the file)
    store.put(2, a)  # tail guard: keeps extent 1 off the rollback path
    for _ in range(ROTATE_WRITES - 1):
        store.put(0, a)
    assert store.extents[store.slots[0][0]][2] == ROTATE_WRITES
    assert store.stats["rotations"] == 0
    store.free(1)  # middle extent goes free; tail rollback can't eat it
    hot = store.slots[0][0]
    store.put(0, a)
    assert store.stats["rotations"] == 1
    assert store.slots[0][0] != hot, "slot must rotate onto the cooler extent"
    assert store.extents[hot][3], "the hot extent is released"
    assert store.get(0) == a
    assert store.get(2) == a
    store.check_invariants()


def test_adjacent_puts_coalesce():
    store = FileStoreSim(compress=False)
    store.put(0, bytes([1] * 32))
    store.put(1, bytes([2] * 32))  # adjacent extent, no get between
    assert store.stats["coalesced_puts"] == 1
    assert store.stats["physical"] == 0, "nothing flushed yet"
    assert store.get(0) == bytes([1] * 32)
    assert store.get(1) == bytes([2] * 32)
    assert store.stats["physical"] == 64, "one merged flush"
