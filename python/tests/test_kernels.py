"""L1 kernel correctness: Pallas (interpret) vs pure-jnp oracle, swept by
hypothesis over shapes; the paper's 1e-4 equivalence bar."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.fused_matmul import fused_matmul, mxu_utilization, vmem_bytes
from compile.kernels.softmax_xent import softmax_xent
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def rand(key, *shape, lo=-1.0, hi=1.0):
    return jax.random.uniform(jax.random.PRNGKey(key), shape, jnp.float32, lo, hi)


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 48),
    k=st.integers(1, 64),
    n=st.integers(1, 48),
    act=st.sampled_from(["none", "sigmoid", "relu", "tanh"]),
    seed=st.integers(0, 2**16),
)
def test_fused_matmul_matches_ref(m, k, n, act, seed):
    x = rand(seed, m, k)
    w = rand(seed + 1, k, n)
    b = rand(seed + 2, n)
    got = fused_matmul(x, w, b, act=act)
    want = ref.fused_matmul_ref(x, w, b, act=act)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("m,n", [(128, 128), (256, 128), (128, 384)])
def test_fused_matmul_tiled_path(m, n):
    # multiples of the 128-tile → multi-tile grid exercised
    k = 96
    x = rand(1, m, k)
    w = rand(2, k, n)
    b = rand(3, n)
    got = fused_matmul(x, w, b, act="relu")
    want = ref.fused_matmul_ref(x, w, b, act="relu")
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_fused_matmul_bf16_inputs():
    x = rand(1, 16, 32).astype(jnp.bfloat16)
    w = rand(2, 32, 8).astype(jnp.bfloat16)
    b = rand(3, 8).astype(jnp.bfloat16)
    got = fused_matmul(x, w, b, act="none").astype(jnp.float32)
    want = ref.fused_matmul_ref(
        x.astype(jnp.float32), w.astype(jnp.float32), b.astype(jnp.float32)
    )
    np.testing.assert_allclose(got, want, rtol=2e-2, atol=2e-2)


@settings(max_examples=20, deadline=None)
@given(r=st.integers(1, 40), c=st.integers(2, 32), seed=st.integers(0, 2**16))
def test_softmax_xent_matches_ref(r, c, seed):
    z = rand(seed, r, c, lo=-4.0, hi=4.0)
    y = rand(seed + 9, r, c, lo=0.0, hi=1.0)
    loss, dz = softmax_xent(z, y)
    loss_ref, dz_ref = ref.softmax_xent_ref(z, y)
    np.testing.assert_allclose(loss, loss_ref, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(dz, dz_ref, rtol=1e-4, atol=1e-5)


def test_softmax_xent_grad_is_jax_grad():
    # the kernel's dlogits equals autodiff of its own loss
    z = rand(5, 8, 10, lo=-3.0, hi=3.0)
    y = jax.nn.one_hot(jnp.arange(8) % 10, 10)
    _, dz = softmax_xent(z, y)
    g = jax.grad(lambda zz: jnp.sum(ref.softmax_xent_ref(zz, y)[0]))(z)
    np.testing.assert_allclose(dz, g, rtol=1e-4, atol=1e-5)


def test_perf_model_sane():
    assert mxu_utilization(256, 64, 256) == 1.0
    assert 0.0 < mxu_utilization(100, 64, 100) <= 1.0
    assert vmem_bytes(128, 64, 128) > 0
