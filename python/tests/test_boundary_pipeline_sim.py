"""Behavioral simulation of the cross-iteration (boundary) swap pipeline.

Ports the PR's swap-engine logic to pure Python and fuzzes it, because the
paper-repro container has no Rust toolchain (see .claude/skills/verify):

* ``live_intervals``'s wrap arm (single reservation + the EO-0 init point)
  and first-fit placement over it — wrap regions must come out pairwise
  disjoint, every layout valid;
* the full engine protocol — ``begin_iteration`` (stale check, carried
  wrap state, two-phase priming), ``pre_step`` (reclaim walk, due walk),
  ``post_step`` (evictions, completion drain, pump), ``end_iteration``
  (sweep, pipelined carry, error path), ``quiesce``,
  ``finish_prefetch`` (every arm incl. the unevicted-at-barrier error,
  the overlap wait, staged/issued/inline restores) and the skip-ahead
  ``pump_issues`` — driven over randomized plans with two simulated FIFO
  workers under random interleaving;
* a write-token oracle (every tensor reads back exactly what it wrote,
  bitwise) plus a data-race detector (CPU write into a range covered by a
  queued, undrained eviction write), pool release/reacquire registry and
  NaN-poison analog included;
* pipelined-vs-drained final-state equality and the exact traffic formula
  ``iters x oneway + wrap_oneway``;
* directed regressions for the three satellite bugfixes (end_iteration
  early-return masking, prefetch head-of-line blocking, unevicted-wrap
  priming) with the PRE-FIX behavior re-injected via flags and shown to
  fail, and sensitivity tests proving the race detector and the
  store-miss guard actually fire when their barriers are sabotaged;
* the bounded epoch-mark and fleet step-latency rings vs unbounded
  oracles.

This checks the *logic*, not the Rust build — tier-1 (`cargo build &&
cargo test`) runs driver/CI-side.
"""

import random

import pytest

POISON = None  # NaN-poison stand-in for freshly released cells

PREFETCH_LEAD = 1
PREFETCH_DEPTH = 2
WRITE_LEAD = 0
U32_MAX = 2**32 - 1


def overlap(r1, r2):
    (o1, l1), (o2, l2) = r1, r2
    return o1 < o2 + l2 and o2 < o1 + l1


class StoreError(Exception):
    pass


class EngineError(Exception):
    pass


# --------------------------------------------------------------- fixtures


class Pool:
    """Token pool with the debug release/reacquire registry semantics."""

    def __init__(self, n):
        self.cells = [0] * n
        self.released = []  # exact-region registry

    def view(self, r):
        o, ln = r
        return list(self.cells[o : o + ln])

    def release_gap(self, r):
        assert r not in self.released, f"double release of {r}"
        self.released.append(r)
        o, ln = r
        self.cells[o : o + ln] = [POISON] * ln

    def reacquire(self, r, data):
        assert r in self.released, f"reacquire of unreleased {r}"
        self.released.remove(r)
        o, ln = r
        assert len(data) == ln
        self.cells[o : o + ln] = list(data)


class Store:
    """Slot store with per-key single-shot failure injection."""

    def __init__(self):
        self.slots = {}
        self.fail_gets = {}
        self.fail_puts = {}

    def put(self, k, data):
        if self.fail_puts.get(k, 0) > 0:
            self.fail_puts[k] -= 1
            raise StoreError(f"injected put failure slot {k}")
        self.slots[k] = list(data)

    def get(self, k):
        if self.fail_gets.get(k, 0) > 0:
            self.fail_gets[k] -= 1
            raise StoreError(f"injected get failure slot {k}")
        if k not in self.slots:
            raise StoreError(f"store miss: slot {k} was never written")
        return list(self.slots[k])


class World:
    """Two FIFO workers (fetch, evict) sharing one completion channel.

    Mirrors the Rust engine's thread structure: requests queue FIFO per
    worker, each is processed atomically at a random later instant, and
    an eviction write reads its pool span at *processing* time (the raw
    PoolSpan) — which is exactly what makes unbarriered CPU writes a
    data race. ``cpu_write`` is the race detector: any engine-external
    write into a range covered by a queued, unprocessed eviction write
    is recorded as a violation.
    """

    def __init__(self, rng, pool, store):
        self.rng = rng
        self.pool = pool
        self.store = store
        self.fetch_q = []
        self.evict_q = []
        self.done = []
        self.violations = []

    def cpu_write(self, region, data, tag):
        for _k, r in self.evict_q:
            if overlap(r, region):
                self.violations.append((tag, region, r))
        o, ln = region
        self.pool.cells[o : o + ln] = list(data)

    def send_fetch(self, i):
        self.fetch_q.append(i)

    def send_write(self, i, region):
        self.evict_q.append((i, region))

    def step(self):
        queues = [q for q in (self.fetch_q, self.evict_q) if q]
        if not queues:
            return False
        q = self.rng.choice(queues)
        if q is self.fetch_q:
            i = q.pop(0)
            try:
                self.done.append(("fetch", i, self.store.get(i), None))
            except StoreError as e:
                self.done.append(("fetch", i, None, e))
        else:
            i, r = q.pop(0)
            data = self.pool.view(r)  # raw span read at processing time
            try:
                self.store.put(i, data)
                self.done.append(("write", i, None, None))
            except StoreError as e:
                self.done.append(("write", i, None, e))
        return True

    def try_recv(self):
        return self.done.pop(0) if self.done else None

    def recv(self):
        while not self.done:
            if not self.step():
                raise AssertionError("deadlock: recv() with no queued work")
        return self.done.pop(0)

    def idle_progress(self, k):
        for _ in range(k):
            if not self.step():
                break


# --------------------------------------------- planner-side ports


class Spec:
    def __init__(self, tid, name, length, eos, boundary_window=None):
        self.id = tid
        self.name = name
        self.len = length
        self.eos = sorted(eos)
        self.boundary_window = boundary_window
        self.region = None


def segments(eos):
    segs = []
    if not eos:
        return segs
    start = prev = eos[0]
    for e in eos[1:]:
        if e > prev + 1:
            segs.append((start, prev))
            start = e
        prev = e
    segs.append((start, prev))
    return segs


class LeadMap:
    def __init__(self, entries):
        self.read = {(e["tensor"], e["pb"]): e["lead"] for e in entries}
        self.write = {(e["tensor"], e["ea"]): e["write_lead"] for e in entries}
        self.boundary = {
            e["tensor"]: (e["pb"], e["ea"], e["lead"], e["write_lead"])
            for e in entries
            if e["wrap"]
        }

    def lead(self, t, seg_start):
        return self.read.get((t, seg_start), PREFETCH_LEAD)

    def write_lead(self, t, seg_end):
        return self.write.get((t, seg_end), WRITE_LEAD)


def live_intervals(spec, leads):
    """Port of planner/offload.rs::live_intervals, incl. the wrap arm's
    EO-0 init point."""
    if leads is None:
        if not spec.eos:
            return []
        return [(spec.eos[0], spec.eos[-1])]
    if spec.id in leads.boundary:
        pb, ea, lead, w = leads.boundary[spec.id]
        start = max(pb - lead, 0)
        end = ea + w
        if start == 0:
            return [(0, end)]
        return [(0, 0), (start, end)]
    segs = segments(spec.eos)
    last = len(segs) - 1
    out = []
    prev_end = 0
    for k, (a, z) in enumerate(segs):
        if k == last:
            end = z
        else:
            end = min(z + leads.write_lead(spec.id, z), segs[k + 1][0] - 1)
        if k == 0:
            start = a
        else:
            start = max(max(a - leads.lead(spec.id, a), 0), prev_end + 1)
        out.append((start, end))
        prev_end = end
    return out


def place_first_fit(specs, leads, offloaded_ids):
    """First-fit placement over the reserved live intervals."""
    placed = []  # (intervals, region)
    for s in specs:
        ivs = live_intervals(s, leads if s.id in offloaded_ids else None)
        off = 0
        while True:
            region = (off, s.len)
            clash = None
            for oivs, oreg in placed:
                if not overlap(region, oreg):
                    continue
                if any(
                    a1 <= z2 and a2 <= z1
                    for (a1, z1) in ivs
                    for (a2, z2) in oivs
                ):
                    clash = oreg
                    break
            if clash is None:
                break
            off = clash[0] + clash[1]
        s.region = region
        placed.append((ivs, region))
    return placed


def derive_entry_bounds(entries, specs, leads, offloaded_ids):
    """Port of runtime/swap.rs::derive_entry_bounds."""
    by_id = {s.id: s for s in specs}
    for e in entries:
        earliest = 0 if e["wrap"] else e["ea"] + 1
        reclaim = U32_MAX
        head_reclaim = U32_MAX
        for s in specs:
            if not s.eos or s.id == e["tensor"] or s.region is None:
                continue
            if not overlap(s.region, by_id[e["tensor"]].region):
                continue
            for a, z in live_intervals(
                s, leads if s.id in offloaded_ids else None
            ):
                if z < e["pb"]:
                    earliest = max(earliest, z + 1)
                if a > e["ea"]:
                    reclaim = min(reclaim, a)
                if e["wrap"] and a < e["pb"]:
                    head_reclaim = min(head_reclaim, a)
        e["max_lead"] = max(e["pb"] - earliest, e["lead"])
        e["reclaim_eo"] = reclaim
        e["head_reclaim_eo"] = head_reclaim


# ------------------------------------------------------ the engine port


class Engine:
    """Line-for-line behavioral port of SwapExec's step protocol.

    The ``prefix_*`` flags re-inject this PR's pre-fix bugs; the
    ``skip_*`` flags sabotage individual hazard barriers so the tests
    can prove the oracle actually detects their absence.
    """

    def __init__(
        self,
        specs,
        entries,
        world,
        depth=PREFETCH_DEPTH,
        boundary_drain=False,
        prefix_end_iteration=False,
        prefix_pump=False,
        prefix_unevicted_wrap_shortcut=False,
        skip_priming=False,
        skip_overlap_wait=False,
        skip_reclaim_barrier=False,
        skip_writable_gate=False,
    ):
        self.world = world
        self.specs = {s.id: s for s in specs}
        self.entries = []
        for e in entries:
            s = self.specs[e["tensor"]]
            ent = dict(e)
            ent["region"] = s.region
            ent["name"] = s.name
            ent["due"] = max(e["pb"] - e["lead"], 0)
            self.entries.append(ent)
        n = len(self.entries)
        self.by_prefetch = sorted(
            range(n), key=lambda i: (self.entries[i]["due"], self.entries[i]["pb"], i)
        )
        self.by_reclaim = []
        for i, e in enumerate(self.entries):
            self.by_reclaim.append((e["reclaim_eo"], i))
            if e["wrap"] and e["head_reclaim_eo"] != U32_MAX:
                self.by_reclaim.append((e["head_reclaim_eo"], i))
        self.by_reclaim.sort()
        self.overlaps = [
            [
                j
                for j in range(n)
                if j != i and overlap(self.entries[i]["region"], self.entries[j]["region"])
            ]
            for i in range(n)
        ]
        self.evict_at = {}
        for i, e in enumerate(self.entries):
            self.evict_at.setdefault(e["ea"], []).append(i)
        self.roots = {
            e["tensor"]: ([e["pb"], e["ea"]] if e["wrap"] else self.specs[e["tensor"]].eos)
            for e in self.entries
        }
        self.residency = {e["tensor"]: "resident" for e in self.entries}
        self.evicted = [False] * n
        self.evict_done = [False] * n
        self.issued = [False] * n
        self.restored = [False] * n
        self.staged = {}
        self.failed = {}
        self.write_failed = {}
        self.next_due = 0
        self.next_reclaim = 0
        self.issue_cursor = 0
        self.outstanding = 0
        self.outstanding_writes = 0
        self.wrap_fetches_inflight = 0
        self.wrap_writes_inflight = 0
        self.depth = depth
        self.boundary_drain = boundary_drain
        self.prefix_end_iteration = prefix_end_iteration
        self.prefix_pump = prefix_pump
        self.prefix_unevicted_wrap_shortcut = prefix_unevicted_wrap_shortcut
        self.skip_priming = skip_priming
        self.skip_overlap_wait = skip_overlap_wait
        self.skip_reclaim_barrier = skip_reclaim_barrier
        self.skip_writable_gate = skip_writable_gate
        self.bg_fetch_done = [False] * n
        self.stats = {
            "evictions": 0,
            "prefetches": 0,
            "sync_fetches": 0,
            "bytes_out": 0,
            "bytes_in": 0,
            "read_stalls": 0,
            "write_stalls": 0,
            "boundary_stalls": 0,
        }
        # epoch-mark ring (satellite 3)
        self.epoch_marks = []
        self.epoch_mark_cap = 1024
        self.epoch_base = dict(self.stats)

    # ---- iteration protocol

    def begin_iteration(self, pool):
        if (
            self.outstanding != self.wrap_fetches_inflight
            or self.outstanding_writes != self.wrap_writes_inflight
            or any(not self.entries[i]["wrap"] for i in self.staged)
        ):
            raise EngineError("stale transfers at iteration start")
        for i, e in enumerate(self.entries):
            if e["wrap"] and self.evicted[i] and not self.restored[i]:
                continue  # carried mid-cycle
            self.evicted[i] = False
            self.evict_done[i] = False
            self.issued[i] = False
            self.restored[i] = False
            self.residency[e["tensor"]] = "resident"
        if not self.skip_priming:
            primed = False
            for i, e in enumerate(self.entries):
                if e["wrap"] and not self.evicted[i]:
                    self.world.store.put(i, pool.view(e["region"]))
                    self.stats["write_stalls"] += 1
                    self.stats["evictions"] += 1
                    self.stats["bytes_out"] += e["region"][1]
                    primed = True
            if primed:
                for i, e in enumerate(self.entries):
                    if e["wrap"] and not self.evicted[i]:
                        pool.release_gap(e["region"])
                        self.evicted[i] = True
                        self.evict_done[i] = True
                        self.issued[i] = False
                        self.restored[i] = False
                        self.residency[e["tensor"]] = "evicted"
        self.failed = {i: err for i, err in self.failed.items() if self.entries[i]["wrap"]}
        self.write_failed = {
            i: err for i, err in self.write_failed.items() if self.entries[i]["wrap"]
        }
        self.next_due = 0
        self.next_reclaim = 0
        self.issue_cursor = 0

    def pre_step(self, eo, pool):
        while self.next_reclaim < len(self.by_reclaim):
            barrier_eo, idx = self.by_reclaim[self.next_reclaim]
            if barrier_eo > eo:
                break
            if (
                self.evicted[idx]
                and not self.evict_done[idx]
                and not self.skip_reclaim_barrier
            ):
                self.wait_write(idx, pool)
            if idx in self.write_failed:
                raise self.write_failed.pop(idx)
            self.next_reclaim += 1
        while self.next_due < len(self.by_prefetch):
            idx = self.by_prefetch[self.next_due]
            if self.entries[idx]["due"] > eo:
                break
            self.finish_prefetch(idx, pool, eo)
            self.next_due += 1

    def check_residency(self, eo):
        for tid, eos in self.roots.items():
            if self.residency.get(tid, "resident") != "resident" and eo in eos:
                raise EngineError(
                    f"residency violation: tensor {tid} is "
                    f"{self.residency[tid]} at EO {eo}"
                )

    def post_step(self, eo, pool):
        for idx in self.evict_at.get(eo, []):
            e = self.entries[idx]
            self.evict_done[idx] = False
            self.world.send_write(idx, e["region"])
            self.outstanding_writes += 1
            if e["wrap"]:
                self.wrap_writes_inflight += 1
            self.evicted[idx] = True
            self.residency[e["tensor"]] = "evicted"
            self.stats["evictions"] += 1
            self.stats["bytes_out"] += e["region"][1]
            if e["wrap"]:
                self.restored[idx] = False
                self.issued[idx] = False
                self.issue_cursor = 0
        self.drain_completions(pool)
        self.pump_issues()

    def end_iteration(self, pool):
        first_err = None
        for idx in self.by_prefetch:
            if self.entries[idx]["wrap"] and not self.boundary_drain:
                continue
            if not self.restored[idx]:
                try:
                    self.finish_prefetch(idx, pool, None)
                except EngineError as err:
                    if self.prefix_end_iteration:
                        raise  # PRE-FIX: early return, transfers still in flight
                    if first_err is None:
                        first_err = err
        self.next_due = len(self.by_prefetch)
        self.next_reclaim = len(self.by_reclaim)
        pipelined = not self.boundary_drain and first_err is None
        while True:
            keep_f, keep_w = (
                (self.wrap_fetches_inflight, self.wrap_writes_inflight)
                if pipelined
                else (0, 0)
            )
            if self.outstanding <= keep_f and self.outstanding_writes <= keep_w:
                break
            self.apply_done(self.world.recv(), pool)
        if first_err is not None:
            self.issue_cursor = len(self.by_prefetch)
            for idx in self.by_prefetch:
                if (
                    self.entries[idx]["wrap"]
                    and self.evicted[idx]
                    and not self.restored[idx]
                ):
                    try:
                        self.finish_prefetch(idx, pool, None)
                    except EngineError:
                        pass  # secondary errors lose to the original
            while self.outstanding > 0 or self.outstanding_writes > 0:
                self.apply_done(self.world.recv(), pool)
            self.staged.clear()
            # Failed non-wrap restores still hold the pool claim from
            # their landed eviction — drop it so the next iteration's
            # re-eviction does not double-release. Wrap entries keep
            # theirs (carried-state path restores the live weights);
            # write-failed entries never released.
            for idx, e in enumerate(self.entries):
                if (
                    not e["wrap"]
                    and self.evicted[idx]
                    and not self.restored[idx]
                    and idx not in self.write_failed
                ):
                    pool.reacquire(e["region"], pool.view(e["region"]))
                    self.restored[idx] = True
            raise first_err
        if pipelined:
            self.staged = {
                i: d for i, d in self.staged.items() if self.entries[i]["wrap"]
            }
            self.issue_cursor = 0
            self.pump_issues()
        else:
            self.staged.clear()
        if self.write_failed:
            idx = next(iter(self.write_failed))
            raise self.write_failed.pop(idx)

    def quiesce(self, pool):
        while self.outstanding > 0 or self.outstanding_writes > 0:
            self.apply_done(self.world.recv(), pool)
        first_err = None
        for idx in self.by_prefetch:
            if (
                self.entries[idx]["wrap"]
                and self.evicted[idx]
                and not self.restored[idx]
            ):
                try:
                    self.finish_prefetch(idx, pool, None)
                except EngineError as err:
                    if first_err is None:
                        first_err = err
        self.staged.clear()
        if first_err is not None:
            raise first_err
        if self.write_failed:
            idx = next(iter(self.write_failed))
            raise self.write_failed.pop(idx)

    def has_carried_state(self):
        return (
            self.outstanding > 0
            or self.outstanding_writes > 0
            or bool(self.staged)
            or any(
                e["wrap"] and self.evicted[i] and not self.restored[i]
                for i, e in enumerate(self.entries)
            )
        )

    # ---- internals

    def apply_done(self, done, pool):
        kind, i, data, err = done
        if kind == "fetch":
            self.outstanding -= 1
            if self.entries[i]["wrap"]:
                self.wrap_fetches_inflight -= 1
            if err is None:
                self.staged[i] = data
                self.bg_fetch_done[i] = True
            else:
                self.failed[i] = EngineError(str(err))
        else:
            self.outstanding_writes -= 1
            if self.entries[i]["wrap"]:
                self.wrap_writes_inflight -= 1
            self.evict_done[i] = True
            if err is None:
                pool.release_gap(self.entries[i]["region"])
            else:
                self.write_failed[i] = EngineError(str(err))

    def wait_write(self, idx, pool):
        self.stats["write_stalls"] += 1
        while not self.evict_done[idx]:
            self.apply_done(self.world.recv(), pool)

    def reacquire(self, idx, data, pool):
        region = self.entries[idx]["region"]
        # a reacquire is itself a CPU write into the range: route it
        # through the race detector before committing
        for _k, r in self.world.evict_q:
            if overlap(r, region):
                self.world.violations.append(("reacquire", region, r))
        pool.reacquire(region, data)

    def finish_prefetch(self, idx, pool, at_eo):
        if self.restored[idx]:
            return
        e = self.entries[idx]
        if not self.evicted[idx]:
            if at_eo is not None:
                fired = (
                    (not e["wrap"] and e["ea"] >= at_eo)
                    if self.prefix_unevicted_wrap_shortcut
                    else e["ea"] >= at_eo
                )
                if fired:
                    cause = (
                        "the boundary cycle was not primed at iteration start"
                        if e["wrap"]
                        else "lead swallows the gap"
                    )
                    raise EngineError(
                        f"swap schedule inconsistent: prefetch barrier for "
                        f"`{e['name']}` fired at EO {at_eo} before its eviction "
                        f"at EO {e['ea']} — {cause}"
                    )
            self.restored[idx] = True
            return
        if idx in self.write_failed:
            raise self.write_failed.pop(idx)
        if idx in self.failed:
            raise self.failed.pop(idx)
        if not self.skip_overlap_wait:
            for j in self.overlaps[idx]:
                if self.evicted[j] and not self.evict_done[j]:
                    self.wait_write(j, pool)
        if idx in self.staged:
            self.reacquire(idx, self.staged.pop(idx), pool)
        elif self.issued[idx]:
            self.stats["read_stalls"] += 1
            if e["wrap"]:
                self.stats["boundary_stalls"] += 1
            while True:
                if idx in self.failed:
                    raise self.failed.pop(idx)
                if idx in self.staged:
                    self.reacquire(idx, self.staged.pop(idx), pool)
                    break
                self.apply_done(self.world.recv(), pool)
        else:
            if not self.evict_done[idx]:
                self.wait_write(idx, pool)
                if idx in self.write_failed:
                    raise self.write_failed.pop(idx)
            try:
                data = self.world.store.get(idx)
            except StoreError as err:
                raise EngineError(str(err))
            self.reacquire(idx, data, pool)
            self.stats["sync_fetches"] += 1
            self.stats["read_stalls"] += 1
            if e["wrap"]:
                self.stats["boundary_stalls"] += 1
        self.restored[idx] = True
        self.residency[e["tensor"]] = "resident"
        self.stats["prefetches"] += 1
        self.stats["bytes_in"] += e["region"][1]
        if e["wrap"]:
            self.evicted[idx] = False
            self.evict_done[idx] = False
            self.issued[idx] = False
        self.pump_issues()

    def drain_completions(self, pool):
        while True:
            done = self.world.try_recv()
            if done is None:
                return
            self.apply_done(done, pool)

    def pump_issues(self):
        k = self.issue_cursor
        pending_skipped = 0
        while self.outstanding < self.depth and k < len(self.by_prefetch):
            idx = self.by_prefetch[k]
            e = self.entries[idx]
            consumed = (
                self.restored[idx]
                or self.issued[idx]
                or (e["wrap"] and (self.boundary_drain or not self.evicted[idx]))
            )
            if consumed:
                if k == self.issue_cursor:
                    self.issue_cursor += 1
                k += 1
                continue
            not_writable = not self.evict_done[idx] or idx in self.write_failed
            if not_writable and not self.skip_writable_gate:
                if self.prefix_pump:
                    break  # PRE-FIX: head-of-line blocking
                pending_skipped += 1
                if pending_skipped >= self.depth:
                    break
                k += 1
                continue
            self.world.send_fetch(idx)
            self.issued[idx] = True
            if e["wrap"]:
                self.wrap_fetches_inflight += 1
            self.residency[e["tensor"]] = "fetching"
            self.outstanding += 1
            if k == self.issue_cursor:
                self.issue_cursor += 1
            k += 1

    # ---- epoch-mark ring (satellite 3)

    def mark_epoch(self):
        self.epoch_marks.append(dict(self.stats))
        while len(self.epoch_marks) > self.epoch_mark_cap:
            self.epoch_base = self.epoch_marks.pop(0)

    def set_epoch_mark_cap(self, cap):
        self.epoch_mark_cap = max(cap, 1)
        while len(self.epoch_marks) > self.epoch_mark_cap:
            self.epoch_base = self.epoch_marks.pop(0)

    def epoch_stats(self):
        prev = self.epoch_base
        out = []
        for mark in self.epoch_marks:
            out.append({k: mark[k] - prev[k] for k in mark})
            prev = mark
        return out


# -------------------------------------------------- plan generation


def gen_scenario(rng):
    """Random placed plan: wrap entries + in-iteration entries + tenants."""
    last_eo = rng.randint(9, 16)
    specs = []
    entries = []
    tid = 0
    for _ in range(rng.randint(1, 3)):  # wrap tensors
        first = rng.randint(1, 4)
        last = rng.randint(max(first, last_eo - 3), last_eo)
        ln = rng.randint(2, 6)
        specs.append(Spec(tid, f"w{tid}", ln, [0, last], boundary_window=(first, last)))
        entries.append(
            {
                "tensor": tid,
                "ea": last,
                "pb": first,
                "lead": min(PREFETCH_LEAD, first),
                "write_lead": WRITE_LEAD,
                "wrap": True,
            }
        )
        tid += 1
    for _ in range(rng.randint(0, 3)):  # in-iteration offloaded tensors
        a = rng.randint(0, 2)
        b = rng.randint(a, a + 1)
        c = rng.randint(b + 3, max(b + 3, last_eo - 1))  # gap fits lead 1
        d = rng.randint(c, last_eo)
        ln = rng.randint(2, 6)
        specs.append(Spec(tid, f"s{tid}", ln, sorted({a, b, c, d})))
        entries.append(
            {
                "tensor": tid,
                "ea": b,
                "pb": c,
                "lead": PREFETCH_LEAD,
                "write_lead": WRITE_LEAD,
                "wrap": False,
            }
        )
        tid += 1
    for _ in range(rng.randint(0, 3)):  # short-lived tenants
        a = rng.randint(1, last_eo - 1)
        z = rng.randint(a, min(a + 2, last_eo))
        ln = rng.randint(1, 5)
        specs.append(Spec(tid, f"t{tid}", ln, sorted({a, z})))
        tid += 1
    leads = LeadMap(entries)
    offloaded = {e["tensor"] for e in entries}
    place_first_fit(specs, leads, offloaded)
    derive_entry_bounds(entries, specs, leads, offloaded)
    return specs, entries, leads, offloaded, last_eo


def assert_placement_valid(specs, leads, offloaded):
    placed = [
        (live_intervals(s, leads if s.id in offloaded else None), s.region, s.id)
        for s in specs
    ]
    for i in range(len(placed)):
        for j in range(i + 1, len(placed)):
            ivs1, r1, id1 = placed[i]
            ivs2, r2, id2 = placed[j]
            if not overlap(r1, r2):
                continue
            for a1, z1 in ivs1:
                for a2, z2 in ivs2:
                    assert not (a1 <= z2 and a2 <= z1), (
                        f"tensors {id1},{id2} share addresses {r1}/{r2} while "
                        f"both live ([{a1},{z1}] vs [{a2},{z2}])"
                    )


# ----------------------------------------------------------- the driver


class TokenGen:
    """Deterministic token stream, shared between compared runs."""

    def __init__(self, seed):
        self.rng = random.Random(seed)

    def fresh(self, n):
        return [self.rng.randint(1, 10**9) for _ in range(n)]


def run_session(
    seed,
    boundary_drain=False,
    iters=4,
    partial_iters=(),
    chaos=True,
    engine_flags=None,
):
    rng = random.Random(seed)
    specs, entries, leads, offloaded, last_eo = gen_scenario(rng)
    assert_placement_valid(specs, leads, offloaded)
    pool_len = max(s.region[0] + s.region[1] for s in specs)
    pool = Pool(pool_len)
    world = World(random.Random(seed ^ 0xABCDEF), pool, Store())
    eng = Engine(
        specs, entries, world, boundary_drain=boundary_drain, **(engine_flags or {})
    )
    tokens = TokenGen(seed ^ 0x5EED)
    by_id = {s.id: s for s in specs}
    wrap_ids = {e["tensor"] for e in entries if e["wrap"]}
    nonwrap_ids = {e["tensor"] for e in entries if not e["wrap"]}
    expected = {}
    # Only persistent (wrap) tensors are resident at t0 — init writes.
    # Everything else (in-iteration entries, tenants) first materializes
    # at its first in-run write; initializing them here would clobber a
    # wrap region they time-share (e.g. a tenant in the head window).
    for s in specs:
        t = tokens.fresh(s.len)
        expected[s.id] = t
        if s.id in wrap_ids:
            o, ln = s.region
            pool.cells[o : o + ln] = list(t)
    gaps = {e["tensor"]: (e["ea"], e["pb"]) for e in entries}

    def cpu_write(tid, tag):
        t = tokens.fresh(by_id[tid].len)
        expected[tid] = t
        world.cpu_write(by_id[tid].region, t, tag)

    def cpu_assert(tid, tag):
        got = pool.view(by_id[tid].region)
        assert got == expected[tid], (
            f"seed {seed} {tag}: tensor {tid} corrupted "
            f"(got {got[:4]}..., want {expected[tid][:4]}...)"
        )

    carried_seen = False
    for it in range(iters):
        eng.begin_iteration(pool)
        stop_at = last_eo
        if it in dict(partial_iters):
            stop_at = dict(partial_iters)[it]
        for eo in range(stop_at + 1):
            eng.pre_step(eo, pool)
            eng.check_residency(eo)
            for s in specs:
                if s.id in wrap_ids:
                    first, last = s.boundary_window
                    if eo == first:
                        cpu_assert(s.id, f"it{it} eo{eo} wrap-first")
                    if eo == last:
                        cpu_write(s.id, f"it{it} eo{eo} wrap-apply")
                elif s.id in nonwrap_ids:
                    ea, pb = gaps[s.id]
                    if eo == s.eos[0]:
                        cpu_write(s.id, f"it{it} eo{eo} seg1-write")
                    if eo == pb:
                        cpu_assert(s.id, f"it{it} eo{eo} seg2-read")
                else:  # tenant
                    if eo == s.eos[0]:
                        cpu_write(s.id, f"it{it} eo{eo} tenant-write")
                    if eo == s.eos[-1] and len(s.eos) > 1:
                        cpu_assert(s.id, f"it{it} eo{eo} tenant-read")
            eng.post_step(eo, pool)
            if chaos:
                world.idle_progress(rng.randint(0, 3))
        eng.end_iteration(pool)
        eng.mark_epoch()
        carried_seen = carried_seen or eng.has_carried_state()
        if chaos:
            world.idle_progress(rng.randint(0, 4))
    eng.quiesce(pool)
    assert not eng.has_carried_state()
    assert not world.violations, f"seed {seed}: data races: {world.violations}"
    # Post-quiesce, only wrap tensors are guaranteed intact: a tensor
    # that time-shares a region (tenant in a wrap head window, tenant
    # after a non-wrap tensor's last use) is legitimately overwritten by
    # the sharer's later restore. Every tensor was already checked
    # bitwise at each of its in-run read points.
    for s in specs:
        if s.id in wrap_ids:
            cpu_assert(s.id, "post-quiesce")
    assert not pool.released, f"seed {seed}: leaked released regions {pool.released}"
    return eng, pool, expected, specs, entries, carried_seen, last_eo


# ================================================================ tests


def test_placement_keeps_wrap_regions_disjoint():
    """The EO-0 init point forces pairwise-disjoint wrap regions in every
    placed plan (two persistent tensors can never time-share)."""
    for seed in range(300):
        rng = random.Random(seed)
        specs, entries, leads, offloaded, _ = gen_scenario(rng)
        assert_placement_valid(specs, leads, offloaded)
        wraps = [e for e in entries if e["wrap"]]
        by_id = {s.id: s for s in specs}
        for i in range(len(wraps)):
            for j in range(i + 1, len(wraps)):
                r1 = by_id[wraps[i]["tensor"]].region
                r2 = by_id[wraps[j]["tensor"]].region
                assert not overlap(r1, r2), (
                    f"seed {seed}: wrap regions {r1} and {r2} overlap — the "
                    f"EO-0 init point must forbid this"
                )


def test_wrap_intervals_have_init_point():
    s = Spec(0, "w", 4, [0, 9], boundary_window=(3, 9))
    leads = LeadMap(
        [{"tensor": 0, "ea": 9, "pb": 3, "lead": 1, "write_lead": 0, "wrap": True}]
    )
    assert live_intervals(s, leads) == [(0, 0), (2, 9)]
    # lead reaching EO 0 merges the init point into one interval
    leads2 = LeadMap(
        [{"tensor": 0, "ea": 9, "pb": 3, "lead": 3, "write_lead": 0, "wrap": True}]
    )
    assert live_intervals(s, leads2) == [(0, 9)]


def test_pipelined_fuzz_bitwise_oracle():
    """The main fuzz: random plans, random worker interleaving, 4
    iterations + quiesce — every tensor round-trips bitwise, no data
    races, traffic exactly iters*oneway + wrap_oneway."""
    for seed in range(120):
        eng, _pool, _exp, _specs, entries, carried, _ = run_session(seed)
        oneway = sum(e["region"][1] for e in eng.entries)
        wrap_oneway = sum(e["region"][1] for e in eng.entries if e["wrap"])
        assert eng.stats["bytes_out"] == eng.stats["bytes_in"]
        assert eng.stats["bytes_out"] == 4 * oneway + wrap_oneway, (
            f"seed {seed}: traffic {eng.stats['bytes_out']} != "
            f"4*{oneway} + {wrap_oneway}"
        )
        if wrap_oneway:
            assert carried, f"seed {seed}: pipeline never carried state"


def test_pipelined_matches_drained_bitwise():
    """Same plan, same token stream: pipelining only moves *when* the
    boundary copies happen, never what lands in the pool."""
    for seed in range(60):
        eng_p, pool_p, exp_p, _, _, _, _ = run_session(seed, boundary_drain=False)
        eng_d, pool_d, exp_d, _, _, _, _ = run_session(seed, boundary_drain=True)
        assert exp_p == exp_d  # identical write streams
        assert pool_p.cells == pool_d.cells, f"seed {seed}: final pools diverge"
        # drained mode re-primes every iteration: one extra round trip per
        # wrap entry per iteration instead of one total
        wrap_oneway = sum(e["region"][1] for e in eng_d.entries if e["wrap"])
        oneway = sum(e["region"][1] for e in eng_d.entries)
        assert eng_d.stats["bytes_out"] == eng_d.stats["bytes_in"]
        assert eng_d.stats["bytes_out"] == 4 * oneway + 4 * wrap_oneway
        if wrap_oneway:
            assert eng_p.stats["bytes_out"] < eng_d.stats["bytes_out"]
            assert not eng_d.has_carried_state()


def test_partial_pass_reprimes_cleanly():
    """A partial pass (early stop mid-schedule) leaves some wrap entries
    restored or still carried; the next begin_iteration must re-prime
    exactly the restored ones and stay bitwise-consistent."""
    for seed in range(60):
        rng = random.Random(seed ^ 0x77)
        cut = rng.randint(0, 6)
        eng, _pool, _exp, _specs, _entries, _carried, _ = run_session(
            seed, iters=4, partial_iters=((1, cut),)
        )
        assert eng.stats["bytes_out"] == eng.stats["bytes_in"]


def test_end_iteration_failure_propagates_and_drains():
    """Satellite 1: a failing restore in the sweep drains everything and
    propagates the ORIGINAL error; the next iteration starts clean. The
    pre-fix early return leaves transfers in flight and masks the error
    as 'stale transfers at iteration start'."""

    def build(prefix):
        specs = [
            Spec(0, "a", 4, [0, 6]),
            Spec(1, "b", 4, [1, 7]),
        ]
        entries = [
            {"tensor": 0, "ea": 0, "pb": 6, "lead": 1, "write_lead": 0, "wrap": False},
            {"tensor": 1, "ea": 1, "pb": 7, "lead": 1, "write_lead": 0, "wrap": False},
        ]
        leads = LeadMap(entries)
        place_first_fit(specs, leads, {0, 1})
        derive_entry_bounds(entries, specs, leads, {0, 1})
        pool = Pool(8)
        world = World(random.Random(3), pool, Store())
        world.store.fail_gets[0] = 1  # a's first restore fails, once
        eng = Engine(specs, entries, world, prefix_end_iteration=prefix)
        return eng, pool, world

    # pre-fix: the sweep hits a's failure while b's restore path still has
    # work in flight; the next begin masks the real error as staleness
    eng, pool, world = build(prefix=True)
    eng.begin_iteration(pool)
    for eo in range(4):  # partial pass: neither barrier reached
        eng.pre_step(eo, pool)
        eng.post_step(eo, pool)
    with pytest.raises(EngineError, match="injected get failure"):
        eng.end_iteration(pool)
    assert eng.outstanding > 0 or eng.outstanding_writes > 0 or eng.staged or any(
        not r for r in eng.restored
    ), "pre-fix must leave un-drained state for the regression to be real"
    with pytest.raises(EngineError, match="stale transfers"):
        eng.begin_iteration(pool)

    # post-fix: original error propagates, engine fully drained, next
    # iteration runs end to end (the injected failure was single-shot)
    eng, pool, world = build(prefix=False)
    eng.begin_iteration(pool)
    for eo in range(4):
        eng.pre_step(eo, pool)
        eng.post_step(eo, pool)
    with pytest.raises(EngineError, match="injected get failure"):
        eng.end_iteration(pool)
    eng.begin_iteration(pool)  # must NOT raise
    for eo in range(8):
        eng.pre_step(eo, pool)
        eng.post_step(eo, pool)
    eng.end_iteration(pool)
    assert not world.violations


def test_pump_skips_unready_head():
    """Satellite 2: an entry whose eviction write has not landed must not
    starve later-deadline entries' background fetches (pre-fix pump
    broke out of the loop at the first non-writable head)."""

    def build(prefix):
        specs = [
            Spec(0, "t0", 4, [2, 6]),  # heads the queue (due 5), evicts late
            Spec(1, "t1", 4, [0, 8]),  # due 7, evicts at EO 0
        ]
        entries = [
            {"tensor": 0, "ea": 2, "pb": 6, "lead": 1, "write_lead": 0, "wrap": False},
            {"tensor": 1, "ea": 0, "pb": 8, "lead": 1, "write_lead": 0, "wrap": False},
        ]
        leads = LeadMap(entries)
        # disjoint manual regions, mirroring the Rust fixture: both
        # entries' gaps overlap in time, and the debug registry matches
        # exact regions, so they must not share an address range here
        specs[0].region = (0, 4)
        specs[1].region = (4, 4)
        derive_entry_bounds(entries, specs, leads, {0, 1})
        pool = Pool(8)
        world = World(random.Random(5), pool, Store())
        eng = Engine(specs, entries, world, prefix_pump=prefix)
        return eng, pool, world

    for prefix in (False, True):
        eng, pool, world = build(prefix)
        eng.begin_iteration(pool)
        eng.pre_step(0, pool)
        eng.post_step(0, pool)  # t1's write ticket queued
        world.step()  # write lands (still in done channel)
        eng.pre_step(1, pool)
        eng.post_step(1, pool)  # drain observes it; pump runs
        eng.pre_step(2, pool)
        eng.post_step(2, pool)  # t0 evicts (write queued, unprocessed):
        # the queue head (t0, due 5) is now non-writable while t1 (due 7)
        # is ready — the fixed pump skips ahead and issues t1
        if prefix:
            assert not eng.issued[1], "pre-fix head-of-line must starve t1"
        else:
            assert eng.issued[1], "fixed pump must issue t1 past the unready head"
        # either way the iteration still completes correctly
        for eo in range(3, 9):
            eng.pre_step(eo, pool)
            eng.post_step(eo, pool)
        eng.end_iteration(pool)
        # t0's own write really was unready at its barrier, so it falls
        # back inline either way; the starvation observable is the
        # issued[1] assert above — pre-fix, t1's fetch could not enter
        # flight until t0's inline restore unblocked the pump head
        assert eng.stats["sync_fetches"] >= 1
        if not prefix:
            assert eng.stats["sync_fetches"] == 1
            assert eng.bg_fetch_done[1]
        assert not world.violations


def _priming_scenario(**flags):
    """One wrap tensor whose head window [1, due) hosts a tenant — the
    exact first-iteration soundness hole priming closes."""
    specs = [
        Spec(0, "w", 4, [0, 9], boundary_window=(4, 9)),
        Spec(1, "ten", 4, [1, 2]),  # tenant inside the head window
    ]
    entries = [
        {"tensor": 0, "ea": 9, "pb": 4, "lead": 1, "write_lead": 0, "wrap": True},
    ]
    leads = LeadMap(entries)
    place_first_fit(specs, leads, {0})
    # the tenant must actually share the wrap region for the hazard to
    # exist; first-fit gives both offset 0 (their intervals are disjoint)
    assert specs[0].region == specs[1].region == (0, 4)
    derive_entry_bounds(entries, specs, leads, {0})
    pool = Pool(4)
    world = World(random.Random(9), pool, Store())
    eng = Engine(specs, entries, world, **flags)
    tok_w = [11, 12, 13, 14]
    pool.cells[0:4] = list(tok_w)
    return eng, pool, world, specs, tok_w


def _drive_priming(eng, pool, world, specs, tok_w):
    tok_t = [91, 92, 93, 94]
    eng.begin_iteration(pool)
    got = None
    for eo in range(10):
        eng.pre_step(eo, pool)
        eng.check_residency(eo)
        if eo == 1:
            world.cpu_write(specs[1].region, tok_t, "tenant")
        if eo == 4:  # the wrap tensor's first real access
            got = pool.view(specs[0].region)
        eng.post_step(eo, pool)
    eng.end_iteration(pool)
    return got


def test_priming_closes_first_iteration_wrap_hole():
    # Fixed engine: priming spills the wrap tensor at begin, the tenant
    # freely uses the head window, and the restore brings the weights
    # back bitwise.
    eng, pool, world, specs, tok_w = _priming_scenario()
    got = _drive_priming(eng, pool, world, specs, tok_w)
    assert got == tok_w, f"wrap tensor corrupted by head tenant: {got}"
    assert not world.violations

    # Priming bypassed, current barrier: the unevicted wrap entry at its
    # restore barrier is genuine drift and must fail LOUDLY.
    eng, pool, world, specs, tok_w = _priming_scenario(skip_priming=True)
    with pytest.raises(EngineError, match="not primed"):
        _drive_priming(eng, pool, world, specs, tok_w)

    # Priming bypassed AND the pre-fix wrap shortcut re-injected: the
    # engine silently marks the entry restored and compute reads the
    # tenant's bytes — the silent-corruption hole this PR closes.
    eng, pool, world, specs, tok_w = _priming_scenario(
        skip_priming=True, prefix_unevicted_wrap_shortcut=True
    )
    got = _drive_priming(eng, pool, world, specs, tok_w)
    assert got != tok_w, "pre-fix shortcut should have read the tenant's bytes"
    assert got == [91, 92, 93, 94]


def test_overlap_wait_sensitivity():
    """Two overlapping manually-planned wrap entries (the Rust
    swap_boundary S4 fixture): a boundary restore's reacquire must wait
    out the other entry's carried in-flight eviction write. TWO barriers
    enforce this — the head-reclaim walk in pre_step (this PR) and the
    overlap wait in finish_prefetch — so each is sabotaged
    independently: either one alone still prevents the race, and only
    removing both lets the reacquire overlap the queued write, which the
    race detector must catch."""

    def build(**flags):
        specs = [
            Spec(0, "a", 4, [0, 6], boundary_window=(4, 6)),
            Spec(1, "c", 4, [0, 2], boundary_window=(1, 2)),
        ]
        entries = [
            {"tensor": 0, "ea": 6, "pb": 4, "lead": 1, "write_lead": 0, "wrap": True},
            {"tensor": 1, "ea": 2, "pb": 1, "lead": 1, "write_lead": 0, "wrap": True},
        ]
        # manual overlapping placement (a placed plan would forbid this;
        # the runtime hazard barrier must still be correct under it)
        specs[0].region = (0, 4)
        specs[1].region = (2, 4)
        leads = LeadMap(entries)
        derive_entry_bounds(entries, specs, leads, {0, 1})
        pool = Pool(6)
        world = World(random.Random(11), pool, Store())
        eng = Engine(specs, entries, world, **flags)
        pool.cells[:] = [1, 2, 3, 4, 5, 6]
        return eng, pool, world

    cases = [
        (False, False, False),
        (True, False, False),  # head-reclaim barrier alone suffices
        (False, True, False),  # overlap wait alone suffices
        (True, True, True),  # no barrier left: the race is real
    ]
    for skip_wait, skip_reclaim, expect_race in cases:
        eng, pool, world = build(
            skip_overlap_wait=skip_wait, skip_reclaim_barrier=skip_reclaim
        )
        # iteration N: both wrap entries evict; a's write (EO 6) stays
        # QUEUED across the boundary (no idle progress) — the carried
        # hazard this PR's ordering rules exist for
        eng.begin_iteration(pool)
        for eo in range(7):
            eng.pre_step(eo, pool)
            eng.post_step(eo, pool)
        eng.end_iteration(pool)
        eng.begin_iteration(pool)
        assert any(k == 0 for k, _ in world.evict_q), (
            "scenario must carry a's eviction write across the boundary"
        )
        # land c's background fetch first (deterministically), so the
        # only thing between its reacquire and a's queued write is the
        # engine's own hazard barriers
        while world.fetch_q:
            i = world.fetch_q.pop(0)
            world.done.append(("fetch", i, world.store.get(i), None))
        eng.pre_step(0, pool)  # c's restore barrier (due 0)
        if expect_race:
            assert world.violations, (
                "with both barriers sabotaged the reacquire must race "
                "the queued write"
            )
            continue  # engine state is corrupt by design; stop here
        assert not world.violations, (
            f"barriers (wait={not skip_wait}, reclaim={not skip_reclaim}) "
            f"failed to order the reacquire after the write"
        )
        for eo in range(1, 7):
            eng.pre_step(eo, pool)
            eng.post_step(eo, pool)
        eng.end_iteration(pool)
        eng.quiesce(pool)
        assert not world.violations


def test_pump_writable_gate_sensitivity():
    """The pump's evict_done gate keeps fetches behind their own eviction
    write. Sabotage it and a fetch can hit a store slot that was never
    written — which must surface as a loud error, never silent data."""
    specs = [Spec(0, "s", 4, [0, 1, 7, 8])]
    entries = [
        {"tensor": 0, "ea": 1, "pb": 7, "lead": 1, "write_lead": 0, "wrap": False}
    ]
    leads = LeadMap(entries)
    place_first_fit(specs, leads, {0})
    derive_entry_bounds(entries, specs, leads, {0})
    pool = Pool(4)
    world = World(random.Random(13), pool, Store())
    eng = Engine(specs, entries, world, skip_writable_gate=True)
    eng.begin_iteration(pool)
    eng.pre_step(0, pool)
    eng.post_step(0, pool)
    eng.pre_step(1, pool)
    eng.post_step(1, pool)  # evict queued; sabotaged pump issues the fetch too
    assert world.fetch_q, "sabotaged gate must have issued the premature fetch"
    # force the fetch worker to win the race: store miss
    while world.fetch_q:
        i = world.fetch_q.pop(0)
        try:
            world.done.append(("fetch", i, world.store.get(i), None))
        except StoreError as e:
            world.done.append(("fetch", i, None, e))
    with pytest.raises(EngineError, match="store miss"):
        for eo in range(2, 9):
            eng.pre_step(eo, pool)
            eng.post_step(eo, pool)


def test_epoch_mark_ring_matches_unbounded_oracle():
    """Satellite 3: the capped epoch-mark ring must report exactly the
    same per-epoch deltas as an unbounded mark list, across wraps and
    cap shrinks."""
    for seed in range(80):
        rng = random.Random(seed)
        specs = [Spec(0, "x", 2, [0, 1, 5, 6])]
        entries = [
            {"tensor": 0, "ea": 1, "pb": 5, "lead": 1, "write_lead": 0, "wrap": False}
        ]
        leads = LeadMap(entries)
        place_first_fit(specs, leads, {0})
        derive_entry_bounds(entries, specs, leads, {0})
        eng = Engine(specs, entries, World(rng, Pool(2), Store()))
        eng.set_epoch_mark_cap(rng.randint(1, 5))
        oracle_marks = []
        dropped = 0  # monotone: a cap grow never resurrects old marks
        for _ in range(rng.randint(1, 40)):
            op = rng.random()
            if op < 0.6:
                for k in ("evictions", "prefetches", "bytes_out", "read_stalls"):
                    eng.stats[k] += rng.randint(0, 5)
            elif op < 0.9:
                eng.mark_epoch()
                oracle_marks.append(dict(eng.stats))
            else:
                eng.set_epoch_mark_cap(rng.randint(1, 6))
            # the oracle: deltas of the FULL mark list restricted to the
            # retained window — the ring must never corrupt a delta
            cap = eng.epoch_mark_cap
            while len(oracle_marks) - dropped > cap:
                dropped += 1
            kept = oracle_marks[dropped:]
            zero = {k: 0 for k in eng.stats}
            base = oracle_marks[dropped - 1] if dropped > 0 else zero
            want = []
            prev = base
            for m in kept:
                want.append({k: m[k] - prev[k] for k in m})
                prev = m
            assert eng.epoch_stats() == want, f"seed {seed}"
            assert len(eng.epoch_marks) <= cap


def test_fleet_step_latency_ring_and_percentile():
    """Satellite 3 (fleet half): the step-latency ring keeps exactly the
    last `cap` samples and the percentile matches a sorted oracle of the
    retained window."""

    def percentile(samples, q):
        if not samples:
            return 0
        s = sorted(samples)
        idx = round((q / 100.0) * (len(s) - 1))
        return s[min(idx, len(s) - 1)]

    for seed in range(80):
        rng = random.Random(seed)
        cap = rng.randint(1, 16)
        ring = []
        oracle = []
        dropped = 0  # monotone: a cap grow never resurrects old samples
        for _ in range(rng.randint(1, 200)):
            if rng.random() < 0.85:
                ns = rng.randint(1, 10**6)
                oracle.append(ns)
                ring.append(ns)
                while len(ring) > cap:
                    ring.pop(0)
            else:
                cap = max(rng.randint(0, 12), 1)
                while len(ring) > cap:
                    ring.pop(0)
            while len(oracle) - dropped > cap:
                dropped += 1
            window = oracle[dropped:]
            assert ring == window, f"seed {seed}"
            for q in (0.0, 50.0, 95.0, 99.0, 100.0):
                assert percentile(ring, q) == percentile(window, q)


def test_quiesce_is_idempotent_and_defensive():
    # build a small pipelined session, then quiesce twice
    rng = random.Random(1234)
    specs, entries, leads, offloaded, last_eo = gen_scenario(rng)
    pool = Pool(max(s.region[0] + s.region[1] for s in specs))
    world = World(random.Random(99), pool, Store())
    eng = Engine(specs, entries, world)
    for s in specs:
        o, ln = s.region
        pool.cells[o : o + ln] = [7] * ln
    eng.begin_iteration(pool)
    for eo in range(last_eo + 1):
        eng.pre_step(eo, pool)
        eng.post_step(eo, pool)
    eng.end_iteration(pool)
    eng.quiesce(pool)
    assert not eng.has_carried_state()
    eng.quiesce(pool)  # defensive second call is a no-op
    assert not eng.has_carried_state()
    assert not world.violations
