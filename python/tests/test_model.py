"""L2 model checks: shapes, kernel-vs-ref forward equivalence, and that
the hand-written backward actually trains."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model as M

jax.config.update("jax_platform_name", "cpu")


def batch(seed=0):
    kx, ky = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.uniform(kx, (M.MLP_BATCH, M.MLP_IN), jnp.float32, 0.0, 1.0)
    labels = jax.random.randint(ky, (M.MLP_BATCH,), 0, M.MLP_OUT)
    return x, jax.nn.one_hot(labels, M.MLP_OUT)


def test_forward_matches_ref():
    params = M.mlp_init()
    x, _ = batch()
    got = M.mlp_forward(*params, x)
    want = M.mlp_forward_ref(*params, x)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_forward_shapes():
    params = M.mlp_init()
    x, _ = batch()
    assert M.mlp_forward(*params, x).shape == (M.MLP_BATCH, M.MLP_OUT)


def test_train_step_decreases_loss():
    params = M.mlp_init()
    x, y = batch(3)
    losses = []
    for _ in range(25):
        *params, loss = M.mlp_train_step(*params, x, y)
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.7, losses


def test_train_step_matches_autodiff():
    """The hand-written backward equals jax.grad of the ref loss."""
    params = M.mlp_init(7)
    x, y = batch(11)

    def loss_fn(w0, b0, w1, b1):
        logits = M.mlp_forward_ref(w0, b0, w1, b1, x)
        l, _ = jax.nn.log_softmax(logits), None
        return jnp.mean(-jnp.sum(y * jax.nn.log_softmax(logits), axis=-1))

    grads = jax.grad(loss_fn, argnums=(0, 1, 2, 3))(*params)
    new = M.mlp_train_step(*params, x, y)
    for p, np_, g in zip(params, new[:4], grads):
        np.testing.assert_allclose(
            (p - np_) / M.MLP_LR, g, rtol=1e-3, atol=1e-5
        )


def test_lstm_ref_shapes_and_determinism():
    from compile.kernels import ref

    k = jax.random.PRNGKey(0)
    x = jax.random.normal(k, (2, 5, 4))
    wx = jax.random.normal(k, (4, 24)) * 0.1
    wh = jax.random.normal(k, (6, 24)) * 0.1
    b = jnp.zeros((24,))
    h1 = ref.lstm_ref(x, wx, wh, b)
    h2 = ref.lstm_ref(x, wx, wh, b)
    assert h1.shape == (2, 5, 6)
    np.testing.assert_array_equal(h1, h2)
