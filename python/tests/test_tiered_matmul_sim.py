"""Behavioral sim for the tiered compute backend's bitwise contract
(rust/src/backend/, DESIGN.md §Compute backend).

The Rust suite proves naive == tiered with `to_bits()`; this file
proves, in f32 via numpy, the *reasons* that equality is structural
rather than lucky:

1. a register accumulator seeded with +0.0 then added into C equals
   accumulating directly into C (when C starts at the fill value),
2. a +0.0-seeded ascending-p sum can never produce -0.0, so the
   register round-trip cannot flip C's sign bit,
3. naive matmul_at's zero-skip (`if av == 0.0: continue`) is exactly
   neutral on every c except a -0.0 accumulator, where adding +0.0 is
   observable — so the tiered port must replicate the skip, not the
   "equivalent" unconditional add,
4. but onto a NONZERO accumulator the two associations genuinely
   diverge — which is why the tiered port replicates each naive
   regime's chain verbatim (register regimes stay register, direct
   regimes stay direct) instead of "equivalently" restructuring,
5. any partition of the *output* elements leaves each element's chain
   untouched (the threading invariant), for either chain style,
6. matmul_bt's 4-way unrolled dot has a fixed association tree that a
   plain left fold does NOT reproduce — the tiered port must copy the
   tree,
7. gathering im2col columns on the fly equals materializing the whole
   matrix first (the implicit-GEMM identity).

No jax here — these run wherever numpy does.
"""

import numpy as np

F = np.float32


def rng(seed):
    return np.random.default_rng(seed)


def rand(r, *shape):
    return r.uniform(-1.0, 1.0, size=shape).astype(F)


# ------------------------------------------------------- chain helpers


def chain_direct(c0, a_row, b_col):
    """Naive in-place chain: c starts at c0, += a*b in ascending p."""
    c = F(c0)
    for av, bv in zip(a_row, b_col):
        c = F(c + F(av * bv))
    return c


def chain_register(c0, a_row, b_col):
    """Microkernel chain: accumulate from +0.0 in a register, then one
    += into C."""
    acc = F(0.0)
    for av, bv in zip(a_row, b_col):
        acc = F(acc + F(av * bv))
    return F(F(c0) + acc)


def test_register_accumulator_equals_direct_chain_from_zero_fill():
    # when !accumulate, naive fills C with +0.0 then runs the direct
    # chain; the microkernel runs the register chain onto the same
    # +0.0. Identical adds in identical order -> identical bits.
    r = rng(1)
    for _ in range(200):
        k = int(r.integers(1, 64))
        a, b = rand(r, k), rand(r, k)
        d = chain_direct(F(0.0), a, b)
        g = chain_register(F(0.0), a, b)
        assert d.tobytes() == g.tobytes(), (d, g)


def test_plus_zero_seeded_sum_never_births_negative_zero():
    # x + y == -0.0 in round-to-nearest only when x == y == -0.0 (or
    # exact negative cancellation, which yields +0.0). Seeded from
    # +0.0, no partial sum can be -0.0, so the register round-trip
    # c0 + acc preserves even a -0.0 c0's fate exactly.
    r = rng(2)
    for _ in range(500):
        k = int(r.integers(1, 32))
        a, b = rand(r, k), rand(r, k)
        # force plenty of exact cancellations too
        if k >= 2 and r.random() < 0.5:
            a[1], b[1] = a[0], F(-b[0])
        acc = F(0.0)
        for av, bv in zip(a, b):
            acc = F(acc + F(av * bv))
            assert not (acc == 0.0 and np.signbit(acc)), "acc became -0.0"


def test_zero_skip_is_observable_only_on_negative_zero_c():
    # matmul_at's general branch skips a == 0.0 (positive AND negative
    # zero: `0.0 == -0.0` is true). For any c except -0.0 the skipped
    # add (c += 0*b) is an identity; for c == -0.0 it would flip to
    # +0.0. The tiered port replicates the skip bit-for-bit.
    for c0 in [F(1.5), F(-2.25), F(0.0)]:
        with_add = F(c0 + F(F(0.0) * F(3.0)))
        assert with_add.tobytes() == F(c0).tobytes()
    neg_zero = F(-0.0)
    flipped = F(neg_zero + F(F(0.0) * F(3.0)))
    assert flipped.tobytes() != neg_zero.tobytes(), "-0.0 + 0.0 must be +0.0"
    # and the skip preserves it
    assert np.signbit(neg_zero)


def test_nonzero_accumulator_separates_the_two_chains():
    # onto a random nonzero c0, ((c0+p0)+p1)+... and c0+((p0+p1)+...)
    # are different f32 values for SOME inputs. This is why the tiered
    # port copies each naive regime's chain style verbatim (matmul's
    # blocked branch and matmul_bt stay register-then-+=, matmul_at
    # stays direct in-place) — a "mathematically equivalent" rewrite
    # would break to_bits() equality exactly on the accumulate paths.
    r = rng(6)
    diffs = 0
    for _ in range(300):
        k = int(r.integers(2, 24))
        a, b, c0 = rand(r, k), rand(r, k), rand(r, 1)[0]
        if chain_direct(c0, a, b).tobytes() != chain_register(c0, a, b).tobytes():
            diffs += 1
    assert diffs > 0, "chains never diverged? suspicious sweep"


def matmul_ref(a, b, c, accumulate, chain):
    """Unpartitioned reference kernel with a pluggable per-element
    chain (the naive side)."""
    m, _ = a.shape
    _, n = b.shape
    if not accumulate:
        c[:] = F(0.0)
    for i in range(m):
        for j in range(n):
            c[i, j] = chain(c[i, j], a[i, :], b[:, j])


def matmul_tiled(a, b, c, accumulate, tiles, chain):
    """Tiered sim: partition OUTPUT columns into bands (any partition),
    same per-element chain. The k loop is never split."""
    m, _ = a.shape
    _, n = b.shape
    if not accumulate:
        c[:] = F(0.0)
    for (j0, j1) in tiles:
        for i in range(m):
            for j in range(j0, j1):
                c[i, j] = chain(c[i, j], a[i, :], b[:, j])


def test_any_output_partition_is_bitwise_invariant():
    # the threading invariant: partitioning disjoint output elements
    # changes WHO computes an element, never its chain — so any tiling
    # is bitwise identical, for register and direct regimes alike,
    # with and without accumulation onto a nonzero C.
    r = rng(3)
    for trial in range(20):
        m, k, n = (int(r.integers(1, 9)) for _ in range(3))
        a, b = rand(r, m, k), rand(r, k, n)
        c0 = rand(r, m, n)
        for chain in (chain_register, chain_direct):
            for accumulate in (False, True):
                want = c0.copy()
                matmul_ref(a, b, want, accumulate, chain)
                # three partitions, incl. degenerate and ragged
                cuts = sorted(
                    {0, n, int(r.integers(0, n + 1)), int(r.integers(0, n + 1))}
                )
                parts = list(zip(cuts, cuts[1:]))
                for tiles in ([(0, n)], parts, [(j, j + 1) for j in range(n)]):
                    got = c0.copy()
                    matmul_tiled(a, b, got, accumulate, tiles, chain)
                    assert got.tobytes() == want.tobytes(), (
                        trial,
                        chain.__name__,
                        accumulate,
                        tiles,
                    )


def dot4(a, b):
    """matmul_bt small-branch dot: 4 parallel partials over the
    unrolled body, combined (acc0+acc1)+(acc2+acc3), then scalar tail."""
    k = len(a)
    acc = [F(0.0)] * 4
    k4 = k - (k % 4)
    for p in range(0, k4, 4):
        for u in range(4):
            acc[u] = F(acc[u] + F(a[p + u] * b[p + u]))
    s = F(F(acc[0] + acc[1]) + F(acc[2] + acc[3]))
    for p in range(k4, k):
        s = F(s + F(a[p] * b[p]))
    return s


def test_four_way_unrolled_dot_is_its_own_association():
    r = rng(4)
    diffs = 0
    for _ in range(300):
        k = int(r.integers(4, 40))
        a, b = rand(r, k), rand(r, k)
        # the tiered port must reproduce dot4 exactly...
        assert dot4(a, b).tobytes() == dot4(a, b).tobytes()
        # ...and a plain left fold is NOT generally the same value
        if dot4(a, b).tobytes() != chain_register(F(0.0), a, b).tobytes():
            diffs += 1
    assert diffs > 0, "association never mattered? suspicious sweep"


# ----------------------------------------------------- implicit im2col


def im2col(x, in_c, in_h, in_w, out_c, k_h, k_w, stride, pad_h, pad_w):
    oh = (in_h + 2 * pad_h - k_h) // stride + 1
    ow = (in_w + 2 * pad_w - k_w) // stride + 1
    rows, cols = in_c * k_h * k_w, oh * ow
    col = np.zeros((rows, cols), dtype=F)
    for rr in range(rows):
        c = rr // (k_h * k_w)
        kh = (rr // k_w) % k_h
        kw = rr % k_w
        for j in range(cols):
            y = (j // ow) * stride + kh - pad_h
            xx = (j % ow) * stride + kw - pad_w
            if 0 <= y < in_h and 0 <= xx < in_w:
                col[rr, j] = x[c, y, xx]
    return col


def im2col_cols(x, geom, rr, j0, width):
    """The on-the-fly gather (native::im2col_cols): row rr, cols
    j0..j0+width of the im2col matrix, no materialization."""
    in_c, in_h, in_w, out_c, k_h, k_w, stride, pad_h, pad_w = geom
    oh = (in_h + 2 * pad_h - k_h) // stride + 1
    ow = (in_w + 2 * pad_w - k_w) // stride + 1
    out = np.zeros(width, dtype=F)
    c = rr // (k_h * k_w)
    kh = (rr // k_w) % k_h
    kw = rr % k_w
    for d in range(width):
        j = j0 + d
        y = (j // ow) * stride + kh - pad_h
        xx = (j % ow) * stride + kw - pad_w
        if 0 <= y < in_h and 0 <= xx < in_w:
            out[d] = x[c, y, xx]
    return out


def test_implicit_gather_equals_materialized_im2col():
    r = rng(5)
    geoms = [
        (3, 9, 9, 5, 3, 3, 1, 1, 1),
        (2, 8, 7, 4, 3, 3, 2, 1, 0),
        (2, 1, 16, 3, 1, 5, 1, 0, 2),  # conv1d-style
    ]
    for geom in geoms:
        in_c, in_h, in_w, out_c, k_h, k_w, stride, pad_h, pad_w = geom
        x = rand(r, in_c, in_h, in_w)
        col = im2col(x, *geom)
        rows, cols = col.shape
        for rr in range(rows):
            # full row and a ragged interior segment
            full = im2col_cols(x, geom, rr, 0, cols)
            assert full.tobytes() == col[rr].tobytes(), (geom, rr)
            j0 = rr % max(1, cols - 1)
            w = min(3, cols - j0)
            seg = im2col_cols(x, geom, rr, j0, w)
            assert seg.tobytes() == col[rr, j0:j0 + w].tobytes(), (geom, rr, j0)
        # and conv-as-GEMM over gathered panels == GEMM over the
        # materialized matrix, including accumulate onto nonzero gw
        wgt = rand(r, out_c, rows)
        want = np.zeros((out_c, cols), dtype=F)
        matmul_ref(wgt, col, want, False, chain_register)
        got = np.zeros((out_c, cols), dtype=F)
        bcol = np.stack([im2col_cols(x, geom, rr, 0, cols) for rr in range(rows)])
        matmul_tiled(wgt, bcol, got, False, [(0, 3), (3, cols)], chain_register)
        assert got.tobytes() == want.tobytes(), geom


if __name__ == "__main__":
    for name, fn in sorted(globals().items()):
        if name.startswith("test_") and callable(fn):
            fn()
            print(f"ok {name}")
    print("all tiered-matmul sim checks passed")
