//! Tacotron2-decoder personalization (paper §5.2 / Fig 14): fine-tune the
//! decoder of a TTS model on a handful of "user recordings" (synthetic
//! mel-like sequences — see DESIGN.md §Substitutions).
//!
//! Exercises the full recurrent feature set: time-distributed Prenet,
//! stacked LSTMs with teacher forcing (the input *is* the ground-truth
//! previous frame), mel + gate heads behind a multi-out, gradient
//! accumulation with deferred apply, gradient clipping, Adam — plus a
//! separately-trained Postnet (Conv1D stack), and a compiler-unrolled
//! attention micro-decoder demonstrating `E`-shared weights.

use nntrainer::compiler::unroll::{at, unroll, UnrollSpec};
use nntrainer::compiler::CompileOpts;
use nntrainer::dataset::{DataProducer, SeqProducer};
use nntrainer::graph::NodeDesc;
use nntrainer::layers::Props;
use nntrainer::metrics::Timer;
use nntrainer::model::{zoo, ModelBuilder, TrainConfig};

const T: usize = 24; // time iterations (paper: >100; scaled to the 1-core box)
const MEL: usize = 40;

fn node(name: &str, ltype: &str, pairs: &[(&str, &str)]) -> NodeDesc {
    NodeDesc::new(name, ltype, Props::from_pairs(pairs.iter().copied()))
}

fn main() -> nntrainer::Result<()> {
    // ---- decoder fine-tuning -------------------------------------------
    let batch = 8;
    let mut decoder = ModelBuilder::new()
        .add_nodes(zoo::tacotron_decoder(T, MEL, 128))
        .optimizer("adam", &[("learning_rate", "0.002")])
        .compile(&CompileOpts {
            batch,
            clip_norm: Some(1.0), // paper: Gradient Clipping supported
            ..Default::default()
        })?;
    println!(
        "decoder plan: peak {:.2} MiB (ideal {:.2} MiB), {} tensors, deferred apply: {}",
        decoder.report.pool_mib(),
        decoder.report.ideal_mib(),
        decoder.report.n_tensors,
        decoder.exec.deferred_apply,
    );

    // "user reads 18 sentences" → 18 mel sequences; labels = [mel | gate]
    let label_len = T * MEL + T;
    let make = move || -> Box<dyn DataProducer> {
        Box::new(SeqProducer::new(64, T, MEL, label_len, 18))
    };
    let timer = Timer::start();
    let summary = decoder.train(make, &TrainConfig { epochs: 4, verbose: true, ..Default::default() })?;
    println!(
        "decoder fine-tune: {} iters, {:.2}s ({:.0} ms/iter), loss {:.4} -> {:.4}",
        summary.iterations,
        summary.wall_s,
        summary.wall_s * 1e3 / summary.iterations as f64,
        summary.losses_per_epoch[0],
        summary.final_loss
    );
    let _ = timer;
    assert!(summary.final_loss < summary.losses_per_epoch[0]);

    // ---- postnet (runs after time iteration, Conv1D over mel x T) ------
    let mut postnet = ModelBuilder::new()
        .add_nodes(zoo::postnet(T, MEL))
        .optimizer("adam", &[("learning_rate", "0.0002")])
        .compile(&CompileOpts { batch: 4, ..Default::default() })?;
    println!("postnet plan: peak {:.2} MiB", postnet.report.pool_mib());
    // residual-refinement task: target = the input mel itself (the
    // postnet learns a near-identity refinement, as in Tacotron2)
    let make_post = move || -> Box<dyn DataProducer> {
        use nntrainer::dataset::producer::CachedProducer;
        let mut seq = SeqProducer::new(16, MEL, T, 1, 4);
        let samples = (0..16)
            .map(|k| {
                let s = seq.sample(k);
                nntrainer::dataset::Sample { label: s.input.clone(), input: s.input }
            })
            .collect();
        Box::new(CachedProducer::new(samples))
    };
    let psum = postnet.train(&make_post, &TrainConfig { epochs: 10, ..Default::default() })?;
    println!("postnet: loss {:.4} -> {:.4}", psum.losses_per_epoch[0], psum.final_loss);

    // ---- unrolled attention micro-decoder (E-shared weights) -----------
    // step: query-fc → attention over encoder memory → state-fc (recurrent)
    let step = vec![
        node("q", "fully_connected", &[("unit", "32"), ("bias", "false"), ("input_layers", "state")]),
        node("ctx", "attention", &[("input_layers", "q,memory")]),
        node("state", "fully_connected", &[("unit", "32"), ("activation", "tanh"), ("input_layers", "ctx")]),
    ];
    let t_steps = 6;
    let unrolled = unroll(
        &step,
        &UnrollSpec { t: t_steps, recurrent: vec![("state".into(), "state".into())] },
    )?;
    let mut nodes = vec![
        node("enc_in", "input", &[("input_shape", "1:10:32")]), // encoder memory, T_enc=10
        node("seed", "input", &[("input_shape", "1:1:32")]),
        node("memory", "flatten", &[("target_shape", "1:10:32"), ("input_layers", "enc_in")]),
        node("state", "fully_connected", &[("unit", "32"), ("bias", "false"), ("input_layers", "seed")]),
    ];
    nodes.extend(unrolled);
    nodes.push(node(
        "readout",
        "fully_connected",
        &[("unit", "8"), ("input_layers", at("state", t_steps - 1).as_str())],
    ));
    nodes.push(node("loss", "mse", &[]));
    let mut attn_dec = ModelBuilder::new()
        .add_nodes(nodes)
        .optimizer("adam", &[("learning_rate", "0.005")])
        .compile(&CompileOpts { batch: 4, clip_norm: Some(1.0), ..Default::default() })?;
    // weights of the unrolled steps share storage: count roots
    let shared: usize = attn_dec
        .exec
        .graph
        .table
        .iter()
        .filter(|s| {
            matches!(s.mode, nntrainer::tensor::CreateMode::Extend(_)) && s.merged_into.is_some()
        })
        .count();
    println!(
        "attention micro-decoder: {} E-merged (zero-cost) unrolled weight/grad tensors",
        shared
    );
    assert!(shared >= (t_steps - 1) * 4, "expected E-sharing across timesteps");
    let make_attn = move || -> Box<dyn DataProducer> {
        Box::new(SeqProducer::new(32, 11, 32, 8, 3)) // 10 memory rows + 1 seed row
    };
    let asum = attn_dec.train(&make_attn, &TrainConfig { epochs: 8, ..Default::default() })?;
    println!("attention decoder: loss {:.4} -> {:.4}", asum.losses_per_epoch[0], asum.final_loss);
    assert!(asum.final_loss < asum.losses_per_epoch[0]);
    println!("TACOTRON PERSONALIZATION OK");
    Ok(())
}
