//! Tacotron2-decoder personalization (paper §5.2 / Fig 14) through the
//! session lifecycle: a "vendor" decoder is pre-trained and checkpointed,
//! then a user device fine-tunes it on a handful of "user recordings"
//! (synthetic mel-like sequences — see DESIGN.md §Substitutions) with the
//! backbone frozen, the output heads swapped fresh, and the whole run
//! held under a primary-memory budget by the proactive swap runtime:
//!
//! * `TrainSpec::freeze` pins the Prenet + first LSTM — no gradient or
//!   optimizer tensors are even planned for them;
//! * `CompiledSession::personalize` loads the checkpoint, re-initializes
//!   the mel/gate heads, and fine-tunes with `EarlyStop` + iteration
//!   callbacks;
//! * frozen weights are asserted **bitwise identical** to the checkpoint
//!   after fine-tuning.
//!
//! Also exercises the rest of the recurrent feature set as before:
//! gradient clipping + Adam with deferred apply, a separately-trained
//! Postnet (Conv1D stack), and a compiler-unrolled attention
//! micro-decoder demonstrating `E`-shared weights.

use nntrainer::compiler::unroll::{at, unroll, UnrollSpec};
use nntrainer::dataset::producer::CachedProducer;
use nntrainer::dataset::{DataProducer, SeqProducer};
use nntrainer::graph::NodeDesc;
use nntrainer::layers::Props;
use nntrainer::model::{
    zoo, CallbackAction, DeviceProfile, EarlyStop, OnIteration, PersonalizeOpts, Session,
    TrainCallback, TrainSpec,
};

const T: usize = 24; // time iterations (paper: >100; scaled to the 1-core box)
const MEL: usize = 40;

fn node(name: &str, ltype: &str, pairs: &[(&str, &str)]) -> NodeDesc {
    NodeDesc::new(name, ltype, Props::from_pairs(pairs.iter().copied()))
}

fn main() -> nntrainer::Result<()> {
    let batch = 8;
    let label_len = T * MEL + T;
    // vendor corpus: 64 synthetic mel sequences; labels = [mel | gate]
    let make = move || -> Box<dyn DataProducer> {
        Box::new(SeqProducer::new(64, T, MEL, label_len, 18))
    };
    // "user reads 18 sentences": a small *fixed* recording set, drawn
    // once from a different stream and cached for every fine-tune epoch
    let user = CachedProducer::materialize(&mut SeqProducer::new(64, T, MEL, label_len, 99), 16)
        .samples;
    let make_user = move || -> Box<dyn DataProducer> {
        Box::new(CachedProducer::new(user.clone()))
    };

    // ---- vendor pre-training + checkpoint ------------------------------
    let mut vendor = Session::describe(zoo::tacotron_decoder(T, MEL, 128))
        .optimizer("adam", &[("learning_rate", "0.002")])
        .configure(TrainSpec {
            batch: Some(batch),
            epochs: 2,
            clip_norm: Some(1.0), // paper: Gradient Clipping supported
            ..Default::default()
        })
        .compile_for(DeviceProfile::unconstrained())?;
    println!(
        "vendor decoder plan: peak {:.2} MiB (ideal {:.2} MiB), {} tensors, deferred apply: {}",
        vendor.report().pool_mib(),
        vendor.report().ideal_mib(),
        vendor.report().n_tensors,
        vendor.model.exec.deferred_apply,
    );
    let pre = vendor.train(make)?;
    println!("vendor pre-train: loss {:.4} -> {:.4}", pre.losses_per_epoch[0], pre.final_loss);
    let ckpt = std::env::temp_dir().join("tacotron_vendor.nntr");
    let ckpt_path = ckpt.to_string_lossy().into_owned();
    vendor.save(&ckpt_path)?;

    // ---- on-device personalization under a budget ----------------------
    let budget = vendor.peak_pool_bytes() * 80 / 100;
    let mut personal = Session::describe(zoo::tacotron_decoder(T, MEL, 128))
        .optimizer("adam", &[("learning_rate", "0.002")])
        .configure(TrainSpec {
            batch: Some(batch),
            epochs: 8,
            clip_norm: Some(1.0),
            freeze: vec!["prenet".into(), "dec_lstm0".into()],
            ..Default::default()
        })
        .compile_for(DeviceProfile::with_budget_bytes(budget))?;
    let frozen = personal.frozen_weight_names();
    println!(
        "personal decoder: pool {:.2} MiB under a {:.2} MiB budget (fits: {:?}, swap: {}), \
         {} frozen weights",
        personal.report().pool_mib(),
        budget as f64 / (1024.0 * 1024.0),
        personal.fits_budget(),
        personal.model.exec.swap_active(),
        frozen.len()
    );
    assert!(!frozen.is_empty(), "freeze must pin the backbone");
    assert!(
        personal.peak_pool_bytes() <= vendor.peak_pool_bytes(),
        "frozen + budgeted plan must not exceed the vendor plan"
    );

    let mut iters_seen = 0usize;
    let mut counter = OnIteration(|_ev: &nntrainer::model::TrainEvent| {
        iters_seen += 1;
        CallbackAction::Continue
    });
    let mut early = EarlyStop::new(2, 1e-4);
    let report = personal.personalize(
        &PersonalizeOpts {
            checkpoint: Some(ckpt_path.clone()),
            reinit: vec!["mel_head".into(), "gate_head".into()],
            ..Default::default()
        },
        make_user,
        &mut [&mut counter as &mut dyn TrainCallback, &mut early],
    )?;
    drop(counter);
    println!(
        "personalize: restored {} tensors, reinitialized {} head weights, \
         {} epochs ({} iterations): loss {:.4} -> {:.4}",
        report.restored,
        report.reinitialized,
        report.summary.epochs,
        report.summary.iterations,
        report.summary.losses_per_epoch[0],
        report.summary.final_loss
    );
    assert!(report.restored > 0, "checkpoint restored nothing");
    assert!(report.reinitialized >= 2, "mel + gate heads must re-init");
    assert_eq!(iters_seen, report.summary.iterations, "on_iteration saw every step");
    assert!(
        report.summary.final_loss < report.summary.losses_per_epoch[0],
        "fine-tuning made no progress"
    );

    // frozen backbone is bitwise identical to the vendor checkpoint
    for name in &frozen {
        let theirs = vendor.model.exec.read_weight(name)?;
        let ours = personal.model.exec.read_weight(name)?;
        assert_eq!(theirs.len(), ours.len(), "{name}: length");
        for (k, (a, b)) in theirs.iter().zip(ours.iter()).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "{name}[{k}] drifted: {a} vs {b}");
        }
    }
    println!("frozen backbone verified bitwise against the checkpoint");
    let _ = std::fs::remove_file(&ckpt_path);

    // ---- postnet (runs after time iteration, Conv1D over mel x T) ------
    let mut postnet = Session::describe(zoo::postnet(T, MEL))
        .optimizer("adam", &[("learning_rate", "0.0002")])
        .configure(TrainSpec { batch: Some(4), epochs: 10, ..Default::default() })
        .compile_for(DeviceProfile::unconstrained())?;
    println!("postnet plan: peak {:.2} MiB", postnet.report().pool_mib());
    // residual-refinement task: target = the input mel itself (the
    // postnet learns a near-identity refinement, as in Tacotron2)
    let make_post = move || -> Box<dyn DataProducer> {
        let mut seq = SeqProducer::new(16, MEL, T, 1, 4);
        let samples = (0..16)
            .map(|k| {
                let s = seq.sample(k);
                nntrainer::dataset::Sample { label: s.input.clone(), input: s.input }
            })
            .collect();
        Box::new(CachedProducer::new(samples))
    };
    let psum = postnet.train(&make_post)?;
    println!("postnet: loss {:.4} -> {:.4}", psum.losses_per_epoch[0], psum.final_loss);

    // ---- unrolled attention micro-decoder (E-shared weights) -----------
    // step: query-fc → attention over encoder memory → state-fc (recurrent)
    let step = vec![
        node("q", "fully_connected", &[("unit", "32"), ("bias", "false"), ("input_layers", "state")]),
        node("ctx", "attention", &[("input_layers", "q,memory")]),
        node("state", "fully_connected", &[("unit", "32"), ("activation", "tanh"), ("input_layers", "ctx")]),
    ];
    let t_steps = 6;
    let unrolled = unroll(
        &step,
        &UnrollSpec { t: t_steps, recurrent: vec![("state".into(), "state".into())] },
    )?;
    let mut nodes = vec![
        node("enc_in", "input", &[("input_shape", "1:10:32")]), // encoder memory, T_enc=10
        node("seed", "input", &[("input_shape", "1:1:32")]),
        node("memory", "flatten", &[("target_shape", "1:10:32"), ("input_layers", "enc_in")]),
        node("state", "fully_connected", &[("unit", "32"), ("bias", "false"), ("input_layers", "seed")]),
    ];
    nodes.extend(unrolled);
    nodes.push(node(
        "readout",
        "fully_connected",
        &[("unit", "8"), ("input_layers", at("state", t_steps - 1).as_str())],
    ));
    nodes.push(node("loss", "mse", &[]));
    let mut attn_dec = Session::describe(nodes)
        .optimizer("adam", &[("learning_rate", "0.005")])
        .configure(TrainSpec {
            batch: Some(4),
            epochs: 8,
            clip_norm: Some(1.0),
            ..Default::default()
        })
        .compile_for(DeviceProfile::unconstrained())?;
    // weights of the unrolled steps share storage: count roots
    let shared: usize = attn_dec
        .model
        .exec
        .graph
        .table
        .iter()
        .filter(|s| {
            matches!(s.mode, nntrainer::tensor::CreateMode::Extend(_)) && s.merged_into.is_some()
        })
        .count();
    println!(
        "attention micro-decoder: {} E-merged (zero-cost) unrolled weight/grad tensors",
        shared
    );
    assert!(shared >= (t_steps - 1) * 4, "expected E-sharing across timesteps");
    let make_attn = move || -> Box<dyn DataProducer> {
        Box::new(SeqProducer::new(32, 11, 32, 8, 3)) // 10 memory rows + 1 seed row
    };
    let asum = attn_dec.train(&make_attn)?;
    println!("attention decoder: loss {:.4} -> {:.4}", asum.losses_per_epoch[0], asum.final_loss);
    assert!(asum.final_loss < asum.losses_per_epoch[0]);
    println!("TACOTRON PERSONALIZATION OK");
    Ok(())
}
