//! HandMoji (paper Fig 13): on-device personalization on a watch-class
//! budget, through the session lifecycle. A frozen backbone acts as
//! feature extractor; the user's few hand-drawn symbols are pushed
//! through it **once**, features are cached, and only a single
//! fully-connected classifier trains — the whole flow finishes in well
//! under the paper's 10-second budget.
//!
//! The classifier description is a ~20-line INI string whose `[Model]`
//! hyper-parameters (`Batch_Size`, `Epochs`, `Learning_rate`) flow
//! straight into the session's `TrainSpec` defaults — mirroring the
//! paper's "entire training configuration is described within 30 lines".

use nntrainer::dataset::producer::{CachedProducer, Sample};
use nntrainer::dataset::{DataProducer, DigitsProducer};
use nntrainer::metrics::Timer;
use nntrainer::model::{zoo, DeviceProfile, Session, TrainSpec};

/// The on-device training half: classifier over cached features.
const HEAD_INI: &str = r#"
# HandMoji classifier — trains on cached backbone features
[Model]
Type = NeuralNetwork
Loss = cross_entropy
Optimizer = sgd
Learning_rate = 0.5
Batch_Size = 5
Epochs = 40

[features]
Type = input
Input_Shape = 1:1:64

[classifier]
Type = fully_connected
Unit = 2
"#;

fn main() -> nntrainer::Result<()> {
    let total = Timer::start();

    // ---- pre-trained backbone (vendor-shipped in the paper; trained
    // here on generic glyphs, then used frozen) -------------------------
    let mut backbone = Session::describe(zoo::handmoji_backbone(16))
        .optimizer("sgd", &[("learning_rate", "0.2")])
        .configure(TrainSpec { batch: Some(10), epochs: 2, ..Default::default() })
        .compile_for(DeviceProfile::unconstrained())?;
    let make = || -> Box<dyn DataProducer> { Box::new(DigitsProducer::new(200, 16, 1, 5)) };
    backbone.train(make)?;
    println!("backbone ready ({:.2} MiB peak)", backbone.report().pool_mib());

    // ---- the user draws 5 samples for each of 2 symbols ----------------
    // (synthetic stand-ins: two distinct digit glyph classes)
    let mut user = DigitsProducer::new(1000, 16, 1, 987);
    let mut samples = Vec::new();
    for k in 0..10 {
        // classes 3 and 7 as the two personal symbols
        let class = if k < 5 { 3 } else { 7 };
        let s = user.sample(class + 10 * k);
        samples.push((s.input, if k < 5 { 0usize } else { 1 }));
    }

    // ---- feature extraction, cached after the first pass (Fig 13's
    // "cache the results from the feature extractor in the first epoch")
    let extract = Timer::start();
    let mut cached = Vec::new();
    for (img, label) in &samples {
        let mut batch = Vec::new();
        for _ in 0..10 {
            batch.extend_from_slice(img);
        }
        let feats = backbone.infer_node(&batch, "feat/activation")?;
        let mut onehot = vec![0f32; 2];
        onehot[*label] = 1.0;
        cached.push(Sample { input: feats[..64].to_vec(), label: onehot });
    }
    println!("features cached once in {:.0} ms", extract.elapsed_ms());

    // ---- train the classifier head from the INI description ------------
    // `configure_default` picks up Batch_Size/Epochs/Learning_rate from
    // the [Model] section.
    let mut head = Session::from_ini_str(HEAD_INI)?
        .configure_default()
        .compile_for(DeviceProfile::unconstrained())?;
    println!(
        "classifier plan: {:.1} KiB peak pool @ batch {} — watch-class budget",
        head.report().pool_bytes as f64 / 1024.0,
        head.batch()
    );
    let train = Timer::start();
    let cached2 = cached.clone();
    let make_head =
        move || -> Box<dyn DataProducer> { Box::new(CachedProducer::new(cached2.clone())) };
    let summary = head.train(&make_head)?;
    println!(
        "personalized in {:.0} ms over {} epochs: loss {:.4} -> {:.4}",
        train.elapsed_ms(),
        summary.epochs,
        summary.losses_per_epoch[0],
        summary.final_loss
    );

    // ---- verify the emoji mapping -------------------------------------
    let mut correct = 0;
    for (feat_sample, want) in cached.iter().zip(samples.iter().map(|s| s.1)) {
        let mut batch = Vec::new();
        for _ in 0..5 {
            batch.extend_from_slice(&feat_sample.input);
        }
        let logits = head.infer(&batch)?;
        let pred = if logits[0] > logits[1] { 0 } else { 1 };
        if pred == want {
            correct += 1;
        }
    }
    println!("emoji mapping: {correct}/10 of the user's samples classified");
    let secs = total.elapsed_s();
    println!("total wall time {secs:.2}s (paper budget: < 10 s)");
    assert!(secs < 10.0);
    Ok(())
}
