//! Fleet simulation: a dozen "users" personalizing one shared backbone
//! through [`FleetService`], under a budget that holds only a couple of
//! head-state copies in RAM — the rest park in a secondary store and
//! come back via the swap-aware round-robin.
//!
//! The narrative version of `benches/fleet_scale.rs`:
//!
//! * a vendor model is trained once and checkpointed;
//! * the fleet compiles ONE `CompiledSession` with the backbone frozen
//!   and loads the checkpoint into it;
//! * each tenant's entire identity is its head Weight+OptState vector
//!   (plus two step counters), swapped in and out of the shared pool;
//! * the admission plan prices everything up front: pool once, a
//!   state-vector sliver per user — vs a full session per user naively.

use nntrainer::dataset::producer::{CachedProducer, Sample};
use nntrainer::dataset::DataProducer;
use nntrainer::fleet::{FleetConfig, FleetService, TenantSpec};
use nntrainer::graph::NodeDesc;
use nntrainer::layers::Props;
use nntrainer::model::{DeviceProfile, Session, TrainSpec};
use nntrainer::rng::Rng;
use nntrainer::runtime::StoreKind;

fn node(name: &str, ltype: &str, pairs: &[(&str, &str)]) -> NodeDesc {
    NodeDesc::new(name, ltype, Props::from_pairs(pairs.iter().copied()))
}

fn net() -> Vec<NodeDesc> {
    vec![
        node("in", "input", &[("input_shape", "2:8:8")]),
        node("c0", "conv2d", &[("filters", "4"), ("kernel_size", "3"), ("padding", "same"), ("activation", "relu")]),
        node("c1", "conv2d", &[("filters", "4"), ("kernel_size", "3"), ("padding", "same"), ("activation", "relu")]),
        node("flat", "flatten", &[]),
        node("head", "fully_connected", &[("unit", "6")]),
        node("loss", "mse", &[]),
    ]
}

fn main() -> nntrainer::Result<()> {
    let batch = 4usize;
    let in_len = 2 * 8 * 8;
    let lb_len = 6;
    let users = 12usize;

    // ---- vendor model, checkpointed once -------------------------------
    let mut vendor = Session::describe(net())
        .optimizer("sgd", &[("learning_rate", "0.05"), ("momentum", "0.9")])
        .configure(TrainSpec { batch: Some(batch), epochs: 2, ..Default::default() })
        .compile_for(DeviceProfile::unconstrained())?;
    let mut vrng = Rng::new(7);
    let corpus: Vec<Sample> = (0..32)
        .map(|_| {
            let mut input = vec![0f32; in_len];
            let mut label = vec![0f32; lb_len];
            vrng.fill_uniform(&mut input, -1.0, 1.0);
            vrng.fill_uniform(&mut label, 0.0, 1.0);
            Sample { input, label }
        })
        .collect();
    let make = move || -> Box<dyn DataProducer> { Box::new(CachedProducer::new(corpus.clone())) };
    vendor.train(&make)?;
    let ckpt = std::env::temp_dir().join("fleet_sim_vendor.nntr");
    let ckpt_path = ckpt.to_string_lossy().into_owned();
    vendor.save(&ckpt_path)?;

    // ---- size the fleet ------------------------------------------------
    let spec = TrainSpec {
        batch: Some(batch),
        freeze: vec!["c0".into(), "c1".into()],
        ..Default::default()
    };
    let probe = FleetService::build(
        net(),
        "sgd",
        &[("learning_rate", "0.05"), ("momentum", "0.9")],
        spec.clone(),
        DeviceProfile::unconstrained(),
        FleetConfig {
            checkpoint: Some(ckpt_path.clone()),
            ..FleetConfig::new(usize::MAX / 2, vec!["head".into()])
        },
    )?;
    let (shared, state, naive) = (
        probe.admission().shared_pool_bytes,
        probe.admission().tenant_state_bytes,
        probe.admission().naive_session_bytes,
    );
    drop(probe);
    let mib = |b: usize| b as f64 / (1024.0 * 1024.0);
    println!(
        "admission plan: shared pool {:.2} MiB, per-tenant state {:.1} KiB \
         ({}x cheaper than a naive {:.2} MiB session per user)",
        mib(shared),
        state as f64 / 1024.0,
        naive / state.max(1),
        mib(naive)
    );

    // budget: the pool + TWO resident state copies — 12 users will churn
    let budget = shared + 2 * state;
    let mut fleet = FleetService::build(
        net(),
        "sgd",
        &[("learning_rate", "0.05"), ("momentum", "0.9")],
        spec,
        DeviceProfile::unconstrained(),
        FleetConfig {
            checkpoint: Some(ckpt_path.clone()),
            park_store: StoreKind::Host,
            quantum: 2,
            ..FleetConfig::new(budget, vec!["head".into()])
        },
    )?;
    println!(
        "fleet budget {:.2} MiB -> max {} resident tenants; the other {} park in the {} store\n",
        mib(budget),
        fleet.admission().max_resident,
        users - fleet.admission().max_resident,
        "host",
    );

    // ---- admit 12 users, run to completion -----------------------------
    let mut ids = Vec::new();
    for u in 0..users {
        let seed = 0x1000 + u as u64;
        let data: Vec<Sample> = {
            let mut rng = Rng::new(seed ^ 0xDA7A);
            (0..16)
                .map(|_| {
                    let mut input = vec![0f32; in_len];
                    let mut label = vec![0f32; lb_len];
                    rng.fill_uniform(&mut input, -1.0, 1.0);
                    rng.fill_uniform(&mut label, 0.0, 1.0);
                    Sample { input, label }
                })
                .collect()
        };
        ids.push(fleet.admit(TenantSpec {
            seed,
            epochs: 2,
            make_producer: Box::new(move || Box::new(CachedProducer::new(data.clone()))),
        }));
    }
    let stats = fleet.run()?;

    println!("user   final loss");
    for &id in &ids {
        println!("  #{id:<3} {:.4}", fleet.tenant_loss(id).unwrap());
    }
    println!(
        "\n{} tenants trained through one session: {} steps, {} context switches, \
         {} parks / {} unparks ({} stalled), peak resident {:.2} MiB \
         (naive for {} concurrent users: {:.2} MiB)",
        stats.completed,
        stats.steps,
        stats.context_switches,
        stats.parks,
        stats.unparks,
        stats.stalled_unparks,
        mib(stats.peak_resident_bytes),
        stats.peak_live_tenants,
        mib(naive * stats.peak_live_tenants),
    );
    assert_eq!(stats.completed, users);
    assert!(stats.parks > 0, "tight budget must park tenants");

    let _ = std::fs::remove_file(&ckpt_path);
    println!("FLEET SIM OK");
    Ok(())
}
