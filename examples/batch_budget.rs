//! Batch-size under a memory budget (the Fig 11 story, as a tool): given
//! a model and a device budget (512 MiB in the paper), report the largest
//! feasible batch per allocation profile — computable *before* any
//! training because the planner knows the peak in advance.
//!
//! Three profiles: the conventional-framework emulation, the NNTrainer
//! planner, and the NNTrainer planner **plus the proactive swap runtime**
//! (idle-gap tensors spend forward→backward gaps in secondary memory, so
//! the primary pool shrinks further and the feasible batch grows).
//!
//! ```sh
//! cargo run --release --example batch_budget [budget_mib]
//! ```

use nntrainer::compiler::CompileOpts;
use nntrainer::metrics::{BASELINE_NNTRAINER_MIB, BASELINE_TENSORFLOW_MIB, MIB};
use nntrainer::model::{zoo, Model, ModelBuilder};
use nntrainer::planner::PlannerKind;

fn compile(batch: usize, planner: PlannerKind, conventional: bool, budget: Option<usize>) -> Model {
    ModelBuilder::new()
        .add_nodes(zoo::model_a_linear())
        .optimizer("sgd", &[])
        .compile(&CompileOpts {
            batch,
            planner,
            conventional,
            inplace: !conventional,
            memory_budget_bytes: budget,
            ..Default::default()
        })
        .expect("compile")
}

fn peak_mib(batch: usize, planner: PlannerKind, conventional: bool) -> f64 {
    compile(batch, planner, conventional, None).peak_pool_bytes() as f64 / MIB
}

/// Pool under the swap runtime, targeting the whole post-baseline budget.
fn swap_peak_mib(batch: usize, target_bytes: usize) -> f64 {
    compile(batch, PlannerKind::Sorting, false, Some(target_bytes)).peak_pool_bytes() as f64 / MIB
}

fn main() {
    let budget: f64 = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(512.0);
    println!("model A (Linear), budget {budget} MiB (incl. framework baseline)\n");
    // Framework baselines from paper §5.1: NNTrainer 12.3 MiB, TF 337.8 MiB.
    println!(
        "{:>6} {:>22} {:>20} {:>26}",
        "batch", "nntrainer (pool+12.3)", "  +swap (pool+12.3)", "conventional (pool+337.8)"
    );
    let swap_target = ((budget - BASELINE_NNTRAINER_MIB).max(1.0) * MIB) as usize;
    let mut max_nn = 0usize;
    let mut max_swap = 0usize;
    let mut max_conv = 0usize;
    for shift in 0..9 {
        let b = 1usize << shift;
        let nn = peak_mib(b, PlannerKind::Sorting, false) + BASELINE_NNTRAINER_MIB;
        let sw = swap_peak_mib(b, swap_target) + BASELINE_NNTRAINER_MIB;
        let conv = peak_mib(b, PlannerKind::Naive, true) + BASELINE_TENSORFLOW_MIB;
        let nn_ok = nn <= budget;
        let sw_ok = sw <= budget;
        let conv_ok = conv <= budget;
        if nn_ok {
            max_nn = b;
        }
        if sw_ok {
            max_swap = b;
        }
        if conv_ok {
            max_conv = b;
        }
        println!(
            "{b:>6} {:>18.1} {} {:>16.1} {} {:>22.1} {}",
            nn,
            if nn_ok { "ok " } else { "OVER" },
            sw,
            if sw_ok { "ok " } else { "OVER" },
            conv,
            if conv_ok { "ok " } else { "OVER" }
        );
    }
    println!(
        "\nlargest feasible batch: nntrainer-profile {max_nn}, with swap runtime {max_swap}, \
         conventional-profile {max_conv}"
    );
    println!(
        "(paper Fig 11: NNTrainer trains at batch 128 under 512 MiB; TensorFlow \
         exceeds it from batch 16 — baselines {BASELINE_NNTRAINER_MIB}/{BASELINE_TENSORFLOW_MIB} MiB from §5.1. \
         The swap column is this repo's extension: the proactive swap runtime executes the \
         offload advisor's plan, so the pool undercuts even the gap-free optimum.)"
    );
    assert!(max_nn > max_conv);
    assert!(max_swap >= max_nn);
}
