//! Batch-size under a memory budget (the Fig 11 story, as an API): give
//! `compile_for` a [`DeviceProfile`] with a budget and *no explicit
//! batch*, and the session auto-selects the largest batch whose planned
//! pool fits — the ROADMAP's budget-aware batch scheduler, computable
//! before any training because the planner knows the peak in advance.
//! (The seed did this by hand with a power-of-two sweep; the search now
//! lives behind `Session::compile_for` and returns the exact maximum.)
//!
//! Three device profiles: the conventional-framework emulation, the
//! NNTrainer planner, and the NNTrainer planner **plus the proactive
//! swap runtime** (idle-gap tensors spend forward→backward gaps in
//! secondary memory, so the primary pool shrinks further and the
//! feasible batch grows).
//!
//! ```sh
//! cargo run --release --example batch_budget [budget_mib]
//! ```

use nntrainer::metrics::{BASELINE_NNTRAINER_MIB, BASELINE_TENSORFLOW_MIB, MIB};
use nntrainer::model::{zoo, DeviceProfile, Session, TrainSpec};
use nntrainer::planner::PlannerKind;

struct Row {
    batch: usize,
    pool_bytes: usize,
    fits: bool,
}

/// Compile model A (Linear) with automatic batch selection under the
/// profile's budget; the session (and its pool) is dropped before the
/// next profile compiles, so the profiles don't stack in memory.
fn auto_row(profile: DeviceProfile) -> Row {
    let cs = Session::describe(zoo::model_a_linear())
        .optimizer("sgd", &[])
        .configure(TrainSpec { batch: None, ..Default::default() })
        .compile_for(profile)
        .expect("compile");
    Row {
        batch: cs.batch(),
        pool_bytes: cs.peak_pool_bytes(),
        fits: cs.fits_budget() == Some(true),
    }
}

fn main() {
    let budget: f64 = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(512.0);
    println!("model A (Linear), device budget {budget} MiB (incl. framework baseline)\n");
    // Framework baselines from paper §5.1: NNTrainer 12.3 MiB, TF 337.8 MiB.
    let nn_pool = ((budget - BASELINE_NNTRAINER_MIB).max(1.0) * MIB) as usize;
    let conv_pool = ((budget - BASELINE_TENSORFLOW_MIB).max(1.0) * MIB) as usize;

    let conv = auto_row(DeviceProfile {
        memory_budget_bytes: Some(conv_pool),
        ..DeviceProfile::conventional()
    });
    let nn = auto_row(DeviceProfile {
        memory_budget_bytes: Some(nn_pool),
        swap: false,
        ..DeviceProfile::default()
    });
    let swapped = auto_row(DeviceProfile {
        memory_budget_bytes: Some(nn_pool),
        swap: true,
        planner: PlannerKind::Sorting,
        ..DeviceProfile::default()
    });

    // both columns baseline-inclusive: pool+baseline vs the device budget
    println!(
        "{:>26} {:>10} {:>16} {:>12} {:>6}",
        "profile", "batch", "pool+base MiB", "budget MiB", "fits"
    );
    for (name, baseline, row) in [
        ("conventional (TF base)", BASELINE_TENSORFLOW_MIB, &conv),
        ("nntrainer", BASELINE_NNTRAINER_MIB, &nn),
        ("nntrainer + swap runtime", BASELINE_NNTRAINER_MIB, &swapped),
    ] {
        println!(
            "{:>26} {:>10} {:>16.1} {:>12.1} {:>6}",
            name,
            row.batch,
            row.pool_bytes as f64 / MIB + baseline,
            budget,
            if row.fits { "yes" } else { "no" },
        );
    }

    println!(
        "\nlargest feasible batch: nntrainer-profile {}, with swap runtime {}, \
         conventional-profile {}",
        nn.batch, swapped.batch, conv.batch
    );
    println!(
        "(paper Fig 11: NNTrainer trains at batch 128 under 512 MiB; TensorFlow \
         exceeds it from batch 16 — baselines {BASELINE_NNTRAINER_MIB}/{BASELINE_TENSORFLOW_MIB} MiB from §5.1. \
         The swap row is this repo's extension: the proactive swap runtime executes the \
         offload advisor's plan, so the pool undercuts even the gap-free optimum — and \
         the batch search, which probes plans without allocating, rides it automatically.)"
    );
    assert!(nn.batch > conv.batch, "planner profile must beat conventional");
    assert!(swapped.batch >= nn.batch, "swap runtime must never shrink the batch");
}
