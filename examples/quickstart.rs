//! Quickstart: the lifecycle-staged session API. Describe a small
//! classifier (*Load*), declare the training contract (*Configure*),
//! compile it for a device (*Compile*/*Initialize* — the memory plan is
//! known *before* training, the paper's headline operational property),
//! then train and run inference.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use nntrainer::dataset::{DataProducer, DigitsProducer};
use nntrainer::metrics::MIB;
use nntrainer::model::{DeviceProfile, Session, TrainSpec};

fn main() -> nntrainer::Result<()> {
    // Load: describe the network (equivalently via INI; see
    // examples/handmoji.rs) and pick an optimizer.
    let session = Session::builder()
        .add("in", "input", &[("input_shape", "1:16:16")])
        .add(
            "conv",
            "conv2d",
            &[("filters", "8"), ("kernel_size", "3"), ("padding", "same"), ("activation", "relu")],
        )
        .add("pool", "pooling2d", &[("pooling", "max"), ("pool_size", "2")])
        .add("flat", "flatten", &[])
        .add("fc", "fully_connected", &[("unit", "32"), ("activation", "sigmoid")])
        .add("head", "fully_connected", &[("unit", "10")])
        .add("loss", "cross_entropy", &[])
        .optimizer("sgd", &[("learning_rate", "0.3")]);

    // Configure: the training-algorithm contract.
    let configured = session.configure(TrainSpec {
        batch: Some(16),
        epochs: 3,
        verbose: true,
        ..Default::default()
    });

    // Compile/Initialize for a device: realizers → Algorithm 1 → planner.
    let mut model = configured.compile_for(DeviceProfile::unconstrained())?;
    let rep = model.report();
    println!("== memory plan ({}) ==", rep.planner);
    println!("peak pool:   {:8.2} MiB (known before execution)", rep.pool_mib());
    println!("ideal bound: {:8.2} MiB", rep.ideal_mib());
    println!("no-reuse sum:{:8.2} MiB", rep.total_bytes as f64 / MIB);
    println!(
        "tensors: {} allocated, {} merged away (MV/RV/E)",
        rep.n_tensors, rep.n_merged
    );

    // setData/Train: synthetic digit glyphs, 3 epochs.
    let make = || -> Box<dyn DataProducer> { Box::new(DigitsProducer::new(320, 16, 1, 42)) };
    let summary = model.train(make)?;
    println!(
        "trained {} iterations in {:.2}s — loss {:.4} -> {:.4}",
        summary.iterations, summary.wall_s, summary.losses_per_epoch[0], summary.final_loss
    );

    // Inference on one batch.
    let mut p = DigitsProducer::new(16, 16, 1, 7);
    let mut batch = Vec::new();
    for i in 0..16 {
        batch.extend_from_slice(&p.sample(i).input);
    }
    let logits = model.infer(&batch)?;
    let correct = (0..16)
        .filter(|&i| {
            let row = &logits[i * 10..(i + 1) * 10];
            let pred = row.iter().enumerate().max_by(|a, b| a.1.total_cmp(b.1)).unwrap().0;
            pred == i % 10
        })
        .count();
    println!("inference: {correct}/16 correct on held-out digits");
    Ok(())
}
