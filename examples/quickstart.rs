//! Quickstart: build a small classifier with the builder API, inspect
//! the memory plan (known *before* training — the paper's headline
//! operational property), train it, run inference.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use nntrainer::compiler::CompileOpts;
use nntrainer::dataset::{DataProducer, DigitsProducer};
use nntrainer::metrics::MIB;
use nntrainer::model::{ModelBuilder, TrainConfig};

fn main() -> nntrainer::Result<()> {
    // Load/Configure: describe the network (equivalently via INI; see
    // examples/handmoji.rs).
    let builder = ModelBuilder::new()
        .add("in", "input", &[("input_shape", "1:16:16")])
        .add(
            "conv",
            "conv2d",
            &[("filters", "8"), ("kernel_size", "3"), ("padding", "same"), ("activation", "relu")],
        )
        .add("pool", "pooling2d", &[("pooling", "max"), ("pool_size", "2")])
        .add("flat", "flatten", &[])
        .add("fc", "fully_connected", &[("unit", "32"), ("activation", "sigmoid")])
        .add("head", "fully_connected", &[("unit", "10")])
        .add("loss", "cross_entropy", &[])
        .optimizer("sgd", &[("learning_rate", "0.3")]);

    // Compile/Initialize: realizers → Algorithm 1 → memory planner.
    let mut model = builder.compile(&CompileOpts { batch: 16, ..Default::default() })?;
    println!("== memory plan ({}) ==", model.report.planner);
    println!("peak pool:   {:8.2} MiB (known before execution)", model.report.pool_mib());
    println!("ideal bound: {:8.2} MiB", model.report.ideal_mib());
    println!("no-reuse sum:{:8.2} MiB", model.report.total_bytes as f64 / MIB);
    println!(
        "tensors: {} allocated, {} merged away (MV/RV/E)",
        model.report.n_tensors, model.report.n_merged
    );

    // setData/Train: synthetic digit glyphs, 3 epochs.
    let make = || -> Box<dyn DataProducer> { Box::new(DigitsProducer::new(320, 16, 1, 42)) };
    let summary = model.train(make, &TrainConfig { epochs: 3, verbose: true, ..Default::default() })?;
    println!(
        "trained {} iterations in {:.2}s — loss {:.4} -> {:.4}",
        summary.iterations, summary.wall_s, summary.losses_per_epoch[0], summary.final_loss
    );

    // Inference on one batch.
    let mut p = DigitsProducer::new(16, 16, 1, 7);
    let mut batch = Vec::new();
    for i in 0..16 {
        batch.extend_from_slice(&p.sample(i).input);
    }
    let logits = model.infer(&batch)?;
    let correct = (0..16)
        .filter(|&i| {
            let row = &logits[i * 10..(i + 1) * 10];
            let pred = row.iter().enumerate().max_by(|a, b| a.1.total_cmp(b.1)).unwrap().0;
            pred == i % 10
        })
        .count();
    println!("inference: {correct}/16 correct on held-out digits");
    Ok(())
}
