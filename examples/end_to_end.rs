//! End-to-end driver: trains the demo MLP on synthetic digit glyphs for a
//! few hundred steps through BOTH compute paths and logs the loss curves:
//!
//! * **XLA path** — the Rust coordinator (Batch Queue, epochs, metrics)
//!   drives the AOT-compiled `mlp_train_step` artifact (JAX fwd/bwd with
//!   the Pallas fused-matmul + softmax-xent kernels inside) via PJRT.
//!   Python is not running; the artifact was lowered once by
//!   `make artifacts`. This proves all three layers compose.
//! * **Native path** — the same architecture on the NNTrainer engine
//!   (Algorithm 1 + sorting planner). Both start from identical weights;
//!   per-step losses must track each other to ~1e-4.
//!
//! Results are recorded in EXPERIMENTS.md §End-to-end.
//!
//! ```sh
//! make artifacts && cargo run --release --example end_to_end
//! ```

use nntrainer::dataset::{BatchQueue, DataProducer, DigitsProducer};
use nntrainer::metrics::Timer;
use nntrainer::model::{zoo, DeviceProfile, Session, TrainSpec};
use nntrainer::rng::Rng;
use nntrainer::runtime::catalog::{self, ArtifactCatalog};
use nntrainer::runtime::XlaRuntime;

const EPOCHS: usize = 5;
const DATASET: usize = 1920; // 60 steps/epoch at batch 32 → 300 steps

fn make_producer() -> Box<dyn DataProducer> {
    Box::new(DigitsProducer::new(DATASET, 16, 1, 1234))
}

fn main() -> nntrainer::Result<()> {
    let (bsz, i, h, o) =
        (catalog::MLP_BATCH, catalog::MLP_IN, catalog::MLP_HIDDEN, catalog::MLP_OUT);

    // identical initial weights for both paths
    let mut rng = Rng::new(4242);
    let a0 = (6.0 / (i + h) as f32).sqrt();
    let a1 = (6.0 / (h + o) as f32).sqrt();
    let mut w0 = vec![0f32; i * h];
    let mut w1 = vec![0f32; h * o];
    rng.fill_uniform(&mut w0, -a0, a0);
    rng.fill_uniform(&mut w1, -a1, a1);
    let mut b0 = vec![0f32; h];
    let mut b1 = vec![0f32; o];

    // ---------------- XLA path (L3 coordinator + PJRT artifact) --------
    let dir = ArtifactCatalog::default_dir();
    ArtifactCatalog::open(&dir)?;
    let mut rt = XlaRuntime::new(dir)?;
    println!("PJRT platform: {}", rt.platform());
    let (mut xw0, mut xb0, mut xw1, mut xb1) = (w0.clone(), b0.clone(), w1.clone(), b1.clone());
    let mut xla_curve = Vec::new();
    let timer = Timer::start();
    let mut steps = 0usize;
    for _epoch in 0..EPOCHS {
        let queue = BatchQueue::spawn(make_producer(), bsz, 2);
        while let Some(batch) = queue.next() {
            let out = rt.run_f32(
                "mlp_train_step",
                &[
                    (&xw0[..], &[i, h][..]),
                    (&xb0[..], &[h][..]),
                    (&xw1[..], &[h, o][..]),
                    (&xb1[..], &[o][..]),
                    (&batch.input[..], &[bsz, i][..]),
                    (&batch.label[..], &[bsz, o][..]),
                ],
            )?;
            xw0.copy_from_slice(&out[0]);
            xb0.copy_from_slice(&out[1]);
            xw1.copy_from_slice(&out[2]);
            xb1.copy_from_slice(&out[3]);
            xla_curve.push(out[4][0]);
            steps += 1;
        }
    }
    let xla_time = timer.elapsed_s();
    println!("XLA path: {steps} steps in {xla_time:.2}s ({:.1} steps/s)", steps as f64 / xla_time);

    // ---------------- native path (NNTrainer engine, session API) -------
    let mut session = Session::describe(zoo::mlp_e2e())
        .optimizer("sgd", &[("learning_rate", "0.5")]) // = MLP_LR in model.py
        .configure(TrainSpec { batch: Some(bsz), ..Default::default() })
        .compile_for(DeviceProfile::unconstrained())?;
    let model = &mut session.model;
    model.exec.write_weight("fc0:weight", &w0)?;
    model.exec.write_weight("fc0:bias", &b0)?;
    model.exec.write_weight("fc1:weight", &w1)?;
    model.exec.write_weight("fc1:bias", &b1)?;
    println!(
        "native plan: peak pool {:.2} MiB (ideal {:.2} MiB)",
        model.report.pool_mib(),
        model.report.ideal_mib()
    );
    let mut native_curve = Vec::new();
    let timer = Timer::start();
    for _epoch in 0..EPOCHS {
        let queue = BatchQueue::spawn(make_producer(), bsz, 2);
        while let Some(batch) = queue.next() {
            model.bind_batch(&batch.input, &batch.label)?;
            native_curve.push(model.exec.train_iteration());
        }
    }
    let native_time = timer.elapsed_s();
    println!(
        "native path: {} steps in {native_time:.2}s ({:.1} steps/s)",
        native_curve.len(),
        native_curve.len() as f64 / native_time
    );

    // ---------------- compare ------------------------------------------
    assert_eq!(xla_curve.len(), native_curve.len());
    let mut max_dev = 0f32;
    for (a, b) in xla_curve.iter().zip(native_curve.iter()) {
        max_dev = max_dev.max((a - b).abs() / b.abs().max(1.0));
    }
    println!("loss curves (every 30th step):");
    println!("{:>6} {:>12} {:>12}", "step", "xla", "native");
    for (k, (a, b)) in xla_curve.iter().zip(native_curve.iter()).enumerate() {
        if k % 30 == 0 || k == xla_curve.len() - 1 {
            println!("{k:>6} {a:>12.5} {b:>12.5}");
        }
    }
    println!("max relative loss deviation xla-vs-native: {max_dev:.2e}");
    let first = native_curve[0];
    let last = *native_curve.last().unwrap();
    println!("convergence: {first:.4} -> {last:.4} ({:.1}% of start)", last / first * 100.0);
    assert!(max_dev < 5e-3, "paths diverged: {max_dev}");
    assert!(last < first * 0.2, "did not converge");
    println!("END-TO-END OK: three layers compose, paths agree, model converges");
    Ok(())
}
