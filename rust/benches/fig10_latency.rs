//! Fig 10: training latency of the component cases — 1 epoch, batch 32
//! (paper: dataset 512 on RPi4; dataset here is
//! `NNTRAINER_BENCH_DATASET`, default 128, on one x86 core).
//!
//! The claim to reproduce: memory planning does NOT cost speed — the
//! planned profile is as fast as (or faster than) the no-reuse profile,
//! because the math is identical and the smaller working set helps cache.
//!
//! Machine-readable path: per-case step latency and throughput land in
//! `BENCH_fig10.json` and gate against the committed baseline
//! (EXPERIMENTS.md).

use nntrainer::bench_report::{finish, BenchReport, Metric};
use nntrainer::bench_util::{
    bench_dataset, conventional_profile, nntrainer_profile, train_random, with_naive_compute, Table,
};
use nntrainer::model::zoo;

fn main() {
    let ds = bench_dataset();
    println!("\n== Fig 10: training latency, 1 epoch, dataset {ds}, batch 32 ==\n");
    let mut table =
        Table::new(&["case", "planned s", "conventional s", "speedup", "GFLOP/s", "vs naive"]);
    let mut report = BenchReport::new("fig10", ds);
    for (name, nodes, _) in zoo::table4_cases() {
        let (model, t_plan, it) =
            train_random(nodes.clone(), &nntrainer_profile(32), ds, 1, 1e-4).expect(name);
        let flops = model.exec.backend().flops() as f64;
        let (_, t_conv, _) =
            train_random(nodes.clone(), &conventional_profile(32), ds, 1, 1e-4).expect(name);
        // same planned profile on the single-threaded naive kernels —
        // the denominator of the tiered-backend speedup column
        let (_, t_naive, _) =
            train_random(nodes, &with_naive_compute(nntrainer_profile(32)), ds, 1, 1e-4)
                .expect(name);
        let gflops = flops / t_plan.max(1e-9) / 1e9;
        let tiered_speedup = t_naive / t_plan.max(1e-9);
        table.row(vec![
            name.to_string(),
            format!("{t_plan:.3}"),
            format!("{t_conv:.3}"),
            format!("x{:.2} ({} iters)", t_conv / t_plan, it),
            format!("{gflops:.2}"),
            format!("x{tiered_speedup:.2}"),
        ]);
        let iters = it.max(1) as f64;
        report.push(
            name,
            vec![
                Metric::lower("planned_s", t_plan),
                Metric::lower("step_latency_ms", t_plan * 1e3 / iters),
                Metric::higher("iters_per_s", iters / t_plan.max(1e-9)),
                Metric::higher("gflops", gflops),
                Metric::higher("tiered_speedup_x", tiered_speedup),
                Metric::info("conventional_s", t_conv),
                Metric::info("speedup_x", t_conv / t_plan.max(1e-9)),
            ],
        );
    }
    table.print();
    println!(
        "\npaper: NNTrainer is faster than or equivalent to the conventional frameworks\n\
         in most cases while consuming a fraction of the memory."
    );
    finish(&report);
}
