//! Fig 10: training latency of the component cases — 1 epoch, batch 32
//! (paper: dataset 512 on RPi4; dataset here is
//! `NNTRAINER_BENCH_DATASET`, default 128, on one x86 core).
//!
//! The claim to reproduce: memory planning does NOT cost speed — the
//! planned profile is as fast as (or faster than) the no-reuse profile,
//! because the math is identical and the smaller working set helps cache.

use nntrainer::bench_util::{bench_dataset, conventional_profile, nntrainer_profile, train_random, Table};
use nntrainer::model::zoo;

fn main() {
    let ds = bench_dataset();
    println!("\n== Fig 10: training latency, 1 epoch, dataset {ds}, batch 32 ==\n");
    let mut table = Table::new(&["case", "planned s", "conventional s", "speedup"]);
    for (name, nodes, _) in zoo::table4_cases() {
        let (_, t_plan, it) =
            train_random(nodes.clone(), &nntrainer_profile(32), ds, 1, 1e-4).expect(name);
        let (_, t_conv, _) =
            train_random(nodes, &conventional_profile(32), ds, 1, 1e-4).expect(name);
        table.row(vec![
            name.to_string(),
            format!("{t_plan:.3}"),
            format!("{t_conv:.3}"),
            format!("x{:.2} ({} iters)", t_conv / t_plan, it),
        ]);
    }
    table.print();
    println!(
        "\npaper: NNTrainer is faster than or equivalent to the conventional frameworks\n\
         in most cases while consuming a fraction of the memory."
    );
}
