//! Fig 11: memory and throughput vs batch size (Model A-Linear).
//!
//! The paper's story: with planned memory, batch 128 fits under the
//! 512 MiB embedded budget and processes a fixed amount of data fastest;
//! the conventional profile blows the budget at small batches (TF from
//! batch 16 with its 337.8 MiB baseline).
//!
//! Machine-readable path: per-batch planned MiB and samples/s land in
//! `BENCH_fig11.json` and gate against the committed baseline
//! (EXPERIMENTS.md).

use nntrainer::bench_report::{finish, BenchReport, Metric};
use nntrainer::bench_util::{
    bench_dataset, conventional_profile, nntrainer_profile, plan, train_random,
    with_naive_compute, Table,
};
use nntrainer::metrics::{BASELINE_NNTRAINER_MIB, BASELINE_TENSORFLOW_MIB, MIB};
use nntrainer::model::zoo;

fn main() {
    let ds = bench_dataset();
    println!("\n== Fig 11: Model A (Linear) vs batch size — fixed data = {ds} samples ==");
    println!("   budget line: 512 MiB incl. framework baseline (12.3 / 337.8 MiB)\n");
    let mut table = Table::new(&[
        "batch",
        "planned MiB",
        "fits512",
        "conv MiB",
        "fits512",
        "time s",
        "samples/s",
        "GFLOP/s",
        "vs naive",
    ]);
    let mut report = BenchReport::new("fig11", ds);
    for &batch in &[1usize, 2, 4, 8, 16, 32, 64, 128] {
        let nn = plan(zoo::model_a_linear(), &nntrainer_profile(batch)).unwrap();
        let conv = plan(zoo::model_a_linear(), &conventional_profile(batch)).unwrap();
        let nn_tot = nn.pool_bytes as f64 / MIB + BASELINE_NNTRAINER_MIB;
        let conv_tot = conv.pool_bytes as f64 / MIB + BASELINE_TENSORFLOW_MIB;
        // time to process the fixed dataset at this batch (1 epoch)
        let (model, secs, iters) =
            train_random(zoo::model_a_linear(), &nntrainer_profile(batch), ds, 1, 1e-4).unwrap();
        let flops = model.exec.backend().flops() as f64;
        let (_, secs_naive, _) = train_random(
            zoo::model_a_linear(),
            &with_naive_compute(nntrainer_profile(batch)),
            ds,
            1,
            1e-4,
        )
        .unwrap();
        let samples = iters * batch;
        let gflops = flops / secs.max(1e-9) / 1e9;
        let tiered_speedup = secs_naive / secs.max(1e-9);
        table.row(vec![
            batch.to_string(),
            format!("{nn_tot:.1}"),
            (if nn_tot <= 512.0 { "yes" } else { "NO" }).into(),
            format!("{conv_tot:.1}"),
            (if conv_tot <= 512.0 { "yes" } else { "NO" }).into(),
            format!("{secs:.3}"),
            format!("{:.0}", samples as f64 / secs),
            format!("{gflops:.2}"),
            format!("x{tiered_speedup:.2}"),
        ]);
        report.push(
            &format!("batch{batch}"),
            vec![
                Metric::lower("planned_mib_incl_base", nn_tot),
                Metric::info("conventional_mib_incl_base", conv_tot),
                Metric::info("fits_512", if nn_tot <= 512.0 { 1.0 } else { 0.0 }),
                Metric::lower("time_s", secs),
                Metric::higher("samples_per_s", samples as f64 / secs.max(1e-9)),
                Metric::higher("gflops", gflops),
                Metric::higher("tiered_speedup_x", tiered_speedup),
            ],
        );
    }
    table.print();
    println!(
        "\npaper: NNTrainer stays under 512 MiB through batch 128 and gets faster with\n\
         batch (cache utilization); TensorFlow exceeds the budget from batch 16."
    );
    finish(&report);
}
