//! Off-loading advisor (paper future work): for each application model,
//! how far below the unconstrained peak can primary memory go by
//! swapping idle-gap tensors to secondary memory, and what swap traffic
//! does it cost per iteration?

use nntrainer::bench_util::{fmt_mib, Table};
use nntrainer::compiler::realizer::realize_all;
use nntrainer::exec::{init_graph, InitOptions};
use nntrainer::graph::Graph;
use nntrainer::layers::builtin_factories;
use nntrainer::model::zoo;
use nntrainer::planner::offload::advise;

fn main() {
    println!("\n== Dynamic off-loading advisor (batch 32) ==\n");
    let mut table = Table::new(&[
        "model",
        "peak",
        "70% target",
        "achieved",
        "fits",
        "swapped tensors",
        "swap MiB/iter",
    ]);
    for (name, nodes) in [
        ("LeNet-5", zoo::lenet5()),
        ("VGG16", zoo::vgg16()),
        ("ResNet18", zoo::resnet18()),
        ("Tacotron2 dec", zoo::tacotron_decoder(24, 80, 256)),
        ("Model A (Linear)", zoo::model_a_linear()),
    ] {
        let graph = Graph::wire(realize_all(nodes).unwrap()).unwrap();
        let ig = init_graph(
            &graph,
            &builtin_factories(),
            &InitOptions { batch: 32, ..Default::default() },
        )
        .unwrap();
        let full = advise(&ig.table, usize::MAX).primary_peak_bytes;
        let target = full * 70 / 100;
        let plan = advise(&ig.table, target);
        table.row(vec![
            name.to_string(),
            fmt_mib(full),
            fmt_mib(target),
            fmt_mib(plan.primary_peak_bytes),
            (if plan.fits { "yes" } else { "no" }).into(),
            plan.entries.len().to_string(),
            fmt_mib(plan.swap_bytes_per_iter),
        ]);
    }
    table.print();
    println!(
        "\nEO-driven prediction (paper §6): evict each tensor after its last pre-gap use,\n\
         prefetch one EO before the next — proactive background swaps, no demand paging."
    );
}
