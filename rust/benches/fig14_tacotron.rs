//! Fig 14: Tacotron2-decoder training — peak memory and per-sample
//! latency vs batch size, planned vs conventional profile.
//!
//! Paper: NNTrainer saves 40–56 % of PyTorch's memory and improves
//! latency ≥24 % at the same batch; at the same *memory*, a 2x batch
//! gives >35 % latency improvement.

use nntrainer::bench_util::{conventional_profile, nntrainer_profile, plan, train_random, Table};
use nntrainer::metrics::MIB;
use nntrainer::model::zoo;

const T: usize = 24;
const MEL: usize = 80;
const UNITS: usize = 256;

fn main() {
    println!(
        "\n== Fig 14: Tacotron2 decoder (T={T}, mel={MEL}, lstm={UNITS}) — memory & latency ==\n"
    );
    let mut table = Table::new(&[
        "batch",
        "planned MiB",
        "conv MiB",
        "saving",
        "ms/sample",
    ]);
    for &batch in &[8usize, 16, 32] {
        let nodes = zoo::tacotron_decoder(T, MEL, UNITS);
        let nn = plan(nodes.clone(), &nntrainer_profile(batch)).unwrap();
        let conv = plan(nodes.clone(), &conventional_profile(batch)).unwrap();
        let saving = 100.0 * (1.0 - nn.pool_bytes as f64 / conv.pool_bytes as f64);
        // latency: 2 iterations, report per-sample
        let (_, secs, iters) = train_random(nodes, &nntrainer_profile(batch), batch * 2, 1, 1e-4).unwrap();
        let ms_per_sample = secs * 1e3 / (iters * batch) as f64;
        table.row(vec![
            batch.to_string(),
            format!("{:.1}", nn.pool_bytes as f64 / MIB),
            format!("{:.1}", conv.pool_bytes as f64 / MIB),
            format!("{saving:.1}%"),
            format!("{ms_per_sample:.1}"),
        ]);
    }
    table.print();
    println!(
        "\npaper: 40-56% memory saving vs PyTorch at the same batch; per-sample latency\n\
         improves with batch (cache utilization), letting NNTrainer run batch 32 in the\n\
         memory PyTorch needs for 16 (>35% latency win at equal memory)."
    );
}
