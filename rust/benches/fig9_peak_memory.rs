//! Fig 9: peak memory consumption of the component cases (batch 64) —
//! NNTrainer profile vs conventional-framework profile vs the ideal.
//!
//! Paper's claim to reproduce in shape: conventional frameworks use
//! x2.19–x6.47 more memory than NNTrainer on average (incl. baselines),
//! and NNTrainer's peak is within noise of the ideal.
//!
//! Machine-readable path: every row also lands in `BENCH_fig9.json`
//! (repo root) and diffs against the committed baseline — the pool and
//! overhead columns are gated (EXPERIMENTS.md).

use nntrainer::bench_report::{finish, BenchReport, Metric};
use nntrainer::bench_util::{conventional_profile, fmt_mib, nntrainer_profile, plan, Table};
use nntrainer::metrics::{BASELINE_NNTRAINER_MIB, BASELINE_PYTORCH_MIB, BASELINE_TENSORFLOW_MIB, MIB};
use nntrainer::model::zoo;

fn main() {
    println!("\n== Fig 9: peak memory, batch 64 (pool MiB; +baseline in ratio cols) ==\n");
    let mut table = Table::new(&[
        "case",
        "ideal",
        "nntrainer",
        "overhead",
        "conventional",
        "x(pool)",
        "x(+TF base)",
        "x(+PT base)",
    ]);
    // plan-only: no dataset is ever touched (dataset 0 in the snapshot)
    let mut report = BenchReport::new("fig9", 0);
    let mut ratios = Vec::new();
    for (name, nodes, _) in zoo::table4_cases() {
        let nn = plan(nodes.clone(), &nntrainer_profile(64)).expect(name);
        let conv = plan(nodes, &conventional_profile(64)).expect(name);
        let nn_mib = nn.pool_bytes as f64 / MIB;
        let conv_mib = conv.pool_bytes as f64 / MIB;
        let x_pool = conv_mib / nn_mib;
        let x_tf = (conv_mib + BASELINE_TENSORFLOW_MIB) / (nn_mib + BASELINE_NNTRAINER_MIB);
        let x_pt = (conv_mib + BASELINE_PYTORCH_MIB) / (nn_mib + BASELINE_NNTRAINER_MIB);
        ratios.push(x_tf);
        ratios.push(x_pt);
        table.row(vec![
            name.to_string(),
            fmt_mib(nn.ideal_bytes),
            fmt_mib(nn.pool_bytes),
            format!("x{:.3}", nn.overhead()),
            fmt_mib(conv.pool_bytes),
            format!("x{x_pool:.2}"),
            format!("x{x_tf:.2}"),
            format!("x{x_pt:.2}"),
        ]);
        report.push(
            name,
            vec![
                Metric::info("ideal_mib", nn.ideal_bytes as f64 / MIB),
                Metric::lower("pool_mib", nn_mib),
                Metric::lower("overhead_x", nn.overhead()),
                Metric::info("conventional_mib", conv_mib),
                Metric::info("ratio_incl_tf_x", x_tf),
                Metric::info("ratio_incl_pt_x", x_pt),
            ],
        );
    }
    table.print();
    let mean = ratios.iter().sum::<f64>() / ratios.len() as f64;
    let (lo, hi) = ratios
        .iter()
        .fold((f64::MAX, f64::MIN), |(l, h), &r| (l.min(r), h.max(r)));
    println!(
        "\nconventional-vs-nntrainer ratio incl. baselines: x{lo:.2}..x{hi:.2} (mean x{mean:.2})\n\
         paper: x2.19..x6.47 on average; NNTrainer peak ~= ideal (overhead column)."
    );
    finish(&report);
}
