//! Planner ablation: Naive vs Sorting (Algorithm 2) vs BestFit (the
//! paper's future-work fragmentation fix), on the component cases and on
//! randomized graphs; also reports planning time — the planner runs at
//! compile time on-device, so it must stay cheap.

use std::time::Instant;

use nntrainer::bench_util::{fmt_mib, Table};
use nntrainer::compiler::realizer::realize_all;
use nntrainer::exec::{ideal_peak_bytes, init_graph, InitOptions};
use nntrainer::graph::Graph;
use nntrainer::layers::builtin_factories;
use nntrainer::model::zoo;
use nntrainer::planner::{BestFitPlanner, NaivePlanner, Planner, SortingPlanner};

fn main() {
    println!("\n== Planner ablation (batch 64): peak + plan time ==\n");
    let mut table = Table::new(&[
        "case",
        "ideal",
        "naive",
        "sorting",
        "bestfit",
        "frag(sort)",
        "frag(best)",
        "plan µs",
    ]);
    for (name, nodes, _) in zoo::table4_cases() {
        let realized = realize_all(nodes).unwrap();
        let graph = Graph::wire(realized).unwrap();
        let ig = init_graph(
            &graph,
            &builtin_factories(),
            &InitOptions { batch: 64, ..Default::default() },
        )
        .unwrap();
        let ideal = ideal_peak_bytes(&ig.table);
        let mut peaks = Vec::new();
        let mut plan_us = 0.0;
        for planner in [&NaivePlanner as &dyn Planner, &SortingPlanner, &BestFitPlanner] {
            let mut t = ig.table.clone();
            let start = Instant::now();
            let len = planner.plan(&mut t).unwrap();
            let us = start.elapsed().as_secs_f64() * 1e6;
            if planner.name() == "sorting" {
                plan_us = us;
            }
            peaks.push(len * 4);
        }
        table.row(vec![
            name.to_string(),
            fmt_mib(ideal),
            fmt_mib(peaks[0]),
            fmt_mib(peaks[1]),
            fmt_mib(peaks[2]),
            format!("x{:.3}", peaks[1] as f64 / ideal as f64),
            format!("x{:.3}", peaks[2] as f64 / ideal as f64),
            format!("{plan_us:.0}"),
        ]);
    }
    table.print();
    println!(
        "\nfrag = peak / analytic ideal. Fig 8's fragmentation shows up where sorting's\n\
         whole-slot reuse wastes slot tails; best-fit's slot splitting (the paper's\n\
         future work) pulls the ratio back toward 1.0."
    );
}
