//! Fig 12: application training memory at batch 32 — LeNet-5, VGG16,
//! ResNet18, ResNet18-transfer, Product Rating.
//!
//! Shape to reproduce: 96.5 % saving on LeNet-5 (x28 incl. baselines),
//! ~65 % on VGG16/ResNet18, >75 % for transfer learning, ~50 % for
//! Product Rating (embedding-table dominated).

use nntrainer::bench_util::{conventional_profile, nntrainer_profile, plan, Table};
use nntrainer::metrics::{BASELINE_NNTRAINER_MIB, BASELINE_TENSORFLOW_MIB, MIB};
use nntrainer::model::zoo;

fn main() {
    println!("\n== Fig 12: application training memory, batch 32 (MiB) ==\n");
    let cases: Vec<(&str, Vec<nntrainer::graph::NodeDesc>, &str)> = vec![
        ("LeNet-5", zoo::lenet5(), "96.5% saving (x28)"),
        ("VGG16", zoo::vgg16(), "~65% saving"),
        ("ResNet18", zoo::resnet18(), "~65% saving"),
        ("ResNet18 transfer", zoo::resnet18_transfer(), ">75% saving"),
        ("Product Rating", zoo::product_rating(), "~50% saving"),
    ];
    let mut table = Table::new(&[
        "application",
        "nntrainer",
        "+base",
        "conventional",
        "+base",
        "saving",
        "paper",
    ]);
    for (name, nodes, paper) in cases {
        let nn = plan(nodes.clone(), &nntrainer_profile(32)).expect(name);
        let conv = plan(nodes, &conventional_profile(32)).expect(name);
        let nn_pool = nn.pool_bytes as f64 / MIB;
        let conv_pool = conv.pool_bytes as f64 / MIB;
        let nn_tot = nn_pool + BASELINE_NNTRAINER_MIB;
        let conv_tot = conv_pool + BASELINE_TENSORFLOW_MIB;
        let saving = 100.0 * (1.0 - nn_tot / conv_tot);
        table.row(vec![
            name.to_string(),
            format!("{nn_pool:.1}"),
            format!("{nn_tot:.1}"),
            format!("{conv_pool:.1}"),
            format!("{conv_tot:.1}"),
            format!("{saving:.1}%"),
            paper.to_string(),
        ]);
    }
    table.print();
    println!(
        "\n(`+base` adds the frameworks' resident baselines from §5.1: NNTrainer 12.3 MiB,\n\
         TensorFlow 337.8 MiB. ResNet18-transfer's ideal per the paper: 80.5 MiB incl.\n\
         baseline; our planned pool + baseline lands in the same regime.)"
    );
}
