//! In-place (MV/RV) ablation: the §3 claim that sharing activation /
//! batch-norm buffers "reduces the memory requirement of inputs by
//! almost half" — measured by toggling the merge pass on models whose
//! structure is dominated by in-place-eligible layers.

use nntrainer::bench_util::{fmt_mib, nntrainer_profile, Table};
use nntrainer::compiler::{plan_only, CompileOpts};
use nntrainer::model::zoo;

fn main() {
    println!("\n== In-place (MV/RV) ablation, batch 64 ==\n");
    let mut table = Table::new(&["case", "inplace ON", "inplace OFF", "saving", "views merged"]);
    for (name, nodes) in [
        ("Model B (Linear)", zoo::model_b_linear()),
        ("Model B (Conv2D)", zoo::model_b_conv()),
        ("Model C (Linear)", zoo::model_c_linear()),
        ("Model C (Conv2D)", zoo::model_c_conv()),
        ("VGG16", zoo::vgg16()),
        ("LeNet-5", zoo::lenet5()),
    ] {
        let on = plan_only(nodes.clone(), &nntrainer_profile(64)).expect(name);
        let off = plan_only(
            nodes,
            &CompileOpts { batch: 64, inplace: false, ..Default::default() },
        )
        .expect(name);
        let saving = 100.0 * (1.0 - on.pool_bytes as f64 / off.pool_bytes as f64);
        table.row(vec![
            name.to_string(),
            fmt_mib(on.pool_bytes),
            fmt_mib(off.pool_bytes),
            format!("{saving:.1}%"),
            format!("{}", on.n_merged),
        ]);
    }
    table.print();
    println!(
        "\nnote: with the sorting planner both variants already reuse dead slots, so the\n\
         in-place win shows on models whose activation tensors peak simultaneously\n\
         (deep conv stacks); the merge also removes derivative buffers (Fig 5's D_1)."
    );
}
