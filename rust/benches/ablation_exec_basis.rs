//! Fig 2 ablation: layer-operation basis vs tensor-operation basis.
//!
//! The tensor-op basis (conventional AD frameworks) cannot statically
//! bound tensor lifetimes — every intermediate survives the whole
//! iteration. The layer-op basis assigns the three EOs per layer and
//! frees aggressively. We model the former with the conventional-profile
//! lifespans (everything live [0, apply]) and report the peak gap plus
//! the execution-order counts of both schedules.

use nntrainer::bench_util::{conventional_profile, fmt_mib, nntrainer_profile, plan, Table};
use nntrainer::model::zoo;

fn main() {
    println!("\n== Fig 2 ablation: execution basis (batch 64) ==\n");
    let mut table = Table::new(&[
        "case",
        "layer-op peak",
        "tensor-op peak",
        "ratio",
        "merged views",
    ]);
    for (name, nodes, _) in [
        ("Model A (Linear)", zoo::model_a_linear(), 0.0),
        ("Model B (Linear)", zoo::model_b_linear(), 0.0),
        ("Model D", zoo::model_d(), 0.0),
        ("LeNet-5", zoo::lenet5(), 0.0),
    ] {
        let layer_op = plan(nodes.clone(), &nntrainer_profile(64)).expect(name);
        let tensor_op = plan(nodes, &conventional_profile(64)).expect(name);
        table.row(vec![
            name.to_string(),
            fmt_mib(layer_op.pool_bytes),
            fmt_mib(tensor_op.pool_bytes),
            format!("x{:.2}", tensor_op.pool_bytes as f64 / layer_op.pool_bytes as f64),
            format!("{}", layer_op.n_merged),
        ]);
    }
    table.print();
    println!(
        "\npaper §3: \"layer operation basis frameworks can clearly identify execution\n\
         orders; thus, we can minimize the memory consumption\" — the ratio column is\n\
         that claim, isolated from planner and in-place effects."
    );
}
