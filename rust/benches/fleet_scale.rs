//! Fleet-scale stress bench: thousands of simulated tenants sharing one
//! compiled backbone under a single global memory budget, with
//! Poisson-ish arrival and churn, against the analytic cost of the
//! naive one-session-per-user design.
//!
//! Scenario per store backend (host / file / file-compressed):
//!
//! * `NNTRAINER_FLEET_TENANTS` tenants (default 1000) arrive on an
//!   exponential-gap clock (seeded, deterministic), each training its
//!   own head for 1–3 epochs of `NNTRAINER_BENCH_DATASET` samples.
//! * The fleet budget holds the shared pool plus a handful of resident
//!   state copies — a small fraction of what the naive design would
//!   need for the *peak concurrent* population — so tenants park and
//!   unpark through the store constantly.
//! * A seeded slice of finished tenants departs, freeing store slots
//!   (churn), while new arrivals keep the run queue full.
//!
//! Reported per backend: step-latency p50/p99, steps/s, peak resident
//! bytes vs the naive design's peak (exact planner numbers on both
//! sides: measured pool + state buffers vs `peak-concurrent x
//! naive_session_bytes`), and the park/unpark/stall telemetry.

use std::time::Instant;

use nntrainer::bench_report::{finish, BenchReport, Metric};
use nntrainer::bench_util::{bench_dataset, Table};
use nntrainer::dataset::producer::{CachedProducer, Sample};
use nntrainer::dataset::DataProducer;
use nntrainer::fleet::{FleetConfig, FleetService, TenantSpec, Tick};
use nntrainer::graph::NodeDesc;
use nntrainer::layers::Props;
use nntrainer::model::{DeviceProfile, Session, TrainSpec};
use nntrainer::rng::Rng;
use nntrainer::runtime::StoreKind;

fn node(name: &str, ltype: &str, pairs: &[(&str, &str)]) -> NodeDesc {
    NodeDesc::new(name, ltype, Props::from_pairs(pairs.iter().copied()))
}

/// Small conv backbone + fc head — the personalization shape, kept
/// small so the bench is tenant-bound, not FLOP-bound.
fn net() -> Vec<NodeDesc> {
    vec![
        node("in", "input", &[("input_shape", "2:8:8")]),
        node("c0", "conv2d", &[("filters", "4"), ("kernel_size", "3"), ("padding", "same"), ("activation", "relu")]),
        node("c1", "conv2d", &[("filters", "4"), ("kernel_size", "3"), ("padding", "same"), ("activation", "relu")]),
        node("flat", "flatten", &[]),
        node("head", "fully_connected", &[("unit", "6")]),
        node("loss", "mse", &[]),
    ]
}

fn spec(batch: usize) -> TrainSpec {
    TrainSpec {
        batch: Some(batch),
        freeze: vec!["c0".into(), "c1".into()],
        ..Default::default()
    }
}

fn tenants_target() -> usize {
    match std::env::var("NNTRAINER_FLEET_TENANTS") {
        Ok(v) => match v.trim().parse::<usize>() {
            Ok(n) if n > 0 => n,
            Ok(_) => panic!("NNTRAINER_FLEET_TENANTS must be > 0"),
            Err(e) => panic!("NNTRAINER_FLEET_TENANTS={v:?} is not a usize: {e}"),
        },
        Err(_) => 1000,
    }
}

struct CaseResult {
    tenants: usize,
    steps: u64,
    wall_s: f64,
    p50_us: f64,
    p99_us: f64,
    peak_mib: f64,
    naive_mib: f64,
    parks: u64,
    unparks: u64,
    stalled: u64,
    yields: u64,
    read_stall_ms: f64,
    departed: usize,
    store_peak_mib: f64,
    store_physical_mib: f64,
}

fn run_case(store: StoreKind, tenants: usize, samples_per_tenant: usize, seed: u64) -> CaseResult {
    let batch = 4usize;
    let in_len = 2 * 8 * 8;
    let lb_len = 6;

    // Budget: the shared pool + 8 resident state copies. Everything
    // beyond that lives in the store — the point of the exercise.
    let probe = FleetService::build(
        net(),
        "sgd",
        &[("learning_rate", "0.05")],
        spec(batch),
        DeviceProfile::unconstrained(),
        FleetConfig::new(usize::MAX / 2, vec!["head".into()]),
    )
    .unwrap();
    let (shared, state) = (
        probe.admission().shared_pool_bytes,
        probe.admission().tenant_state_bytes,
    );
    drop(probe);

    let mut fleet = FleetService::build(
        net(),
        "sgd",
        &[("learning_rate", "0.05")],
        spec(batch),
        DeviceProfile::unconstrained(),
        FleetConfig {
            park_store: store,
            quantum: 4,
            max_active: Some(64),
            ..FleetConfig::new(shared + 8 * state, vec!["head".into()])
        },
    )
    .unwrap();

    // Exponential-gap arrival ticks: tenant k arrives after
    // sum of k draws of (-ln U) / lambda scheduler ticks.
    let mut rng = Rng::new(seed);
    let lambda = 0.5f64; // arrivals per tick
    let mut arrivals: Vec<f64> = Vec::with_capacity(tenants);
    let mut t = 0.0f64;
    for _ in 0..tenants {
        let u = f64::from(rng.next_f32()).max(1e-9);
        t += -u.ln() / lambda;
        arrivals.push(t);
    }

    let mk_tenant = |rng: &mut Rng| -> TenantSpec {
        let seed = rng.next_u64();
        let epochs = 1 + (rng.next_u64() % 3) as usize;
        let n = samples_per_tenant;
        TenantSpec {
            seed,
            epochs,
            make_producer: Box::new(move || {
                let mut drng = Rng::new(seed ^ 0xDA7A);
                let data: Vec<Sample> = (0..n)
                    .map(|_| {
                        let mut input = vec![0f32; in_len];
                        let mut label = vec![0f32; lb_len];
                        drng.fill_uniform(&mut input, -1.0, 1.0);
                        drng.fill_uniform(&mut label, 0.0, 1.0);
                        Sample { input, label }
                    })
                    .collect();
                Box::new(CachedProducer::new(data)) as Box<dyn DataProducer>
            }),
        }
    };

    let t0 = Instant::now();
    let mut arrived = 0usize;
    let mut ticks = 0f64;
    let mut finished_pool: Vec<usize> = Vec::new();
    let mut departed = 0usize;
    loop {
        while arrived < tenants && arrivals[arrived] <= ticks {
            fleet.admit(mk_tenant(&mut rng));
            arrived += 1;
        }
        match fleet.tick().unwrap() {
            Tick::Stepped { tenant, finished, .. } => {
                if finished {
                    finished_pool.push(tenant);
                    // churn: roughly half of finishers depart right
                    // away, freeing their store slot
                    if rng.next_u64() % 2 == 0 {
                        let k = (rng.next_u64() as usize) % finished_pool.len();
                        let victim = finished_pool.swap_remove(k);
                        fleet.depart(victim).unwrap();
                        departed += 1;
                    }
                }
            }
            Tick::Yielded { .. } => {}
            Tick::Idle => {
                if arrived >= tenants {
                    break;
                }
                // quiet gap before the next arrival: advance the clock
                ticks = arrivals[arrived];
                continue;
            }
        }
        ticks += 1.0;
    }
    let wall_s = t0.elapsed().as_secs_f64();

    let stats = fleet.stats().clone();
    let store = fleet.park_store_stats();
    assert_eq!(stats.admitted, tenants);
    assert_eq!(stats.completed, tenants, "every admitted tenant must finish");
    let naive_bytes = fleet
        .admission()
        .naive_total(stats.peak_live_tenants);
    CaseResult {
        tenants,
        steps: stats.steps,
        wall_s,
        p50_us: fleet.step_latency_percentile(50.0) as f64 / 1e3,
        p99_us: fleet.step_latency_percentile(99.0) as f64 / 1e3,
        peak_mib: stats.peak_resident_bytes as f64 / (1024.0 * 1024.0),
        naive_mib: naive_bytes as f64 / (1024.0 * 1024.0),
        parks: stats.parks,
        unparks: stats.unparks,
        stalled: stats.stalled_unparks,
        yields: stats.yields,
        read_stall_ms: stats.read_stall_ns as f64 / 1e6,
        departed,
        store_peak_mib: store.peak_bytes as f64 / (1024.0 * 1024.0),
        store_physical_mib: store.physical_bytes as f64 / (1024.0 * 1024.0),
    }
}

fn main() {
    let dataset = bench_dataset();
    let tenants = tenants_target();
    println!(
        "fleet_scale: {tenants} tenants x {dataset} samples/epoch \
         (NNTRAINER_FLEET_TENANTS / NNTRAINER_BENCH_DATASET)\n"
    );

    let mut report = BenchReport::new("fleet_scale", dataset);
    let mut table = Table::new(&[
        "store", "tenants", "steps", "p50 us", "p99 us", "steps/s", "peak MiB", "naive MiB",
        "store MiB", "parks", "unparks", "stalled", "stall ms",
    ]);

    for (store, id) in [
        (StoreKind::Host, "fleet/host"),
        (StoreKind::File, "fleet/file"),
        (StoreKind::FileCompressed, "fleet/file-compressed"),
    ] {
        let r = run_case(store, tenants, dataset, 0xF1EE7);
        let steps_per_s = r.steps as f64 / r.wall_s.max(1e-9);
        table.row(vec![
            id.into(),
            r.tenants.to_string(),
            r.steps.to_string(),
            format!("{:.1}", r.p50_us),
            format!("{:.1}", r.p99_us),
            format!("{:.0}", steps_per_s),
            format!("{:.1}", r.peak_mib),
            format!("{:.1}", r.naive_mib),
            format!("{:.1}", r.store_peak_mib),
            r.parks.to_string(),
            r.unparks.to_string(),
            r.stalled.to_string(),
            format!("{:.1}", r.read_stall_ms),
        ]);
        report.push(
            id,
            vec![
                Metric::info("tenants", r.tenants as f64),
                Metric::info("steps", r.steps as f64),
                Metric::lower("p50_step_us", r.p50_us),
                Metric::lower("p99_step_us", r.p99_us),
                Metric::higher("steps_per_s", steps_per_s),
                Metric::lower("peak_resident_mib", r.peak_mib),
                Metric::info("naive_peak_mib", r.naive_mib),
                Metric::lower("store_peak_mib", r.store_peak_mib),
                Metric::info("store_physical_mib", r.store_physical_mib),
                Metric::info("rss_vs_naive_pct", 100.0 * r.peak_mib / r.naive_mib.max(1e-9)),
                Metric::info("parks", r.parks as f64),
                Metric::info("unparks", r.unparks as f64),
                Metric::info("stalled_unparks", r.stalled as f64),
                Metric::info("yields", r.yields as f64),
                Metric::lower("read_stall_ms", r.read_stall_ms),
                Metric::info("departed", r.departed as f64),
            ],
        );
    }

    table.print();
    println!(
        "\npeak MiB = shared pool + state buffers actually allocated; naive MiB = \
         peak-concurrent tenants x one full session pool (exact planner numbers \
         on both sides). The gap is the tentpole: per-user marginal cost collapses \
         from a session to a head-state vector."
    );
    finish(&report);
}
