//! Swap-runtime bench: does the executed OffloadPlan *realize* the
//! advisor's primary peak, and what does proactive swapping cost per
//! iteration? For each application model at a 70% memory target:
//!
//! * `advised`  — the advisor's live-set peak under the plan
//! * `achieved` — the gap-aware planner's actual pool (what training
//!   allocates; the number that must undercut the device budget)
//! * `frag%`    — fragmentation: achieved-over-advised overhead, the
//!   ROADMAP metric the first-fit vs best-fit placement comparison runs
//!   on (placer column: `gapfit` = first-fit, `gapfit-bestfit`)
//! * `tuning`/`lead`/`depth` — swap tuning: `fixed` keeps the global
//!   1-EO lead and depth 2; `calibrated` micro-benchmarks the store and
//!   derives per-entry leads (`lead` = widest) plus the in-flight depth,
//!   then keeps refining both from observed per-entry fetch times
//! * `evict`    — eviction mode: `sync` puts every evicted tensor to the
//!   store on the training thread (the pre-full-duplex baseline);
//!   `async` ships write tickets to the background evict worker and
//!   only blocks at a reclaim barrier
//! * `rstall`   — wall time per iteration the training thread waited on
//!   swap-ins (read barriers + inline fetches)
//! * `wstall`   — wall time per iteration the training thread waited on
//!   eviction writes. The acceptance row: on the file-spill store the
//!   calibrated `async` row's write stall must undercut the
//!   synchronous-eviction baseline row — eviction leaves the critical
//!   path — with bitwise-identical training either way.
//!
//! Run: `cargo bench --bench swap_runtime` (dataset size via
//! `NNTRAINER_BENCH_DATASET`).
//!
//! Machine-readable path: every row also lands in
//! `BENCH_swap_runtime.json` — peak/frag/stall/step-latency are gated
//! against the committed baseline (EXPERIMENTS.md). The runtime also
//! snapshots its counters at every epoch boundary
//! (`Executor::swap_epoch_stats`, the `epochs_marked` metric), so
//! multi-epoch runs keep a per-epoch trajectory, not just totals.

use nntrainer::bench_report::{finish, BenchReport, Metric};
use nntrainer::bench_util::{
    bench_dataset, budget_profile, fmt_mib, nntrainer_profile, train_random_with, Table,
};
use nntrainer::compiler::plan_only;
use nntrainer::graph::NodeDesc;
use nntrainer::metrics::MIB;
use nntrainer::model::zoo;
use nntrainer::planner::PlannerKind;
use nntrainer::runtime::{StoreKind, SwapTuning};

/// How the iteration boundary is handled for persistent (wrap) entries.
#[derive(Clone, Copy, PartialEq, Eq)]
enum Boundary {
    /// No wrap entries planned (`swap_pipeline` off) — the default rows.
    Off,
    /// Wrap entries planned, boundary transfers overlap iterations.
    Pipelined,
    /// Wrap entries planned but `end_iteration` drains them and the
    /// restores run inline at the sweep — the baseline the pipelined
    /// row's `bstall` column must undercut.
    Drained,
}

impl Boundary {
    fn label(self) -> &'static str {
        match self {
            Boundary::Off => "-",
            Boundary::Pipelined => "pipelined",
            Boundary::Drained => "drained",
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn run_case(
    table: &mut Table,
    report: &mut BenchReport,
    name: &str,
    nodes: Vec<NodeDesc>,
    batch: usize,
    store: StoreKind,
    placer: PlannerKind,
    tuning: SwapTuning,
    sync_evict: bool,
    boundary: Boundary,
) -> f64 {
    let base = plan_only(nodes.clone(), &nntrainer_profile(batch)).expect("plan");
    let target = base.pool_bytes * 70 / 100;
    let mut opts = budget_profile(batch, target);
    opts.swap_tuning = tuning;
    opts.swap_store = store;
    opts.planner = placer;
    opts.swap_pipeline = boundary != Boundary::Off;
    let dataset = bench_dataset();
    let (model, secs, iters, _) =
        train_random_with(nodes, &opts, dataset, 1, 0.01, |model| {
            if let Some(sw) = model.exec.swap_mut() {
                if sync_evict {
                    sw.set_sync_evictions(true);
                }
                if boundary == Boundary::Drained {
                    sw.set_boundary_drain(true);
                }
            }
        })
        .expect("train");
    let plan = model.exec.swap_plan().expect("swap plan").clone();
    let stats = model.exec.swap_stats().expect("swap stats");
    let st = model.exec.swap_store_stats().expect("store stats");
    let depth = model.exec.swap_depth().unwrap_or(0);
    let lead = model.exec.swap_max_lead().unwrap_or(0);
    let iters = iters.max(1);
    let achieved = model.peak_pool_bytes();
    let frag = if plan.primary_peak_bytes > 0 {
        (achieved as f64 - plan.primary_peak_bytes as f64) * 100.0
            / plan.primary_peak_bytes as f64
    } else {
        0.0
    };
    let bstall_per_iter = stats.boundary_stall_ms() / iters as f64;
    table.row(vec![
        name.to_string(),
        model.report.planner.to_string(),
        format!("{:?}", store).to_lowercase(),
        format!("{:?}", tuning).to_lowercase(),
        (if sync_evict { "sync" } else { "async" }).into(),
        boundary.label().into(),
        fmt_mib(base.pool_bytes),
        fmt_mib(target),
        fmt_mib(plan.primary_peak_bytes),
        fmt_mib(achieved),
        format!("{frag:.1}"),
        format!("{:.1}", stats.frag_pct()),
        format!("{}", st.rewrites),
        (if plan.fits { "yes" } else { "no" }).into(),
        fmt_mib(plan.swap_bytes_per_iter),
        format!("{lead}"),
        format!("{depth}"),
        format!("{:.3}", stats.read_stall_ms() / iters as f64),
        format!("{:.3}", stats.write_stall_ms() / iters as f64),
        format!("{:.3}", bstall_per_iter),
        format!("{:.1}", stats.sync_fetches as f64 / iters as f64),
        format!("{:.1}", secs * 1e3 / iters as f64),
    ]);
    let epochs_marked = model.exec.swap_epoch_stats().map(|v| v.len()).unwrap_or(0);
    let evict = if sync_evict { "sync" } else { "async" };
    let store_s = format!("{store:?}").to_lowercase();
    let tuning_s = format!("{tuning:?}").to_lowercase();
    // boundary-off rows keep their historical ids (baseline continuity);
    // the wrap rows get their own id namespace
    let id = match boundary {
        Boundary::Off => {
            format!("{name}/{}/{store_s}/{tuning_s}/{evict}", model.report.planner)
        }
        b => format!(
            "{name}/{}/{store_s}/{tuning_s}/{evict}/{}",
            model.report.planner,
            b.label()
        ),
    };
    let mut metrics = vec![
        Metric::lower("advised_mib", plan.primary_peak_bytes as f64 / MIB),
        Metric::lower("achieved_mib", achieved as f64 / MIB),
        Metric::lower("frag_pct", frag),
        Metric::lower("pool_frag_pct", stats.frag_pct()),
        Metric::lower("store_rewrites", st.rewrites as f64),
        Metric::info("store_peak_mib", st.peak_bytes as f64 / MIB),
        Metric::info("store_physical_mib", st.physical_bytes as f64 / MIB),
        Metric::info("fits", if plan.fits { 1.0 } else { 0.0 }),
        Metric::info("swap_mib_per_iter", plan.swap_bytes_per_iter as f64 / MIB),
        Metric::info("lead", lead as f64),
        Metric::info("depth", depth as f64),
        Metric::lower("rstall_ms_per_iter", stats.read_stall_ms() / iters as f64),
        Metric::lower("wstall_ms_per_iter", stats.write_stall_ms() / iters as f64),
        Metric::info("sync_fetches_per_iter", stats.sync_fetches as f64 / iters as f64),
        Metric::lower("step_latency_ms", secs * 1e3 / iters as f64),
        Metric::higher("iters_per_s", iters as f64 / secs.max(1e-9)),
        Metric::info("epochs_marked", epochs_marked as f64),
    ];
    if boundary != Boundary::Off {
        // gated: the boundary-bubble cost per iteration. Only wrap rows
        // carry it — on boundary-off rows it is structurally zero.
        metrics.push(Metric::lower("boundary_stall_ms_per_iter", bstall_per_iter));
        metrics.push(Metric::info(
            "wrap_entries",
            model.exec.swap_n_wrap_entries().unwrap_or(0) as f64,
        ));
    }
    report.push(&id, metrics);
    bstall_per_iter
}

fn main() {
    println!("\n== Proactive swap runtime: realized peak + per-iteration cost (70% target) ==\n");
    let mut table = Table::new(&[
        "model",
        "placer",
        "store",
        "tuning",
        "evict",
        "boundary",
        "unswapped",
        "target",
        "advised",
        "achieved",
        "frag%",
        "pool frag%",
        "rewrites",
        "fits",
        "swap MiB/it",
        "lead",
        "depth",
        "rstall ms/it",
        "wstall ms/it",
        "bstall ms/it",
        "sync/it",
        "iter ms",
    ]);
    let mut report = BenchReport::new("swap_runtime", bench_dataset());
    for placer in [PlannerKind::Sorting, PlannerKind::BestFit, PlannerKind::Skyline] {
        run_case(&mut table, &mut report, "LeNet-5", zoo::lenet5(), 32, StoreKind::Host, placer, SwapTuning::Fixed, false, Boundary::Off);
        run_case(&mut table, &mut report, "Model A (Conv)", zoo::model_a_conv(), 16, StoreKind::Host, placer, SwapTuning::Fixed, false, Boundary::Off);
        run_case(&mut table, &mut report, "Model B (Conv)", zoo::model_b_conv(), 16, StoreKind::Host, placer, SwapTuning::Fixed, false, Boundary::Off);
    }
    // the acceptance comparison: fixed vs calibrated tuning and sync vs
    // full-duplex (async) eviction on the file-spill store — the slow
    // path where fixed constants stall and synchronous writes sit on
    // the training thread
    for tuning in [SwapTuning::Fixed, SwapTuning::Calibrated] {
        for sync_evict in [true, false] {
            run_case(&mut table, &mut report, "LeNet-5", zoo::lenet5(), 32, StoreKind::File, PlannerKind::Sorting, tuning, sync_evict, Boundary::Off);
        }
    }
    for sync_evict in [true, false] {
        run_case(&mut table, &mut report, "Model A (Conv)", zoo::model_a_conv(), 16, StoreKind::File, PlannerKind::Sorting, SwapTuning::Calibrated, sync_evict, Boundary::Off);
    }
    run_case(&mut table, &mut report, "LeNet-5", zoo::lenet5(), 32, StoreKind::Host, PlannerKind::Sorting, SwapTuning::Calibrated, false, Boundary::Off);
    // the compressed spill store: fewer physical bytes per put (the
    // byte-shuffled RLE codec) at encode cost on the workers — run with
    // the skyline placer too so the full new stack has a perf row
    for placer in [PlannerKind::Sorting, PlannerKind::Skyline] {
        run_case(&mut table, &mut report, "LeNet-5", zoo::lenet5(), 32, StoreKind::FileCompressed, placer, SwapTuning::Calibrated, false, Boundary::Off);
    }
    // cross-iteration pipelining: the same plan with wrap entries,
    // boundary transfers either overlapped into the neighbouring
    // iterations (pipelined) or drained-and-restored inline at
    // `end_iteration` (the bubble baseline). Under injected store
    // latency (NNTRAINER_STORE_DELAY_US) the pipelined row's bstall
    // must sit strictly below the drained row's.
    let drained = run_case(&mut table, &mut report, "LeNet-5", zoo::lenet5(), 32, StoreKind::File, PlannerKind::Sorting, SwapTuning::Calibrated, false, Boundary::Drained);
    let pipelined = run_case(&mut table, &mut report, "LeNet-5", zoo::lenet5(), 32, StoreKind::File, PlannerKind::Sorting, SwapTuning::Calibrated, false, Boundary::Pipelined);
    table.print();
    println!(
        "\nboundary bubble: drained {drained:.3} ms/it vs pipelined {pipelined:.3} ms/it \
         (bstall; run with NNTRAINER_STORE_DELAY_US to magnify on a fast disk)"
    );
    println!(
        "\nachieved = gap-aware planner pool (what training actually allocates); \
         advised = live-set bound under the plan; frag% = achieved overhead \
         over the advised bound (first-fit `gapfit` vs `gapfit-bestfit` placement).\n\
         tuning: fixed = global 1-EO lead / depth 2; calibrated = per-entry leads \
         and depth derived from measured store bandwidth, then re-derived every \
         iteration from observed per-entry fetch times (lead column = widest lead \
         in effect, depth = in-flight fetches after adaptation).\n\
         evict: sync = store puts on the training thread (baseline); async = \
         background write tickets with reclaim barriers (full-duplex engine).\n\
         rstall = training-thread wait on swap-ins; wstall = training-thread wait \
         on eviction writes — the number async eviction takes off the critical \
         path; the rest of the traffic is hidden by the background workers.\n\
         pool frag% = internal fragmentation of the placed arena (bytes no \
         tensor ever occupies); rewrites = store-slot overwrites (the wear \
         number slot rotation spreads; see store_peak/physical in the JSON).\n\
         boundary: `-` = no wrap entries; pipelined/drained rows additionally \
         spill persistent tensors across the iteration boundary, and bstall = \
         training-thread wait attributable to those boundary restores — the \
         drain bubble pipelining removes."
    );
    finish(&report);
}
