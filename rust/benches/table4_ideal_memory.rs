//! Table 4: ideal (minimum) training memory of the ten component test
//! cases at batch 64, from the §3 analysis — computed here by the
//! analytic live-set bound over the Algorithm-1 execution orders.
//!
//! Paper values are reprinted for comparison; dims of Models A–D were
//! recovered from those values (DESIGN.md). Matching within ~10 % means
//! the lifespan analysis agrees with the paper's hand calculation.

use nntrainer::bench_util::{fmt_kib, nntrainer_profile, plan, Table};
use nntrainer::model::zoo;

fn main() {
    println!("\n== Table 4: ideal memory of component test cases (batch 64) ==\n");
    let mut table = Table::new(&["case", "ideal KiB (ours)", "ideal KiB (paper)", "ratio"]);
    let opts = nntrainer_profile(64);
    for (name, nodes, paper_kib) in zoo::table4_cases() {
        let rep = plan(nodes, &opts).expect(name);
        let ours = rep.ideal_bytes;
        let ratio = ours as f64 / 1024.0 / paper_kib;
        table.row(vec![
            name.to_string(),
            fmt_kib(ours),
            format!("{paper_kib:.0}"),
            format!("{ratio:.3}"),
        ]);
    }
    table.print();
    println!(
        "\nratio ~1.0 = our Algorithm-1 lifespan analysis reproduces the paper's §3 hand\n\
         calculation; deviations come from biasless-vs-bias choices and the im2col buffer\n\
         (which the paper counts for NNTrainer's Conv2D but not in `ideal`)."
    );
}
