//! Plan validation: no two tensors whose live EO intervals intersect may
//! occupy overlapping pool regions. Run after every plan (cheap —
//! hundreds of tensors) and hammered by the property tests.

use std::collections::HashSet;

use crate::error::{Error, Result};
use crate::planner::gapfit::intervals_overlap;
use crate::planner::offload::{live_intervals, OffloadPlan};
use crate::tensor::{TensorId, TensorTable};

/// Shared structural check: the tensor has a region, it covers its dims,
/// and it lies inside the pool. Returns the region.
fn checked_region(
    s: &crate::tensor::TensorSpec,
    pool_len: usize,
) -> Result<crate::tensor::Region> {
    let r = s
        .region
        .ok_or_else(|| Error::planner(format!("tensor `{}` not assigned a region", s.name)))?;
    if r.len < s.dim.len() {
        return Err(Error::planner(format!(
            "tensor `{}` region too small: {} < {}",
            s.name,
            r.len,
            s.dim.len()
        )));
    }
    if r.end() > pool_len {
        return Err(Error::planner(format!(
            "tensor `{}` region {:?} exceeds pool {}",
            s.name, r, pool_len
        )));
    }
    Ok(r)
}

/// Check the planner's core invariant. Also verifies every allocatable
/// tensor received a region that fits its dims inside `pool_len`.
pub fn validate_plan(table: &TensorTable, pool_len: usize) -> Result<()> {
    let mut live: Vec<(u32, u32, usize, usize, &str)> = Vec::new(); // (min, max, off, end, name)
    for s in table.iter() {
        if s.merged_into.is_some() || s.eos.is_empty() {
            continue;
        }
        let r = checked_region(s, pool_len)?;
        live.push((s.min_eo().unwrap(), s.max_eo().unwrap(), r.offset, r.end(), &s.name));
    }
    for i in 0..live.len() {
        for j in i + 1..live.len() {
            let a = &live[i];
            let b = &live[j];
            let time_overlap = a.0 <= b.1 && b.0 <= a.1;
            let space_overlap = a.2 < b.3 && b.2 < a.3;
            if time_overlap && space_overlap {
                return Err(Error::planner(format!(
                    "live tensors overlap: `{}` [{},{}]@{}..{} vs `{}` [{},{}]@{}..{}",
                    a.4, a.0, a.1, a.2, a.3, b.4, b.0, b.1, b.2, b.3
                )));
            }
        }
    }
    Ok(())
}

/// Gap-aware variant of [`validate_plan`]: under an [`OffloadPlan`], an
/// offloaded tensor only occupies its region during its live segments
/// (front-widened by each gap's own prefetch lead), so overlap is
/// checked against interval *lists* rather than one `[min, max]` span
/// per tensor.
pub fn validate_gap_plan(
    table: &TensorTable,
    plan: &OffloadPlan,
    pool_len: usize,
) -> Result<()> {
    let offloaded: HashSet<TensorId> = plan.entries.iter().map(|e| e.tensor).collect();
    let leads = plan.lead_map();
    // Boundary (wrap) entries: the fetch window wraps the schedule end,
    // so the geometry constraints differ from in-iteration gaps — the
    // restore must fit before the first real access (due ≥ 0) and the
    // eviction-write reservation must not run past the schedule.
    let max_eo = table.iter().filter_map(|s| s.max_eo()).max().unwrap_or(0);
    for e in plan.entries.iter().filter(|e| e.wrap) {
        if e.prefetch_before < 1 || e.lead > e.prefetch_before {
            return Err(Error::planner(format!(
                "wrap entry `{}`: lead {} does not fit before first access EO {}",
                e.name, e.lead, e.prefetch_before
            )));
        }
        if e.prefetch_before > e.evict_after {
            return Err(Error::planner(format!(
                "wrap entry `{}`: prefetch_before {} > evict_after {} (gap must wrap)",
                e.name, e.prefetch_before, e.evict_after
            )));
        }
        if e.evict_after.saturating_add(e.write_lead) > max_eo {
            return Err(Error::planner(format!(
                "wrap entry `{}`: write reservation {}+{} runs past schedule end {}",
                e.name, e.evict_after, e.write_lead, max_eo
            )));
        }
    }
    let mut live: Vec<(Vec<(u32, u32)>, usize, usize, &str)> = Vec::new();
    for s in table.iter() {
        if s.merged_into.is_some() || s.eos.is_empty() {
            continue;
        }
        let r = checked_region(s, pool_len)?;
        live.push((
            live_intervals(s, offloaded.contains(&s.id).then_some(&leads)),
            r.offset,
            r.end(),
            &s.name,
        ));
    }
    for i in 0..live.len() {
        for j in i + 1..live.len() {
            let a = &live[i];
            let b = &live[j];
            let space_overlap = a.1 < b.3 && b.1 < a.3;
            if space_overlap && intervals_overlap(&a.0, &b.0) {
                return Err(Error::planner(format!(
                    "live tensors overlap under offload plan: `{}` {:?}@{}..{} vs `{}` {:?}@{}..{}",
                    a.3, a.0, a.1, a.2, b.3, b.0, b.1, b.2
                )));
            }
        }
    }
    Ok(())
}

/// Merged tensors must resolve to a root with a region covering them.
pub fn validate_merges(table: &TensorTable) -> Result<()> {
    for s in table.iter() {
        if s.merged_into.is_none() || s.eos.is_empty() {
            continue;
        }
        let root = table.resolve(s.id);
        let rs = table.get(root);
        if rs.merged_into.is_some() {
            return Err(Error::planner(format!(
                "merge chain of `{}` ends in merged tensor `{}`",
                s.name, rs.name
            )));
        }
        if let Some(r) = rs.region {
            if r.len < s.dim.len() {
                return Err(Error::planner(format!(
                    "view `{}` larger than its root `{}`",
                    s.name, rs.name
                )));
            }
        }
    }
    Ok(())
}
