//! Dynamic off-loading advisor — the paper's stated future work:
//! "dynamic off-loading using secondary memory … is expected to be
//! highly efficient because NNTrainer can predict and decide when a
//! buffer is accessed; thus, we can swap in and out proactively in
//! background."
//!
//! The prediction is exactly the Algorithm-1 execution orders: a tensor
//! with an *idle gap* between consecutive EOs (the classic case: an
//! activation written in forward at EO `i` and next read at its
//! compute-gradient EO `3N−2(i+1)`) can live in secondary memory during
//! the gap. This module decides *which* tensors to swap to fit a primary
//! budget, and reports the resulting peak and the per-iteration swap
//! traffic the background copies would cost.

use std::collections::HashMap;

use crate::tensor::{TensorId, TensorRole, TensorSpec, TensorTable};

/// Default number of EOs before its next use that a prefetched tensor
/// must be resident again (`SwapTuning::Fixed`). Under
/// `SwapTuning::Calibrated` the calibrator widens each entry's lead
/// individually (`runtime/calibrate.rs`) until the estimated fetch time
/// fits in the compute time available before the use EO; the gap-aware
/// planner reserves each region from its entry's own lead point, so the
/// planner and the runtime never disagree.
pub const PREFETCH_LEAD: u32 = 1;

/// Default number of background prefetches kept in flight (double
/// buffering). The calibrator raises it when measured store speed says
/// the pipeline cannot keep up at depth 2.
pub const PREFETCH_DEPTH: usize = 2;

/// Default number of EOs after `evict_after` that an evicted region
/// stays reserved while the background write ticket drains. Zero keeps
/// fixed-tuning pool layouts identical to the synchronous-eviction era:
/// a gap tenant may be placed right after the eviction EO, and the swap
/// runtime's reclaim barrier blocks (correctly, counted as write stall)
/// if the copy has not landed by the tenant's first use. Calibrated
/// tuning widens the reservation (`runtime/calibrate.rs`) so the write
/// usually lands inside it and the barrier never fires.
pub const WRITE_LEAD: u32 = 0;

/// One swap decision: evict after `evict_after`, prefetch back before
/// `prefetch_before` (both EOs; the gap in between is spent in secondary
/// memory). `lead` is how many EOs before `prefetch_before` the region
/// is reserved again and the prefetch barrier completes — the per-entry
/// value the calibrator derives from store bandwidth vs. compute time
/// (fixed tuning leaves it at [`PREFETCH_LEAD`]). `write_lead` mirrors
/// it on the eviction side: how many EOs past `evict_after` the region
/// stays reserved for the in-flight background write (fixed tuning:
/// [`WRITE_LEAD`]). The two may never meet: `lead + write_lead` must be
/// strictly less than the gap, which the swap runtime rejects at
/// construction.
#[derive(Clone, Debug)]
pub struct OffloadEntry {
    pub tensor: TensorId,
    pub name: String,
    pub bytes: usize,
    pub evict_after: u32,
    pub prefetch_before: u32,
    pub lead: u32,
    pub write_lead: u32,
    /// Boundary (cross-iteration) entry: the idle gap wraps the schedule
    /// end — evicted late in iteration N (`evict_after`), restored early
    /// in iteration N+1 (`prefetch_before` ≤ `evict_after`). The swap
    /// runtime carries the eviction/prefetch state across `end_iteration`
    /// instead of draining it, which is what lets the fetch worker pull
    /// iteration N+1's earliest-due entries while N's tail writes land.
    pub wrap: bool,
}

/// Per-gap transfer leads — the lookup shared by the advisor's peak
/// accounting, the gap-aware planner and the plan validator, so all
/// three reserve exactly the intervals the swap runtime will occupy.
/// Read leads are keyed by `(tensor, segment-start EO)` (the gap's
/// `prefetch_before`); write leads by `(tensor, segment-end EO)` (the
/// gap's `evict_after`).
#[derive(Clone, Debug, Default)]
pub struct LeadMap {
    read: HashMap<(TensorId, u32), u32>,
    write: HashMap<(TensorId, u32), u32>,
    /// Boundary entries: tensor → (prefetch_before, evict_after, lead,
    /// write_lead). A wrap tensor's effective fetch window extends into
    /// the previous iteration, so its residency is the single interval
    /// `[prefetch_before − lead, evict_after + write_lead]` — never the
    /// segment gaps of its recorded EOs, which for persistent tensors are
    /// only the conservative `{0, eo_apply}` bracket.
    boundary: HashMap<TensorId, (u32, u32, u32, u32)>,
}

impl LeadMap {
    /// Prefetch lead for the segment of `tensor` starting at `seg_start`
    /// (a segment without an entry keeps the default lead).
    pub fn lead(&self, tensor: TensorId, seg_start: u32) -> u32 {
        self.read.get(&(tensor, seg_start)).copied().unwrap_or(PREFETCH_LEAD)
    }

    /// Eviction-write lead for the segment of `tensor` ending at
    /// `seg_end`.
    pub fn write_lead(&self, tensor: TensorId, seg_end: u32) -> u32 {
        self.write.get(&(tensor, seg_end)).copied().unwrap_or(WRITE_LEAD)
    }

    /// Boundary (wrap) geometry of `tensor`, if it has a cross-iteration
    /// entry: `(prefetch_before, evict_after, lead, write_lead)`.
    pub fn boundary(&self, tensor: TensorId) -> Option<(u32, u32, u32, u32)> {
        self.boundary.get(&tensor).copied()
    }
}

/// Advisor output.
#[derive(Clone, Debug, Default)]
pub struct OffloadPlan {
    pub entries: Vec<OffloadEntry>,
    /// Peak primary-memory bytes *after* applying the plan (live-set
    /// bound with offloaded gaps excluded).
    pub primary_peak_bytes: usize,
    /// Bytes copied to+from secondary memory per training iteration.
    pub swap_bytes_per_iter: usize,
    /// Whether the requested budget was met.
    pub fits: bool,
    /// Initial in-flight prefetch depth for the swap runtime. Fixed
    /// tuning uses the double-buffering default; the calibrator derives
    /// it from store-vs-compute speed (`runtime/calibrate.rs`).
    pub prefetch_depth: usize,
}

impl OffloadPlan {
    /// Per-gap lead lookup for planners/validators.
    pub fn lead_map(&self) -> LeadMap {
        LeadMap {
            read: self
                .entries
                .iter()
                .map(|e| ((e.tensor, e.prefetch_before), e.lead))
                .collect(),
            write: self
                .entries
                .iter()
                .map(|e| ((e.tensor, e.evict_after), e.write_lead))
                .collect(),
            boundary: self
                .entries
                .iter()
                .filter(|e| e.wrap)
                .map(|e| (e.tensor, (e.prefetch_before, e.evict_after, e.lead, e.write_lead)))
                .collect(),
        }
    }

    /// Largest per-entry lead (diagnostics, benches).
    pub fn max_lead(&self) -> u32 {
        self.entries.iter().map(|e| e.lead).max().unwrap_or(0)
    }
}

/// Live segments of a tensor: maximal runs of consecutive EOs with gaps
/// of at most 1 between them. A tensor with one segment never idles.
pub fn segments(eos: &[u32]) -> Vec<(u32, u32)> {
    let mut segs = Vec::new();
    let mut start = match eos.first() {
        Some(&e) => e,
        None => return segs,
    };
    let mut prev = start;
    for &e in &eos[1..] {
        if e > prev + 1 {
            segs.push((start, prev));
            start = e;
        }
        prev = e;
    }
    segs.push((start, prev));
    segs
}

/// EO intervals (inclusive) during which a tensor occupies its primary
/// region. Not offloaded (`leads = None`): one interval spanning its
/// whole life. Offloaded: one interval per live segment; every segment
/// except the first is widened at the front by its gap's *read* lead
/// from the [`LeadMap`] (the prefetch copy lands before the segment's
/// first use — the first segment instead *starts* with the tensor's
/// first write, so widening it would grow the footprint beyond the
/// unswapped life and break peak monotonicity), and every segment
/// except the last is extended at the back by its gap's *write* lead
/// (the eviction copy drains in the background while the region stays
/// reserved). The two extensions never meet inside a gap: a lead pair
/// that swallowed the gap would merge the intervals and the swap
/// runtime rejects such entries outright; for arbitrary maps the write
/// extension is clipped below the next use and the front widening is
/// floored above the previous extended end. This is the liveness model
/// shared by the advisor's peak accounting, the gap-aware planner and
/// the plan validator.
pub fn live_intervals(s: &TensorSpec, leads: Option<&LeadMap>) -> Vec<(u32, u32)> {
    match leads {
        None => match (s.min_eo(), s.max_eo()) {
            (Some(a), Some(z)) => vec![(a, z)],
            _ => vec![],
        },
        // Boundary (wrap) tensor: resident for the single interval from
        // its reacquire point through its eviction-write drain. The
        // recorded EOs are the `{0, eo_apply}` bracket — splitting on
        // their gap would free EOs where unrecorded real accesses live,
        // so the wrap geometry overrides segmentation entirely.
        Some(leads) if leads.boundary(s.id).is_some() => {
            let (pb, ea, lead, w) = leads.boundary(s.id).unwrap();
            let start = pb.saturating_sub(lead);
            let end = ea.saturating_add(w);
            if start == 0 {
                vec![(0, end)]
            } else {
                // The extra point at EO 0 is the tensor's *init
                // residency*: every persistent tensor's bytes are
                // written at t0, before the swap runtime primes it out
                // (`SwapExec::begin_iteration`), so two wrap tensors
                // may never time-share an address range — the second
                // init would stomp the first. Sharing this point keeps
                // every placer from overlapping them and charges the
                // init-time live set to the peak truthfully; the head
                // window open to other tenants is `[1, start)`.
                vec![(0, 0), (start, end)]
            }
        }
        Some(leads) => {
            let segs = segments(&s.eos);
            let last = segs.len().saturating_sub(1);
            let mut out = Vec::with_capacity(segs.len());
            let mut prev_end = 0u32;
            for (k, &(a, z)) in segs.iter().enumerate() {
                let end = if k == last {
                    z
                } else {
                    let w = leads.write_lead(s.id, z);
                    z.saturating_add(w).min(segs[k + 1].0 - 1)
                };
                let start = if k == 0 {
                    a
                } else {
                    let lead = leads.lead(s.id, a);
                    a.saturating_sub(lead).max(prev_end + 1)
                };
                out.push((start, end));
                prev_end = end;
            }
            out
        }
    }
}

/// Peak live bytes when `offloaded` tensors only occupy primary memory
/// during their live segments (front-widened by their gap leads).
fn peak_with(table: &TensorTable, offloaded: &[bool], leads: &LeadMap) -> usize {
    let mut events: Vec<(u32, i64)> = Vec::new();
    for s in table.iter() {
        if s.merged_into.is_some() || s.eos.is_empty() {
            continue;
        }
        let b = s.dim.bytes() as i64;
        for (a, z) in live_intervals(s, offloaded[s.id].then_some(leads)) {
            events.push((a, b));
            events.push((z + 1, -b));
        }
    }
    events.sort();
    let mut cur = 0i64;
    let mut peak = 0i64;
    for (_, d) in events {
        cur += d;
        peak = peak.max(cur);
    }
    peak as usize
}

/// Recompute the plan's live-set peak after per-entry leads changed
/// (wider leads hold residency longer, so the peak can only grow).
/// Returns the new peak; callers refresh `primary_peak_bytes`/`fits`.
pub fn peak_of_plan(table: &TensorTable, plan: &OffloadPlan) -> usize {
    let mut offloaded = vec![false; table.len()];
    for e in &plan.entries {
        offloaded[e.tensor] = true;
    }
    peak_with(table, &offloaded, &plan.lead_map())
}

/// Greedy advisor: offload the largest idle-gap tensors first until the
/// budget is met (or no candidates remain). Weights and optimizer state
/// are never offloaded mid-iteration (they have no idle gap in training);
/// placeholders are skipped (externally bound).
pub fn advise(table: &TensorTable, budget_bytes: usize) -> OffloadPlan {
    let n = table.len();
    let mut offloaded = vec![false; n];
    // candidates: (idle-gap weight, id)
    let mut cands: Vec<(usize, TensorId)> = table
        .iter()
        .filter(|s| s.merged_into.is_none() && s.eos.len() >= 2 && !s.is_placeholder())
        .filter(|s| {
            matches!(
                s.role,
                TensorRole::Activation | TensorRole::Temp | TensorRole::Derivative
            )
        })
        // Whole-training tensors (e.g. batch-norm running stats) record
        // only {0, apply} as EOs — their real per-step accesses are not in
        // the set, so their apparent idle gap is an illusion: never swap.
        .filter(|s| !s.lifespan.is_max())
        .filter_map(|s| {
            let segs = segments(&s.eos);
            if segs.len() < 2 {
                return None;
            }
            // total idle EOs × bytes = how much pressure offloading relieves
            let idle: u32 = segs.windows(2).map(|w| w[1].0 - w[0].1 - 1).sum();
            Some(((idle as usize) * s.dim.bytes(), s.id))
        })
        .collect();
    cands.sort_by(|a, b| b.0.cmp(&a.0));

    let default_leads = LeadMap::default();
    let mut peak = peak_with(table, &offloaded, &default_leads);
    for (_, id) in cands {
        if peak <= budget_bytes {
            break;
        }
        offloaded[id] = true;
        peak = peak_with(table, &offloaded, &default_leads);
    }

    let mut entries = Vec::new();
    let mut swap = 0usize;
    for s in table.iter() {
        if s.merged_into.is_none() && !s.eos.is_empty() && offloaded[s.id] {
            let segs = segments(&s.eos);
            for w in segs.windows(2) {
                entries.push(OffloadEntry {
                    tensor: s.id,
                    name: s.name.clone(),
                    bytes: s.dim.bytes(),
                    evict_after: w[0].1,
                    prefetch_before: w[1].0,
                    lead: PREFETCH_LEAD,
                    write_lead: WRITE_LEAD,
                    wrap: false,
                });
                swap += 2 * s.dim.bytes(); // out + back in, per iteration
            }
        }
    }
    OffloadPlan {
        entries,
        primary_peak_bytes: peak,
        swap_bytes_per_iter: swap,
        fits: peak <= budget_bytes,
        prefetch_depth: PREFETCH_DEPTH,
    }
}

/// Cross-iteration (boundary) offload pass: spill persistent tensors —
/// weights and optimizer state — across the iteration boundary. Eligible
/// tensors carry a `boundary_window` annotation (their true first/last
/// access EOs under per-layer apply); the wrap entry evicts after the
/// last real access and restores before the first, so the region is free
/// through the schedule tail, the boundary, and the next iteration's
/// head. Every wrap reservation — the init point at EO 0 plus
/// `[first − lead, last]` (see [`live_intervals`]) — is a subset of the
/// unswapped `[0, eo_apply]` life, so adding entries can only lower the
/// peak; all eligible tensors are offloaded (the point of the pipeline
/// is to stream trainable state through the store, and partial spills
/// would make plan shape depend on budget slack). Callers gate this on
/// per-layer apply being in effect — under deferred apply the recorded
/// bracket is the truth and there is no boundary window.
pub fn advise_boundary(table: &TensorTable, plan: &mut OffloadPlan, budget_bytes: usize) {
    let mut added = false;
    for s in table.iter() {
        if s.merged_into.is_some() || s.is_placeholder() || s.eos.is_empty() {
            continue;
        }
        if !matches!(s.role, TensorRole::Weight | TensorRole::OptState) {
            continue;
        }
        let Some((first, last)) = s.boundary_window else { continue };
        // lead ≥ 1 must fit before the first access; a first access at EO
        // 0 leaves no head window to restore into.
        if first < 1 || first > last || s.dim.bytes() == 0 {
            continue;
        }
        plan.entries.push(OffloadEntry {
            tensor: s.id,
            name: s.name.clone(),
            bytes: s.dim.bytes(),
            evict_after: last,
            prefetch_before: first,
            lead: PREFETCH_LEAD.min(first),
            write_lead: WRITE_LEAD,
            wrap: true,
        });
        plan.swap_bytes_per_iter += 2 * s.dim.bytes();
        added = true;
    }
    if added {
        plan.primary_peak_bytes = peak_of_plan(table, plan);
        plan.fits = plan.primary_peak_bytes <= budget_bytes;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{CreateMode, Initializer, Lifespan, TensorDim, TensorRole, TensorTable};

    fn table_with(entries: &[(&str, usize, &[u32], TensorRole)]) -> TensorTable {
        let mut t = TensorTable::new();
        for (name, len, eos, role) in entries {
            let id = t
                .request(*name, TensorDim::vec(1, *len), *role, CreateMode::Create, Initializer::None)
                .unwrap();
            for &e in *eos {
                t.add_eo(id, e, Lifespan::FORWARD);
            }
        }
        t.finish_orders();
        t
    }

    #[test]
    fn segments_split_on_gaps() {
        assert_eq!(segments(&[0, 1, 2, 7, 8]), vec![(0, 2), (7, 8)]);
        assert_eq!(segments(&[3]), vec![(3, 3)]);
        assert_eq!(segments(&[0, 9]), vec![(0, 0), (9, 9)]);
    }

    #[test]
    fn live_intervals_widen_both_ends() {
        let t = table_with(&[("a", 8, &[0, 1, 10, 11, 20], TensorRole::Activation)]);
        let s = t.get(0);
        // default leads: read 1, write 0 — the synchronous-era intervals
        let plan = OffloadPlan {
            entries: vec![
                OffloadEntry {
                    tensor: 0,
                    name: "a".into(),
                    bytes: 32,
                    evict_after: 1,
                    prefetch_before: 10,
                    lead: 3,
                    write_lead: 2,
                    wrap: false,
                },
                OffloadEntry {
                    tensor: 0,
                    name: "a".into(),
                    bytes: 32,
                    evict_after: 11,
                    prefetch_before: 20,
                    lead: PREFETCH_LEAD,
                    write_lead: WRITE_LEAD,
                    wrap: false,
                },
            ],
            ..Default::default()
        };
        let leads = plan.lead_map();
        // first segment end-extended by write lead 2, second segment
        // front-widened by read lead 3 and end-extended by the default
        // write lead 0, last segment front-widened by the default read
        // lead 1
        assert_eq!(
            live_intervals(s, Some(&leads)),
            vec![(0, 3), (7, 11), (19, 20)]
        );
        // a write lead that would reach the next use is clipped below it
        let mut wide = plan.clone();
        wide.entries[0].write_lead = 100;
        assert_eq!(live_intervals(s, Some(&wide.lead_map()))[0], (0, 9));
    }

    #[test]
    fn offload_relieves_pressure() {
        // two big activations idle across the middle; a weight pinned
        let t = table_with(&[
            ("a0", 1000, &[0, 10], TensorRole::Activation),
            ("a1", 1000, &[2, 8], TensorRole::Activation),
            ("w", 100, &[0, 12], TensorRole::Weight),
        ]);
        let no_offload = advise(&t, usize::MAX);
        assert!(no_offload.entries.is_empty());
        assert_eq!(no_offload.primary_peak_bytes, (2000 + 100) * 4);

        // budget forces both activations out during their idle gaps
        let plan = advise(&t, 1400 * 4);
        assert!(plan.fits, "{plan:?}");
        // greedy stops as soon as the budget fits — offloading a0 alone
        // (the larger idle-gap pressure) is enough here
        assert_eq!(plan.entries.len(), 1);
        assert_eq!(plan.swap_bytes_per_iter, 2 * 1000 * 4);
        assert!(plan.primary_peak_bytes <= 1400 * 4);
    }

    #[test]
    fn weights_never_offloaded() {
        let t = table_with(&[
            ("w", 5000, &[0, 20], TensorRole::Weight),
            ("a", 10, &[1, 19], TensorRole::Activation),
        ]);
        let plan = advise(&t, 1);
        assert!(!plan.fits);
        assert!(plan.entries.iter().all(|e| e.name != "w"));
    }

    #[test]
    fn real_model_offload() {
        use crate::compiler::realizer::realize_all;
        use crate::exec::{init_graph, InitOptions};
        use crate::graph::{Graph, NodeDesc};
        use crate::layers::{builtin_factories, Props};
        // conv stack: activations dominate weights, so idle-gap
        // offloading has real leverage
        let nodes = vec![
            NodeDesc::new("in", "input", Props::from_pairs([("input_shape", "4:16:16")])),
            NodeDesc::new("c0", "conv2d", Props::from_pairs([("filters", "16"), ("kernel_size", "3"), ("padding", "same"), ("activation", "relu")])),
            NodeDesc::new("c1", "conv2d", Props::from_pairs([("filters", "16"), ("kernel_size", "3"), ("padding", "same"), ("activation", "relu")])),
            NodeDesc::new("c2", "conv2d", Props::from_pairs([("filters", "16"), ("kernel_size", "3"), ("padding", "same"), ("activation", "relu")])),
            NodeDesc::new("flat", "flatten", Props::new()),
            NodeDesc::new("fc", "fully_connected", Props::from_pairs([("unit", "10")])),
            NodeDesc::new("loss", "mse", Props::new()),
        ];
        let graph = Graph::wire(realize_all(nodes).unwrap()).unwrap();
        let ig = init_graph(&graph, &builtin_factories(), &InitOptions { batch: 32, ..Default::default() }).unwrap();
        let full = advise(&ig.table, usize::MAX).primary_peak_bytes;
        // ask for 75% of the unconstrained peak — activations idling
        // between forward and backward cover it (the floor below that is
        // weights + gradients, which never idle within an iteration)
        let plan = advise(&ig.table, full * 75 / 100);
        assert!(plan.fits, "peak {} target {}", plan.primary_peak_bytes, full * 75 / 100);
        assert!(!plan.entries.is_empty());
        // every entry's gap is genuinely idle (evict < prefetch)
        for e in &plan.entries {
            assert!(e.evict_after < e.prefetch_before);
        }
    }
}
