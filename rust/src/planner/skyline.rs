//! Plain (non-gap) skyline planner: the segment-tree placer applied to
//! whole `[min EO, max EO]` live intervals, with the same portfolio
//! fallback the gap tier uses — so `PlannerKind::Skyline` works with or
//! without a memory budget and never plans a larger pool than the
//! best-fit planner on the same table.

use crate::error::Result;
use crate::tensor::TensorTable;

use super::gapfit::GapSkylinePlanner;
use super::offload::OffloadPlan;
use super::Planner;

pub struct SkylinePlanner;

impl Planner for SkylinePlanner {
    fn name(&self) -> &'static str {
        "skyline"
    }

    fn plan(&self, table: &mut TensorTable) -> Result<usize> {
        // an empty offload plan degrades the gap machinery to whole
        // [min, max] intervals (pinned by gapfit's
        // `no_offloads_behaves_like_plain_planner`)
        let plan = OffloadPlan::default();
        GapSkylinePlanner { plan: &plan }.plan(table)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::planner::validate::validate_plan;
    use crate::tensor::{CreateMode, Initializer, Lifespan, TensorDim, TensorRole, TensorTable};

    #[test]
    fn plans_valid_layout_and_reuses_dead_slots() {
        let mut t = TensorTable::new();
        for (name, len, eos) in
            [("a", 10usize, vec![0u32, 3]), ("b", 10, vec![4, 6]), ("w", 4, vec![0, 6])]
        {
            let id = t
                .request(
                    name,
                    TensorDim::vec(1, len),
                    TensorRole::Activation,
                    CreateMode::Create,
                    Initializer::None,
                )
                .unwrap();
            for e in eos {
                t.add_eo(id, e, Lifespan::FORWARD);
            }
        }
        t.finish_orders();
        let pool_len = SkylinePlanner.plan(&mut t).unwrap();
        assert_eq!(pool_len, 14, "b reuses a's slot; w pinned alongside");
        validate_plan(&t, pool_len).unwrap();
    }
}
