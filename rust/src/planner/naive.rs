//! Naive planner: every tensor gets its own allocation, no reuse.
//!
//! This is the baseline that models conventional frameworks' allocation
//! policy for the Fig 9 / Fig 11 / Fig 12 comparisons (see DESIGN.md
//! §Substitutions): TensorFlow/PyTorch keep all activations, derivatives
//! and gradients alive for the whole iteration, so their peak is the sum
//! of everything.

use crate::error::Result;
use crate::tensor::{Region, TensorTable};

use super::{allocatable, Planner};

pub struct NaivePlanner;

impl Planner for NaivePlanner {
    fn name(&self) -> &'static str {
        "naive"
    }

    fn plan(&self, table: &mut TensorTable) -> Result<usize> {
        let ids = allocatable(table);
        let mut off = 0usize;
        for id in ids {
            let len = table.get(id).dim.len();
            table.get_mut(id).region = Some(Region { offset: off, len });
            off += len;
        }
        Ok(off)
    }
}
