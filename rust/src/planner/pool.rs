//! The Memory Pool: one contiguous arena holding every tensor of a
//! compiled model at planner-assigned offsets (paper §4.2).
//!
//! Peak memory is `buf.len() * 4` bytes and is known *before* training
//! starts — the paper's headline operational property ("engineers can
//! calculate the memory requirement before actual execution").

use std::cell::UnsafeCell;

use crate::tensor::Region;

/// Contiguous f32 arena.
///
/// # Safety discipline
/// Views are handed out as raw-slice reborrows of disjoint regions. The
/// Memory Planner guarantees (and `planner::validate` checks) that any two
/// distinct live tensors occupy disjoint regions; tensors that *do* share a
/// region (MV/RV/E merges) are only accessed through layers written for
/// in-place semantics. The pool itself is `!Sync` and every view is
/// created on the training thread.
///
/// One sanctioned cross-thread exception: the swap runtime's evict
/// worker *reads* an evicted region's bytes through a raw span
/// (`runtime/swap.rs::PoolSpan`) while training continues. The
/// contract making that sound: (a) the training thread never writes
/// that range until the ticket's completion is observed (reclaim
/// barrier + reacquire overlap-wait), so the disjointness invariant
/// extends across threads; (b) views here derive region pointers from
/// the buffer's data pointer — the transient `&mut Vec` below asserts
/// uniqueness over the Vec *header* only, never over the heap bytes a
/// raw span is reading; and (c) `SwapExec` joins the worker before the
/// pool can drop (`Executor` declares `swap` before `pool`).
pub struct MemoryPool {
    buf: UnsafeCell<Vec<f32>>,
}

impl MemoryPool {
    pub fn new(len: usize) -> Self {
        MemoryPool {
            buf: UnsafeCell::new(vec![0.0; len]),
        }
    }

    pub fn len(&self) -> usize {
        unsafe { (*self.buf.get()).len() }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn bytes(&self) -> usize {
        self.len() * std::mem::size_of::<f32>()
    }

    /// Immutable view of a region.
    #[inline]
    pub fn view(&self, r: Region) -> &[f32] {
        debug_assert!(r.end() <= self.len(), "region {:?} out of pool", r);
        unsafe {
            let v = &*self.buf.get();
            std::slice::from_raw_parts(v.as_ptr().add(r.offset), r.len)
        }
    }

    /// Mutable view of a region.
    ///
    /// Takes `&self`: disjointness of simultaneously-held views is the
    /// planner's (validated) invariant, see type-level docs.
    #[inline]
    #[allow(clippy::mut_from_ref)]
    pub fn view_mut(&self, r: Region) -> &mut [f32] {
        debug_assert!(r.end() <= self.len(), "region {:?} out of pool", r);
        unsafe {
            let v = &mut *self.buf.get();
            std::slice::from_raw_parts_mut(v.as_mut_ptr().add(r.offset), r.len)
        }
    }

    /// Release a region for the duration of an offload gap. The caller
    /// (the swap runtime) has already copied the contents to the
    /// secondary store; the gap-aware planner may hand the same address
    /// range to other tensors until the region is reacquired. In debug
    /// builds the region is poisoned with NaN so that any read of
    /// evicted data is immediately visible in the numerics.
    pub fn release_gap(&self, r: Region) {
        #[cfg(debug_assertions)]
        self.view_mut(r).fill(f32::NAN);
        #[cfg(not(debug_assertions))]
        let _ = r;
    }

    /// Reacquire a released region: copy the secondary-store bytes back.
    /// Any gap-sharing tenant of this address range is dead by now — the
    /// gap-aware planner reserves the range from one EO before the
    /// owner's next use.
    pub fn reacquire(&self, r: Region, data: &[f32]) {
        self.view_mut(r)[..data.len()].copy_from_slice(data);
    }

    /// Zero the whole arena (used between inference/training switches).
    pub fn clear(&self) {
        self.view_mut(Region {
            offset: 0,
            len: self.len(),
        })
        .fill(0.0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disjoint_views() {
        let p = MemoryPool::new(16);
        let a = p.view_mut(Region { offset: 0, len: 8 });
        let b = p.view_mut(Region { offset: 8, len: 8 });
        a.fill(1.0);
        b.fill(2.0);
        assert_eq!(p.view(Region { offset: 0, len: 8 })[7], 1.0);
        assert_eq!(p.view(Region { offset: 8, len: 8 })[0], 2.0);
    }

    #[test]
    fn bytes() {
        let p = MemoryPool::new(10);
        assert_eq!(p.bytes(), 40);
    }
}
