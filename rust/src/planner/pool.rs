//! The Memory Pool: one contiguous arena holding every tensor of a
//! compiled model at planner-assigned offsets (paper §4.2).
//!
//! Peak memory is `buf.len() * 4` bytes and is known *before* training
//! starts — the paper's headline operational property ("engineers can
//! calculate the memory requirement before actual execution").

use std::cell::UnsafeCell;

use crate::tensor::Region;

/// Contiguous f32 arena.
///
/// # Safety discipline
/// Views are handed out as raw-slice reborrows of disjoint regions. The
/// Memory Planner guarantees (and `planner::validate` checks) that any two
/// distinct live tensors occupy disjoint regions; tensors that *do* share a
/// region (MV/RV/E merges) are only accessed through layers written for
/// in-place semantics. The pool itself is `!Sync` and every view is
/// created on the training thread.
///
/// One sanctioned cross-thread exception: the swap runtime's evict
/// worker *reads* an evicted region's bytes through a raw span
/// (`runtime/swap.rs::PoolSpan`) while training continues. The
/// contract making that sound: (a) the training thread never writes
/// that range until the ticket's completion is observed (reclaim
/// barrier + reacquire overlap-wait), so the disjointness invariant
/// extends across threads; (b) views here derive region pointers from
/// the buffer's data pointer — the transient `&mut Vec` below asserts
/// uniqueness over the Vec *header* only, never over the heap bytes a
/// raw span is reading; and (c) `SwapExec` joins the worker before the
/// pool can drop (`Executor` declares `swap` before `pool`).
///
/// In debug builds the pool additionally keeps a registry of released
/// gap regions: `release_gap`/`reacquire` must pair up on the *exact*
/// same region, so a placer or swap-runtime bug that releases twice,
/// reacquires something never released, or walks out of bounds panics
/// loudly instead of silently aliasing a gap tenant. (Released regions
/// of different entries may legitimately overlap each other — two
/// entries whose gaps overlap in time can share addresses — so the
/// registry matches exact regions, not overlap.)
pub struct MemoryPool {
    buf: UnsafeCell<Vec<f32>>,
    /// Debug-only registry of currently-released gap regions.
    #[cfg(debug_assertions)]
    released: UnsafeCell<Vec<Region>>,
}

impl MemoryPool {
    pub fn new(len: usize) -> Self {
        MemoryPool {
            buf: UnsafeCell::new(vec![0.0; len]),
            #[cfg(debug_assertions)]
            released: UnsafeCell::new(Vec::new()),
        }
    }

    pub fn len(&self) -> usize {
        unsafe { (*self.buf.get()).len() }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn bytes(&self) -> usize {
        self.len() * std::mem::size_of::<f32>()
    }

    /// Immutable view of a region.
    #[inline]
    pub fn view(&self, r: Region) -> &[f32] {
        debug_assert!(r.end() <= self.len(), "region {:?} out of pool", r);
        unsafe {
            let v = &*self.buf.get();
            std::slice::from_raw_parts(v.as_ptr().add(r.offset), r.len)
        }
    }

    /// Mutable view of a region.
    ///
    /// Takes `&self`: disjointness of simultaneously-held views is the
    /// planner's (validated) invariant, see type-level docs.
    #[inline]
    #[allow(clippy::mut_from_ref)]
    pub fn view_mut(&self, r: Region) -> &mut [f32] {
        debug_assert!(r.end() <= self.len(), "region {:?} out of pool", r);
        unsafe {
            let v = &mut *self.buf.get();
            std::slice::from_raw_parts_mut(v.as_mut_ptr().add(r.offset), r.len)
        }
    }

    /// Release a region for the duration of an offload gap. The caller
    /// (the swap runtime) has already copied the contents to the
    /// secondary store; the gap-aware planner may hand the same address
    /// range to other tensors until the region is reacquired. In debug
    /// builds the region is poisoned with NaN so that any read of
    /// evicted data is immediately visible in the numerics, and the
    /// release is recorded so a double release of the same region (an
    /// eviction issued twice without a reacquire between) panics.
    pub fn release_gap(&self, r: Region) {
        #[cfg(debug_assertions)]
        {
            assert!(
                r.end() <= self.len(),
                "release_gap: region {r:?} out of pool (len {})",
                self.len()
            );
            let reg = unsafe { &mut *self.released.get() };
            assert!(
                !reg.contains(&r),
                "release_gap: region {r:?} released twice without a reacquire — \
                 the swap schedule and the pool have drifted"
            );
            reg.push(r);
            self.view_mut(r).fill(f32::NAN);
        }
        #[cfg(not(debug_assertions))]
        let _ = r;
    }

    /// Reacquire a released region: copy the secondary-store bytes back.
    /// Any gap-sharing tenant of this address range is dead by now — the
    /// gap-aware planner reserves the range from one EO before the
    /// owner's next use. In debug builds the region must match a prior
    /// `release_gap` exactly (same offset and length) — a mismatched
    /// reacquire is a placer/runtime drift that would silently clobber
    /// a tenant, so it panics instead.
    pub fn reacquire(&self, r: Region, data: &[f32]) {
        #[cfg(debug_assertions)]
        {
            assert!(
                r.end() <= self.len(),
                "reacquire: region {r:?} out of pool (len {})",
                self.len()
            );
            assert!(
                data.len() <= r.len,
                "reacquire: {} f32s into region {r:?}",
                data.len()
            );
            let reg = unsafe { &mut *self.released.get() };
            match reg.iter().position(|x| *x == r) {
                Some(i) => {
                    reg.swap_remove(i);
                }
                None => panic!(
                    "reacquire: region {r:?} was never released — \
                     the swap schedule and the pool have drifted"
                ),
            }
        }
        self.view_mut(r)[..data.len()].copy_from_slice(data);
    }

    /// Copy a region's bytes to a lower destination (pool compaction).
    /// Overlap-safe like `memmove`; the compaction planner guarantees
    /// `to.offset <= from.offset` and equal lengths.
    pub fn move_region(&self, from: Region, to: Region) {
        debug_assert_eq!(from.len, to.len, "move_region: length mismatch {from:?} -> {to:?}");
        debug_assert!(
            to.offset <= from.offset,
            "move_region: compaction only slides down ({from:?} -> {to:?})"
        );
        debug_assert!(from.end() <= self.len(), "move_region: source {from:?} out of pool");
        unsafe {
            let v = &mut *self.buf.get();
            v.copy_within(from.offset..from.end(), to.offset);
        }
    }

    /// Shrink the arena to `new_len` elements (pool compaction: every
    /// region now ends at or below `new_len`). Must only be called at a
    /// swap-quiescent barrier — no raw spans into the pool may be
    /// outstanding. Never reallocates (truncate), so concurrent-read
    /// safety questions do not arise; the freed tail stays owned by the
    /// Vec as spare capacity.
    pub fn shrink(&self, new_len: usize) {
        #[cfg(debug_assertions)]
        {
            let reg = unsafe { &*self.released.get() };
            assert!(
                reg.iter().all(|r| r.end() <= new_len),
                "shrink({new_len}): a released region is still out: {reg:?}"
            );
        }
        unsafe {
            let v = &mut *self.buf.get();
            if new_len < v.len() {
                v.truncate(new_len);
            }
        }
    }

    /// Zero the whole arena (used between inference/training switches).
    pub fn clear(&self) {
        self.view_mut(Region {
            offset: 0,
            len: self.len(),
        })
        .fill(0.0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disjoint_views() {
        let p = MemoryPool::new(16);
        let a = p.view_mut(Region { offset: 0, len: 8 });
        let b = p.view_mut(Region { offset: 8, len: 8 });
        a.fill(1.0);
        b.fill(2.0);
        assert_eq!(p.view(Region { offset: 0, len: 8 })[7], 1.0);
        assert_eq!(p.view(Region { offset: 8, len: 8 })[0], 2.0);
    }

    #[test]
    fn bytes() {
        let p = MemoryPool::new(10);
        assert_eq!(p.bytes(), 40);
    }

    #[test]
    fn release_reacquire_roundtrip() {
        let p = MemoryPool::new(8);
        let r = Region { offset: 2, len: 4 };
        p.view_mut(r).fill(3.0);
        p.release_gap(r);
        p.reacquire(r, &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(p.view(r), &[1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn move_region_slides_down_with_overlap() {
        let p = MemoryPool::new(10);
        let from = Region { offset: 4, len: 4 };
        p.view_mut(from).copy_from_slice(&[1.0, 2.0, 3.0, 4.0]);
        let to = Region { offset: 2, len: 4 };
        p.move_region(from, to);
        assert_eq!(p.view(to), &[1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn shrink_truncates() {
        let p = MemoryPool::new(10);
        p.shrink(6);
        assert_eq!(p.len(), 6);
        assert_eq!(p.bytes(), 24);
    }

    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "released twice")]
    fn double_release_panics() {
        let p = MemoryPool::new(8);
        let r = Region { offset: 0, len: 4 };
        p.release_gap(r);
        p.release_gap(r);
    }

    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "never released")]
    fn unmatched_reacquire_panics() {
        let p = MemoryPool::new(8);
        p.reacquire(Region { offset: 0, len: 4 }, &[0.0; 4]);
    }

    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "out of pool")]
    fn release_out_of_bounds_panics() {
        let p = MemoryPool::new(8);
        p.release_gap(Region { offset: 6, len: 4 });
    }
}
