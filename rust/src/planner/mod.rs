//! Memory planners (paper §4.2): map every root tensor's live interval
//! `[min EO, max EO]` to an offset in the Memory Pool.
//!
//! * [`NaivePlanner`] — no reuse; models conventional frameworks.
//! * [`SortingPlanner`] — the paper's Algorithm 2 (simple sorting-based,
//!   whole-slot reuse; fragments as in Fig 8).
//! * [`BestFitPlanner`] — the paper's stated future work: slot splitting
//!   with best-fit selection, resolving the Fig 8 fragmentation.
//! * [`SkylinePlanner`] — segment-tree skyline placement (see
//!   `planner/placer.rs`), the widest portfolio tier.

pub mod bestfit;
pub mod compact;
pub mod gapfit;
pub mod naive;
pub mod offload;
pub mod placer;
pub mod pool;
pub mod skyline;
pub mod sorting;
pub mod validate;

use crate::error::Result;
use crate::tensor::{TensorId, TensorTable};

pub use bestfit::BestFitPlanner;
pub use compact::{frag_gauge, plan_compaction, CompactionMove, CompactionPlan, FragGauge};
pub use gapfit::{GapBestFitPlanner, GapFitPlanner, GapSkylinePlanner};
pub use naive::NaivePlanner;
pub use offload::{OffloadEntry, OffloadPlan};
pub use placer::{BestFitPlacer, FirstFitPlacer, PlaceItem, Placer, SkylinePlacer};
pub use pool::MemoryPool;
pub use skyline::SkylinePlanner;
pub use sorting::SortingPlanner;

/// Planner selector used in model compile options.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PlannerKind {
    Naive,
    Sorting,
    BestFit,
    /// Segment-tree skyline placement with the widest order/strategy
    /// portfolio — never plans a larger pool than `BestFit`.
    Skyline,
}

impl PlannerKind {
    pub fn instance(&self) -> Box<dyn Planner> {
        match self {
            PlannerKind::Naive => Box::new(NaivePlanner),
            PlannerKind::Sorting => Box::new(SortingPlanner),
            PlannerKind::BestFit => Box::new(BestFitPlanner),
            PlannerKind::Skyline => Box::new(SkylinePlanner),
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s.trim().to_ascii_lowercase().as_str() {
            "naive" => Some(PlannerKind::Naive),
            "sorting" => Some(PlannerKind::Sorting),
            "bestfit" | "best_fit" => Some(PlannerKind::BestFit),
            "skyline" => Some(PlannerKind::Skyline),
            _ => None,
        }
    }
}

/// A memory planner assigns a `Region` to every allocatable root tensor
/// and returns the pool length (f32 elements). Peak memory is therefore
/// known before execution.
pub trait Planner {
    fn name(&self) -> &'static str;
    fn plan(&self, table: &mut TensorTable) -> Result<usize>;
}

/// Tensors that need pool space: merge roots with at least one EO.
/// (Placeholders are hosted in the pool too — the Batch Queue binds user
/// data by copying into their regions.)
pub fn allocatable(table: &TensorTable) -> Vec<TensorId> {
    table
        .iter()
        .filter(|s| s.merged_into.is_none() && !s.eos.is_empty())
        .map(|s| s.id)
        .collect()
}

/// Sort ids by ascending first-use EO, ties by descending last-use EO
/// (Algorithm 2 lines 1–4).
pub fn sort_by_schedule(table: &TensorTable, ids: &mut [TensorId]) {
    ids.sort_by(|&a, &b| {
        let sa = table.get(a);
        let sb = table.get(b);
        sa.min_eo()
            .cmp(&sb.min_eo())
            .then(sb.max_eo().cmp(&sa.max_eo()))
            .then(a.cmp(&b))
    });
}
