//! Pool compaction: a plan-time region-relocation map applied between
//! epochs at a swap-quiescent barrier (see `Executor::compact_pool` and
//! DESIGN.md §Memory pool & spill store).
//!
//! The gap planner commits the minimum-peak layout over its portfolio,
//! but the winning candidate (often a size-descending order) can leave
//! never-used holes below high-address tensors. Compaction re-places
//! every tensor at the lowest feasible offset in ascending current
//! address order — a slide-down pass over the committed layout. The
//! resulting map has two structural properties this module's tests pin:
//!
//! * **Validity** — the relocated layout satisfies the same segmented
//!   liveness constraints (checked with `validate_gap_plan` after
//!   application).
//! * **Monotone, downward moves** — processing in ascending source
//!   offset, every destination is at or below its source, and no
//!   persistent tensor's destination overlaps a later persistent
//!   tensor's source: persistent (MAX-lifespan) tensors are live at
//!   every EO, so their regions are pairwise space-disjoint, and an
//!   earlier move's destination end never exceeds its own source end,
//!   which sits at or below the next persistent source. Applying data
//!   copies in map order is therefore memmove-safe.
//!
//! Only persistent tensors carry data across the barrier (weights,
//! optimizer state, running statistics — everything with
//! `Lifespan::MAX`); transient tensors just get new regions.

use std::collections::HashSet;

use crate::tensor::{Region, TensorId, TensorTable};

use super::gapfit::{intervals_overlap, place_items};
use super::offload::OffloadPlan;

/// One relocation: tensor `id` moves from `from` to `to`
/// (`to.offset < from.offset` always — see module docs).
#[derive(Clone, Copy, Debug)]
pub struct CompactionMove {
    pub id: TensorId,
    pub from: Region,
    pub to: Region,
    /// Whether the tensor's bytes must be copied (MAX lifespan — data
    /// survives across iterations; transient regions hold garbage at
    /// the epoch barrier).
    pub persistent: bool,
}

/// A relocation map produced at plan time, applied once at the first
/// epoch boundary (a swap-quiescent point: `SwapExec::end_iteration`
/// has drained every transfer).
#[derive(Clone, Debug)]
pub struct CompactionPlan {
    /// Moves in ascending source offset (the safe application order).
    pub moves: Vec<CompactionMove>,
    /// Pool length after relocation (≤ the committed length).
    pub new_len: usize,
    pub old_len: usize,
}

/// Fragmentation gauge over a committed layout: pool addresses never
/// covered by any region are pure placement waste.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FragGauge {
    pub pool_bytes: u64,
    /// Bytes of the pool no tensor region ever covers.
    pub unused_bytes: u64,
    /// Longest contiguous never-covered run (includes the tail above
    /// the highest region — the headroom a shrink reclaims).
    pub largest_free_extent_bytes: u64,
}

impl FragGauge {
    pub fn frag_pct(&self) -> f64 {
        if self.pool_bytes == 0 {
            0.0
        } else {
            self.unused_bytes as f64 / self.pool_bytes as f64 * 100.0
        }
    }
}

/// Measure the fragmentation of the committed layout: merge all root
/// regions into covered spans and sum the holes.
pub fn frag_gauge(table: &TensorTable, pool_len: usize) -> FragGauge {
    let mut spans: Vec<(usize, usize)> = table
        .iter()
        .filter(|s| s.merged_into.is_none() && !s.eos.is_empty())
        .filter_map(|s| s.region)
        .map(|r| (r.offset, r.end()))
        .collect();
    spans.sort_unstable();
    let mut unused = 0usize;
    let mut largest = 0usize;
    let mut cursor = 0usize;
    for (a, b) in spans {
        if a > cursor {
            let hole = a - cursor;
            unused += hole;
            largest = largest.max(hole);
        }
        cursor = cursor.max(b);
    }
    if pool_len > cursor {
        let tail = pool_len - cursor;
        unused += tail;
        largest = largest.max(tail);
    }
    FragGauge {
        pool_bytes: (pool_len * 4) as u64,
        unused_bytes: (unused * 4) as u64,
        largest_free_extent_bytes: (largest * 4) as u64,
    }
}

/// Compute the slide-down relocation map for a committed gap layout.
/// Returns `None` when the layout is already compact (no tensor can
/// move down).
pub fn plan_compaction(
    table: &TensorTable,
    plan: &OffloadPlan,
    pool_len: usize,
) -> Option<CompactionPlan> {
    let mut items = place_items(table, plan);
    // ascending current offset; ties (space-sharing, time-disjoint
    // tensors) broken by id for determinism
    items.sort_by_key(|it| (table.get(it.id).region.map(|r| r.offset).unwrap_or(0), it.id));
    let persistent: HashSet<TensorId> = table
        .iter()
        .filter(|s| s.lifespan.is_max())
        .map(|s| s.id)
        .collect();

    struct Placed {
        intervals_idx: usize,
        offset: usize,
        len: usize,
    }
    let mut placed: Vec<Placed> = Vec::with_capacity(items.len());
    let mut moves = Vec::new();
    let mut new_len = 0usize;
    for (k, item) in items.iter().enumerate() {
        let from = table.get(item.id).region.expect("compaction runs on a committed layout");
        // first-fit against the already-relocated prefix
        let mut forbidden: Vec<(usize, usize)> = placed
            .iter()
            .filter(|p| intervals_overlap(&items[p.intervals_idx].intervals, &item.intervals))
            .map(|p| (p.offset, p.offset + p.len))
            .collect();
        forbidden.sort_unstable();
        let mut offset = 0usize;
        for &(a, b) in &forbidden {
            if offset + item.need <= a {
                break;
            }
            offset = offset.max(b);
        }
        debug_assert!(
            offset <= from.offset,
            "slide-down moved `{}` up: {} -> {offset}",
            table.get(item.id).name,
            from.offset
        );
        let to = Region { offset, len: item.need };
        if to != from {
            moves.push(CompactionMove {
                id: item.id,
                from,
                to,
                persistent: persistent.contains(&item.id),
            });
        }
        new_len = new_len.max(to.end());
        placed.push(Placed { intervals_idx: k, offset, len: item.need });
    }
    if moves.is_empty() && new_len >= pool_len {
        return None;
    }
    Some(CompactionPlan { moves, new_len, old_len: pool_len })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::planner::validate::validate_gap_plan;
    use crate::tensor::{CreateMode, Initializer, Lifespan, TensorDim, TensorRole, TensorTable};

    fn table_with(entries: &[(&str, usize, &[u32], TensorRole)]) -> TensorTable {
        let mut t = TensorTable::new();
        for (name, len, eos, role) in entries {
            let id = t
                .request(*name, TensorDim::vec(1, *len), *role, CreateMode::Create, Initializer::None)
                .unwrap();
            for &e in *eos {
                t.add_eo(id, e, Lifespan::FORWARD);
            }
        }
        t.finish_orders();
        t
    }

    #[test]
    fn frag_gauge_counts_holes_and_tail() {
        let mut t = table_with(&[
            ("a", 10, &[0, 3], TensorRole::Activation),
            ("b", 5, &[0, 3], TensorRole::Activation),
        ]);
        t.get_mut(0).region = Some(Region { offset: 0, len: 10 });
        t.get_mut(1).region = Some(Region { offset: 14, len: 5 });
        let g = frag_gauge(&t, 25);
        assert_eq!(g.pool_bytes, 100);
        // hole 10..14 (4 elems) + tail 19..25 (6 elems)
        assert_eq!(g.unused_bytes, 40);
        assert_eq!(g.largest_free_extent_bytes, 24);
        assert!((g.frag_pct() - 40.0).abs() < 1e-9);
    }

    #[test]
    fn compaction_slides_layout_down() {
        // hand-build a fragmented committed layout: b sits above a hole
        let mut t = table_with(&[
            ("a", 10, &[0, 3], TensorRole::Activation),
            ("b", 5, &[0, 3], TensorRole::Activation),
        ]);
        t.get_mut(0).region = Some(Region { offset: 0, len: 10 });
        t.get_mut(1).region = Some(Region { offset: 14, len: 5 });
        let plan = OffloadPlan::default();
        let cp = plan_compaction(&t, &plan, 19).expect("hole must compact");
        assert_eq!(cp.new_len, 15);
        assert_eq!(cp.moves.len(), 1);
        assert_eq!(cp.moves[0].to, Region { offset: 10, len: 5 });
        assert!(!cp.moves[0].persistent, "activations carry no data across epochs");
        // applying the map yields a valid plan
        for m in &cp.moves {
            t.get_mut(m.id).region = Some(m.to);
        }
        validate_gap_plan(&t, &plan, cp.new_len).unwrap();
        assert_eq!(frag_gauge(&t, cp.new_len).unused_bytes, 0);
    }

    #[test]
    fn compact_layout_yields_no_plan() {
        let mut t = table_with(&[
            ("a", 10, &[0, 3], TensorRole::Activation),
            ("b", 5, &[4, 6], TensorRole::Activation),
        ]);
        t.get_mut(0).region = Some(Region { offset: 0, len: 10 });
        t.get_mut(1).region = Some(Region { offset: 0, len: 5 });
        assert!(plan_compaction(&t, &OffloadPlan::default(), 10).is_none());
    }

    #[test]
    fn persistent_tensors_are_flagged() {
        let mut t = TensorTable::new();
        let w = t
            .request("w", TensorDim::vec(1, 4), TensorRole::Weight, CreateMode::Create, Initializer::None)
            .unwrap();
        t.add_eo(w, 0, Lifespan::MAX);
        t.add_eo(w, 9, Lifespan::MAX);
        let a = t
            .request("a", TensorDim::vec(1, 6), TensorRole::Activation, CreateMode::Create, Initializer::None)
            .unwrap();
        t.add_eo(a, 1, Lifespan::FORWARD);
        t.add_eo(a, 2, Lifespan::FORWARD);
        t.finish_orders();
        t.get_mut(a).region = Some(Region { offset: 0, len: 6 });
        t.get_mut(w).region = Some(Region { offset: 10, len: 4 });
        let cp = plan_compaction(&t, &OffloadPlan::default(), 14).expect("w slides down");
        let wm = cp.moves.iter().find(|m| m.id == w).expect("w moved");
        assert!(wm.persistent, "weights must be flagged for data copy");
        assert_eq!(wm.to.offset, 6);
        assert_eq!(cp.new_len, 10);
    }
}
