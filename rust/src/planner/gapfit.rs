//! Gap-aware memory planner: realizes an [`OffloadPlan`] spatially.
//!
//! The plain planners treat every tensor as live over one contiguous EO
//! interval `[min EO, max EO]`. Under an offload plan, an offloaded
//! tensor's region is *released* during each idle gap (the data lives in
//! the secondary store) and *reacquired* `lead` EOs before the next use
//! — and stays reserved `write_lead` EOs past the eviction while the
//! background write ticket drains — so its primary footprint is the
//! union of its lead-widened live segments instead. This planner places
//! tensors so that two tensors may share pool space whenever none of
//! their live intervals overlap in time — which is what lets the pool
//! actually shrink to the advisor's `primary_peak_bytes` instead of
//! merely reporting it.
//!
//! Placement runs a *portfolio* over the [`Placer`] strategies in
//! `planner/placer.rs` crossed with deterministic orderings, committing
//! the layout with the smallest pool. Each `PlannerKind` tier evaluates
//! a superset of the tier below's candidates:
//!
//! * [`GapFitPlanner`] — first-fit × {schedule, size-descending}.
//! * [`GapBestFitPlanner`] — {first-fit, best-fit} × the same orders
//!   (best-fit candidates preferred on ties).
//! * [`GapSkylinePlanner`] — {skyline, best-fit, first-fit} ×
//!   {schedule, size-descending, interval-area-descending} (skyline
//!   candidates preferred on ties).
//!
//! The nesting makes the peak ordering skyline ≤ best-fit ≤ first-fit
//! hold on *every* topology by construction — the property
//! `tests/placer_props.rs` asserts across the stress generator.

use std::collections::HashSet;

use crate::error::Result;
use crate::tensor::{TensorId, TensorTable};

use super::offload::{live_intervals, OffloadPlan};
use super::placer::{BestFitPlacer, FirstFitPlacer, PlaceItem, Placer, SkylinePlacer};
use super::{allocatable, sort_by_schedule, Planner};

/// Planner that consumes an [`OffloadPlan`] and assigns regions under the
/// plan's segmented liveness model using first-fit placement.
pub struct GapFitPlanner<'a> {
    pub plan: &'a OffloadPlan,
}

/// Best-fit variant of [`GapFitPlanner`], selected under a memory budget
/// by `CompileOpts`/`DeviceProfile` `planner = PlannerKind::BestFit`.
pub struct GapBestFitPlanner<'a> {
    pub plan: &'a OffloadPlan,
}

/// Skyline variant of [`GapFitPlanner`] (widest portfolio), selected
/// under a memory budget by `planner = PlannerKind::Skyline`.
pub struct GapSkylinePlanner<'a> {
    pub plan: &'a OffloadPlan,
}

/// Do two sorted inclusive interval lists share any EO?
pub fn intervals_overlap(a: &[(u32, u32)], b: &[(u32, u32)]) -> bool {
    let (mut i, mut j) = (0usize, 0usize);
    while i < a.len() && j < b.len() {
        let (a0, a1) = a[i];
        let (b0, b1) = b[j];
        if a0 <= b1 && b0 <= a1 {
            return true;
        }
        if a1 < b1 {
            i += 1;
        } else {
            j += 1;
        }
    }
    false
}

/// Build the placement items for every allocatable tensor: size plus
/// lead-widened live intervals under the plan's segmented liveness.
pub(crate) fn place_items(table: &TensorTable, plan: &OffloadPlan) -> Vec<PlaceItem> {
    let offloaded: HashSet<TensorId> = plan.entries.iter().map(|e| e.tensor).collect();
    let leads = plan.lead_map();
    allocatable(table)
        .into_iter()
        .map(|id| {
            let s = table.get(id);
            PlaceItem {
                id,
                need: s.dim.len(),
                intervals: live_intervals(s, offloaded.contains(&id).then_some(&leads)),
            }
        })
        .collect()
}

/// The deterministic orderings the portfolio crosses with each placer.
#[derive(Clone, Copy, PartialEq, Eq)]
enum Order {
    /// Algorithm 2's sort: first use ascending, last use descending.
    Schedule,
    /// Size descending (large tensors anchor low addresses).
    SizeDesc,
    /// Total live EO-area (size × live EOs) descending — items that
    /// dominate the (address × time) plane place first.
    AreaDesc,
}

fn ordered(table: &TensorTable, items: &[PlaceItem], order: Order) -> Vec<PlaceItem> {
    let mut ids: Vec<TensorId> = items.iter().map(|it| it.id).collect();
    match order {
        Order::Schedule => sort_by_schedule(table, &mut ids),
        Order::SizeDesc => ids.sort_by_key(|&id| {
            let s = table.get(id);
            (std::cmp::Reverse(s.dim.len()), s.min_eo().unwrap_or(u32::MAX), id)
        }),
        Order::AreaDesc => {
            let area_of = |id: TensorId| -> u64 {
                let it = &items[items.iter().position(|x| x.id == id).unwrap()];
                let eos: u64 = it
                    .intervals
                    .iter()
                    .map(|&(a, z)| (z.saturating_sub(a) as u64) + 1)
                    .sum();
                it.need as u64 * eos
            };
            ids.sort_by_key(|&id| {
                (std::cmp::Reverse(area_of(id)), table.get(id).min_eo().unwrap_or(u32::MAX), id)
            });
        }
    }
    ids.into_iter()
        .map(|id| items[items.iter().position(|x| x.id == id).unwrap()].clone())
        .collect()
}

/// Run `candidates` (placer × order pairs, in preference order) and
/// commit the first strictly-smallest layout into the table.
fn plan_portfolio(
    table: &mut TensorTable,
    plan: &OffloadPlan,
    candidates: &[(&dyn Placer, Order)],
) -> Result<usize> {
    let items = place_items(table, plan);
    let mut best: Option<(usize, Vec<(TensorId, crate::tensor::Region)>)> = None;
    for &(placer, order) in candidates {
        let seq = ordered(table, &items, order);
        let (len, regions) = placer.place(&seq);
        if best.as_ref().map(|(b, _)| len < *b).unwrap_or(true) {
            best = Some((len, regions));
        }
    }
    let (pool_len, regions) = best.expect("portfolio has at least one candidate");
    for (id, r) in regions {
        table.get_mut(id).region = Some(r);
    }
    Ok(pool_len)
}

impl Planner for GapFitPlanner<'_> {
    fn name(&self) -> &'static str {
        "gapfit"
    }

    fn plan(&self, table: &mut TensorTable) -> Result<usize> {
        plan_portfolio(
            table,
            self.plan,
            &[(&FirstFitPlacer, Order::Schedule), (&FirstFitPlacer, Order::SizeDesc)],
        )
    }
}

impl Planner for GapBestFitPlanner<'_> {
    fn name(&self) -> &'static str {
        "gapfit-bestfit"
    }

    fn plan(&self, table: &mut TensorTable) -> Result<usize> {
        plan_portfolio(
            table,
            self.plan,
            &[
                (&BestFitPlacer, Order::Schedule),
                (&BestFitPlacer, Order::SizeDesc),
                (&FirstFitPlacer, Order::Schedule),
                (&FirstFitPlacer, Order::SizeDesc),
            ],
        )
    }
}

impl Planner for GapSkylinePlanner<'_> {
    fn name(&self) -> &'static str {
        "gapfit-skyline"
    }

    fn plan(&self, table: &mut TensorTable) -> Result<usize> {
        plan_portfolio(
            table,
            self.plan,
            &[
                (&SkylinePlacer, Order::Schedule),
                (&SkylinePlacer, Order::SizeDesc),
                (&SkylinePlacer, Order::AreaDesc),
                (&BestFitPlacer, Order::Schedule),
                (&BestFitPlacer, Order::SizeDesc),
                (&BestFitPlacer, Order::AreaDesc),
                (&FirstFitPlacer, Order::Schedule),
                (&FirstFitPlacer, Order::SizeDesc),
                (&FirstFitPlacer, Order::AreaDesc),
            ],
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::planner::offload::advise;
    use crate::planner::validate::validate_gap_plan;
    use crate::tensor::{
        CreateMode, Initializer, Lifespan, Region, TensorDim, TensorRole, TensorTable,
    };

    fn table_with(entries: &[(&str, usize, &[u32], TensorRole)]) -> TensorTable {
        let mut t = TensorTable::new();
        for (name, len, eos, role) in entries {
            let id = t
                .request(*name, TensorDim::vec(1, *len), *role, CreateMode::Create, Initializer::None)
                .unwrap();
            for &e in *eos {
                t.add_eo(id, e, Lifespan::FORWARD);
            }
        }
        t.finish_orders();
        t
    }

    #[test]
    fn interval_overlap_cases() {
        assert!(intervals_overlap(&[(0, 3)], &[(3, 5)]));
        assert!(!intervals_overlap(&[(0, 3)], &[(4, 5)]));
        assert!(intervals_overlap(&[(0, 1), (8, 9)], &[(3, 8)]));
        assert!(!intervals_overlap(&[(0, 1), (8, 9)], &[(3, 6)]));
        assert!(!intervals_overlap(&[], &[(0, 100)]));
    }

    #[test]
    fn gap_reuse_shrinks_pool() {
        // `a` idles over EOs 2..9 — with `a` offloaded, `b` (live only in
        // the gap) can take the same address range.
        let mut t = table_with(&[
            ("a", 1000, &[0, 1, 10], TensorRole::Activation),
            ("b", 1000, &[4, 5], TensorRole::Activation),
        ]);
        let full = advise(&t, usize::MAX).primary_peak_bytes;
        assert_eq!(full, 2000 * 4);
        let plan = advise(&t, 1000 * 4);
        assert!(plan.fits, "{plan:?}");
        let pool_len = GapFitPlanner { plan: &plan }.plan(&mut t).unwrap();
        assert_eq!(pool_len, 1000, "b must reuse a's released region");
        validate_gap_plan(&t, &plan, pool_len).unwrap();
        // both tensors share the same offset
        assert_eq!(t.get(0).region, t.get(1).region);
    }

    #[test]
    fn prefetch_lead_blocks_tight_reuse() {
        // `b` is live through EO 9; `a` returns at EO 10 but its region is
        // reacquired at EO 9 (lead 1) — so they must NOT share space.
        let mut t = table_with(&[
            ("a", 1000, &[0, 1, 10], TensorRole::Activation),
            ("b", 1000, &[4, 5, 6, 7, 8, 9], TensorRole::Activation),
        ]);
        let plan = advise(&t, 1000 * 4);
        let pool_len = GapFitPlanner { plan: &plan }.plan(&mut t).unwrap();
        validate_gap_plan(&t, &plan, pool_len).unwrap();
        assert_eq!(pool_len, 2000);
    }

    #[test]
    fn bestfit_validates_and_reuses_gaps() {
        // same scenario as `gap_reuse_shrinks_pool`: best-fit must find
        // the identical (optimal) single-slot layout
        let mut t = table_with(&[
            ("a", 1000, &[0, 1, 10], TensorRole::Activation),
            ("b", 1000, &[4, 5], TensorRole::Activation),
        ]);
        let plan = advise(&t, 1000 * 4);
        assert!(plan.fits, "{plan:?}");
        let pool_len = GapBestFitPlanner { plan: &plan }.plan(&mut t).unwrap();
        assert_eq!(pool_len, 1000);
        validate_gap_plan(&t, &plan, pool_len).unwrap();
    }

    #[test]
    fn skyline_validates_and_reuses_gaps() {
        let mut t = table_with(&[
            ("a", 1000, &[0, 1, 10], TensorRole::Activation),
            ("b", 1000, &[4, 5], TensorRole::Activation),
        ]);
        let plan = advise(&t, 1000 * 4);
        assert!(plan.fits, "{plan:?}");
        let pool_len = GapSkylinePlanner { plan: &plan }.plan(&mut t).unwrap();
        assert_eq!(pool_len, 1000);
        validate_gap_plan(&t, &plan, pool_len).unwrap();
    }

    #[test]
    fn bestfit_prefers_smallest_adequate_hole() {
        // `q` and `s` die at EO 1, carving two bounded holes (30-wide at
        // offset 5, 12-wide at offset 40) between the long-lived blockers;
        // the 10-element `t` must take the 12-hole under best-fit and the
        // lower 30-hole under first-fit
        let t = table_with(&[
            ("p", 5, &[0, 30], TensorRole::Activation),
            ("q", 30, &[0, 1], TensorRole::Activation),
            ("r", 5, &[0, 30], TensorRole::Activation),
            ("s", 12, &[0, 1], TensorRole::Activation),
            ("u", 8, &[0, 30], TensorRole::Activation),
            ("t", 10, &[5, 30], TensorRole::Activation),
        ]);
        let plan = OffloadPlan::default();
        let items = place_items(&t, &plan);
        let (_, ff) = FirstFitPlacer.place(&items);
        let (_, bf) = BestFitPlacer.place(&items);
        let off = |rs: &[(TensorId, Region)], id: TensorId| {
            rs.iter().find(|(i, _)| *i == id).unwrap().1.offset
        };
        for rs in [&ff, &bf] {
            assert_eq!(off(rs, 0), 0);
            assert_eq!(off(rs, 1), 5);
            assert_eq!(off(rs, 2), 35);
            assert_eq!(off(rs, 3), 40);
            assert_eq!(off(rs, 4), 52);
        }
        assert_eq!(off(&ff, 5), 5, "first-fit takes the lowest (30-wide) hole");
        assert_eq!(off(&bf, 5), 40, "best-fit takes the least-waste (12-wide) hole");
    }

    #[test]
    fn tier_peaks_are_monotone() {
        // nested portfolios: skyline tier ≤ best-fit tier ≤ first-fit
        // tier, regardless of topology
        let make = || {
            table_with(&[
                ("a", 37, &[0, 3], TensorRole::Activation),
                ("b", 11, &[2, 8], TensorRole::Activation),
                ("c", 23, &[4, 9], TensorRole::Activation),
                ("d", 53, &[1, 6], TensorRole::Activation),
                ("e", 7, &[5, 12], TensorRole::Activation),
                ("f", 31, &[10, 14], TensorRole::Activation),
            ])
        };
        let plan = OffloadPlan::default();
        let ff = GapFitPlanner { plan: &plan }.plan(&mut make()).unwrap();
        let bf = GapBestFitPlanner { plan: &plan }.plan(&mut make()).unwrap();
        let sky = GapSkylinePlanner { plan: &plan }.plan(&mut make()).unwrap();
        assert!(sky <= bf, "skyline {sky} > bestfit {bf}");
        assert!(bf <= ff, "bestfit {bf} > firstfit {ff}");
    }

    #[test]
    fn no_offloads_behaves_like_plain_planner() {
        let mut t = table_with(&[
            ("a", 10, &[0, 3], TensorRole::Activation),
            ("b", 10, &[4, 6], TensorRole::Activation),
            ("w", 4, &[0, 6], TensorRole::Weight),
        ]);
        let plan = advise(&t, usize::MAX);
        assert!(plan.entries.is_empty());
        let pool_len = GapFitPlanner { plan: &plan }.plan(&mut t).unwrap();
        // b reuses a's slot; w is pinned alongside
        assert_eq!(pool_len, 14);
        validate_gap_plan(&t, &plan, pool_len).unwrap();
    }
}
