//! Gap-aware memory planner: realizes an [`OffloadPlan`] spatially.
//!
//! The plain planners treat every tensor as live over one contiguous EO
//! interval `[min EO, max EO]`. Under an offload plan, an offloaded
//! tensor's region is *released* during each idle gap (the data lives in
//! the secondary store) and *reacquired* `lead` EOs before the next use
//! — and stays reserved `write_lead` EOs past the eviction while the
//! background write ticket drains — so its primary footprint is the
//! union of its lead-widened live segments instead. This planner places
//! tensors so that two tensors may share pool space whenever none of
//! their live intervals overlap in time — which is what lets the pool
//! actually shrink to the advisor's `primary_peak_bytes` instead of
//! merely reporting it.
//!
//! Placement: for each tensor, collect the address ranges of every
//! already-placed, time-overlapping tensor, then pick a hole by one of
//! two [`GapStrategy`] rules — *first-fit* (lowest feasible offset, the
//! PR-1 default) or *best-fit* (smallest adequate hole between blocked
//! ranges, reducing the fragmentation first-fit leaves behind). Two
//! deterministic orderings are tried — schedule order (Algorithm 2's
//! sort) and size-descending — and the layout with the smaller pool
//! wins; on the evaluation models this lands within a few percent of the
//! advisor's analytic live-set peak.

use std::collections::HashSet;

use crate::error::Result;
use crate::tensor::{Region, TensorId, TensorTable};

use super::offload::{live_intervals, LeadMap, OffloadPlan};
use super::{allocatable, sort_by_schedule, Planner};

/// Hole-selection rule for gap-aware placement.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum GapStrategy {
    /// Lowest feasible offset.
    #[default]
    FirstFit,
    /// Smallest adequate hole between blocked ranges (least waste); falls
    /// back to the open end above every blocked range. ROADMAP follow-up:
    /// `benches/swap_runtime.rs` reports the fragmentation of both.
    BestFit,
}

/// Planner that consumes an [`OffloadPlan`] and assigns regions under the
/// plan's segmented liveness model using first-fit placement.
pub struct GapFitPlanner<'a> {
    pub plan: &'a OffloadPlan,
}

/// Best-fit variant of [`GapFitPlanner`], selected under a memory budget
/// by `CompileOpts`/`DeviceProfile` `planner = PlannerKind::BestFit`.
pub struct GapBestFitPlanner<'a> {
    pub plan: &'a OffloadPlan,
}

/// Do two sorted inclusive interval lists share any EO?
pub fn intervals_overlap(a: &[(u32, u32)], b: &[(u32, u32)]) -> bool {
    let (mut i, mut j) = (0usize, 0usize);
    while i < a.len() && j < b.len() {
        let (a0, a1) = a[i];
        let (b0, b1) = b[j];
        if a0 <= b1 && b0 <= a1 {
            return true;
        }
        if a1 < b1 {
            i += 1;
        } else {
            j += 1;
        }
    }
    false
}

/// Placement of `ids` (in the given order) under segmented liveness;
/// returns the pool length and each tensor's region.
fn place(
    table: &TensorTable,
    offloaded: &HashSet<TensorId>,
    leads: &LeadMap,
    ids: &[TensorId],
    strategy: GapStrategy,
) -> (usize, Vec<(TensorId, Region)>) {
    struct Placed {
        intervals: Vec<(u32, u32)>,
        offset: usize,
        len: usize,
    }
    let mut placed: Vec<Placed> = Vec::with_capacity(ids.len());
    let mut regions: Vec<(TensorId, Region)> = Vec::with_capacity(ids.len());
    let mut pool_len = 0usize;
    for &id in ids {
        let s = table.get(id);
        let need = s.dim.len();
        let intervals = live_intervals(s, offloaded.contains(&id).then_some(leads));
        // address ranges blocked by time-overlapping placements
        let mut forbidden: Vec<(usize, usize)> = placed
            .iter()
            .filter(|p| intervals_overlap(&p.intervals, &intervals))
            .map(|p| (p.offset, p.offset + p.len))
            .collect();
        forbidden.sort_unstable();
        let offset = match strategy {
            GapStrategy::FirstFit => {
                let mut offset = 0usize;
                for &(a, b) in &forbidden {
                    if offset + need <= a {
                        break;
                    }
                    offset = offset.max(b);
                }
                offset
            }
            GapStrategy::BestFit => {
                // sweep the (possibly mutually overlapping) blocked ranges
                // in address order, scoring each bounded hole by waste; the
                // open end above everything is the fallback
                let mut best: Option<(usize, usize)> = None; // (offset, waste)
                let mut cursor = 0usize;
                for &(a, b) in &forbidden {
                    if a > cursor {
                        let hole = a - cursor;
                        if hole >= need {
                            let waste = hole - need;
                            if best.map(|(_, w)| waste < w).unwrap_or(true) {
                                best = Some((cursor, waste));
                            }
                        }
                    }
                    cursor = cursor.max(b);
                }
                best.map(|(o, _)| o).unwrap_or(cursor)
            }
        };
        regions.push((id, Region { offset, len: need }));
        pool_len = pool_len.max(offset + need);
        placed.push(Placed { intervals, offset, len: need });
    }
    (pool_len, regions)
}

/// Shared driver: try both deterministic orderings under `strategy`,
/// commit the smaller layout.
fn plan_gaps(
    table: &mut TensorTable,
    plan: &OffloadPlan,
    strategy: GapStrategy,
) -> Result<usize> {
    let offloaded: HashSet<TensorId> = plan.entries.iter().map(|e| e.tensor).collect();
    let leads = plan.lead_map();
    let ids = allocatable(table);

    let mut by_schedule = ids.clone();
    sort_by_schedule(table, &mut by_schedule);
    let mut by_size = ids;
    by_size.sort_by_key(|&id| {
        let s = table.get(id);
        (std::cmp::Reverse(s.dim.len()), s.min_eo().unwrap_or(u32::MAX), id)
    });

    let (len_a, regions_a) = place(table, &offloaded, &leads, &by_schedule, strategy);
    let (len_b, regions_b) = place(table, &offloaded, &leads, &by_size, strategy);
    let (pool_len, regions) = if len_b < len_a {
        (len_b, regions_b)
    } else {
        (len_a, regions_a)
    };
    for (id, r) in regions {
        table.get_mut(id).region = Some(r);
    }
    Ok(pool_len)
}

impl Planner for GapFitPlanner<'_> {
    fn name(&self) -> &'static str {
        "gapfit"
    }

    fn plan(&self, table: &mut TensorTable) -> Result<usize> {
        plan_gaps(table, self.plan, GapStrategy::FirstFit)
    }
}

impl Planner for GapBestFitPlanner<'_> {
    fn name(&self) -> &'static str {
        "gapfit-bestfit"
    }

    fn plan(&self, table: &mut TensorTable) -> Result<usize> {
        plan_gaps(table, self.plan, GapStrategy::BestFit)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::planner::offload::advise;
    use crate::planner::validate::validate_gap_plan;
    use crate::tensor::{
        CreateMode, Initializer, Lifespan, TensorDim, TensorRole, TensorTable,
    };

    fn table_with(entries: &[(&str, usize, &[u32], TensorRole)]) -> TensorTable {
        let mut t = TensorTable::new();
        for (name, len, eos, role) in entries {
            let id = t
                .request(*name, TensorDim::vec(1, *len), *role, CreateMode::Create, Initializer::None)
                .unwrap();
            for &e in *eos {
                t.add_eo(id, e, Lifespan::FORWARD);
            }
        }
        t.finish_orders();
        t
    }

    #[test]
    fn interval_overlap_cases() {
        assert!(intervals_overlap(&[(0, 3)], &[(3, 5)]));
        assert!(!intervals_overlap(&[(0, 3)], &[(4, 5)]));
        assert!(intervals_overlap(&[(0, 1), (8, 9)], &[(3, 8)]));
        assert!(!intervals_overlap(&[(0, 1), (8, 9)], &[(3, 6)]));
        assert!(!intervals_overlap(&[], &[(0, 100)]));
    }

    #[test]
    fn gap_reuse_shrinks_pool() {
        // `a` idles over EOs 2..9 — with `a` offloaded, `b` (live only in
        // the gap) can take the same address range.
        let mut t = table_with(&[
            ("a", 1000, &[0, 1, 10], TensorRole::Activation),
            ("b", 1000, &[4, 5], TensorRole::Activation),
        ]);
        let full = advise(&t, usize::MAX).primary_peak_bytes;
        assert_eq!(full, 2000 * 4);
        let plan = advise(&t, 1000 * 4);
        assert!(plan.fits, "{plan:?}");
        let pool_len = GapFitPlanner { plan: &plan }.plan(&mut t).unwrap();
        assert_eq!(pool_len, 1000, "b must reuse a's released region");
        validate_gap_plan(&t, &plan, pool_len).unwrap();
        // both tensors share the same offset
        assert_eq!(t.get(0).region, t.get(1).region);
    }

    #[test]
    fn prefetch_lead_blocks_tight_reuse() {
        // `b` is live through EO 9; `a` returns at EO 10 but its region is
        // reacquired at EO 9 (lead 1) — so they must NOT share space.
        let mut t = table_with(&[
            ("a", 1000, &[0, 1, 10], TensorRole::Activation),
            ("b", 1000, &[4, 5, 6, 7, 8, 9], TensorRole::Activation),
        ]);
        let plan = advise(&t, 1000 * 4);
        let pool_len = GapFitPlanner { plan: &plan }.plan(&mut t).unwrap();
        validate_gap_plan(&t, &plan, pool_len).unwrap();
        assert_eq!(pool_len, 2000);
    }

    #[test]
    fn bestfit_validates_and_reuses_gaps() {
        // same scenario as `gap_reuse_shrinks_pool`: best-fit must find
        // the identical (optimal) single-slot layout
        let mut t = table_with(&[
            ("a", 1000, &[0, 1, 10], TensorRole::Activation),
            ("b", 1000, &[4, 5], TensorRole::Activation),
        ]);
        let plan = advise(&t, 1000 * 4);
        assert!(plan.fits, "{plan:?}");
        let pool_len = GapBestFitPlanner { plan: &plan }.plan(&mut t).unwrap();
        assert_eq!(pool_len, 1000);
        validate_gap_plan(&t, &plan, pool_len).unwrap();
    }

    #[test]
    fn bestfit_prefers_smallest_adequate_hole() {
        // `q` and `s` die at EO 1, carving two bounded holes (30-wide at
        // offset 5, 12-wide at offset 40) between the long-lived blockers;
        // the 10-element `t` must take the 12-hole under best-fit and the
        // lower 30-hole under first-fit
        let t = table_with(&[
            ("p", 5, &[0, 30], TensorRole::Activation),
            ("q", 30, &[0, 1], TensorRole::Activation),
            ("r", 5, &[0, 30], TensorRole::Activation),
            ("s", 12, &[0, 1], TensorRole::Activation),
            ("u", 8, &[0, 30], TensorRole::Activation),
            ("t", 10, &[5, 30], TensorRole::Activation),
        ]);
        let ids: Vec<TensorId> = (0..6).collect();
        let none = HashSet::new();
        let leads = LeadMap::default();
        let (_, ff) = place(&t, &none, &leads, &ids, GapStrategy::FirstFit);
        let (_, bf) = place(&t, &none, &leads, &ids, GapStrategy::BestFit);
        let off = |rs: &[(TensorId, Region)], id: TensorId| {
            rs.iter().find(|(i, _)| *i == id).unwrap().1.offset
        };
        for rs in [&ff, &bf] {
            assert_eq!(off(rs, 0), 0);
            assert_eq!(off(rs, 1), 5);
            assert_eq!(off(rs, 2), 35);
            assert_eq!(off(rs, 3), 40);
            assert_eq!(off(rs, 4), 52);
        }
        assert_eq!(off(&ff, 5), 5, "first-fit takes the lowest (30-wide) hole");
        assert_eq!(off(&bf, 5), 40, "best-fit takes the least-waste (12-wide) hole");
    }

    #[test]
    fn no_offloads_behaves_like_plain_planner() {
        let mut t = table_with(&[
            ("a", 10, &[0, 3], TensorRole::Activation),
            ("b", 10, &[4, 6], TensorRole::Activation),
            ("w", 4, &[0, 6], TensorRole::Weight),
        ]);
        let plan = advise(&t, usize::MAX);
        assert!(plan.entries.is_empty());
        let pool_len = GapFitPlanner { plan: &plan }.plan(&mut t).unwrap();
        // b reuses a's slot; w is pinned alongside
        assert_eq!(pool_len, 14);
        validate_gap_plan(&t, &plan, pool_len).unwrap();
    }
}
