//! Gap-aware memory planner: realizes an [`OffloadPlan`] spatially.
//!
//! The plain planners treat every tensor as live over one contiguous EO
//! interval `[min EO, max EO]`. Under an offload plan, an offloaded
//! tensor's region is *released* during each idle gap (the data lives in
//! the secondary store) and *reacquired* one EO before the next use, so
//! its primary footprint is the union of its live segments instead. This
//! planner places tensors so that two tensors may share pool space
//! whenever none of their live intervals overlap in time — which is what
//! lets the pool actually shrink to the advisor's `primary_peak_bytes`
//! instead of merely reporting it.
//!
//! Placement is lowest-feasible-offset first-fit: for each tensor,
//! collect the address ranges of every already-placed, time-overlapping
//! tensor and slide up from offset 0 to the first hole large enough. Two
//! deterministic orderings are tried — schedule order (Algorithm 2's
//! sort) and size-descending — and the layout with the smaller pool
//! wins; on the evaluation models this lands within a few percent of the
//! advisor's analytic live-set peak.

use std::collections::HashSet;

use crate::error::Result;
use crate::tensor::{Region, TensorId, TensorTable};

use super::offload::{live_intervals, OffloadPlan};
use super::{allocatable, sort_by_schedule, Planner};

/// Planner that consumes an [`OffloadPlan`] and assigns regions under the
/// plan's segmented liveness model.
pub struct GapFitPlanner<'a> {
    pub plan: &'a OffloadPlan,
}

/// Do two sorted inclusive interval lists share any EO?
pub fn intervals_overlap(a: &[(u32, u32)], b: &[(u32, u32)]) -> bool {
    let (mut i, mut j) = (0usize, 0usize);
    while i < a.len() && j < b.len() {
        let (a0, a1) = a[i];
        let (b0, b1) = b[j];
        if a0 <= b1 && b0 <= a1 {
            return true;
        }
        if a1 < b1 {
            i += 1;
        } else {
            j += 1;
        }
    }
    false
}

/// First-fit placement of `ids` (in the given order) under segmented
/// liveness; returns the pool length and each tensor's region.
fn place(
    table: &TensorTable,
    offloaded: &HashSet<TensorId>,
    ids: &[TensorId],
) -> (usize, Vec<(TensorId, Region)>) {
    struct Placed {
        intervals: Vec<(u32, u32)>,
        offset: usize,
        len: usize,
    }
    let mut placed: Vec<Placed> = Vec::with_capacity(ids.len());
    let mut regions: Vec<(TensorId, Region)> = Vec::with_capacity(ids.len());
    let mut pool_len = 0usize;
    for &id in ids {
        let s = table.get(id);
        let need = s.dim.len();
        let intervals = live_intervals(s, offloaded.contains(&id));
        // address ranges blocked by time-overlapping placements
        let mut forbidden: Vec<(usize, usize)> = placed
            .iter()
            .filter(|p| intervals_overlap(&p.intervals, &intervals))
            .map(|p| (p.offset, p.offset + p.len))
            .collect();
        forbidden.sort_unstable();
        let mut offset = 0usize;
        for &(a, b) in &forbidden {
            if offset + need <= a {
                break;
            }
            offset = offset.max(b);
        }
        regions.push((id, Region { offset, len: need }));
        pool_len = pool_len.max(offset + need);
        placed.push(Placed { intervals, offset, len: need });
    }
    (pool_len, regions)
}

impl Planner for GapFitPlanner<'_> {
    fn name(&self) -> &'static str {
        "gapfit"
    }

    fn plan(&self, table: &mut TensorTable) -> Result<usize> {
        let offloaded: HashSet<TensorId> =
            self.plan.entries.iter().map(|e| e.tensor).collect();
        let ids = allocatable(table);

        let mut by_schedule = ids.clone();
        sort_by_schedule(table, &mut by_schedule);
        let mut by_size = ids;
        by_size.sort_by_key(|&id| {
            let s = table.get(id);
            (std::cmp::Reverse(s.dim.len()), s.min_eo().unwrap_or(u32::MAX), id)
        });

        let (len_a, regions_a) = place(table, &offloaded, &by_schedule);
        let (len_b, regions_b) = place(table, &offloaded, &by_size);
        let (pool_len, regions) = if len_b < len_a {
            (len_b, regions_b)
        } else {
            (len_a, regions_a)
        };
        for (id, r) in regions {
            table.get_mut(id).region = Some(r);
        }
        Ok(pool_len)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::planner::offload::advise;
    use crate::planner::validate::validate_gap_plan;
    use crate::tensor::{
        CreateMode, Initializer, Lifespan, TensorDim, TensorRole, TensorTable,
    };

    fn table_with(entries: &[(&str, usize, &[u32], TensorRole)]) -> TensorTable {
        let mut t = TensorTable::new();
        for (name, len, eos, role) in entries {
            let id = t
                .request(*name, TensorDim::vec(1, *len), *role, CreateMode::Create, Initializer::None)
                .unwrap();
            for &e in *eos {
                t.add_eo(id, e, Lifespan::FORWARD);
            }
        }
        t.finish_orders();
        t
    }

    #[test]
    fn interval_overlap_cases() {
        assert!(intervals_overlap(&[(0, 3)], &[(3, 5)]));
        assert!(!intervals_overlap(&[(0, 3)], &[(4, 5)]));
        assert!(intervals_overlap(&[(0, 1), (8, 9)], &[(3, 8)]));
        assert!(!intervals_overlap(&[(0, 1), (8, 9)], &[(3, 6)]));
        assert!(!intervals_overlap(&[], &[(0, 100)]));
    }

    #[test]
    fn gap_reuse_shrinks_pool() {
        // `a` idles over EOs 2..9 — with `a` offloaded, `b` (live only in
        // the gap) can take the same address range.
        let mut t = table_with(&[
            ("a", 1000, &[0, 1, 10], TensorRole::Activation),
            ("b", 1000, &[4, 5], TensorRole::Activation),
        ]);
        let full = advise(&t, usize::MAX).primary_peak_bytes;
        assert_eq!(full, 2000 * 4);
        let plan = advise(&t, 1000 * 4);
        assert!(plan.fits, "{plan:?}");
        let pool_len = GapFitPlanner { plan: &plan }.plan(&mut t).unwrap();
        assert_eq!(pool_len, 1000, "b must reuse a's released region");
        validate_gap_plan(&t, &plan, pool_len).unwrap();
        // both tensors share the same offset
        assert_eq!(t.get(0).region, t.get(1).region);
    }

    #[test]
    fn prefetch_lead_blocks_tight_reuse() {
        // `b` is live through EO 9; `a` returns at EO 10 but its region is
        // reacquired at EO 9 (lead 1) — so they must NOT share space.
        let mut t = table_with(&[
            ("a", 1000, &[0, 1, 10], TensorRole::Activation),
            ("b", 1000, &[4, 5, 6, 7, 8, 9], TensorRole::Activation),
        ]);
        let plan = advise(&t, 1000 * 4);
        let pool_len = GapFitPlanner { plan: &plan }.plan(&mut t).unwrap();
        validate_gap_plan(&t, &plan, pool_len).unwrap();
        assert_eq!(pool_len, 2000);
    }

    #[test]
    fn no_offloads_behaves_like_plain_planner() {
        let mut t = table_with(&[
            ("a", 10, &[0, 3], TensorRole::Activation),
            ("b", 10, &[4, 6], TensorRole::Activation),
            ("w", 4, &[0, 6], TensorRole::Weight),
        ]);
        let plan = advise(&t, usize::MAX);
        assert!(plan.entries.is_empty());
        let pool_len = GapFitPlanner { plan: &plan }.plan(&mut t).unwrap();
        // b reuses a's slot; w is pinned alongside
        assert_eq!(pool_len, 14);
        validate_gap_plan(&t, &plan, pool_len).unwrap();
    }
}
