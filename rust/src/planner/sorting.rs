//! Algorithm 2: the paper's simple sorting-based memory planner.
//!
//! Tensors are laid out in ascending first-use order; each new tensor
//! reuses the slot of a previously-placed tensor whose last use strictly
//! precedes the new tensor's first use (`EO_max(T_j) < EO_min(T_i)`),
//! provided the slot is large enough. A slot keeps its original length —
//! a smaller tensor occupying a large dead slot wastes the tail, which
//! is exactly the fragmentation the paper shows in Fig 8 and defers to
//! future work (see [`super::BestFitPlanner`]).

use crate::error::Result;
use crate::tensor::{Region, TensorTable};

use super::{allocatable, sort_by_schedule, Planner};

pub struct SortingPlanner;

#[derive(Debug)]
struct Slot {
    offset: usize,
    len: usize,
    /// Last EO of the current occupant.
    max_eo: u32,
}

impl Planner for SortingPlanner {
    fn name(&self) -> &'static str {
        "sorting"
    }

    fn plan(&self, table: &mut TensorTable) -> Result<usize> {
        let mut ids = allocatable(table);
        sort_by_schedule(table, &mut ids);
        let mut slots: Vec<Slot> = Vec::new();
        let mut pool_len = 0usize;
        for id in ids {
            let (need, min_eo, max_eo) = {
                let s = table.get(id);
                (s.dim.len(), s.min_eo().unwrap(), s.max_eo().unwrap())
            };
            // find a dead slot big enough (first match in offset order —
            // the paper's backwards scan keeps the earliest assignment)
            let mut chosen: Option<usize> = None;
            for (k, sl) in slots.iter().enumerate() {
                if sl.max_eo < min_eo && sl.len >= need {
                    chosen = Some(k);
                    break;
                }
            }
            match chosen {
                Some(k) => {
                    let sl = &mut slots[k];
                    table.get_mut(id).region = Some(Region { offset: sl.offset, len: need });
                    sl.max_eo = max_eo;
                }
                None => {
                    table.get_mut(id).region = Some(Region { offset: pool_len, len: need });
                    slots.push(Slot { offset: pool_len, len: need, max_eo });
                    pool_len += need;
                }
            }
        }
        Ok(pool_len)
    }
}
