//! Best-fit planner with slot splitting — the paper's stated future work
//! ("a planning algorithm that can minimize or resolve fragmentation").
//!
//! Differences from Algorithm 2: a dead slot may be *split* (a tensor
//! takes only the prefix it needs, the remainder stays reusable), and
//! among eligible slots the smallest adequate one is chosen (best fit).
//! Adjacent free remainders are not coalesced across different death
//! times; in practice this removes the Fig 8 `ΔW_0`-vs-`D_2`
//! fragmentation case entirely (see `ablation_planners`).

use crate::error::Result;
use crate::tensor::{Region, TensorTable};

use super::{allocatable, sort_by_schedule, Planner};

pub struct BestFitPlanner;

#[derive(Debug, Clone)]
struct Slot {
    offset: usize,
    len: usize,
    max_eo: u32,
}

impl Planner for BestFitPlanner {
    fn name(&self) -> &'static str {
        "bestfit"
    }

    fn plan(&self, table: &mut TensorTable) -> Result<usize> {
        let mut ids = allocatable(table);
        sort_by_schedule(table, &mut ids);
        let mut slots: Vec<Slot> = Vec::new();
        let mut pool_len = 0usize;
        for id in ids {
            let (need, min_eo, max_eo) = {
                let s = table.get(id);
                (s.dim.len(), s.min_eo().unwrap(), s.max_eo().unwrap())
            };
            // best fit among dead slots
            let mut best: Option<(usize, usize)> = None; // (idx, waste)
            for (k, sl) in slots.iter().enumerate() {
                if sl.max_eo < min_eo && sl.len >= need {
                    let waste = sl.len - need;
                    if best.map(|(_, w)| waste < w).unwrap_or(true) {
                        best = Some((k, waste));
                    }
                }
            }
            match best {
                Some((k, _)) => {
                    let sl = slots[k].clone();
                    table.get_mut(id).region = Some(Region { offset: sl.offset, len: need });
                    // occupied prefix
                    slots[k] = Slot { offset: sl.offset, len: need, max_eo };
                    // free remainder keeps the old death time
                    if sl.len > need {
                        slots.push(Slot {
                            offset: sl.offset + need,
                            len: sl.len - need,
                            max_eo: sl.max_eo,
                        });
                    }
                }
                None => {
                    table.get_mut(id).region = Some(Region { offset: pool_len, len: need });
                    slots.push(Slot { offset: pool_len, len: need, max_eo });
                    pool_len += need;
                }
            }
        }
        Ok(pool_len)
    }
}
