//! Placement strategies for the gap-aware planner, unified behind one
//! [`Placer`] trait (the seam `gapfit.rs` drives its order/strategy
//! portfolio through).
//!
//! A placer maps an ordered list of [`PlaceItem`]s — tensors with their
//! sizes and (possibly segmented, lead-widened) live interval lists —
//! to pool offsets such that no two items whose intervals overlap in
//! time overlap in address space. Three strategies:
//!
//! * [`FirstFitPlacer`] — lowest feasible offset (the PR-1 default).
//! * [`BestFitPlacer`] — smallest adequate hole between blocked ranges.
//! * [`SkylinePlacer`] — a segment tree over the EO axis tracks, for
//!   every execution order, the highest occupied address (the
//!   *skyline*); each item lands on top of the skyline maximum across
//!   its own live intervals. One `O(k log E)` query replaces the
//!   `O(n)` blocked-range scan per item, so deep randomized topologies
//!   place in near-linear time — and the structure is exactly the free
//!   interval map over (address × EO-lifespan) that the compaction
//!   planner reuses.
//!
//! No single strategy dominates on every topology, so the gap planner
//! runs a *portfolio* (see `gapfit.rs`): each `PlannerKind` tier
//! evaluates a superset of the candidate layouts of the tier below and
//! commits the minimum — which is what makes the peak ordering
//! skyline ≤ best-fit ≤ first-fit a structural guarantee rather than a
//! per-topology accident.

use crate::tensor::{Region, TensorId};

use super::gapfit::intervals_overlap;

/// One tensor to place: its id, pool length, and the (sorted,
/// inclusive) EO intervals during which it occupies its region.
#[derive(Clone, Debug)]
pub struct PlaceItem {
    pub id: TensorId,
    pub need: usize,
    pub intervals: Vec<(u32, u32)>,
}

/// A placement strategy: assign offsets to `items` in the given order.
/// Returns the pool length and each item's region.
pub trait Placer {
    fn name(&self) -> &'static str;
    fn place(&self, items: &[PlaceItem]) -> (usize, Vec<(TensorId, Region)>);
}

/// Address ranges blocked by already-placed, time-overlapping items.
fn blocked_ranges(
    placed: &[(Vec<(u32, u32)>, usize, usize)],
    intervals: &[(u32, u32)],
) -> Vec<(usize, usize)> {
    let mut forbidden: Vec<(usize, usize)> = placed
        .iter()
        .filter(|(iv, _, _)| intervals_overlap(iv, intervals))
        .map(|&(_, off, len)| (off, off + len))
        .collect();
    forbidden.sort_unstable();
    forbidden
}

/// Lowest feasible offset.
pub struct FirstFitPlacer;

impl Placer for FirstFitPlacer {
    fn name(&self) -> &'static str {
        "firstfit"
    }

    fn place(&self, items: &[PlaceItem]) -> (usize, Vec<(TensorId, Region)>) {
        let mut placed: Vec<(Vec<(u32, u32)>, usize, usize)> = Vec::with_capacity(items.len());
        let mut regions = Vec::with_capacity(items.len());
        let mut pool_len = 0usize;
        for item in items {
            let forbidden = blocked_ranges(&placed, &item.intervals);
            let mut offset = 0usize;
            for &(a, b) in &forbidden {
                if offset + item.need <= a {
                    break;
                }
                offset = offset.max(b);
            }
            regions.push((item.id, Region { offset, len: item.need }));
            pool_len = pool_len.max(offset + item.need);
            placed.push((item.intervals.clone(), offset, item.need));
        }
        (pool_len, regions)
    }
}

/// Smallest adequate hole between blocked ranges (least waste); falls
/// back to the open end above every blocked range.
pub struct BestFitPlacer;

impl Placer for BestFitPlacer {
    fn name(&self) -> &'static str {
        "bestfit"
    }

    fn place(&self, items: &[PlaceItem]) -> (usize, Vec<(TensorId, Region)>) {
        let mut placed: Vec<(Vec<(u32, u32)>, usize, usize)> = Vec::with_capacity(items.len());
        let mut regions = Vec::with_capacity(items.len());
        let mut pool_len = 0usize;
        for item in items {
            let forbidden = blocked_ranges(&placed, &item.intervals);
            // sweep the (possibly mutually overlapping) blocked ranges
            // in address order, scoring each bounded hole by waste; the
            // open end above everything is the fallback
            let mut best: Option<(usize, usize)> = None; // (offset, waste)
            let mut cursor = 0usize;
            for &(a, b) in &forbidden {
                if a > cursor {
                    let hole = a - cursor;
                    if hole >= item.need {
                        let waste = hole - item.need;
                        if best.map(|(_, w)| waste < w).unwrap_or(true) {
                            best = Some((cursor, waste));
                        }
                    }
                }
                cursor = cursor.max(b);
            }
            let offset = best.map(|(o, _)| o).unwrap_or(cursor);
            regions.push((item.id, Region { offset, len: item.need }));
            pool_len = pool_len.max(offset + item.need);
            placed.push((item.intervals.clone(), offset, item.need));
        }
        (pool_len, regions)
    }
}

/// Segment tree over the EO axis: per execution order, the highest
/// occupied address so far. Supports range *raise* (chmax) when a
/// region is committed over an interval, and range max query — the
/// skyline height an item must clear to be placed "on top".
pub struct SkylineTree {
    len: usize,
    max_v: Vec<usize>,
    lazy: Vec<usize>,
}

impl SkylineTree {
    /// Tree over `len` compressed EO coordinates.
    pub fn new(len: usize) -> Self {
        let n = len.max(1);
        SkylineTree { len: n, max_v: vec![0; 4 * n], lazy: vec![0; 4 * n] }
    }

    fn push(&mut self, node: usize) {
        let pend = self.lazy[node];
        if pend > 0 {
            for child in [2 * node, 2 * node + 1] {
                self.max_v[child] = self.max_v[child].max(pend);
                self.lazy[child] = self.lazy[child].max(pend);
            }
            self.lazy[node] = 0;
        }
    }

    fn raise_rec(&mut self, node: usize, l: usize, r: usize, a: usize, b: usize, h: usize) {
        if b < l || r < a {
            return;
        }
        if a <= l && r <= b {
            self.max_v[node] = self.max_v[node].max(h);
            self.lazy[node] = self.lazy[node].max(h);
            return;
        }
        self.push(node);
        let mid = (l + r) / 2;
        self.raise_rec(2 * node, l, mid, a, b, h);
        self.raise_rec(2 * node + 1, mid + 1, r, a, b, h);
        self.max_v[node] = self.max_v[2 * node].max(self.max_v[2 * node + 1]);
    }

    fn query_rec(&mut self, node: usize, l: usize, r: usize, a: usize, b: usize) -> usize {
        if b < l || r < a {
            return 0;
        }
        if a <= l && r <= b {
            return self.max_v[node];
        }
        self.push(node);
        let mid = (l + r) / 2;
        self.query_rec(2 * node, l, mid, a, b)
            .max(self.query_rec(2 * node + 1, mid + 1, r, a, b))
    }

    /// Raise the skyline to at least `h` over coordinates `[a, b]`.
    pub fn raise(&mut self, a: usize, b: usize, h: usize) {
        let b = b.min(self.len - 1);
        self.raise_rec(1, 0, self.len - 1, a, b, h);
    }

    /// Highest skyline point over coordinates `[a, b]`.
    pub fn query(&mut self, a: usize, b: usize) -> usize {
        let b = b.min(self.len - 1);
        self.query_rec(1, 0, self.len - 1, a, b)
    }
}

/// Skyline placement: each item lands at the maximum skyline height
/// across its live intervals, then raises the skyline there. Never
/// scans other placements — feasibility is the tree invariant (every
/// committed region raised the skyline over exactly its own
/// intervals, so clearing the maximum clears every one of them).
pub struct SkylinePlacer;

impl Placer for SkylinePlacer {
    fn name(&self) -> &'static str {
        "skyline"
    }

    fn place(&self, items: &[PlaceItem]) -> (usize, Vec<(TensorId, Region)>) {
        // coordinate-compress the EO endpoints (interval containment is
        // preserved: every query/raise uses the same endpoints)
        let mut coords: Vec<u32> = items
            .iter()
            .flat_map(|it| it.intervals.iter().flat_map(|&(a, z)| [a, z]))
            .collect();
        coords.sort_unstable();
        coords.dedup();
        let coord_of = |eo: u32| coords.binary_search(&eo).expect("endpoint is a coordinate");
        let mut tree = SkylineTree::new(coords.len());
        let mut regions = Vec::with_capacity(items.len());
        let mut pool_len = 0usize;
        for item in items {
            let mut offset = 0usize;
            for &(a, z) in &item.intervals {
                offset = offset.max(tree.query(coord_of(a), coord_of(z)));
            }
            let top = offset + item.need;
            for &(a, z) in &item.intervals {
                tree.raise(coord_of(a), coord_of(z), top);
            }
            regions.push((item.id, Region { offset, len: item.need }));
            pool_len = pool_len.max(top);
        }
        (pool_len, regions)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn item(id: TensorId, need: usize, intervals: &[(u32, u32)]) -> PlaceItem {
        PlaceItem { id, need, intervals: intervals.to_vec() }
    }

    /// Brute-force validity: every pair of time-overlapping items has
    /// space-disjoint regions.
    fn assert_valid(items: &[PlaceItem], regions: &[(TensorId, Region)]) {
        for i in 0..items.len() {
            for j in i + 1..items.len() {
                if intervals_overlap(&items[i].intervals, &items[j].intervals) {
                    let a = regions[i].1;
                    let b = regions[j].1;
                    assert!(
                        !a.overlaps(&b),
                        "items {} and {} overlap in time and space: {a:?} vs {b:?}",
                        items[i].id,
                        items[j].id
                    );
                }
            }
        }
    }

    #[test]
    fn all_placers_produce_valid_layouts() {
        let items = vec![
            item(0, 10, &[(0, 3)]),
            item(1, 10, &[(4, 6)]),
            item(2, 4, &[(0, 6)]),
            item(3, 7, &[(2, 5)]),
            item(4, 3, &[(0, 1), (5, 6)]),
        ];
        for placer in [&FirstFitPlacer as &dyn Placer, &BestFitPlacer, &SkylinePlacer] {
            let (len, regions) = placer.place(&items);
            assert_valid(&items, &regions);
            assert!(len >= 10, "{} too small: {len}", placer.name());
            assert_eq!(len, regions.iter().map(|(_, r)| r.end()).max().unwrap());
        }
    }

    #[test]
    fn skyline_reuses_time_disjoint_space() {
        // b lives strictly inside a's dead time — the skyline over b's
        // interval is untouched by a only if a's intervals skip it
        let items = vec![
            item(0, 100, &[(0, 1), (8, 9)]),
            item(1, 100, &[(3, 5)]),
        ];
        let (len, regions) = SkylinePlacer.place(&items);
        assert_eq!(len, 100, "b must reuse a's address range");
        assert_eq!(regions[0].1.offset, 0);
        assert_eq!(regions[1].1.offset, 0);
    }

    #[test]
    fn skyline_stacks_time_overlapping_items() {
        let items = vec![item(0, 8, &[(0, 4)]), item(1, 8, &[(2, 6)]), item(2, 8, &[(3, 3)])];
        let (len, regions) = SkylinePlacer.place(&items);
        assert_valid(&items, &regions);
        assert_eq!(len, 24, "all three are live at EO 3");
    }

    #[test]
    fn segment_tree_raise_and_query() {
        let mut t = SkylineTree::new(16);
        assert_eq!(t.query(0, 15), 0);
        t.raise(2, 5, 10);
        t.raise(4, 9, 7);
        assert_eq!(t.query(0, 1), 0);
        assert_eq!(t.query(2, 3), 10);
        assert_eq!(t.query(5, 5), 10);
        assert_eq!(t.query(6, 9), 7);
        assert_eq!(t.query(0, 15), 10);
        t.raise(0, 15, 3);
        assert_eq!(t.query(0, 1), 3);
        assert_eq!(t.query(2, 3), 10);
    }

    #[test]
    fn single_coordinate_tree() {
        let mut t = SkylineTree::new(1);
        t.raise(0, 0, 5);
        assert_eq!(t.query(0, 0), 5);
    }
}
