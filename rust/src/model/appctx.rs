//! AppContext (paper §4): per-application registry of custom layers so
//! "applications running multiple neural network models simultaneously"
//! can share extensions across their models.

use std::collections::HashMap;

use crate::layers::{builtin_factories, LayerFactory};

/// Registry of layer factories (built-ins + application extensions).
#[derive(Default)]
pub struct AppContext {
    custom: HashMap<&'static str, LayerFactory>,
}

impl AppContext {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register (or override) a layer type.
    pub fn register_layer(&mut self, name: &'static str, factory: LayerFactory) {
        self.custom.insert(name, factory);
    }

    /// Effective factory table: built-ins overlaid with customs.
    pub fn factories(&self) -> HashMap<&'static str, LayerFactory> {
        let mut m = builtin_factories();
        for (k, v) in &self.custom {
            m.insert(k, *v);
        }
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::error::Result;
    use crate::layers::{FinalizeOut, Layer, Props, RunCtx};
    use crate::tensor::TensorDim;

    struct Identity;
    impl Layer for Identity {
        fn kind(&self) -> &'static str {
            "identity"
        }
        fn finalize(&mut self, in_dims: &[TensorDim]) -> Result<FinalizeOut> {
            Ok(FinalizeOut { out_dims: vec![in_dims[0]], ..Default::default() })
        }
        fn forward(&self, ctx: &RunCtx) {
            let (x, o) = (ctx.input(0), ctx.output(0));
            if x.as_ptr() != o.as_ptr() {
                o.copy_from_slice(x);
            }
        }
        fn calc_derivative(&self, ctx: &RunCtx) {
            if ctx.has_in_deriv(0) {
                ctx.in_deriv(0).copy_from_slice(ctx.out_deriv(0));
            }
        }
    }

    fn make_identity(_p: &Props) -> Result<Box<dyn Layer>> {
        Ok(Box::new(Identity))
    }

    #[test]
    fn custom_layer_registration() {
        let mut ctx = AppContext::new();
        ctx.register_layer("identity", make_identity);
        let f = ctx.factories();
        assert!(f.contains_key("identity"));
        assert!(f.contains_key("fully_connected"));
    }
}
