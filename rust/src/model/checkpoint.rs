//! Checkpointing: binary weight save/load (`NNTR` format, version 1).
//!
//! Layout: magic `NNTR`, u32 version, u32 count, then per weight:
//! u32 name-len, name bytes, u32 f32-count, little-endian f32 data.
//! Used by the transfer-learning flow (train backbone → save → load into
//! a frozen-backbone model whose weight names match).

use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Write};

use crate::error::{Error, Result};
use crate::exec::Executor;

const MAGIC: &[u8; 4] = b"NNTR";
const VERSION: u32 = 1;

pub fn save(exec: &Executor, path: &str) -> Result<()> {
    let mut w = BufWriter::new(File::create(path)?);
    let names = exec.weight_names();
    w.write_all(MAGIC)?;
    w.write_all(&VERSION.to_le_bytes())?;
    w.write_all(&(names.len() as u32).to_le_bytes())?;
    for name in names {
        let data = exec.read_weight(&name)?;
        w.write_all(&(name.len() as u32).to_le_bytes())?;
        w.write_all(name.as_bytes())?;
        w.write_all(&(data.len() as u32).to_le_bytes())?;
        for v in data {
            w.write_all(&v.to_le_bytes())?;
        }
    }
    Ok(())
}

/// Load weights by name; unknown names are skipped (transfer learning
/// loads a backbone checkpoint into a bigger model). Returns the number
/// of tensors restored.
pub fn load(exec: &Executor, path: &str) -> Result<usize> {
    let mut r = BufReader::new(File::open(path)?);
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        return Err(Error::Checkpoint(format!("bad magic {magic:?}")));
    }
    let version = read_u32(&mut r)?;
    if version != VERSION {
        return Err(Error::Checkpoint(format!("unsupported version {version}")));
    }
    let count = read_u32(&mut r)? as usize;
    let mut restored = 0usize;
    for _ in 0..count {
        let nlen = read_u32(&mut r)? as usize;
        if nlen > 4096 {
            return Err(Error::Checkpoint(format!("implausible name length {nlen}")));
        }
        let mut nbuf = vec![0u8; nlen];
        r.read_exact(&mut nbuf)?;
        let name = String::from_utf8(nbuf)
            .map_err(|e| Error::Checkpoint(format!("bad name utf8: {e}")))?;
        let dlen = read_u32(&mut r)? as usize;
        let mut data = vec![0f32; dlen];
        let mut b4 = [0u8; 4];
        for v in data.iter_mut() {
            r.read_exact(&mut b4)?;
            *v = f32::from_le_bytes(b4);
        }
        if exec.write_weight(&name, &data).is_ok() {
            restored += 1;
        }
    }
    Ok(restored)
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}
