//! Checkpointing: binary weight save/load (`NNTR` format).
//!
//! **Version 2** opens with an explicit manifest so a checkpoint's
//! contents can be diffed against a model *before* any weight bytes
//! move:
//!
//! ```text
//! magic `NNTR` | u32 version=2 | u32 count
//! manifest: count × { u32 name-len | name | 4 × u32 dims (b,c,h,w) | u32 f32-count }
//! data:     count × { f32-count little-endian f32 }
//! ```
//!
//! Version 1 (no manifest; name/len/data interleaved) is still read.
//!
//! Loading is *strict*: every tensor the checkpoint carries must exist
//! in the model with a matching element count, or the load fails with a
//! full name/shape diff — the silent-skip behaviour that used to train
//! personalized models from random init when a layer was renamed is
//! gone. The deliberate exception is [`load_matching`], where the
//! caller names the layers it is about to re-initialize anyway (the
//! swapped head of `personalize()`): entries under those prefixes are
//! never restored — not even when their shapes happen to match — so
//! the restored count is deterministic; everything else still fails
//! loudly. Model weights absent from the checkpoint are always fine
//! (transfer learning loads a backbone into a bigger model).
//!
//! All lengths read from the file are validated against the bytes that
//! actually remain, so a truncated or corrupted checkpoint errors
//! cleanly instead of attempting a multi-gigabyte allocation or
//! returning garbage tensors.

use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Write};

use crate::error::{Error, Result};
use crate::exec::Executor;
use crate::tensor::TensorDim;

const MAGIC: &[u8; 4] = b"NNTR";
const VERSION: u32 = 2;
/// Longest plausible `layer:weight` tensor name.
const MAX_NAME: usize = 4096;

pub fn save(exec: &Executor, path: &str) -> Result<()> {
    let mut w = BufWriter::new(File::create(path)?);
    let names = exec.weight_names();
    w.write_all(MAGIC)?;
    w.write_all(&VERSION.to_le_bytes())?;
    w.write_all(&(names.len() as u32).to_le_bytes())?;
    // manifest
    for name in &names {
        let dim = weight_dim(exec, name)?;
        w.write_all(&(name.len() as u32).to_le_bytes())?;
        w.write_all(name.as_bytes())?;
        for d in [dim.b, dim.c, dim.h, dim.w] {
            w.write_all(&(d as u32).to_le_bytes())?;
        }
        w.write_all(&(dim.len() as u32).to_le_bytes())?;
    }
    // data
    for name in &names {
        let data = exec.read_weight(name)?;
        for v in data {
            w.write_all(&v.to_le_bytes())?;
        }
    }
    Ok(())
}

/// One manifest row: what the checkpoint says it carries.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ManifestEntry {
    pub name: String,
    pub dim: TensorDim,
    /// Element count of the stored data (equals `dim.len()` for files
    /// this crate writes; trusted only after length validation).
    pub len: usize,
}

/// Byte-counting reader: every length field is checked against the
/// bytes genuinely remaining in the file before anything is allocated.
struct CheckedReader<R> {
    inner: R,
    remaining: u64,
}

impl<R: Read> CheckedReader<R> {
    fn new(inner: R, total: u64) -> Self {
        CheckedReader { inner, remaining: total }
    }

    fn take(&mut self, n: usize, what: &str) -> Result<Vec<u8>> {
        if (n as u64) > self.remaining {
            return Err(Error::Checkpoint(format!(
                "truncated checkpoint: {what} needs {n} bytes but only {} remain",
                self.remaining
            )));
        }
        let mut buf = vec![0u8; n];
        self.inner.read_exact(&mut buf)?;
        self.remaining -= n as u64;
        Ok(buf)
    }

    fn u32(&mut self, what: &str) -> Result<u32> {
        let b = self.take(4, what)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn f32s(&mut self, n: usize, what: &str) -> Result<Vec<f32>> {
        let bytes = self.take(n * 4, what)?;
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    fn name(&mut self, what: &str) -> Result<String> {
        let nlen = self.u32(what)? as usize;
        if nlen > MAX_NAME {
            return Err(Error::Checkpoint(format!(
                "implausible name length {nlen} for {what}"
            )));
        }
        let nbuf = self.take(nlen, what)?;
        String::from_utf8(nbuf).map_err(|e| Error::Checkpoint(format!("bad name utf8: {e}")))
    }

    /// Largest sane entry count given the bytes actually remaining —
    /// pre-allocation bound, so a corrupted count field cannot demand a
    /// multi-gigabyte `Vec` before the first entry read fails cleanly
    /// (`min_entry_bytes`: smallest on-disk footprint of one entry).
    fn capacity_for(&self, count: usize, min_entry_bytes: u64) -> usize {
        count.min((self.remaining / min_entry_bytes.max(1)) as usize + 1)
    }
}

impl CheckedReader<BufReader<File>> {
    /// Consume `n` bytes without reading them: the manifest scan over a
    /// v1 file skips each tensor's weight data in O(1) (`seek_relative`
    /// keeps the buffer when the jump stays inside it).
    fn skip(&mut self, n: usize, what: &str) -> Result<()> {
        if (n as u64) > self.remaining {
            return Err(Error::Checkpoint(format!(
                "truncated checkpoint: {what} needs {n} bytes but only {} remain",
                self.remaining
            )));
        }
        self.inner.seek_relative(n as i64)?;
        self.remaining -= n as u64;
        Ok(())
    }
}

fn open_checked(path: &str) -> Result<(CheckedReader<BufReader<File>>, u32, usize)> {
    let file = File::open(path)?;
    let total = file.metadata()?.len();
    let mut r = CheckedReader::new(BufReader::new(file), total);
    let magic = r.take(4, "magic")?;
    if magic != MAGIC {
        return Err(Error::Checkpoint(format!("bad magic {magic:?}")));
    }
    let version = r.u32("version")?;
    if version != 1 && version != VERSION {
        return Err(Error::Checkpoint(format!("unsupported version {version}")));
    }
    let count = r.u32("tensor count")? as usize;
    Ok((r, version, count))
}

fn read_manifest_from(
    r: &mut CheckedReader<BufReader<File>>,
    count: usize,
) -> Result<Vec<ManifestEntry>> {
    // one v2 manifest row is at least name-len + 4 dims + data-len
    let mut manifest = Vec::with_capacity(r.capacity_for(count, 24));
    for i in 0..count {
        let name = r.name(&format!("manifest entry {i}"))?;
        let mut d = [0usize; 4];
        for v in &mut d {
            *v = r.u32(&format!("dims of `{name}`"))? as usize;
        }
        let len = r.u32(&format!("data length of `{name}`"))? as usize;
        // the data section must still be able to hold this many f32s
        if (len as u64) * 4 > r.remaining {
            return Err(Error::Checkpoint(format!(
                "corrupted checkpoint: `{name}` claims {len} f32s but at most {} bytes \
                 of data remain in the file",
                r.remaining
            )));
        }
        manifest.push(ManifestEntry {
            name,
            dim: TensorDim::new(d[0], d[1], d[2], d[3]),
            len,
        });
    }
    Ok(manifest)
}

/// Read a v2 checkpoint's manifest without touching the weight data
/// (v1 files have none — this errors for them).
pub fn read_manifest(path: &str) -> Result<Vec<ManifestEntry>> {
    let (mut r, version, count) = open_checked(path)?;
    if version != VERSION {
        return Err(Error::Checkpoint(format!(
            "version {version} checkpoints carry no manifest"
        )));
    }
    read_manifest_from(&mut r, count)
}

/// Any checkpoint's manifest, with the version it came from: v2 files
/// carry one up front; for v1 files the entries are reconstructed by
/// scanning the whole file (name + element count — v1 stored no dims,
/// so each entry reports the flat `1:1:1:len` shape). Lengths are
/// validated against the remaining bytes exactly like the load paths.
pub fn manifest_of(path: &str) -> Result<(u32, Vec<ManifestEntry>)> {
    let (mut r, version, count) = open_checked(path)?;
    if version == VERSION {
        return Ok((version, read_manifest_from(&mut r, count)?));
    }
    // one v1 entry is at least name-len + data-len
    let mut manifest = Vec::with_capacity(r.capacity_for(count, 8));
    for i in 0..count {
        let name = r.name(&format!("entry {i}"))?;
        let len = r.u32(&format!("data length of `{name}`"))? as usize;
        r.skip(len * 4, &format!("data of `{name}`"))?;
        manifest.push(ManifestEntry { name, dim: TensorDim::vec(1, len), len });
    }
    Ok((version, manifest))
}

/// Render a deterministic, name-sorted diff of two manifests (the
/// `checkpoint diff` CLI): entries only in `a` print as `-`, only in
/// `b` as `+`, shape/length changes as `~`; identical entries are
/// counted. An empty-difference diff is exactly the trailing count
/// line. `compare_dims` is false when either side is a v1 file whose
/// dims are reconstructed flat — then only element counts can honestly
/// differ.
pub fn diff_manifests(
    label_a: &str,
    a: &[ManifestEntry],
    label_b: &str,
    b: &[ManifestEntry],
    compare_dims: bool,
) -> String {
    use std::collections::BTreeMap;
    let ma: BTreeMap<&str, &ManifestEntry> = a.iter().map(|m| (m.name.as_str(), m)).collect();
    let mb: BTreeMap<&str, &ManifestEntry> = b.iter().map(|m| (m.name.as_str(), m)).collect();
    let mut out = String::new();
    let mut same = 0usize;
    for (name, ea) in &ma {
        match mb.get(name) {
            None => {
                out.push_str(&format!(
                    "- `{name}` {} ({} f32) only in {label_a}\n",
                    ea.dim, ea.len
                ));
            }
            Some(eb) if ea.len != eb.len || (compare_dims && ea.dim != eb.dim) => {
                out.push_str(&format!(
                    "~ `{name}` {} ({} f32) -> {} ({} f32)\n",
                    ea.dim, ea.len, eb.dim, eb.len
                ));
            }
            Some(_) => same += 1,
        }
    }
    for (name, eb) in &mb {
        if !ma.contains_key(name) {
            out.push_str(&format!(
                "+ `{name}` {} ({} f32) only in {label_b}\n",
                eb.dim, eb.len
            ));
        }
    }
    out.push_str(&format!("{same} tensor(s) identical\n"));
    out
}

/// Diff two checkpoint files by manifest (v1 and v2 both accepted) —
/// the `nntrainer checkpoint diff` subcommand. Dims take part in the
/// comparison only when both files carry a real manifest (v2).
pub fn diff_files(path_a: &str, path_b: &str) -> Result<String> {
    let (va, ma) = manifest_of(path_a)?;
    let (vb, mb) = manifest_of(path_b)?;
    let mut out = format!(
        "a: {path_a} (v{va}, {} tensors)\nb: {path_b} (v{vb}, {} tensors)\n",
        ma.len(),
        mb.len()
    );
    out.push_str(&diff_manifests("a", &ma, "b", &mb, va == VERSION && vb == VERSION));
    Ok(out)
}

/// Load weights by name, strictly: any checkpoint tensor the model
/// cannot take (unknown name, element-count mismatch) fails the load
/// with a diff. Returns the number of tensors restored.
pub fn load(exec: &Executor, path: &str) -> Result<usize> {
    load_matching(exec, path, &[])
}

/// [`load`] with an allow-list: checkpoint tensors whose *layer name*
/// starts with one of `skip_prefixes` are never restored (matching or
/// not — the caller re-initializes them anyway, and restoring only the
/// shape-coincident ones would make the restored count depend on the
/// coincidence). `personalize()` passes its head (reinit) prefixes
/// here, so a swapped head with a different shape loads cleanly while
/// a typoed backbone layer still fails with a diff.
pub fn load_matching(exec: &Executor, path: &str, skip_prefixes: &[String]) -> Result<usize> {
    let (mut r, version, count) = open_checked(path)?;
    match version {
        VERSION => {
            let manifest = read_manifest_from(&mut r, count)?;
            // diff the whole manifest before moving any bytes: the model
            // must take every non-skipped entry, or nothing is written
            let mut diffs = Vec::new();
            for m in &manifest {
                if skipped(&m.name, skip_prefixes) {
                    continue;
                }
                match model_len(exec, &m.name) {
                    None => diffs.push(format!(
                        "  `{}` {} ({} f32) — model has no such weight",
                        m.name, m.dim, m.len
                    )),
                    Some(have) if have != m.len => diffs.push(format!(
                        "  `{}` {} ({} f32) — model expects {} f32 ({})",
                        m.name,
                        m.dim,
                        m.len,
                        have,
                        model_dim(exec, &m.name)
                    )),
                    Some(_) => {}
                }
            }
            if !diffs.is_empty() {
                return Err(Error::Checkpoint(format!(
                    "checkpoint `{path}` does not match the model ({} of {} tensors):\n{}",
                    diffs.len(),
                    manifest.len(),
                    diffs.join("\n")
                )));
            }
            let mut restored = 0usize;
            for m in &manifest {
                let data = r.f32s(m.len, &format!("data of `{}`", m.name))?;
                if skipped(&m.name, skip_prefixes) {
                    continue; // the head being swapped out — bytes consumed, not applied
                }
                exec.write_weight(&m.name, &data)?;
                restored += 1;
            }
            Ok(restored)
        }
        _ => load_v1(exec, &mut r, count, skip_prefixes),
    }
}

/// Version-1 fallback: no manifest, so the whole file is read and
/// diffed *before* any weight is written (the mixed-state hazard —
/// "first entry restored, second entry fails" — must not come back
/// through the legacy path). Mismatches outside `skip_prefixes` fail
/// with the collected diff; every length is validated before
/// allocation.
fn load_v1(
    exec: &Executor,
    r: &mut CheckedReader<BufReader<File>>,
    count: usize,
    skip_prefixes: &[String],
) -> Result<usize> {
    let mut pending: Vec<(String, Vec<f32>)> = Vec::with_capacity(r.capacity_for(count, 8));
    let mut diffs = Vec::new();
    for i in 0..count {
        let name = r.name(&format!("entry {i}"))?;
        let dlen = r.u32(&format!("data length of `{name}`"))? as usize;
        if (dlen as u64) * 4 > r.remaining {
            return Err(Error::Checkpoint(format!(
                "truncated checkpoint: `{name}` claims {dlen} f32s but only {} bytes remain",
                r.remaining
            )));
        }
        let data = r.f32s(dlen, &format!("data of `{name}`"))?;
        match model_len(exec, &name) {
            Some(have) if have == dlen => {
                if !skipped(&name, skip_prefixes) {
                    pending.push((name, data));
                }
            }
            miss => {
                if !skipped(&name, skip_prefixes) {
                    diffs.push(match miss {
                        None => format!("  `{name}` ({dlen} f32) — model has no such weight"),
                        Some(have) => format!(
                            "  `{name}` ({dlen} f32) — model expects {have} f32"
                        ),
                    });
                }
            }
        }
    }
    if !diffs.is_empty() {
        return Err(Error::Checkpoint(format!(
            "checkpoint does not match the model ({} of {count} tensors):\n{}",
            diffs.len(),
            diffs.join("\n")
        )));
    }
    for (name, data) in &pending {
        exec.write_weight(name, data)?;
    }
    Ok(pending.len())
}

fn skipped(tensor_name: &str, prefixes: &[String]) -> bool {
    let layer = tensor_name.split(':').next().unwrap_or("");
    prefixes.iter().any(|p| layer.starts_with(p.as_str()))
}

fn model_len(exec: &Executor, name: &str) -> Option<usize> {
    let id = exec.graph.table.by_name(name)?;
    let root = exec.graph.table.resolve(id);
    Some(exec.graph.table.get(root).dim.len())
}

fn model_dim(exec: &Executor, name: &str) -> TensorDim {
    exec.graph
        .table
        .by_name(name)
        .map(|id| exec.graph.table.get(exec.graph.table.resolve(id)).dim)
        .unwrap_or(TensorDim::new(0, 0, 0, 0))
}

fn weight_dim(exec: &Executor, name: &str) -> Result<TensorDim> {
    let id = exec
        .graph
        .table
        .by_name(name)
        .ok_or_else(|| Error::Checkpoint(format!("unknown weight `{name}`")))?;
    Ok(exec.graph.table.get(exec.graph.table.resolve(id)).dim)
}
