//! The seed-era `Model` type and `ModelBuilder` — kept as a thin
//! **deprecated shim** over the lifecycle-staged [`Session`] API
//! (`model/session.rs`): `ModelBuilder::compile(&CompileOpts)` routes
//! through `Session::configure(..).compile_for(..)` so every seed and
//! PR-1 caller (including the swap-equivalence suites) runs unchanged,
//! while new code gets budget-aware batch selection, freeze contracts and
//! training callbacks from the one real path.

use crate::compiler::CompileOpts;
use crate::dataset::DataProducer;
use crate::error::{Error, Result};
use crate::exec::Executor;
use crate::graph::NodeDesc;
use crate::layers::Props;
use crate::metrics::PlanReport;
use crate::model::appctx::AppContext;
use crate::model::session::{run_training, DeviceProfile, Session, TrainSpec};

/// Builder: accumulates layer descriptions and hyper-parameters
/// (the *Load*/*Configure* stages). Deprecated in favour of [`Session`].
pub struct ModelBuilder {
    pub nodes: Vec<NodeDesc>,
    pub optimizer_kind: String,
    pub optimizer_props: Props,
    pub appctx: AppContext,
}

impl Default for ModelBuilder {
    fn default() -> Self {
        Self::new()
    }
}

impl ModelBuilder {
    pub fn new() -> Self {
        ModelBuilder {
            nodes: vec![],
            optimizer_kind: "sgd".into(),
            optimizer_props: Props::new(),
            appctx: AppContext::new(),
        }
    }

    /// Add one layer: `add("fc1", "fully_connected", &[("unit","10")])`.
    pub fn add(mut self, name: &str, ltype: &str, pairs: &[(&str, &str)]) -> Self {
        self.nodes.push(NodeDesc::new(name, ltype, Props::from_pairs(pairs.iter().copied())));
        self
    }

    pub fn add_node(mut self, node: NodeDesc) -> Self {
        self.nodes.push(node);
        self
    }

    pub fn add_nodes(mut self, nodes: impl IntoIterator<Item = NodeDesc>) -> Self {
        self.nodes.extend(nodes);
        self
    }

    pub fn optimizer(mut self, kind: &str, pairs: &[(&str, &str)]) -> Self {
        self.optimizer_kind = kind.to_string();
        self.optimizer_props = Props::from_pairs(pairs.iter().copied());
        self
    }

    pub fn with_appctx(mut self, ctx: AppContext) -> Self {
        self.appctx = ctx;
        self
    }

    /// *Compile* + *Initialize* — **deprecated shim**: lowers the flat
    /// `CompileOpts` onto the [`Session`] lifecycle
    /// (`configure(TrainSpec)` + `compile_for(DeviceProfile)`) and
    /// unwraps the result. Field-for-field equivalent to the seed path,
    /// so plans, pools and training remain bitwise identical.
    pub fn compile(self, opts: &CompileOpts) -> Result<Model> {
        let spec = TrainSpec {
            batch: Some(opts.batch),
            training: opts.training,
            clip_norm: opts.clip_norm,
            seed: opts.seed,
            ..TrainSpec::default()
        };
        let profile = DeviceProfile {
            memory_budget_bytes: opts.memory_budget_bytes,
            swap: true,
            swap_store: opts.swap_store,
            swap_tuning: opts.swap_tuning,
            planner: opts.planner,
            conventional: opts.conventional,
            inplace: opts.inplace,
            compute: opts.compute,
            pool_compaction: opts.pool_compaction,
            ..DeviceProfile::default()
        };
        Ok(Session::from_builder(self).configure(spec).compile_for(profile)?.into_model())
    }
}

/// Epoch-level training configuration.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub epochs: usize,
    /// Batch-queue depth (backpressure bound).
    pub queue_depth: usize,
    /// Print per-epoch summaries.
    pub verbose: bool,
    /// Fraction of each epoch's batches held out for forward-only loss
    /// evaluation (see `TrainSpec::val_split`). `0.0` = none.
    pub val_split: f32,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig { epochs: 1, queue_depth: 2, verbose: false, val_split: 0.0 }
    }
}

/// Result of a training run.
#[derive(Clone, Debug, Default)]
pub struct TrainSummary {
    pub epochs: usize,
    pub iterations: usize,
    pub final_loss: f32,
    pub losses_per_epoch: Vec<f32>,
    /// Held-out loss per epoch (empty unless a validation split was
    /// configured).
    pub val_losses_per_epoch: Vec<f32>,
    pub wall_s: f64,
}

/// A compiled, planned, ready-to-train model.
pub struct Model {
    pub exec: Executor,
    pub report: PlanReport,
    pub opts: CompileOpts,
}

/// Which placeholder family a flat buffer scatters into.
#[derive(Clone, Copy)]
enum BindTarget {
    Input,
    Label,
}

/// Split a flat `[batch, total_feat]` buffer across the target nodes in
/// graph order — the one scatter loop shared by batch binding and the
/// inference path (the seed shipped two diverging copies of it).
fn scatter_flat(
    exec: &Executor,
    batch: usize,
    data: &[f32],
    target: BindTarget,
    what: &str,
) -> Result<()> {
    let feats: Vec<usize> = match target {
        BindTarget::Input => exec
            .graph
            .input_nodes
            .iter()
            .map(|&n| exec.graph.nodes[n].out_dims[0].feature_len())
            .collect(),
        BindTarget::Label => exec
            .graph
            .loss_nodes
            .iter()
            .map(|&n| exec.graph.nodes[n].in_dims[0].feature_len())
            .collect(),
    };
    let total: usize = feats.iter().sum();
    if data.len() != total * batch {
        return Err(Error::shape(format!("{what} len {} != {}x{}", data.len(), batch, total)));
    }
    let mut off = 0usize;
    for (k, &f) in feats.iter().enumerate() {
        let bind = |buf: &[f32]| match target {
            BindTarget::Input => exec.bind_input(k, buf),
            BindTarget::Label => exec.bind_label(k, buf),
        };
        if feats.len() == 1 {
            bind(data)?;
        } else {
            let mut buf = vec![0f32; batch * f];
            for s in 0..batch {
                buf[s * f..(s + 1) * f]
                    .copy_from_slice(&data[s * total + off..s * total + off + f]);
            }
            bind(&buf)?;
        }
        off += f;
    }
    Ok(())
}

impl Model {
    /// Peak training memory (the pool), known before execution.
    pub fn peak_pool_bytes(&self) -> usize {
        self.report.pool_bytes
    }

    /// Bind one assembled batch: the flat `[batch, total_in_feat]` input
    /// is split across input nodes (in graph order), `[batch,
    /// total_label_feat]` across loss labels.
    pub fn bind_batch(&self, input: &[f32], label: &[f32]) -> Result<()> {
        scatter_flat(&self.exec, self.opts.batch, input, BindTarget::Input, "batch input")?;
        scatter_flat(&self.exec, self.opts.batch, label, BindTarget::Label, "batch label")
    }

    /// Train for `cfg.epochs` epochs; `make_producer` is called once per
    /// epoch (the Batch Queue consumes the producer on its thread).
    pub fn train(
        &mut self,
        make_producer: impl Fn() -> Box<dyn DataProducer>,
        cfg: &TrainConfig,
    ) -> Result<TrainSummary> {
        run_training(self, &make_producer, cfg, &mut [])
    }

    /// Forward-only pass over one bound batch; returns the last non-loss
    /// node's output.
    pub fn infer(&mut self, input: &[f32]) -> Result<Vec<f32>> {
        scatter_flat(&self.exec, self.opts.batch, input, BindTarget::Input, "infer input")?;
        self.exec.try_forward_pass()?;
        // last non-loss, non-input node
        let last = self
            .exec
            .graph
            .nodes
            .iter()
            .rev()
            .find(|n| !n.is_loss && !n.is_input)
            .ok_or_else(|| Error::graph("no output node"))?;
        let name = last.name.clone();
        self.exec.read_output(&name)
    }

    /// Forward-only pass reading a named node's output (feature
    /// extraction).
    pub fn infer_node(&mut self, input: &[f32], node: &str) -> Result<Vec<f32>> {
        scatter_flat(&self.exec, self.opts.batch, input, BindTarget::Input, "infer input")?;
        self.exec.try_forward_pass()?;
        self.exec.read_output(node)
    }

    pub fn save(&self, path: &str) -> Result<()> {
        crate::model::checkpoint::save(&self.exec, path)
    }

    pub fn load(&mut self, path: &str) -> Result<usize> {
        crate::model::checkpoint::load(&self.exec, path)
    }
}
