//! The `Model` type: the user-facing API tying compiler, planner,
//! executor and data pipeline together.

use crate::compiler::{compile_with, CompileOpts};
use crate::dataset::{BatchQueue, DataProducer};
use crate::error::{Error, Result};
use crate::exec::Executor;
use crate::graph::NodeDesc;
use crate::layers::Props;
use crate::metrics::{PlanReport, Timer};
use crate::model::appctx::AppContext;
use crate::optimizer;

/// Builder: accumulates layer descriptions and hyper-parameters
/// (the *Load*/*Configure* stages).
pub struct ModelBuilder {
    pub nodes: Vec<NodeDesc>,
    pub optimizer_kind: String,
    pub optimizer_props: Props,
    pub appctx: AppContext,
}

impl Default for ModelBuilder {
    fn default() -> Self {
        Self::new()
    }
}

impl ModelBuilder {
    pub fn new() -> Self {
        ModelBuilder {
            nodes: vec![],
            optimizer_kind: "sgd".into(),
            optimizer_props: Props::new(),
            appctx: AppContext::new(),
        }
    }

    /// Add one layer: `add("fc1", "fully_connected", &[("unit","10")])`.
    pub fn add(mut self, name: &str, ltype: &str, pairs: &[(&str, &str)]) -> Self {
        self.nodes.push(NodeDesc::new(name, ltype, Props::from_pairs(pairs.iter().copied())));
        self
    }

    pub fn add_node(mut self, node: NodeDesc) -> Self {
        self.nodes.push(node);
        self
    }

    pub fn add_nodes(mut self, nodes: impl IntoIterator<Item = NodeDesc>) -> Self {
        self.nodes.extend(nodes);
        self
    }

    pub fn optimizer(mut self, kind: &str, pairs: &[(&str, &str)]) -> Self {
        self.optimizer_kind = kind.to_string();
        self.optimizer_props = Props::from_pairs(pairs.iter().copied());
        self
    }

    pub fn with_appctx(mut self, ctx: AppContext) -> Self {
        self.appctx = ctx;
        self
    }

    /// *Compile* + *Initialize*: realizers, Algorithm 1, memory planning,
    /// pool allocation, weight init.
    pub fn compile(self, opts: &CompileOpts) -> Result<Model> {
        let opt = optimizer::create(&self.optimizer_kind, &self.optimizer_props)?;
        let factories = self.appctx.factories();
        let (exec, report) = compile_with(self.nodes, opt, opts, &factories)?;
        Ok(Model { exec, report, opts: opts.clone() })
    }
}

/// Epoch-level training configuration.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub epochs: usize,
    /// Batch-queue depth (backpressure bound).
    pub queue_depth: usize,
    /// Print per-epoch summaries.
    pub verbose: bool,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig { epochs: 1, queue_depth: 2, verbose: false }
    }
}

/// Result of a training run.
#[derive(Clone, Debug, Default)]
pub struct TrainSummary {
    pub epochs: usize,
    pub iterations: usize,
    pub final_loss: f32,
    pub losses_per_epoch: Vec<f32>,
    pub wall_s: f64,
}

/// A compiled, planned, ready-to-train model.
pub struct Model {
    pub exec: Executor,
    pub report: PlanReport,
    pub opts: CompileOpts,
}

impl Model {
    /// Peak training memory (the pool), known before execution.
    pub fn peak_pool_bytes(&self) -> usize {
        self.report.pool_bytes
    }

    /// Bind one assembled batch: the flat `[batch, total_in_feat]` input
    /// is split across input nodes (in graph order), `[batch,
    /// total_label_feat]` across loss labels.
    pub fn bind_batch(&self, input: &[f32], label: &[f32]) -> Result<()> {
        let batch = self.opts.batch;
        // split inputs by per-node feature size
        let feats: Vec<usize> = self
            .exec
            .graph
            .input_nodes
            .iter()
            .map(|&n| self.exec.graph.nodes[n].out_dims[0].feature_len())
            .collect();
        let total: usize = feats.iter().sum();
        if input.len() != total * batch {
            return Err(Error::shape(format!(
                "batch input len {} != {}x{}",
                input.len(),
                batch,
                total
            )));
        }
        let mut off = 0usize;
        for (k, &f) in feats.iter().enumerate() {
            if feats.len() == 1 {
                self.exec.bind_input(k, input)?;
            } else {
                let mut buf = vec![0f32; batch * f];
                for s in 0..batch {
                    buf[s * f..(s + 1) * f]
                        .copy_from_slice(&input[s * total + off..s * total + off + f]);
                }
                self.exec.bind_input(k, &buf)?;
            }
            off += f;
        }
        // split labels by loss-node label size
        let lfeats: Vec<usize> = self
            .exec
            .graph
            .loss_nodes
            .iter()
            .map(|&n| self.exec.graph.nodes[n].in_dims[0].feature_len())
            .collect();
        let ltotal: usize = lfeats.iter().sum();
        if label.len() != ltotal * batch {
            return Err(Error::shape(format!(
                "batch label len {} != {}x{}",
                label.len(),
                batch,
                ltotal
            )));
        }
        let mut loff = 0usize;
        for (k, &f) in lfeats.iter().enumerate() {
            if lfeats.len() == 1 {
                self.exec.bind_label(k, label)?;
            } else {
                let mut buf = vec![0f32; batch * f];
                for s in 0..batch {
                    buf[s * f..(s + 1) * f]
                        .copy_from_slice(&label[s * ltotal + loff..s * ltotal + loff + f]);
                }
                self.exec.bind_label(k, &buf)?;
            }
            loff += f;
        }
        Ok(())
    }

    /// Train for `cfg.epochs` epochs; `make_producer` is called once per
    /// epoch (the Batch Queue consumes the producer on its thread).
    pub fn train(
        &mut self,
        make_producer: impl Fn() -> Box<dyn DataProducer>,
        cfg: &TrainConfig,
    ) -> Result<TrainSummary> {
        let timer = Timer::start();
        let mut summary = TrainSummary { epochs: cfg.epochs, ..Default::default() };
        for epoch in 0..cfg.epochs {
            let queue = BatchQueue::spawn(make_producer(), self.opts.batch, cfg.queue_depth);
            let mut epoch_loss = 0f64;
            let mut batches = 0usize;
            while let Some(b) = queue.next() {
                self.bind_batch(&b.input, &b.label)?;
                let loss = self.exec.try_train_iteration()?;
                epoch_loss += loss as f64;
                batches += 1;
            }
            if batches == 0 {
                return Err(Error::Dataset("no full batch produced".into()));
            }
            let mean = (epoch_loss / batches as f64) as f32;
            summary.losses_per_epoch.push(mean);
            summary.iterations += batches;
            summary.final_loss = mean;
            if cfg.verbose {
                println!("epoch {:>3}: loss {:.6} ({} iters)", epoch + 1, mean, batches);
            }
        }
        summary.wall_s = timer.elapsed_s();
        Ok(summary)
    }

    /// Forward-only pass over one bound batch; returns the named node's
    /// output (defaults to the last non-loss node).
    pub fn infer(&mut self, input: &[f32]) -> Result<Vec<f32>> {
        // bind input only; labels untouched
        let feats: Vec<usize> = self
            .exec
            .graph
            .input_nodes
            .iter()
            .map(|&n| self.exec.graph.nodes[n].out_dims[0].feature_len())
            .collect();
        let total: usize = feats.iter().sum();
        let batch = self.opts.batch;
        if input.len() != total * batch {
            return Err(Error::shape(format!(
                "infer input len {} != {}x{}",
                input.len(),
                batch,
                total
            )));
        }
        let mut off = 0usize;
        for (k, &f) in feats.iter().enumerate() {
            if feats.len() == 1 {
                self.exec.bind_input(k, input)?;
            } else {
                let mut buf = vec![0f32; batch * f];
                for s in 0..batch {
                    buf[s * f..(s + 1) * f]
                        .copy_from_slice(&input[s * total + off..s * total + off + f]);
                }
                self.exec.bind_input(k, &buf)?;
            }
            off += f;
        }
        self.exec.try_forward_pass()?;
        // last non-loss, non-input node
        let last = self
            .exec
            .graph
            .nodes
            .iter()
            .rev()
            .find(|n| !n.is_loss && !n.is_input)
            .ok_or_else(|| Error::graph("no output node"))?;
        let name = last.name.clone();
        self.exec.read_output(&name)
    }

    pub fn save(&self, path: &str) -> Result<()> {
        crate::model::checkpoint::save(&self.exec, path)
    }

    pub fn load(&mut self, path: &str) -> Result<usize> {
        crate::model::checkpoint::load(&self.exec, path)
    }
}
