//! Lifecycle-staged session API — the paper's pipeline as a typestate:
//!
//! ```text
//! Session::describe(..)            Load      (builder calls, zoo nets, INI)
//!   .configure(TrainSpec)          Configure (what is trainable, epochs, clip)
//!   .compile_for(DeviceProfile)    Compile + Initialize (what the device affords)
//!   -> CompiledSession             Train / Infer / Personalize
//! ```
//!
//! Each stage is a distinct type, so stage order is enforced by the
//! compiler: you cannot train an unplanned model or re-plan a compiled
//! one. [`TrainSpec`] owns the training-algorithm contract (batch,
//! epochs, gradient clipping, *freeze* set); [`DeviceProfile`] owns the
//! device contract (memory budget, swap store, planner choice) that used
//! to be hand-assembled as `CompileOpts`. `compile_for` implements the
//! ROADMAP's budget-aware batch scheduler: with no explicit batch and a
//! memory budget, it binary-searches the largest batch whose *planned*
//! pool fits — pure analysis via [`crate::compiler::plan_graph`], no pool
//! is allocated during the search.
//!
//! [`CompiledSession::personalize`] makes the paper's §5 scenario
//! first-class: load a checkpoint, keep the frozen backbone bitwise
//! intact, re-initialize a swapped head, and fine-tune under the budget
//! with [`TrainCallback`] hooks (`on_iteration`, `on_epoch_end`,
//! [`EarlyStop`]) so training algorithms compose without touching the
//! executor.
//!
//! The seed-era `ModelBuilder::compile(&CompileOpts)` survives as a thin
//! shim over this path (see `model.rs`), so PR-1 callers run unchanged.

use std::collections::HashMap;

use crate::backend::ComputeKind;
use crate::compiler::{analyze, compile_graph, plan_graph, CompileOpts};
use crate::dataset::{BatchQueue, DataProducer};
use crate::error::{Error, Result};
use crate::exec::ShapeTemplate;
use crate::graph::{Graph, NodeDesc};
use crate::layers::{LayerFactory, Props};
use crate::metrics::{PlanReport, Timer, MIB};
use crate::model::appctx::AppContext;
use crate::model::model::{Model, ModelBuilder, TrainConfig, TrainSummary};
use crate::model::{checkpoint, ini};
use crate::optimizer::{self, Optimizer};
use crate::planner::PlannerKind;
use crate::runtime::calibrate::SwapTuning;
use crate::runtime::store::StoreKind;
use crate::tensor::Region;

/// Batch used when neither the caller nor a memory budget decides one.
pub const DEFAULT_BATCH: usize = 32;

// --------------------------------------------------------------- contracts

/// The training-algorithm contract (*Configure* stage): what is trained,
/// for how long, and under which regularization — everything the device
/// does not dictate.
#[derive(Clone, Debug)]
pub struct TrainSpec {
    /// Samples per iteration. `None` delegates the choice: under a
    /// [`DeviceProfile`] memory budget the largest fitting batch is
    /// auto-selected, otherwise [`DEFAULT_BATCH`] is used.
    pub batch: Option<usize>,
    pub epochs: usize,
    /// Global-norm gradient clipping (forces deferred apply).
    pub clip_norm: Option<f32>,
    /// Weight-init / shuffle seed.
    pub seed: u64,
    /// Batch-queue depth (backpressure bound).
    pub queue_depth: usize,
    /// Print per-epoch summaries.
    pub verbose: bool,
    /// Compile for training (backward graph + gradients). `false` plans
    /// a forward-only (inference/feature-extraction) session.
    pub training: bool,
    /// Layer-name prefixes to freeze (`trainable = false`): frozen layers
    /// get no gradient or optimizer-state tensors planned at all — the
    /// planner table shrinks, not just the update loop. This is the
    /// paper's fine-tune-a-frozen-backbone contract as an API instead of
    /// per-layer string props.
    pub freeze: Vec<String>,
    /// Fraction of each epoch's batches held out for validation
    /// (`0.0` = none, clamped to `0.5`). Held-out batches run a
    /// forward-only loss evaluation (no weight update, inference mode);
    /// their epoch mean lands in `TrainEvent::val_loss` and
    /// `TrainSummary::val_losses_per_epoch`, and [`EarlyStop`] watches
    /// it instead of the training loss whenever it exists.
    pub val_split: f32,
}

impl Default for TrainSpec {
    fn default() -> Self {
        TrainSpec {
            batch: None,
            epochs: 1,
            clip_norm: None,
            seed: 42,
            queue_depth: 2,
            verbose: false,
            training: true,
            freeze: vec![],
            val_split: 0.0,
        }
    }
}

/// The device contract (*Compile* stage): what the hardware affords.
/// Subsumes the seed-era `CompileOpts` knobs that described the device
/// rather than the algorithm.
#[derive(Clone, Debug)]
pub struct DeviceProfile {
    /// Primary-memory budget in bytes. Drives both automatic batch
    /// selection (when [`TrainSpec::batch`] is `None`) and — with
    /// [`DeviceProfile::swap`] — the proactive swap runtime. The budget
    /// is a target, not a hard guarantee; check
    /// [`CompiledSession::fits_budget`].
    pub memory_budget_bytes: Option<usize>,
    /// Engage the proactive swap runtime under the budget. With `false`
    /// the budget only constrains batch selection against the plain
    /// planner's pool.
    pub swap: bool,
    /// Secondary store backing the swap runtime.
    pub swap_store: StoreKind,
    /// How the swap runtime's prefetch leads and in-flight depth are
    /// chosen: `Fixed` keeps the global 1-EO lead / depth-2 constants;
    /// `Calibrated` micro-benchmarks the store at compile time, derives
    /// per-entry leads from bandwidth vs. per-EO compute, and keeps
    /// adapting depth from stall telemetry at epoch boundaries. Results
    /// are bitwise identical either way — tuning only moves when the
    /// background copies happen.
    pub swap_tuning: SwapTuning,
    /// Memory planner; under a budget `BestFit` selects the best-fit
    /// gap-aware placement, `Skyline` the skyline portfolio placer,
    /// anything else the first-fit default.
    pub planner: PlannerKind,
    /// Plan a one-shot pool compaction, applied at the first epoch
    /// boundary: persistent tensors slide down into layout holes and the
    /// arena truncates. Opt-in because compile-time `Region` captures
    /// (e.g. [`CompiledSession::head_state_layout`] snapshots held by
    /// the fleet) go stale across a relocation. Only meaningful under a
    /// budget with swap engaged.
    pub pool_compaction: bool,
    /// Cross-iteration swap pipelining: persistent tensors (weights,
    /// optimizer state) additionally spill across the iteration
    /// boundary, their transfers overlapping the adjacent iterations
    /// instead of draining at the boundary. Only effective under
    /// per-layer apply (no gradient clipping, no shared weights) —
    /// otherwise a structural no-op. Bitwise identical either way.
    /// Opt-in; only meaningful under a budget with swap engaged.
    pub swap_pipeline: bool,
    /// Conventional-framework allocation profile (Fig 9 baseline).
    pub conventional: bool,
    /// MV/RV in-place realization.
    pub inplace: bool,
    /// Upper bound for the automatic batch search.
    pub max_batch: usize,
    /// Compute backend executing the layer math. `Tiered` (default)
    /// runs the cache-blocked worker-pool GEMMs and drops conv's
    /// materialized im2col temp; `Naive` keeps the original
    /// single-threaded kernels as a bitwise regression baseline.
    pub compute: ComputeKind,
}

impl Default for DeviceProfile {
    fn default() -> Self {
        DeviceProfile {
            memory_budget_bytes: None,
            swap: true,
            swap_store: StoreKind::Host,
            swap_tuning: SwapTuning::Fixed,
            planner: PlannerKind::Sorting,
            pool_compaction: false,
            swap_pipeline: false,
            conventional: false,
            inplace: true,
            max_batch: 512,
            compute: ComputeKind::default(),
        }
    }
}

impl DeviceProfile {
    /// No budget: plan with the selected planner, allocate whatever the
    /// model needs.
    pub fn unconstrained() -> Self {
        Self::default()
    }

    /// Budget in bytes, swap runtime engaged.
    pub fn with_budget_bytes(bytes: usize) -> Self {
        DeviceProfile { memory_budget_bytes: Some(bytes), ..Self::default() }
    }

    /// Budget in MiB, swap runtime engaged.
    pub fn with_budget_mib(mib: f64) -> Self {
        Self::with_budget_bytes((mib * MIB) as usize)
    }

    /// Same profile with bandwidth-calibrated swap tuning.
    pub fn calibrated(mut self) -> Self {
        self.swap_tuning = SwapTuning::Calibrated;
        self
    }

    /// Same profile with epoch-boundary pool compaction enabled. Do not
    /// combine with compile-time `Region` captures (fleet head-state
    /// layouts) — they go stale when the pool relocates.
    pub fn compacting(mut self) -> Self {
        self.pool_compaction = true;
        self
    }

    /// Same profile on the naive single-threaded compute backend —
    /// the bitwise regression baseline for the tiered kernels.
    pub fn naive_compute(mut self) -> Self {
        self.compute = ComputeKind::Naive;
        self
    }

    /// Same profile with cross-iteration swap pipelining: persistent
    /// tensors stream through the store across the iteration boundary,
    /// overlapping the boundary transfers with the adjacent iterations.
    pub fn pipelined(mut self) -> Self {
        self.swap_pipeline = true;
        self
    }

    /// Conventional-framework emulation (naive planner, no in-place, no
    /// swap) — the evaluation's baseline device profile.
    pub fn conventional() -> Self {
        DeviceProfile {
            planner: PlannerKind::Naive,
            conventional: true,
            inplace: false,
            swap: false,
            ..Self::default()
        }
    }
}

// --------------------------------------------------------------- callbacks

/// What a callback wants the training loop to do next.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CallbackAction {
    Continue,
    /// Stop training after the current bookkeeping; `TrainSummary.epochs`
    /// reflects the epochs actually run.
    Stop,
}

/// One training observation handed to callbacks. For `on_iteration`,
/// `loss` is the iteration loss; for `on_epoch_end` it is the epoch mean.
#[derive(Clone, Copy, Debug)]
pub struct TrainEvent {
    pub epoch: usize,
    /// Global iteration count so far (1-based).
    pub iteration: usize,
    pub loss: f32,
    /// Held-out loss (epoch mean), present at `on_epoch_end` when
    /// [`TrainSpec::val_split`] held batches out this epoch.
    pub val_loss: Option<f32>,
}

/// Training-loop hooks. Both methods default to `Continue`, so a
/// callback implements only the events it cares about.
pub trait TrainCallback {
    fn on_iteration(&mut self, _ev: &TrainEvent) -> CallbackAction {
        CallbackAction::Continue
    }
    fn on_epoch_end(&mut self, _ev: &TrainEvent) -> CallbackAction {
        CallbackAction::Continue
    }
}

/// Adapter: a closure as an `on_iteration` callback.
pub struct OnIteration<F: FnMut(&TrainEvent) -> CallbackAction>(pub F);

impl<F: FnMut(&TrainEvent) -> CallbackAction> TrainCallback for OnIteration<F> {
    fn on_iteration(&mut self, ev: &TrainEvent) -> CallbackAction {
        (self.0)(ev)
    }
}

/// Adapter: a closure as an `on_epoch_end` callback.
pub struct OnEpochEnd<F: FnMut(&TrainEvent) -> CallbackAction>(pub F);

impl<F: FnMut(&TrainEvent) -> CallbackAction> TrainCallback for OnEpochEnd<F> {
    fn on_epoch_end(&mut self, ev: &TrainEvent) -> CallbackAction {
        (self.0)(ev)
    }
}

/// Stop when the monitored epoch-mean loss has not improved by at least
/// `min_delta` for `patience` consecutive epochs. Monitors the held-out
/// loss whenever the training loop provides one
/// ([`TrainSpec::val_split`]), else the training loss — overfitting on
/// a personalization-sized dataset shows up on the held-out split while
/// the training loss still falls.
pub struct EarlyStop {
    pub patience: usize,
    pub min_delta: f32,
    best: f32,
    bad: usize,
}

impl EarlyStop {
    pub fn new(patience: usize, min_delta: f32) -> Self {
        EarlyStop { patience, min_delta, best: f32::INFINITY, bad: 0 }
    }

    /// Best epoch-mean loss seen so far.
    pub fn best(&self) -> f32 {
        self.best
    }
}

impl TrainCallback for EarlyStop {
    fn on_epoch_end(&mut self, ev: &TrainEvent) -> CallbackAction {
        let monitored = ev.val_loss.unwrap_or(ev.loss);
        if monitored < self.best - self.min_delta {
            self.best = monitored;
            self.bad = 0;
            CallbackAction::Continue
        } else {
            self.bad += 1;
            if self.bad >= self.patience {
                CallbackAction::Stop
            } else {
                CallbackAction::Continue
            }
        }
    }
}

// -------------------------------------------------------------- typestates

/// *Load* stage: an editable model description plus optimizer choice.
pub struct Session {
    nodes: Vec<NodeDesc>,
    optimizer_kind: String,
    optimizer_props: Props,
    appctx: AppContext,
    defaults: TrainSpec,
}

impl Session {
    /// Describe from a ready node list (zoo nets, realizer output).
    pub fn describe(nodes: impl IntoIterator<Item = NodeDesc>) -> Self {
        Session::builder().add_nodes(nodes)
    }

    /// Empty description; grow it with [`Session::add`].
    pub fn builder() -> Self {
        Session {
            nodes: vec![],
            optimizer_kind: "sgd".into(),
            optimizer_props: Props::new(),
            appctx: AppContext::new(),
            defaults: TrainSpec::default(),
        }
    }

    /// Adopt a seed-era [`ModelBuilder`] (the compat shim's entry).
    pub fn from_builder(b: ModelBuilder) -> Self {
        Session {
            nodes: b.nodes,
            optimizer_kind: b.optimizer_kind,
            optimizer_props: b.optimizer_props,
            appctx: b.appctx,
            defaults: TrainSpec::default(),
        }
    }

    /// *Load* from INI text. The `[Model]` hyper-parameters that the
    /// seed parsed and then ignored — `Batch_Size`, `Epochs` (and
    /// `Learning_rate`, which flows into the optimizer) — become the
    /// session's [`TrainSpec`] defaults; see [`Session::default_spec`].
    pub fn from_ini_str(text: &str) -> Result<Self> {
        let (b, hyper) = ini::builder_from_ini(text)?;
        let mut s = Session::from_builder(b);
        s.defaults.batch = Some(hyper.batch);
        s.defaults.epochs = hyper.epochs;
        Ok(s)
    }

    /// *Load* from an INI file path.
    pub fn from_ini_file(path: &str) -> Result<Self> {
        let text = std::fs::read_to_string(path)?;
        Session::from_ini_str(&text)
    }

    /// Add one layer: `add("fc1", "fully_connected", &[("unit","10")])`.
    pub fn add(mut self, name: &str, ltype: &str, pairs: &[(&str, &str)]) -> Self {
        self.nodes.push(NodeDesc::new(name, ltype, Props::from_pairs(pairs.iter().copied())));
        self
    }

    pub fn add_node(mut self, node: NodeDesc) -> Self {
        self.nodes.push(node);
        self
    }

    pub fn add_nodes(mut self, nodes: impl IntoIterator<Item = NodeDesc>) -> Self {
        self.nodes.extend(nodes);
        self
    }

    pub fn optimizer(mut self, kind: &str, pairs: &[(&str, &str)]) -> Self {
        self.optimizer_kind = kind.to_string();
        self.optimizer_props = Props::from_pairs(pairs.iter().copied());
        self
    }

    pub fn with_appctx(mut self, ctx: AppContext) -> Self {
        self.appctx = ctx;
        self
    }

    /// The spec [`Session::configure_default`] would use — INI-derived
    /// where the description came from INI. Clone, tweak, pass to
    /// [`Session::configure`].
    pub fn default_spec(&self) -> TrainSpec {
        self.defaults.clone()
    }

    /// *Configure* with an explicit spec.
    pub fn configure(self, spec: TrainSpec) -> ConfiguredSession {
        ConfiguredSession { session: self, spec }
    }

    /// *Configure* with the description's own defaults.
    pub fn configure_default(self) -> ConfiguredSession {
        let spec = self.default_spec();
        self.configure(spec)
    }
}

/// *Configure* stage: description + training contract, awaiting a device.
pub struct ConfiguredSession {
    session: Session,
    spec: TrainSpec,
}

impl ConfiguredSession {
    pub fn spec(&self) -> &TrainSpec {
        &self.spec
    }

    /// *Compile* + *Initialize* for a device: apply the freeze set,
    /// realize + wire once, pick the batch (auto under a budget, probing
    /// the shared graph through a memoized shape template), run
    /// Algorithm 1 / planning / validation, allocate the pool, init
    /// weights.
    pub fn compile_for(self, profile: DeviceProfile) -> Result<CompiledSession> {
        let ConfiguredSession { session, spec } = self;
        let mut nodes = session.nodes;
        apply_freeze(&mut nodes, &spec.freeze)?;
        let optimizer: Box<dyn Optimizer> =
            optimizer::create(&session.optimizer_kind, &session.optimizer_props)?;
        let factories = session.appctx.factories();
        let graph = analyze(nodes)?;
        let batch = match (spec.batch, profile.memory_budget_bytes) {
            (Some(b), _) => b,
            (None, Some(budget)) => {
                auto_batch(&graph, &spec, &profile, optimizer.state_slots(), &factories, budget)?
            }
            (None, None) => DEFAULT_BATCH,
        };
        let opts = resolve_opts(batch, &spec, &profile);
        let (exec, report) = compile_graph(&graph, optimizer, &opts, &factories)?;
        Ok(CompiledSession { model: Model { exec, report, opts }, spec, profile })
    }
}

/// Set `trainable = false` on every layer whose name starts with one of
/// `prefixes`; a prefix matching nothing is an error (a silently inert
/// freeze is how backbones end up trained by accident).
pub(crate) fn apply_freeze(nodes: &mut [NodeDesc], prefixes: &[String]) -> Result<usize> {
    let mut frozen = 0usize;
    for p in prefixes {
        let mut hit = false;
        for nd in nodes.iter_mut() {
            if nd.name.starts_with(p.as_str()) {
                nd.props.set("trainable", "false");
                hit = true;
                frozen += 1;
            }
        }
        if !hit {
            return Err(Error::model(format!("freeze prefix `{p}` matches no layer")));
        }
    }
    Ok(frozen)
}

/// Lower the two contracts onto the executable `CompileOpts`.
pub(crate) fn resolve_opts(batch: usize, spec: &TrainSpec, profile: &DeviceProfile) -> CompileOpts {
    CompileOpts {
        batch,
        training: spec.training,
        planner: profile.planner,
        inplace: profile.inplace,
        conventional: profile.conventional,
        clip_norm: spec.clip_norm,
        seed: spec.seed,
        memory_budget_bytes: if profile.swap { profile.memory_budget_bytes } else { None },
        swap_store: profile.swap_store,
        swap_tuning: profile.swap_tuning,
        compute: profile.compute,
        pool_compaction: profile.pool_compaction,
        swap_pipeline: profile.swap_pipeline,
    }
}

/// Budget-aware batch scheduler (ROADMAP): largest batch whose *planned*
/// pool fits `budget`, found by exponential growth + binary search over
/// the monotone batch→pool curve. Probes run through
/// [`crate::compiler::plan_graph`] — full planning and validation, no
/// pool allocation — over the one wired graph, and per-layer shape
/// analysis is memoized across probes: an [`ShapeTemplate`] inferred
/// from two reference batches substitutes batch-scaled dims instead of
/// re-finalizing every layer per probe (models whose shapes are not
/// batch-linear fall back to full analysis). When the swap runtime is
/// engaged the probe pool is the advised (gap-aware) peak, so swapping
/// buys larger batches. If even batch 1 misses the budget, 1 is
/// returned (the budget is a target; the caller can inspect
/// [`CompiledSession::fits_budget`]).
fn auto_batch(
    graph: &Graph,
    spec: &TrainSpec,
    profile: &DeviceProfile,
    opt_slots: usize,
    factories: &HashMap<&'static str, LayerFactory>,
    budget: usize,
) -> Result<usize> {
    let template = ShapeTemplate::build(graph, factories, profile.compute);
    let fits = |b: usize| -> Result<bool> {
        let report = plan_graph(
            graph,
            &resolve_opts(b, spec, profile),
            factories,
            opt_slots,
            template.as_ref(),
        )?;
        Ok(report.pool_bytes <= budget)
    };
    if !fits(1)? {
        return Ok(1);
    }
    let mut lo = 1usize; // known to fit
    let mut first_over = None;
    let mut b = 2usize;
    while b <= profile.max_batch {
        if fits(b)? {
            lo = b;
            b *= 2;
        } else {
            first_over = Some(b);
            break;
        }
    }
    let mut hi = match first_over {
        Some(h) => h,
        // doubling ran past the cap without finding a miss: the answer is
        // in (lo, max_batch] — check the cap itself, else search up to it
        None => {
            if lo >= profile.max_batch {
                return Ok(lo);
            }
            if fits(profile.max_batch)? {
                return Ok(profile.max_batch);
            }
            profile.max_batch
        }
    };
    while hi - lo > 1 {
        let mid = lo + (hi - lo) / 2;
        if fits(mid)? {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    Ok(lo)
}

// ------------------------------------------------------- compiled session

/// Head-swap + fine-tune description for [`CompiledSession::personalize`].
#[derive(Clone, Debug)]
pub struct PersonalizeOpts {
    /// Checkpoint to restore before fine-tuning (backbone weights).
    /// Loading is strict: a checkpoint tensor the model cannot take
    /// fails with a name/shape diff — unless its layer is named in
    /// `reinit` (it is about to be re-initialized anyway).
    pub checkpoint: Option<String>,
    /// Layer-name prefixes whose weights are re-initialized after the
    /// checkpoint load — the swapped-in head. Optimizer state re-zeroes
    /// alongside; a prefix matching no weight tensor errors (like
    /// [`TrainSpec::freeze`]), so a typoed head name cannot silently keep
    /// the checkpoint's head.
    pub reinit: Vec<String>,
    pub reinit_seed: u64,
    /// Fine-tune epochs; `None` uses the session's [`TrainSpec::epochs`].
    pub epochs: Option<usize>,
}

impl Default for PersonalizeOpts {
    fn default() -> Self {
        PersonalizeOpts { checkpoint: None, reinit: vec![], reinit_seed: 0x5EED, epochs: None }
    }
}

/// What [`CompiledSession::personalize`] did.
#[derive(Clone, Debug)]
pub struct PersonalizeReport {
    /// Tensors restored from the checkpoint.
    pub restored: usize,
    /// Weight tensors re-initialized (the swapped head).
    pub reinitialized: usize,
    pub summary: TrainSummary,
}

/// *Initialize*d and ready: train, infer, personalize. The planned peak
/// is known before the first iteration ([`CompiledSession::peak_pool_bytes`]).
pub struct CompiledSession {
    /// The underlying compiled model — the escape hatch for callers that
    /// need executor-level access (oracle tests, weight I/O).
    pub model: Model,
    spec: TrainSpec,
    profile: DeviceProfile,
}

impl CompiledSession {
    /// The batch the session trains at (explicit or auto-selected).
    pub fn batch(&self) -> usize {
        self.model.opts.batch
    }

    pub fn spec(&self) -> &TrainSpec {
        &self.spec
    }

    pub fn profile(&self) -> &DeviceProfile {
        &self.profile
    }

    pub fn report(&self) -> &PlanReport {
        &self.model.report
    }

    /// Peak training memory (the pool), known before execution.
    pub fn peak_pool_bytes(&self) -> usize {
        self.model.peak_pool_bytes()
    }

    /// Whether the planned pool honours the profile's budget
    /// (`None` when no budget was set).
    pub fn fits_budget(&self) -> Option<bool> {
        self.profile
            .memory_budget_bytes
            .map(|b| self.model.report.pool_bytes <= b)
    }

    /// Root weights the freeze set pinned (bitwise-invariant under
    /// training).
    pub fn frozen_weight_names(&self) -> Vec<String> {
        self.model.exec.frozen_weight_names()
    }

    // ------------------------------------------- head state extract/restore
    //
    // The multi-tenant surface (`fleet::FleetService`): one compiled
    // session is time-shared between tenants that differ only in their
    // re-initialized head. A tenant's whole persistent identity is the
    // head layers' Weight + OptState pool regions plus the executor's
    // step counters; everything below (frozen backbone, activations,
    // gradients) is shared or transient.

    /// Pool layout of the per-tenant head state: every root `Weight` and
    /// `OptState` region of the layers matching `prefixes`, in table
    /// order (`Executor::state_layout_matching`). Stable for the
    /// lifetime of the compiled session.
    pub fn head_state_layout(&self, prefixes: &[String]) -> Result<Vec<(String, Region)>> {
        self.model.exec.state_layout_matching(prefixes)
    }

    /// Concatenate the head state described by `layout` into `out`
    /// (cleared first; capacity reused).
    pub fn export_head_state(&self, layout: &[(String, Region)], out: &mut Vec<f32>) {
        self.model.exec.export_state(layout, out)
    }

    /// Restore a previously exported head state bitwise.
    pub fn import_head_state(&mut self, layout: &[(String, Region)], data: &[f32]) -> Result<()> {
        self.model.exec.import_state(layout, data)
    }

    /// Train for the spec's epochs.
    pub fn train(
        &mut self,
        make_producer: impl Fn() -> Box<dyn DataProducer>,
    ) -> Result<TrainSummary> {
        self.train_with(make_producer, &mut [])
    }

    /// Train with callbacks observing every iteration and epoch.
    pub fn train_with(
        &mut self,
        make_producer: impl Fn() -> Box<dyn DataProducer>,
        callbacks: &mut [&mut dyn TrainCallback],
    ) -> Result<TrainSummary> {
        let cfg = self.train_config();
        run_training(&mut self.model, &make_producer, &cfg, callbacks)
    }

    /// The paper's §5 flow in one call: restore a checkpoint, re-init the
    /// swapped head, fine-tune with callbacks. Frozen layers (declared in
    /// [`TrainSpec::freeze`] before compile) have no gradient or
    /// optimizer tensors planned, so their weights are untouchable by
    /// construction.
    pub fn personalize(
        &mut self,
        opts: &PersonalizeOpts,
        make_producer: impl Fn() -> Box<dyn DataProducer>,
        callbacks: &mut [&mut dyn TrainCallback],
    ) -> Result<PersonalizeReport> {
        // strict load with the head prefixes allow-listed: a renamed or
        // reshaped backbone layer fails with a name/shape diff instead
        // of silently training from random init; only the layers about
        // to be re-initialized anyway may mismatch
        let restored = match &opts.checkpoint {
            Some(path) => checkpoint::load_matching(&self.model.exec, path, &opts.reinit)?,
            None => 0,
        };
        let reinitialized = if opts.reinit.is_empty() {
            0
        } else {
            self.model.exec.reinit_weights_matching(&opts.reinit, opts.reinit_seed)?
        };
        let mut cfg = self.train_config();
        if let Some(epochs) = opts.epochs {
            cfg.epochs = epochs;
        }
        let summary = run_training(&mut self.model, &make_producer, &cfg, callbacks)?;
        Ok(PersonalizeReport { restored, reinitialized, summary })
    }

    /// Forward-only pass; returns the last non-loss node's output.
    pub fn infer(&mut self, input: &[f32]) -> Result<Vec<f32>> {
        self.model.infer(input)
    }

    /// Forward-only pass reading a named node's output — feature
    /// extraction for the cache-then-train personalization flows.
    pub fn infer_node(&mut self, input: &[f32], node: &str) -> Result<Vec<f32>> {
        self.model.infer_node(input, node)
    }

    pub fn save(&self, path: &str) -> Result<()> {
        self.model.save(path)
    }

    pub fn load(&mut self, path: &str) -> Result<usize> {
        self.model.load(path)
    }

    /// Unwrap into the seed-era [`Model`] (the compat shim's exit).
    pub fn into_model(self) -> Model {
        self.model
    }

    fn train_config(&self) -> TrainConfig {
        TrainConfig {
            epochs: self.spec.epochs,
            queue_depth: self.spec.queue_depth,
            verbose: self.spec.verbose,
            val_split: self.spec.val_split,
        }
    }
}

// ----------------------------------------------------------- training loop

/// The one training loop (epochs × Batch-Queue iterations) shared by
/// [`Model::train`], [`CompiledSession::train_with`] and
/// [`CompiledSession::personalize`]. Callback `Stop` ends training after
/// the current iteration's bookkeeping; a partial epoch still contributes
/// its mean to `losses_per_epoch`, and `summary.epochs` reports the
/// epochs actually entered.
///
/// With `cfg.val_split > 0`, every `round(1/split)`-th batch of an
/// epoch is held out: bound like a training batch but run through a
/// forward-only loss evaluation (`Executor::try_eval_loss` — no weight
/// update, inference mode). Held-out batches do not count as iterations
/// and fire no `on_iteration`; their epoch mean reaches
/// `on_epoch_end` as [`TrainEvent::val_loss`] (which [`EarlyStop`]
/// monitors when present) and `summary.val_losses_per_epoch`.
pub(crate) fn run_training<F>(
    model: &mut Model,
    make_producer: &F,
    cfg: &TrainConfig,
    callbacks: &mut [&mut dyn TrainCallback],
) -> Result<TrainSummary>
where
    F: Fn() -> Box<dyn DataProducer>,
{
    let timer = Timer::start();
    let mut summary = TrainSummary { epochs: cfg.epochs, ..Default::default() };
    let mut stopped = false;
    // every period-th batch is validation; period >= 2 keeps at least
    // half of every epoch training
    let period = if cfg.val_split > 0.0 {
        (1.0 / f64::from(cfg.val_split.clamp(0.0, 0.5))).round().max(2.0) as usize
    } else {
        0
    };
    for epoch in 0..cfg.epochs {
        let queue = BatchQueue::spawn(make_producer(), model.opts.batch, cfg.queue_depth);
        let mut epoch_loss = 0f64;
        let mut batches = 0usize;
        let mut val_loss = 0f64;
        let mut val_batches = 0usize;
        let mut in_epoch = 0usize;
        while let Some(b) = queue.next() {
            model.bind_batch(&b.input, &b.label)?;
            in_epoch += 1;
            if period > 0 && in_epoch % period == 0 {
                val_loss += model.exec.try_eval_loss()? as f64;
                val_batches += 1;
                continue;
            }
            let loss = model.exec.try_train_iteration()?;
            epoch_loss += loss as f64;
            batches += 1;
            let ev = TrainEvent {
                epoch,
                iteration: summary.iterations + batches,
                loss,
                val_loss: None,
            };
            for cb in callbacks.iter_mut() {
                if cb.on_iteration(&ev) == CallbackAction::Stop {
                    stopped = true;
                }
            }
            if stopped {
                break;
            }
        }
        if batches == 0 {
            return Err(Error::Dataset("no full batch produced".into()));
        }
        // a configured split that held out nothing must not silently
        // degrade EarlyStop to the training loss (a callback Stop can
        // legitimately cut an epoch short of its first held-out batch)
        if period > 0 && val_batches == 0 && !stopped {
            return Err(Error::Dataset(format!(
                "val_split {} held out no batch in an epoch of {} batches \
                 (every {period}-th batch is held out) — lower val_split or \
                 provide at least {period} batches per epoch",
                cfg.val_split, in_epoch
            )));
        }
        let mean = (epoch_loss / batches as f64) as f32;
        let val_mean = if val_batches > 0 {
            Some((val_loss / val_batches as f64) as f32)
        } else {
            None
        };
        summary.losses_per_epoch.push(mean);
        if let Some(v) = val_mean {
            summary.val_losses_per_epoch.push(v);
        }
        summary.iterations += batches;
        summary.final_loss = mean;
        if cfg.verbose {
            match val_mean {
                Some(v) => println!(
                    "epoch {:>3}: loss {:.6} val {:.6} ({} iters)",
                    epoch + 1,
                    mean,
                    v,
                    batches
                ),
                None => println!("epoch {:>3}: loss {:.6} ({} iters)", epoch + 1, mean, batches),
            }
        }
        // epoch boundary: apply any parked pool compaction first
        // (compact_pool quiesces the swap runtime itself — including
        // carried cross-iteration transfers — before relocating regions
        // and truncating the arena), then snapshot the swap counters for
        // the per-epoch trajectory and let calibrated swap tuning react
        // to the stall telemetry this epoch accrued (all no-ops under
        // Fixed / no swap)
        model.exec.compact_pool()?;
        if let Some(sw) = model.exec.swap_mut() {
            sw.mark_epoch();
            sw.adapt_depth();
        }
        if !stopped {
            let ev = TrainEvent {
                epoch,
                iteration: summary.iterations,
                loss: mean,
                val_loss: val_mean,
            };
            for cb in callbacks.iter_mut() {
                if cb.on_epoch_end(&ev) == CallbackAction::Stop {
                    stopped = true;
                }
            }
        }
        if stopped {
            summary.epochs = epoch + 1;
            break;
        }
    }
    // run end is a mandatory full-drain point: under cross-iteration
    // pipelining the last iteration legitimately left boundary transfers
    // in flight, and callers read weights straight out of the pool next
    model.exec.quiesce_swap()?;
    summary.wall_s = timer.elapsed_s();
    Ok(summary)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn early_stop_counts_plateaus() {
        let mut es = EarlyStop::new(2, 0.01);
        let ev = |loss| TrainEvent { epoch: 0, iteration: 1, loss, val_loss: None };
        assert_eq!(es.on_epoch_end(&ev(1.0)), CallbackAction::Continue);
        assert_eq!(es.on_epoch_end(&ev(0.5)), CallbackAction::Continue); // improves
        assert_eq!(es.on_epoch_end(&ev(0.499)), CallbackAction::Continue); // < min_delta
        assert_eq!(es.on_epoch_end(&ev(0.498)), CallbackAction::Stop); // 2nd plateau
        assert_eq!(es.best(), 0.5);
    }

    #[test]
    fn early_stop_resets_on_improvement() {
        let mut es = EarlyStop::new(2, 0.0);
        let ev = |loss| TrainEvent { epoch: 0, iteration: 1, loss, val_loss: None };
        assert_eq!(es.on_epoch_end(&ev(1.0)), CallbackAction::Continue);
        assert_eq!(es.on_epoch_end(&ev(1.0)), CallbackAction::Continue); // plateau 1
        assert_eq!(es.on_epoch_end(&ev(0.9)), CallbackAction::Continue); // reset
        assert_eq!(es.on_epoch_end(&ev(0.9)), CallbackAction::Continue); // plateau 1
        assert_eq!(es.on_epoch_end(&ev(0.9)), CallbackAction::Stop); // plateau 2
    }

    #[test]
    fn early_stop_monitors_val_loss_when_present() {
        let mut es = EarlyStop::new(1, 0.0);
        let ev = |loss, val| TrainEvent { epoch: 0, iteration: 1, loss, val_loss: Some(val) };
        assert_eq!(es.on_epoch_end(&ev(1.0, 1.0)), CallbackAction::Continue);
        // train loss improves but the held-out loss plateaus → stop
        assert_eq!(es.on_epoch_end(&ev(0.5, 1.0)), CallbackAction::Stop);
        assert_eq!(es.best(), 1.0, "best tracks the monitored (val) loss");
    }

    #[test]
    fn freeze_prefix_must_match() {
        let mut nodes = vec![NodeDesc::new("conv0", "conv2d", Props::new())];
        assert!(apply_freeze(&mut nodes, &["conv".into()]).is_ok());
        assert_eq!(nodes[0].props.get("trainable"), Some("false"));
        assert!(apply_freeze(&mut nodes, &["nope".into()]).is_err());
    }
}
