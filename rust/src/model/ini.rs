//! INI model loader (paper §4 *Load*: "NNTrainer users may describe a
//! neural network model … with an initialization file").
//!
//! Format mirrors NNTrainer's: a `[Model]` section with hyper-parameters
//! (loss, optimizer, batch size, epochs), then one section per layer in
//! topological order:
//!
//! ```ini
//! [Model]
//! Type = NeuralNetwork
//! Loss = cross_entropy
//! Optimizer = sgd
//! Learning_rate = 0.01
//! Batch_Size = 32
//! Epochs = 3
//!
//! [inputlayer]
//! Type = input
//! Input_Shape = 1:28:28
//!
//! [fc1]
//! Type = fully_connected
//! Unit = 100
//! Activation = relu
//! ```

use crate::error::{Error, Result};
use crate::graph::NodeDesc;
use crate::layers::Props;

use super::model::ModelBuilder;

/// Parsed INI description.
#[derive(Debug, Default)]
pub struct IniModel {
    pub model_props: Props,
    pub layers: Vec<NodeDesc>,
}

/// Parse INI text. `#` and `;` start comments; keys are
/// case-insensitive; section order defines layer order.
pub fn parse(text: &str) -> Result<IniModel> {
    let mut out = IniModel::default();
    let mut section: Option<String> = None;
    let mut props = Props::new();
    let flush = |name: Option<String>, props: &mut Props, out: &mut IniModel| -> Result<()> {
        let Some(name) = name else { return Ok(()) };
        if name.eq_ignore_ascii_case("model") {
            out.model_props = std::mem::take(props);
        } else {
            let mut p = std::mem::take(props);
            let ltype = p
                .string("type")
                .ok_or_else(|| Error::model(format!("section [{name}] missing Type")))?
                .to_ascii_lowercase();
            p.set("type", "");
            out.layers.push(NodeDesc::new(name, ltype, p));
        }
        Ok(())
    };
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.split(['#', ';']).next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        if line.starts_with('[') {
            if !line.ends_with(']') {
                return Err(Error::model(format!("line {}: unterminated section", lineno + 1)));
            }
            flush(section.take(), &mut props, &mut out)?;
            section = Some(line[1..line.len() - 1].trim().to_string());
        } else if let Some(eq) = line.find('=') {
            if section.is_none() {
                return Err(Error::model(format!("line {}: key outside a section", lineno + 1)));
            }
            props.set(line[..eq].trim(), line[eq + 1..].trim());
        } else {
            return Err(Error::model(format!("line {}: expected `key = value`", lineno + 1)));
        }
    }
    flush(section.take(), &mut props, &mut out)?;
    Ok(out)
}

/// Hyper-parameters pulled from the `[Model]` section. They are wired
/// into the session lifecycle by `Session::from_ini_str`: `Batch_Size`
/// and `Epochs` become the `TrainSpec` defaults; `Learning_rate` (and
/// the other optimizer keys) reach the model through the builder's
/// optimizer props below, not through this struct.
#[derive(Debug, Clone)]
pub struct IniHyper {
    pub batch: usize,
    pub epochs: usize,
    pub loss: Option<String>,
}

/// Build a `ModelBuilder` from INI text: layers + a loss layer appended
/// from `Loss =`, optimizer from `Optimizer =`.
pub fn builder_from_ini(text: &str) -> Result<(ModelBuilder, IniHyper)> {
    let ini = parse(text)?;
    let hyper = IniHyper {
        batch: ini.model_props.usize_or("batch_size", 32)?,
        epochs: ini.model_props.usize_or("epochs", 1)?,
        loss: ini.model_props.string("loss"),
    };
    let mut b = ModelBuilder::new().add_nodes(ini.layers);
    if let Some(loss) = &hyper.loss {
        let ltype = match loss.to_ascii_lowercase().as_str() {
            "mse" => "mse",
            "cross_entropy" | "cross_entropy_softmax" => "cross_entropy",
            other => return Err(Error::model(format!("unknown loss `{other}`"))),
        };
        b = b.add("loss", ltype, &[]);
    }
    let opt_kind = ini.model_props.string("optimizer").unwrap_or_else(|| "sgd".into());
    let mut opt_props = Props::new();
    for k in ["learning_rate", "momentum", "beta1", "beta2", "epsilon"] {
        if let Some(v) = ini.model_props.get(k) {
            opt_props.set(k, v);
        }
    }
    b.optimizer_kind = opt_kind;
    b.optimizer_props = opt_props;
    Ok((b, hyper))
}

/// Read + build from a file path.
pub fn builder_from_file(path: &str) -> Result<(ModelBuilder, IniHyper)> {
    let text = std::fs::read_to_string(path)?;
    builder_from_ini(&text)
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# HandMoji-style description (paper Fig 13: "entire training
# configuration is described within 30 lines")
[Model]
Type = NeuralNetwork
Loss = cross_entropy
Optimizer = adam
Learning_rate = 0.001
Batch_Size = 8
Epochs = 2

[inputlayer]
Type = input
Input_Shape = 1:16:16

[conv]
Type = conv2d
Filters = 4
Kernel_Size = 3
Padding = same
Activation = relu

[flat]
Type = flatten

[classifier]
Type = fully_connected
Unit = 10
"#;

    #[test]
    fn parses_sections_in_order() {
        let ini = parse(SAMPLE).unwrap();
        assert_eq!(ini.layers.len(), 4);
        assert_eq!(ini.layers[0].ltype, "input");
        assert_eq!(ini.layers[1].name, "conv");
        assert_eq!(ini.layers[1].props.usize("filters").unwrap(), Some(4));
        assert_eq!(ini.model_props.string("loss").unwrap(), "cross_entropy");
    }

    #[test]
    fn builder_appends_loss_and_optimizer() {
        let (b, hyper) = builder_from_ini(SAMPLE).unwrap();
        assert_eq!(hyper.batch, 8);
        assert_eq!(hyper.epochs, 2);
        assert_eq!(b.nodes.last().unwrap().ltype, "cross_entropy");
        assert_eq!(b.optimizer_kind, "adam");
        assert_eq!(b.optimizer_props.f32("learning_rate").unwrap(), Some(0.001));
    }

    #[test]
    fn rejects_missing_type() {
        assert!(parse("[x]\nunit = 3\n").unwrap_err().to_string().contains("Type")
            || builder_from_ini("[x]\nunit = 3\n").is_err());
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("[unterminated\n").is_err());
        assert!(parse("key = 1\n").is_err());
        assert!(parse("[s]\nnot-an-assignment\n").is_err());
    }

    #[test]
    fn comments_ignored() {
        let ini = parse("# top\n[Model] ; trailing\nType = NeuralNetwork # x\n").unwrap();
        assert_eq!(ini.model_props.string("type").unwrap(), "NeuralNetwork");
    }
}
