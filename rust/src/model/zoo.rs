//! Model zoo: every network the paper's evaluation uses, as description
//! builders (Table 4 component cases, Fig 12 applications, Fig 14
//! Tacotron2-decoder). Loss layers are included; optimizers are chosen by
//! the caller.

use crate::graph::NodeDesc;
use crate::layers::Props;

fn node(name: &str, ltype: &str, pairs: &[(&str, &str)]) -> NodeDesc {
    NodeDesc::new(name, ltype, Props::from_pairs(pairs.iter().copied()))
}

// ------------------------------------------------------------ Table 4 cases

/// `Linear`: 150528 → fc 10, MSE (Table 4 row 1).
pub fn linear_case() -> Vec<NodeDesc> {
    vec![
        node("in", "input", &[("input_shape", "1:1:150528")]),
        node("fc0", "fully_connected", &[("unit", "10"), ("bias", "false")]),
        node("loss", "mse", &[]),
    ]
}

/// `Conv2D`: 3:224:224 → conv(3 filters, 3x3, stride 2, pad 1) →
/// 3:112:112, MSE (Table 4 row 2).
pub fn conv2d_case() -> Vec<NodeDesc> {
    vec![
        node("in", "input", &[("input_shape", "3:224:224")]),
        node(
            "conv0",
            "conv2d",
            &[("filters", "3"), ("kernel_size", "3"), ("stride", "2"), ("padding", "1"), ("bias", "false")],
        ),
        node("loss", "mse", &[]),
    ]
}

/// `LSTM`: 150528 (T=1) → lstm(10), MSE (Table 4 row 3).
pub fn lstm_case() -> Vec<NodeDesc> {
    vec![
        node("in", "input", &[("input_shape", "1:1:150528")]),
        node("lstm0", "lstm", &[("unit", "10")]),
        node("loss", "mse", &[]),
    ]
}

/// Model A (Linear): fc128 → fc64 → fc10 (paper Fig 4; dims recovered
/// from Table 4's 188250 kiB ideal — see DESIGN.md).
pub fn model_a_linear() -> Vec<NodeDesc> {
    vec![
        node("in", "input", &[("input_shape", "1:1:150528")]),
        node("fc0", "fully_connected", &[("unit", "128"), ("bias", "false")]),
        node("fc1", "fully_connected", &[("unit", "64"), ("bias", "false")]),
        node("fc2", "fully_connected", &[("unit", "10"), ("bias", "false")]),
        node("loss", "mse", &[]),
    ]
}

/// Model A (Conv2D): three stride-2 convs, 224 → 112 → 56 → 28.
pub fn model_a_conv() -> Vec<NodeDesc> {
    let conv = |name: &str| {
        node(
            name,
            "conv2d",
            &[("filters", "3"), ("kernel_size", "3"), ("stride", "2"), ("padding", "1"), ("bias", "false")],
        )
    };
    vec![
        node("in", "input", &[("input_shape", "3:224:224")]),
        conv("conv0"),
        conv("conv1"),
        conv("conv2"),
        node("loss", "mse", &[]),
    ]
}

/// Model B (Linear): fc64 → sigmoid → fc10 (Fig 5; 112935 kiB ideal).
pub fn model_b_linear() -> Vec<NodeDesc> {
    vec![
        node("in", "input", &[("input_shape", "1:1:150528")]),
        node("fc0", "fully_connected", &[("unit", "64"), ("bias", "false")]),
        node("act", "activation", &[("act", "sigmoid")]),
        node("fc1", "fully_connected", &[("unit", "10"), ("bias", "false")]),
        node("loss", "mse", &[]),
    ]
}

/// Model B (Conv2D): conv s2 → sigmoid → conv s2, 224 → 112 → 56.
pub fn model_b_conv() -> Vec<NodeDesc> {
    vec![
        node("in", "input", &[("input_shape", "3:224:224")]),
        node(
            "conv0",
            "conv2d",
            &[("filters", "3"), ("kernel_size", "3"), ("stride", "2"), ("padding", "1"), ("bias", "false")],
        ),
        node("act", "activation", &[("act", "sigmoid")]),
        node(
            "conv1",
            "conv2d",
            &[("filters", "3"), ("kernel_size", "3"), ("stride", "2"), ("padding", "1"), ("bias", "false")],
        ),
        node("loss", "mse", &[]),
    ]
}

/// Model C (Linear): fc10 → sigmoid → flatten → fc10 (Fig 6; ~49399 kiB).
pub fn model_c_linear() -> Vec<NodeDesc> {
    vec![
        node("in", "input", &[("input_shape", "1:1:150528")]),
        node("fc0", "fully_connected", &[("unit", "10"), ("bias", "false")]),
        node("act", "activation", &[("act", "sigmoid")]),
        node("flat", "flatten", &[]),
        node("fc1", "fully_connected", &[("unit", "10"), ("bias", "false")]),
        node("loss", "mse", &[]),
    ]
}

/// Model C (Conv2D): conv s2 → sigmoid → flatten (out 64:1:1:37632).
pub fn model_c_conv() -> Vec<NodeDesc> {
    vec![
        node("in", "input", &[("input_shape", "3:224:224")]),
        node(
            "conv0",
            "conv2d",
            &[("filters", "3"), ("kernel_size", "3"), ("stride", "2"), ("padding", "1"), ("bias", "false")],
        ),
        node("act", "activation", &[("act", "sigmoid")]),
        node("flat", "flatten", &[]),
        node("loss", "mse", &[]),
    ]
}

/// Model D: input → fc → multiout → {sigmoid, relu} → addition → fc10
/// (paper: "input layer, addition, and linear … and a multi-output layer
/// with two activation layers").
pub fn model_d() -> Vec<NodeDesc> {
    vec![
        node("in", "input", &[("input_shape", "1:1:150528")]),
        node("fc0", "fully_connected", &[("unit", "128"), ("bias", "false")]),
        node("mo", "multiout", &[("outputs", "2")]),
        node("act_a", "activation", &[("act", "sigmoid"), ("input_layers", "mo(0)")]),
        node("act_b", "activation", &[("act", "relu"), ("input_layers", "mo(1)")]),
        node("add", "addition", &[("input_layers", "act_a,act_b")]),
        node("fc1", "fully_connected", &[("unit", "10"), ("bias", "false")]),
        node("loss", "mse", &[]),
    ]
}

/// All ten Table-4 component cases, in the paper's row order.
pub fn table4_cases() -> Vec<(&'static str, Vec<NodeDesc>, f64)> {
    // (name, nodes, paper's ideal kiB)
    vec![
        ("Linear", linear_case(), 49397.0),
        ("Conv2D", conv2d_case(), 65856.0),
        ("LSTM", lstm_case(), 84731.0),
        ("Model A (Linear)", model_a_linear(), 188250.0),
        ("Model A (Conv2D)", model_a_conv(), 51157.0),
        ("Model B (Linear)", model_b_linear(), 112935.0),
        ("Model B (Conv2D)", model_b_conv(), 54097.0),
        ("Model C (Linear)", model_c_linear(), 49399.0),
        ("Model C (Conv2D)", model_c_conv(), 65856.0),
        ("Model D", model_d(), 162295.0),
    ]
}

// ------------------------------------------------------- Fig 12 applications

/// LeNet-5 on 1:32:32 (Fig 12 first case — the 96.5 % saving headline).
pub fn lenet5() -> Vec<NodeDesc> {
    vec![
        node("in", "input", &[("input_shape", "1:32:32")]),
        node("c1", "conv2d", &[("filters", "6"), ("kernel_size", "5"), ("activation", "tanh")]),
        node("s2", "pooling2d", &[("pooling", "average"), ("pool_size", "2")]),
        node("c3", "conv2d", &[("filters", "16"), ("kernel_size", "5"), ("activation", "tanh")]),
        node("s4", "pooling2d", &[("pooling", "average"), ("pool_size", "2")]),
        node("flat", "flatten", &[]),
        node("f5", "fully_connected", &[("unit", "120"), ("activation", "tanh")]),
        node("f6", "fully_connected", &[("unit", "84"), ("activation", "tanh")]),
        node("f7", "fully_connected", &[("unit", "10")]),
        node("loss", "cross_entropy", &[]),
    ]
}

/// VGG16 (CIFAR layout, 3:32:32; 512-unit head as in common CIFAR ports).
pub fn vgg16() -> Vec<NodeDesc> {
    let mut nodes = vec![node("in", "input", &[("input_shape", "3:32:32")])];
    let cfg: &[usize] = &[64, 64, 0, 128, 128, 0, 256, 256, 256, 0, 512, 512, 512, 0, 512, 512, 512, 0];
    let mut ci = 0usize;
    let mut pi = 0usize;
    let filters_strings: Vec<String> = cfg.iter().map(|f| f.to_string()).collect();
    for (k, &f) in cfg.iter().enumerate() {
        if f == 0 {
            nodes.push(node(&format!("pool{pi}"), "pooling2d", &[("pooling", "max"), ("pool_size", "2")]));
            pi += 1;
        } else {
            nodes.push(node(
                &format!("conv{ci}"),
                "conv2d",
                &[
                    ("filters", filters_strings[k].as_str()),
                    ("kernel_size", "3"),
                    ("padding", "same"),
                    ("activation", "relu"),
                ],
            ));
            ci += 1;
        }
    }
    nodes.push(node("flat", "flatten", &[]));
    nodes.push(node("fc0", "fully_connected", &[("unit", "512"), ("activation", "relu")]));
    nodes.push(node("fc1", "fully_connected", &[("unit", "512"), ("activation", "relu")]));
    nodes.push(node("fc2", "fully_connected", &[("unit", "10")]));
    nodes.push(node("loss", "cross_entropy", &[]));
    nodes
}

/// ResNet-18 (CIFAR layout): conv64 + 4 stages × 2 basic blocks with
/// addition shortcuts, global average pool, fc10.
pub fn resnet18() -> Vec<NodeDesc> {
    resnet18_inner(false)
}

/// ResNet-18 with the backbone frozen and only the final fc trainable —
/// the Fig 12 "transfer learning" case.
pub fn resnet18_transfer() -> Vec<NodeDesc> {
    resnet18_inner(true)
}

fn resnet18_inner(freeze_backbone: bool) -> Vec<NodeDesc> {
    let tr = if freeze_backbone { "false" } else { "true" };
    let mut nodes = vec![
        node("in", "input", &[("input_shape", "3:32:32")]),
        node(
            "stem",
            "conv2d",
            &[("filters", "64"), ("kernel_size", "3"), ("padding", "same"), ("activation", "relu"), ("trainable", tr)],
        ),
    ];
    let mut prev = "stem".to_string();
    let stages: &[(usize, usize)] = &[(64, 1), (64, 1), (128, 2), (128, 1), (256, 2), (256, 1), (512, 2), (512, 1)];
    for (bi, &(filters, stride)) in stages.iter().enumerate() {
        let f = filters.to_string();
        let s = stride.to_string();
        let c1 = format!("b{bi}_c1");
        let c2 = format!("b{bi}_c2");
        let add = format!("b{bi}_add");
        let out = format!("b{bi}_out");
        // main path
        nodes.push(NodeDesc::new(
            &c1,
            "conv2d",
            Props::from_pairs([
                ("filters", f.as_str()),
                ("kernel_size", "3"),
                ("padding", "same"),
                ("stride", s.as_str()),
                ("activation", "relu"),
                ("input_layers", prev.as_str()),
                ("trainable", tr),
            ]),
        ));
        nodes.push(NodeDesc::new(
            &c2,
            "conv2d",
            Props::from_pairs([
                ("filters", f.as_str()),
                ("kernel_size", "3"),
                ("padding", "same"),
                ("input_layers", c1.as_str()),
                ("trainable", tr),
            ]),
        ));
        // shortcut (1x1 stride conv when shape changes)
        let shortcut = if stride != 1 || (bi > 0 && stages[bi - 1].0 != filters) || bi == 2 || bi == 4 || bi == 6 {
            let sc = format!("b{bi}_sc");
            nodes.push(NodeDesc::new(
                &sc,
                "conv2d",
                Props::from_pairs([
                    ("filters", f.as_str()),
                    ("kernel_size", "1"),
                    ("stride", s.as_str()),
                    ("input_layers", prev.as_str()),
                    ("trainable", tr),
                ]),
            ));
            sc
        } else {
            prev.clone()
        };
        nodes.push(NodeDesc::new(
            &add,
            "addition",
            Props::from_pairs([("input_layers", format!("{c2},{shortcut}").as_str())]),
        ));
        nodes.push(NodeDesc::new(
            &out,
            "activation",
            Props::from_pairs([("act", "relu"), ("input_layers", add.as_str())]),
        ));
        prev = out;
    }
    nodes.push(NodeDesc::new(
        "gap",
        "pooling2d",
        Props::from_pairs([("pooling", "global_average"), ("input_layers", prev.as_str())]),
    ));
    nodes.push(node("flat", "flatten", &[]));
    nodes.push(node("fc", "fully_connected", &[("unit", "10")]));
    nodes.push(node("loss", "cross_entropy", &[]));
    nodes
}

/// Product Rating (Fig 12, last case): two embeddings (MovieLens-sized
/// user table) → concat → 3 linear layers → rating.
pub fn product_rating() -> Vec<NodeDesc> {
    vec![
        node("user", "input", &[("input_shape", "1:1:1")]),
        node("item", "input", &[("input_shape", "1:1:1")]),
        node(
            "emb_u",
            "embedding",
            &[("in_dim", "193610"), ("out_dim", "64"), ("input_layers", "user")],
        ),
        node(
            "emb_m",
            "embedding",
            &[("in_dim", "26744"), ("out_dim", "64"), ("input_layers", "item")],
        ),
        node("flat_u", "flatten", &[("input_layers", "emb_u")]),
        node("flat_m", "flatten", &[("input_layers", "emb_m")]),
        node("cat", "concat", &[("input_layers", "flat_u,flat_m")]),
        node("fc0", "fully_connected", &[("unit", "128"), ("activation", "relu")]),
        node("fc1", "fully_connected", &[("unit", "64"), ("activation", "relu")]),
        node("fc2", "fully_connected", &[("unit", "1"), ("activation", "sigmoid")]),
        node("loss", "mse", &[]),
    ]
}

// ------------------------------------------------------ Fig 14 Tacotron2

/// Tacotron2-decoder-shaped model (see DESIGN.md §Substitutions):
/// teacher-forced prev-frame sequence → Prenet (2 time-distributed
/// linears) → 2 LSTMs → mel + gate heads. `t` = time iterations,
/// `mel` = mel bins (80).
pub fn tacotron_decoder(t: usize, mel: usize, lstm_units: usize) -> Vec<NodeDesc> {
    let shape = format!("1:{t}:{mel}");
    let units = lstm_units.to_string();
    let melu = mel.to_string();
    vec![
        node("frames", "input", &[("input_shape", shape.as_str())]),
        node(
            "prenet0",
            "fully_connected",
            &[("unit", "256"), ("time_distributed", "true"), ("activation", "relu")],
        ),
        node(
            "prenet1",
            "fully_connected",
            &[("unit", "128"), ("time_distributed", "true"), ("activation", "relu")],
        ),
        node("dec_lstm0", "lstm", &[("unit", units.as_str()), ("return_sequences", "true")]),
        node("dec_lstm1", "lstm", &[("unit", units.as_str()), ("return_sequences", "true")]),
        node("mo", "multiout", &[("outputs", "2")]),
        node(
            "mel_head",
            "fully_connected",
            &[("unit", melu.as_str()), ("time_distributed", "true"), ("input_layers", "mo(0)")],
        ),
        node(
            "gate_head",
            "fully_connected",
            &[
                ("unit", "1"),
                ("time_distributed", "true"),
                ("activation", "sigmoid"),
                ("input_layers", "mo(1)"),
            ],
        ),
        node("mel_loss", "mse", &[("input_layers", "mel_head")]),
        node("gate_loss", "mse", &[("input_layers", "gate_head")]),
    ]
}

/// Tacotron2 Postnet: 5 Conv1D layers over `mel:1:t` (channels × time).
pub fn postnet(t: usize, mel: usize) -> Vec<NodeDesc> {
    let shape = format!("{mel}:1:{t}");
    let melu = mel.to_string();
    let mut nodes = vec![node("mel_in", "input", &[("input_shape", shape.as_str())])];
    for k in 0..4 {
        nodes.push(node(
            &format!("post{k}"),
            "conv1d",
            &[("filters", "512"), ("kernel_size", "5"), ("padding", "same"), ("activation", "tanh")],
        ));
    }
    nodes.push(node(
        "post4",
        "conv1d",
        &[("filters", melu.as_str()), ("kernel_size", "5"), ("padding", "same")],
    ));
    nodes.push(node("loss", "mse", &[]));
    nodes
}

// ----------------------------------------------------------- e2e / misc

/// Small MLP whose shapes match the AOT artifact catalog
/// (`python/compile/model.py::MLP_SPEC`) — used by the end-to-end example
/// and the XLA-vs-native oracle tests. 16x16 digits → 256-64-10.
pub fn mlp_e2e() -> Vec<NodeDesc> {
    vec![
        node("in", "input", &[("input_shape", "1:1:256")]),
        node("fc0", "fully_connected", &[("unit", "64"), ("activation", "sigmoid")]),
        node("fc1", "fully_connected", &[("unit", "10")]),
        node("loss", "cross_entropy", &[]),
    ]
}

/// HandMoji classifier head (Fig 13): cached backbone features → 1 fc.
pub fn handmoji_head(feat: usize, classes: usize) -> Vec<NodeDesc> {
    let f = format!("1:1:{feat}");
    let c = classes.to_string();
    vec![
        node("feat", "input", &[("input_shape", f.as_str())]),
        node("classifier", "fully_connected", &[("unit", c.as_str())]),
        node("loss", "cross_entropy", &[]),
    ]
}

/// Small conv backbone standing in for MobileNetV2 in the HandMoji flow.
pub fn handmoji_backbone(side: usize) -> Vec<NodeDesc> {
    let shape = format!("1:{side}:{side}");
    vec![
        node("in", "input", &[("input_shape", shape.as_str())]),
        node("c0", "conv2d", &[("filters", "8"), ("kernel_size", "3"), ("padding", "same"), ("activation", "relu")]),
        node("p0", "pooling2d", &[("pooling", "max"), ("pool_size", "2")]),
        node("c1", "conv2d", &[("filters", "16"), ("kernel_size", "3"), ("padding", "same"), ("activation", "relu")]),
        node("p1", "pooling2d", &[("pooling", "max"), ("pool_size", "2")]),
        node("flat", "flatten", &[]),
        node("feat", "fully_connected", &[("unit", "64"), ("activation", "relu")]),
        node("head", "fully_connected", &[("unit", "10")]),
        node("loss", "cross_entropy", &[]),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zoo_models_have_losses() {
        for (name, nodes) in [
            ("lenet", lenet5()),
            ("vgg", vgg16()),
            ("resnet", resnet18()),
            ("pr", product_rating()),
            ("taco", tacotron_decoder(10, 80, 256)),
            ("postnet", postnet(10, 80)),
        ] {
            assert!(
                nodes.iter().any(|n| n.ltype.contains("mse") || n.ltype.contains("cross_entropy")),
                "{name} missing loss"
            );
        }
    }

    #[test]
    fn table4_has_ten_cases() {
        assert_eq!(table4_cases().len(), 10);
    }
}
