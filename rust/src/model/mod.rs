//! Model pipeline (paper §4): *Load* (INI or builder API) → *Configure* →
//! *Compile* (realizers) → *Initialize* (Algorithm 1 + planning) →
//! *setData* (Batch Queue) → *Train*.
//!
//! The staged lifecycle is a typestate (`session.rs`):
//! `Session::describe → configure(TrainSpec) → compile_for(DeviceProfile)
//! → CompiledSession::{train, infer, personalize}`. The seed-era
//! `ModelBuilder`/`Model` pair survives as a shim over it.

pub mod appctx;
pub mod checkpoint;
pub mod ini;
pub mod model;
pub mod session;
pub mod zoo;

pub use appctx::AppContext;
pub use model::{Model, ModelBuilder, TrainConfig, TrainSummary};
pub use session::{
    CallbackAction, CompiledSession, ConfiguredSession, DeviceProfile, EarlyStop, OnEpochEnd,
    OnIteration, PersonalizeOpts, PersonalizeReport, Session, TrainCallback, TrainEvent,
    TrainSpec, DEFAULT_BATCH,
};
