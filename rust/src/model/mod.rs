//! Model pipeline (paper §4): *Load* (INI or builder API) → *Configure* →
//! *Compile* (realizers) → *Initialize* (Algorithm 1 + planning) →
//! *setData* (Batch Queue) → *Train*.

pub mod appctx;
pub mod checkpoint;
pub mod ini;
pub mod model;
pub mod zoo;

pub use appctx::AppContext;
pub use model::{Model, ModelBuilder, TrainConfig, TrainSummary};
