//! Tier 1 + 2 of the tiered matmul: the register microkernel
//! (`MatmulInstruction`) and the cache-blocked packing layer
//! (`BlockMatmul`, here `PackedBlock`). Tier 3 (`BatchMatmul` — output
//! partitioning across the worker pool) lives in `tiered.rs`.
//!
//! The contract that makes threading safe to expose by default: for
//! every output element, the floating-point accumulation chain is the
//! *same chain, in the same order*, as the naive kernel's — packing
//! relocates bytes, never reassociates. Tiles partition the output
//! disjointly and each element's k-loop runs sequentially on exactly
//! one thread, so results are bitwise identical at any pool width.

use super::native::Conv2dGeom;
pub use super::native::{MR, NR};

/// Send+Sync wrapper for a raw output pointer. Tasks write disjoint
/// index ranges of one `&mut [f32]`; handing each thread a raw pointer
/// (instead of overlapping `&mut` slices) keeps that sound.
#[derive(Clone, Copy)]
pub struct CPtr(pub *mut f32);
unsafe impl Send for CPtr {}
unsafe impl Sync for CPtr {}

impl CPtr {
    /// # Safety
    /// `i` must be in bounds of the underlying buffer and no other
    /// thread may concurrently touch index `i`.
    #[inline(always)]
    pub unsafe fn at(self, i: usize) -> *mut f32 {
        self.0.add(i)
    }
}

/// `[len*t/parts, len*(t+1)/parts)` — contiguous near-equal chunks,
/// computed arithmetically so the hot path never allocates a partition
/// table.
#[inline]
pub fn chunk_bounds(len: usize, parts: usize, t: usize) -> (usize, usize) {
    (len * t / parts, len * (t + 1) / parts)
}

/// Task count for a work axis of length `len` on a pool of `width`
/// threads: ~4 tasks per thread for load balance, capped at `len`.
/// Width ≤ 1 gets a single task — the inline path must not re-gather
/// shared rows once per task for nothing.
#[inline]
pub fn parts_for(len: usize, width: usize) -> usize {
    if width <= 1 {
        1
    } else {
        (width * 4).min(len).max(1)
    }
}

/// Grow-and-borrow: scratch vectors persist across calls per worker,
/// so steady-state training does zero allocation in the kernels.
#[inline]
pub fn ensure(v: &mut Vec<f32>, len: usize) -> &mut [f32] {
    if v.len() < len {
        v.resize(len, 0.0);
    }
    &mut v[..len]
}

/// Per-worker packing scratch (A panel, B panel, single-row buffer).
#[derive(Default)]
pub struct PackScratch {
    pub apack: Vec<f32>,
    pub bpack: Vec<f32>,
    pub rowbuf: Vec<f32>,
}

/// Where the B operand of a `C[m,n] += A[m,k] · B[k,n]` product comes
/// from: a dense row-major matrix, or an im2col matrix materialized
/// on the fly from a conv input (implicit GEMM — the planner never
/// sees a `col` temp for this path).
pub enum BSource<'a> {
    Dense { b: &'a [f32], n: usize },
    Im2col { image: &'a [f32], geom: &'a Conv2dGeom },
}

impl BSource<'_> {
    /// Pack rows `0..k` × columns `j0..j0+w` into `out[p*w + s]`.
    pub fn pack(&self, k: usize, j0: usize, w: usize, out: &mut [f32]) {
        match *self {
            BSource::Dense { b, n } => {
                for p in 0..k {
                    out[p * w..(p + 1) * w].copy_from_slice(&b[p * n + j0..p * n + j0 + w]);
                }
            }
            BSource::Im2col { image, geom } => {
                for p in 0..k {
                    super::native::im2col_cols(image, geom, p, j0, &mut out[p * w..(p + 1) * w]);
                }
            }
        }
    }

    /// Borrow row `p`, columns `j0..j0+w`. Dense sources return a
    /// subslice; im2col sources gather into `buf`.
    pub fn row<'s>(&'s self, p: usize, j0: usize, w: usize, buf: &'s mut Vec<f32>) -> &'s [f32] {
        match *self {
            BSource::Dense { b, n } => &b[p * n + j0..p * n + j0 + w],
            BSource::Im2col { image, geom } => {
                let out = ensure(buf, w);
                super::native::im2col_cols(image, geom, p, j0, out);
                out
            }
        }
    }
}

/// B^T operand source for `matmul_bt` (B stored `[n, k]`, row `j` of
/// B^T-as-stored is the length-`k` vector dotted against every A row).
/// The im2col variant serves conv weight gradients: `dout · col^T`
/// with `col` never materialized.
pub enum BtSource<'a> {
    Dense { b: &'a [f32], k: usize },
    Im2col { image: &'a [f32], geom: &'a Conv2dGeom },
}

impl BtSource<'_> {
    /// Borrow row `j` (length `k`).
    pub fn row<'s>(&'s self, j: usize, buf: &'s mut Vec<f32>) -> &'s [f32] {
        match *self {
            BtSource::Dense { b, k } => &b[j * k..(j + 1) * k],
            BtSource::Im2col { image, geom } => {
                let cols = geom.col_cols();
                let out = ensure(buf, cols);
                super::native::im2col_cols(image, geom, j, 0, out);
                out
            }
        }
    }
}

/// Tier 1: the register microkernel. Computes an `rows×w` output tile
/// (`rows ≤ MR`, `w ≤ NR`) from packed panels, accumulating into C.
/// Panels are packed `apack[p*rows + r]`, `bpack[p*w + s]` — i.e. the
/// k-index is the outer stride, so the p-loop walks both contiguously.
pub trait MatmulInstruction: Send + Sync {
    fn mr(&self) -> usize;
    fn nr(&self) -> usize;
    /// # Safety
    /// `c` must be valid for writes at `r*ldc + s` for all
    /// `r < rows, s < w`, with no concurrent access to those elements.
    unsafe fn tile(
        &self,
        apack: &[f32],
        bpack: &[f32],
        k: usize,
        rows: usize,
        w: usize,
        c: *mut f32,
        ldc: usize,
    );
}

/// 4×8 f32 microkernel — the same register shape as the naive kernel's
/// tiled branch, so full tiles replicate its accumulation chain
/// exactly: `acc[r][s]` starts at +0.0, sums p-ascending, then lands
/// with one `+=` into C.
pub struct Micro4x8;

impl MatmulInstruction for Micro4x8 {
    fn mr(&self) -> usize {
        MR
    }

    fn nr(&self) -> usize {
        NR
    }

    unsafe fn tile(
        &self,
        apack: &[f32],
        bpack: &[f32],
        k: usize,
        rows: usize,
        w: usize,
        c: *mut f32,
        ldc: usize,
    ) {
        if rows == MR && w == NR {
            let mut acc = [[0f32; NR]; MR];
            for p in 0..k {
                let arow = &apack[p * MR..p * MR + MR];
                let brow = &bpack[p * NR..p * NR + NR];
                for (r, &av) in arow.iter().enumerate() {
                    let accr = &mut acc[r];
                    for (s, &bv) in brow.iter().enumerate() {
                        accr[s] += av * bv;
                    }
                }
            }
            for (r, accr) in acc.iter().enumerate() {
                for (s, &v) in accr.iter().enumerate() {
                    *c.add(r * ldc + s) += v;
                }
            }
        } else {
            // Edge tile: one scalar chain per element, same shape as
            // the naive kernel's remainder loops.
            for r in 0..rows {
                for s in 0..w {
                    let mut acc = 0f32;
                    for p in 0..k {
                        acc += apack[p * rows + r] * bpack[p * w + s];
                    }
                    *c.add(r * ldc + s) += acc;
                }
            }
        }
    }
}

/// Tier 2: cache-blocked matmul over packed panels. Owns no scratch —
/// the caller passes per-worker `PackScratch` so the pool's threads
/// never contend and the hot loop stays malloc-free.
pub struct PackedBlock<I: MatmulInstruction> {
    pub micro: I,
}

impl<I: MatmulInstruction> PackedBlock<I> {
    /// Compute the output band `C[0..m, j0..j1] += A · B[:, j0..j1]`.
    /// B columns are packed once per NR-strip and reused across all
    /// row tiles; A is packed per tile (`apack[p*rows + r]`).
    ///
    /// # Safety
    /// `c` must cover an `m×n` row-major matrix and no concurrent
    /// writer may touch columns `j0..j1`.
    pub unsafe fn run_band(
        &self,
        a: &[f32],
        bsrc: &BSource,
        c: CPtr,
        m: usize,
        k: usize,
        n: usize,
        j0: usize,
        j1: usize,
        sc: &mut PackScratch,
    ) {
        let mr = self.micro.mr();
        let nr = self.micro.nr();
        let mut j = j0;
        while j < j1 {
            let w = nr.min(j1 - j);
            let bpack = ensure(&mut sc.bpack, k * w);
            bsrc.pack(k, j, w, bpack);
            let mut i = 0;
            while i < m {
                let rows = mr.min(m - i);
                let apack = ensure(&mut sc.apack, k * rows);
                for p in 0..k {
                    for r in 0..rows {
                        apack[p * rows + r] = a[(i + r) * k + p];
                    }
                }
                self.micro
                    .tile(apack, &sc.bpack[..k * w], k, rows, w, c.at(i * n + j), n);
                i += rows;
            }
            j += w;
        }
    }
}
