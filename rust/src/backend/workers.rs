//! Hand-rolled, zero-dependency worker pool for the tiered compute backend.
//!
//! Design constraints, in priority order:
//!
//! 1. **Determinism.** The pool never decides *what* is computed — only
//!    *who* computes it. Callers partition work into `tasks` disjoint
//!    pieces and the pool guarantees each task index in `0..tasks` runs
//!    exactly once. Task claiming is a shared atomic counter, so the
//!    mapping of task → thread is racy, but the tiered kernels are built
//!    so every task writes a disjoint output range with a fixed
//!    reduction order — results are bitwise identical for any width.
//! 2. **No allocation per job.** Submitting a job takes a lock and a
//!    condvar broadcast; no boxing, no channels, no per-task allocation.
//! 3. **Panic safety.** A panicking task (on any thread) propagates to
//!    the submitting caller as a panic; the pool itself stays usable.
//!
//! Width resolution follows the PR 6 loud-failure convention:
//! `NNTRAINER_THREADS` unset → `std::thread::available_parallelism()`;
//! set but unparseable or zero → panic. Silent fallback on a typo'd
//! override would quietly serialize every benchmark.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::thread::JoinHandle;

/// Parse a `NNTRAINER_THREADS` value. Pure so the panic paths are
/// testable without touching process environment (env mutation is racy
/// under the parallel test harness).
pub fn parse_width(v: &str) -> usize {
    match v.trim().parse::<usize>() {
        Ok(n) if n > 0 => n,
        Ok(_) => panic!("NNTRAINER_THREADS must be > 0 (got {v:?})"),
        Err(e) => panic!("NNTRAINER_THREADS={v:?} is not a usize: {e}"),
    }
}

/// Worker-pool width from the environment: `NNTRAINER_THREADS` if set
/// (loud panic on garbage), otherwise the machine's available
/// parallelism, otherwise 1.
pub fn configured_width() -> usize {
    match std::env::var("NNTRAINER_THREADS") {
        Ok(v) => parse_width(&v),
        Err(std::env::VarError::NotPresent) => std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1),
        Err(e) => panic!("NNTRAINER_THREADS is set but unreadable: {e}"),
    }
}

/// A published job: a borrowed task closure plus the task count. The
/// pointer is only dereferenced between publication and the caller's
/// completion wait, during which the closure is guaranteed alive.
#[derive(Clone, Copy)]
struct Job {
    f: *const (dyn Fn(usize, usize) + Sync),
    tasks: usize,
}
// SAFETY: the closure behind `f` is `Sync` and outlives the job (the
// submitting caller blocks until every worker has deregistered).
unsafe impl Send for Job {}

struct PoolState {
    job: Option<Job>,
    /// Bumped per job so a worker that wakes late never re-runs a
    /// job it already participated in.
    epoch: u64,
    /// Workers currently registered on the published job.
    active: usize,
    shutdown: bool,
    panicked: bool,
}

struct Shared {
    state: Mutex<PoolState>,
    work: Condvar,
    done: Condvar,
    /// Task-claim cursor for the current job.
    next: AtomicUsize,
    /// Serializes `run` callers (e.g. parallel tests sharing the
    /// global pool); held for the whole duration of a job.
    submit: Mutex<()>,
}

/// Fixed-width thread pool. Width 1 means "no threads": `run` executes
/// inline on the caller.
pub struct WorkerPool {
    shared: Arc<Shared>,
    width: usize,
    handles: Vec<JoinHandle<()>>,
}

impl WorkerPool {
    pub fn new(width: usize) -> Self {
        let width = width.max(1);
        let shared = Arc::new(Shared {
            state: Mutex::new(PoolState {
                job: None,
                epoch: 0,
                active: 0,
                shutdown: false,
                panicked: false,
            }),
            work: Condvar::new(),
            done: Condvar::new(),
            next: AtomicUsize::new(0),
            submit: Mutex::new(()),
        });
        // The caller itself acts as worker 0; spawn width-1 helpers.
        let handles = (1..width)
            .map(|i| {
                let sh = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("nnt-worker{i}"))
                    .spawn(move || worker_loop(&sh))
                    .expect("spawn worker thread")
            })
            .collect();
        WorkerPool { shared, width, handles }
    }

    /// Pool width including the calling thread.
    pub fn width(&self) -> usize {
        self.width
    }

    /// Run `f(task, worker)` for every `task` in `0..tasks`, spread
    /// across the pool. Blocks until all tasks finish. `worker` is in
    /// `0..width` and is stable within one task — kernels use it to
    /// index per-worker scratch. Panics (from any task) propagate.
    pub fn run(&self, tasks: usize, f: &(dyn Fn(usize, usize) + Sync)) {
        if tasks == 0 {
            return;
        }
        if self.width == 1 || tasks == 1 {
            for t in 0..tasks {
                f(t, 0);
            }
            return;
        }
        let _turn = self.shared.submit.lock().unwrap();
        {
            let mut st = self.shared.state.lock().unwrap();
            self.shared.next.store(0, Ordering::Relaxed);
            st.panicked = false;
            st.epoch += 1;
            st.job = Some(Job {
                f: f as *const (dyn Fn(usize, usize) + Sync),
                tasks,
            });
            self.shared.work.notify_all();
        }
        // The caller drains tasks as worker 0. Catch a local panic so
        // we still wait for helpers before unwinding past `f`.
        let local = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            loop {
                let t = self.shared.next.fetch_add(1, Ordering::Relaxed);
                if t >= tasks {
                    break;
                }
                f(t, 0);
            }
        }));
        let mut st = self.shared.state.lock().unwrap();
        while st.active > 0 {
            st = self.shared.done.wait(st).unwrap();
        }
        st.job = None;
        let panicked = st.panicked;
        drop(st);
        if let Err(p) = local {
            std::panic::resume_unwind(p);
        }
        if panicked {
            panic!("worker thread panicked during pooled job");
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
            self.shared.work.notify_all();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(sh: &Shared) {
    let mut seen = 0u64;
    loop {
        let job = {
            let mut st = sh.state.lock().unwrap();
            loop {
                if st.shutdown {
                    return;
                }
                if let Some(job) = st.job {
                    if st.epoch != seen {
                        seen = st.epoch;
                        st.active += 1;
                        break job;
                    }
                }
                st = sh.work.wait(st).unwrap();
            }
        };
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            // SAFETY: the submitting caller keeps the closure alive
            // until `active` drops to 0, which happens below.
            let f = unsafe { &*job.f };
            loop {
                let t = sh.next.fetch_add(1, Ordering::Relaxed);
                if t >= job.tasks {
                    break;
                }
                f(t, worker_index());
            }
        }));
        let mut st = sh.state.lock().unwrap();
        if res.is_err() {
            st.panicked = true;
        }
        st.active -= 1;
        if st.active == 0 {
            sh.done.notify_all();
        }
    }
}

/// Worker index from the thread name ("nnt-worker{i}"); worker 0 is
/// always the submitting caller.
fn worker_index() -> usize {
    std::thread::current()
        .name()
        .and_then(|n| n.strip_prefix("nnt-worker"))
        .and_then(|i| i.parse().ok())
        .unwrap_or(0)
}

/// Process-wide pool at the configured width. Built once on first use;
/// never dropped (workers park on the condvar between jobs).
pub fn global_pool() -> Arc<WorkerPool> {
    static GLOBAL: OnceLock<Arc<WorkerPool>> = OnceLock::new();
    Arc::clone(GLOBAL.get_or_init(|| Arc::new(WorkerPool::new(configured_width()))))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU32;

    #[test]
    fn every_task_runs_exactly_once() {
        let pool = WorkerPool::new(3);
        let slots: Vec<AtomicU32> = (0..97).map(|_| AtomicU32::new(0)).collect();
        pool.run(slots.len(), &|t, _w| {
            slots[t].fetch_add(1, Ordering::Relaxed);
        });
        for (t, s) in slots.iter().enumerate() {
            assert_eq!(s.load(Ordering::Relaxed), 1, "task {t}");
        }
    }

    #[test]
    fn pool_is_reusable_across_jobs() {
        let pool = WorkerPool::new(2);
        for round in 0..16 {
            let hits = AtomicU32::new(0);
            pool.run(round + 1, &|_t, _w| {
                hits.fetch_add(1, Ordering::Relaxed);
            });
            assert_eq!(hits.load(Ordering::Relaxed) as usize, round + 1);
        }
    }

    #[test]
    fn width_one_runs_inline() {
        let pool = WorkerPool::new(1);
        let on_caller = AtomicU32::new(0);
        let caller = std::thread::current().id();
        pool.run(5, &|_t, w| {
            assert_eq!(w, 0);
            if std::thread::current().id() == caller {
                on_caller.fetch_add(1, Ordering::Relaxed);
            }
        });
        assert_eq!(on_caller.load(Ordering::Relaxed), 5);
    }

    #[test]
    fn worker_indices_stay_in_range() {
        let pool = WorkerPool::new(4);
        pool.run(64, &|_t, w| {
            assert!(w < 4, "worker index {w} out of range");
        });
    }

    #[test]
    fn task_panic_propagates_and_pool_survives() {
        let pool = WorkerPool::new(2);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run(8, &|t, _w| {
                if t == 3 {
                    panic!("boom");
                }
            });
        }));
        assert!(r.is_err(), "panic should propagate to the caller");
        // Pool remains usable after a panicked job.
        let hits = AtomicU32::new(0);
        pool.run(4, &|_t, _w| {
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 4);
    }

    #[test]
    fn parse_width_accepts_positive() {
        assert_eq!(parse_width("1"), 1);
        assert_eq!(parse_width(" 8 "), 8);
    }

    #[test]
    fn parse_width_panics_on_zero() {
        let r = std::panic::catch_unwind(|| parse_width("0"));
        assert!(r.is_err());
    }

    #[test]
    fn parse_width_panics_on_garbage() {
        let r = std::panic::catch_unwind(|| parse_width("many"));
        assert!(r.is_err());
    }
}
