//! Compute backends ("Delegates" in the paper). The native CPU backend is
//! the default; the PJRT runtime (`crate::runtime`) is the AOT-compiled
//! XLA path used by the end-to-end example and the numerics oracle tests.

pub mod native;
