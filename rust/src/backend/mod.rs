//! Compute backends ("Delegates" in the paper). The native CPU kernels
//! are the numeric ground truth; the `Backend` trait is the seam every
//! layer kernels through, selected per-model at `compile_for` time via
//! `DeviceProfile::compute`. The PJRT runtime (`crate::runtime`) is the
//! AOT-compiled XLA path used by the end-to-end example and the
//! numerics oracle tests; a PJRT-backed delegate would implement this
//! same trait and slot in without touching the executor or any layer.

pub mod native;
pub mod tiered;
pub mod tiers;
pub mod workers;

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

pub use native::Conv2dGeom;
pub use tiered::TieredBackend;
pub use workers::WorkerPool;

/// Which compute backend a compiled model runs on.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum ComputeKind {
    /// Three-tier blocked kernels over the worker pool (default).
    /// Bitwise identical to `Naive` at every pool width.
    #[default]
    Tiered,
    /// The original single-threaded free-function kernels — kept as
    /// the regression baseline and the planner's conservative profile
    /// (it is the only backend that needs the materialized conv `col`
    /// temp).
    Naive,
}

impl ComputeKind {
    pub fn name(self) -> &'static str {
        match self {
            ComputeKind::Tiered => "tiered",
            ComputeKind::Naive => "naive",
        }
    }

    /// Instantiate the backend. `Tiered` shares the process-global
    /// worker pool (width from `NNTRAINER_THREADS`, else core count).
    pub fn instance(self) -> Arc<dyn Backend> {
        match self {
            ComputeKind::Tiered => Arc::new(TieredBackend::new()),
            ComputeKind::Naive => Arc::new(NaiveBackend::default()),
        }
    }
}

/// The compute seam. Implementations must be numerically
/// interchangeable *bitwise* — the session equivalence suites train
/// the same model under each kind and compare losses and weights with
/// `to_bits()`.
pub trait Backend: Send + Sync {
    fn kind(&self) -> ComputeKind;

    /// C[m,n] (+)= A[m,k] · B[k,n].
    fn matmul(
        &self,
        a: &[f32],
        b: &[f32],
        c: &mut [f32],
        m: usize,
        k: usize,
        n: usize,
        accumulate: bool,
    );

    /// C[m,n] (+)= Aᵀ · B (A stored [k,m]).
    fn matmul_at(
        &self,
        a: &[f32],
        b: &[f32],
        c: &mut [f32],
        m: usize,
        k: usize,
        n: usize,
        accumulate: bool,
    );

    /// C[m,n] (+)= A · Bᵀ (B stored [n,k]).
    fn matmul_bt(
        &self,
        a: &[f32],
        b: &[f32],
        c: &mut [f32],
        m: usize,
        k: usize,
        n: usize,
        accumulate: bool,
    );

    /// Batched conv forward: out[s] = W · im2col(x[s]) for each sample
    /// (bias is the layer's business). `col` is scratch for one
    /// sample's materialized im2col matrix; backends that gather
    /// implicitly ignore it and accept `None`.
    fn conv2d_forward(
        &self,
        x: &[f32],
        w: &[f32],
        out: &mut [f32],
        g: &Conv2dGeom,
        batch: usize,
        col: Option<&mut [f32]>,
    );

    /// Conv weight gradient: gw (+)= Σ_s dout[s] · im2col(x[s])ᵀ,
    /// accumulated in sample order.
    fn conv2d_grad_w(
        &self,
        x: &[f32],
        dout: &[f32],
        gw: &mut [f32],
        g: &Conv2dGeom,
        batch: usize,
        col: Option<&mut [f32]>,
    );

    /// FLOPs issued through this backend since construction / the last
    /// `reset_flops` (2·m·k·n per matmul) — feeds the bench GFLOP/s
    /// columns.
    fn flops(&self) -> u64;
    fn reset_flops(&self);
}

/// The original kernels behind the seam, verbatim.
#[derive(Default)]
pub struct NaiveBackend {
    flops: AtomicU64,
}

impl NaiveBackend {
    fn bump(&self, m: usize, k: usize, n: usize) {
        self.flops.fetch_add(2 * (m * k * n) as u64, Ordering::Relaxed);
    }
}

impl Backend for NaiveBackend {
    fn kind(&self) -> ComputeKind {
        ComputeKind::Naive
    }

    fn matmul(
        &self,
        a: &[f32],
        b: &[f32],
        c: &mut [f32],
        m: usize,
        k: usize,
        n: usize,
        accumulate: bool,
    ) {
        self.bump(m, k, n);
        native::matmul(a, b, c, m, k, n, accumulate);
    }

    fn matmul_at(
        &self,
        a: &[f32],
        b: &[f32],
        c: &mut [f32],
        m: usize,
        k: usize,
        n: usize,
        accumulate: bool,
    ) {
        self.bump(m, k, n);
        native::matmul_at(a, b, c, m, k, n, accumulate);
    }

    fn matmul_bt(
        &self,
        a: &[f32],
        b: &[f32],
        c: &mut [f32],
        m: usize,
        k: usize,
        n: usize,
        accumulate: bool,
    ) {
        self.bump(m, k, n);
        native::matmul_bt(a, b, c, m, k, n, accumulate);
    }

    fn conv2d_forward(
        &self,
        x: &[f32],
        w: &[f32],
        out: &mut [f32],
        g: &Conv2dGeom,
        batch: usize,
        col: Option<&mut [f32]>,
    ) {
        let col = col.expect("naive compute backend needs the explicit conv `col` temp");
        let in_sz = g.in_c * g.in_h * g.in_w;
        let out_sz = g.out_c * g.col_cols();
        for s in 0..batch {
            native::im2col(&x[s * in_sz..(s + 1) * in_sz], g, col);
            self.bump(g.out_c, g.col_rows(), g.col_cols());
            let o = &mut out[s * out_sz..(s + 1) * out_sz];
            native::matmul(w, col, o, g.out_c, g.col_rows(), g.col_cols(), false);
        }
    }

    fn conv2d_grad_w(
        &self,
        x: &[f32],
        dout: &[f32],
        gw: &mut [f32],
        g: &Conv2dGeom,
        batch: usize,
        col: Option<&mut [f32]>,
    ) {
        let col = col.expect("naive compute backend needs the explicit conv `col` temp");
        let in_sz = g.in_c * g.in_h * g.in_w;
        let out_sz = g.out_c * g.col_cols();
        for s in 0..batch {
            native::im2col(&x[s * in_sz..(s + 1) * in_sz], g, col);
            self.bump(g.out_c, g.col_cols(), g.col_rows());
            let d = &dout[s * out_sz..(s + 1) * out_sz];
            native::matmul_bt(d, col, gw, g.out_c, g.col_cols(), g.col_rows(), true);
        }
    }

    fn flops(&self) -> u64 {
        self.flops.load(Ordering::Relaxed)
    }

    fn reset_flops(&self) {
        self.flops.store(0, Ordering::Relaxed)
    }
}
