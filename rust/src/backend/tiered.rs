//! Tier 3: `TieredBackend` — partitions kernel output across the
//! worker pool and dispatches bands into the packed-block tier.
//!
//! **Bitwise-threading invariant.** Every kernel here mirrors the
//! exact regime branch (`native::TALL_K_MIN_K` / `CACHE_BLOCK_ELEMS`)
//! and per-element accumulation chain of the naive kernel it shadows,
//! and parallelizes only across *disjoint output elements* — each
//! element's k-chain runs sequentially on exactly one thread. Thread
//! count (and the task partition) therefore changes who computes an
//! element, never how, so `Tiered` at any width is bitwise identical
//! to `Naive`. The equivalence suites in `tests/compute_backend.rs`
//! and the session-level suites hold this to `to_bits()` equality.
//!
//! Convolutions run as **implicit GEMM**: the packing tier gathers B
//! panels straight from the input image via `native::im2col_cols`, so
//! the forward/weight-gradient paths need no materialized `col` temp
//! and the planner's peak drops accordingly.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use super::native::{Conv2dGeom, CACHE_BLOCK_ELEMS, TALL_K_MIN_K};
use super::tiers::{
    chunk_bounds, parts_for, BSource, BtSource, CPtr, Micro4x8, PackScratch, PackedBlock, NR,
};
use super::workers::{global_pool, WorkerPool};
use super::{Backend, ComputeKind};

pub struct TieredBackend {
    pool: Arc<WorkerPool>,
    /// One scratch set per worker index; uncontended in steady state
    /// (the pool runs one job at a time and a worker index maps to one
    /// thread), the Mutex just makes that locally provable.
    scratch: Vec<Mutex<PackScratch>>,
    block: PackedBlock<Micro4x8>,
    flops: AtomicU64,
}

impl TieredBackend {
    /// Backend over the process-global worker pool (width from
    /// `NNTRAINER_THREADS` / available parallelism).
    pub fn new() -> Self {
        Self::with_pool(global_pool())
    }

    /// Backend over an explicit pool — the determinism suites use this
    /// to compare widths side by side within one process.
    pub fn with_pool(pool: Arc<WorkerPool>) -> Self {
        let scratch = (0..pool.width()).map(|_| Mutex::new(PackScratch::default())).collect();
        TieredBackend {
            pool,
            scratch,
            block: PackedBlock { micro: Micro4x8 },
            flops: AtomicU64::new(0),
        }
    }

    pub fn width(&self) -> usize {
        self.pool.width()
    }

    fn bump(&self, m: usize, k: usize, n: usize) {
        self.flops.fetch_add(2 * (m * k * n) as u64, Ordering::Relaxed);
    }

    /// C[m,n] (+)= A[m,k] · B[k,n], B supplied dense or as an implicit
    /// im2col of a conv input.
    pub fn matmul_src(
        &self,
        a: &[f32],
        bsrc: &BSource,
        c: &mut [f32],
        m: usize,
        k: usize,
        n: usize,
        accumulate: bool,
    ) {
        if !accumulate {
            c.fill(0.0);
        }
        if m == 0 || n == 0 {
            return;
        }
        self.bump(m, k, n);
        let cp = CPtr(c.as_mut_ptr());
        let width = self.pool.width();
        if k >= TALL_K_MIN_K && m * n <= CACHE_BLOCK_ELEMS {
            // Rank-1 regime: direct-into-C chains, p ascending —
            // naive's exact chain. Partition the larger C axis.
            if n >= m {
                let parts = parts_for(n, width);
                self.pool.run(parts, &|t, w| {
                    let (j0, j1) = chunk_bounds(n, parts, t);
                    let mut sc = self.scratch[w].lock().unwrap();
                    for p in 0..k {
                        let brow = bsrc.row(p, j0, j1 - j0, &mut sc.rowbuf);
                        for i in 0..m {
                            let av = a[i * k + p];
                            // SAFETY: this task owns columns j0..j1 of
                            // every row; tasks are column-disjoint.
                            let crow = unsafe {
                                std::slice::from_raw_parts_mut(cp.at(i * n + j0), j1 - j0)
                            };
                            for (cv, &bv) in crow.iter_mut().zip(brow.iter()) {
                                *cv += av * bv;
                            }
                        }
                    }
                });
            } else {
                let parts = parts_for(m, width);
                self.pool.run(parts, &|t, w| {
                    let (i0, i1) = chunk_bounds(m, parts, t);
                    let mut sc = self.scratch[w].lock().unwrap();
                    for p in 0..k {
                        let brow = bsrc.row(p, 0, n, &mut sc.rowbuf);
                        for i in i0..i1 {
                            let av = a[i * k + p];
                            // SAFETY: tasks are row-disjoint.
                            let crow =
                                unsafe { std::slice::from_raw_parts_mut(cp.at(i * n), n) };
                            for (cv, &bv) in crow.iter_mut().zip(brow.iter()) {
                                *cv += av * bv;
                            }
                        }
                    }
                });
            }
            return;
        }
        // Blocked regime: register chains (acc from +0.0, p ascending,
        // one += into C) — naive's exact chain for full and edge tiles
        // alike. Bands are NR-aligned so tile boundaries (and thus
        // packing) are identical for every partition.
        let tiles = n.div_ceil(NR).max(1);
        let parts = parts_for(tiles, width);
        self.pool.run(parts, &|t, w| {
            let (t0, t1) = chunk_bounds(tiles, parts, t);
            if t0 == t1 {
                return;
            }
            let (j0, j1) = (t0 * NR, (t1 * NR).min(n));
            let mut sc = self.scratch[w].lock().unwrap();
            // SAFETY: tasks own disjoint NR-aligned column bands.
            unsafe { self.block.run_band(a, bsrc, cp, m, k, n, j0, j1, &mut sc) };
        });
    }

    /// C[m,n] (+)= Aᵀ[k,m]·B[k,n] (A stored [k,m]). Partitioned by
    /// output rows in both regimes; mirrors naive's branchless small
    /// path and zero-skipping general path chain for chain.
    pub fn matmul_at_impl(
        &self,
        a: &[f32],
        b: &[f32],
        c: &mut [f32],
        m: usize,
        k: usize,
        n: usize,
        accumulate: bool,
    ) {
        if !accumulate {
            c.fill(0.0);
        }
        if m == 0 || n == 0 {
            return;
        }
        self.bump(m, k, n);
        let cp = CPtr(c.as_mut_ptr());
        let parts = parts_for(m, self.pool.width());
        if k * n <= CACHE_BLOCK_ELEMS {
            self.pool.run(parts, &|t, _w| {
                let (i0, i1) = chunk_bounds(m, parts, t);
                for i in i0..i1 {
                    // SAFETY: tasks are row-disjoint.
                    let crow = unsafe { std::slice::from_raw_parts_mut(cp.at(i * n), n) };
                    for p in 0..k {
                        let av = a[p * m + i];
                        let brow = &b[p * n..(p + 1) * n];
                        for (cv, &bv) in crow.iter_mut().zip(brow.iter()) {
                            *cv += av * bv;
                        }
                    }
                }
            });
        } else {
            self.pool.run(parts, &|t, _w| {
                let (i0, i1) = chunk_bounds(m, parts, t);
                for p in 0..k {
                    let arow = &a[p * m..(p + 1) * m];
                    let brow = &b[p * n..(p + 1) * n];
                    for (i, &av) in arow.iter().enumerate().take(i1).skip(i0) {
                        // The zero-skip is part of the observable
                        // chain (c0 = -0.0 would flip on += +0.0), so
                        // it must match naive exactly.
                        if av == 0.0 {
                            continue;
                        }
                        // SAFETY: tasks are row-disjoint.
                        let crow = unsafe { std::slice::from_raw_parts_mut(cp.at(i * n), n) };
                        for (cv, &bv) in crow.iter_mut().zip(brow.iter()) {
                            *cv += av * bv;
                        }
                    }
                }
            });
        }
    }

    /// C[m,n] (+)= A[m,k]·Bᵀ (B stored [n,k], dense or implicit
    /// im2col). Partitioned by output columns (= B rows) in both
    /// regimes so the per-`j` row gather happens once per column.
    pub fn matmul_bt_src(
        &self,
        a: &[f32],
        bsrc: &BtSource,
        c: &mut [f32],
        m: usize,
        k: usize,
        n: usize,
        accumulate: bool,
    ) {
        if !accumulate {
            c.fill(0.0);
        }
        if m == 0 || n == 0 {
            return;
        }
        self.bump(m, k, n);
        let cp = CPtr(c.as_mut_ptr());
        let parts = parts_for(n, self.pool.width());
        if m * k <= CACHE_BLOCK_ELEMS {
            self.pool.run(parts, &|t, w| {
                let (j0, j1) = chunk_bounds(n, parts, t);
                let mut sc = self.scratch[w].lock().unwrap();
                for j in j0..j1 {
                    let brow = bsrc.row(j, &mut sc.rowbuf);
                    for i in 0..m {
                        let arow = &a[i * k..(i + 1) * k];
                        // Naive's 4-way unrolled dot, replicated
                        // association for association.
                        let mut acc = [0f32; 4];
                        let chunks = k / 4;
                        for t4 in 0..chunks {
                            let o = t4 * 4;
                            acc[0] += arow[o] * brow[o];
                            acc[1] += arow[o + 1] * brow[o + 1];
                            acc[2] += arow[o + 2] * brow[o + 2];
                            acc[3] += arow[o + 3] * brow[o + 3];
                        }
                        let mut s = (acc[0] + acc[1]) + (acc[2] + acc[3]);
                        for t4 in chunks * 4..k {
                            s += arow[t4] * brow[t4];
                        }
                        // SAFETY: tasks are column-disjoint.
                        unsafe { *cp.at(i * n + j) += s };
                    }
                }
            });
        } else {
            // Naive iterates i-outer here; per-element chains are
            // single sequential dots, so element order is free and we
            // keep j outer to gather each Bᵀ row exactly once.
            self.pool.run(parts, &|t, w| {
                let (j0, j1) = chunk_bounds(n, parts, t);
                let mut sc = self.scratch[w].lock().unwrap();
                for j in j0..j1 {
                    let brow = bsrc.row(j, &mut sc.rowbuf);
                    for i in 0..m {
                        let arow = &a[i * k..(i + 1) * k];
                        let mut acc = 0f32;
                        for (&av, &bv) in arow.iter().zip(brow.iter()) {
                            acc += av * bv;
                        }
                        // SAFETY: tasks are column-disjoint.
                        unsafe { *cp.at(i * n + j) += acc };
                    }
                }
            });
        }
    }
}

impl Default for TieredBackend {
    fn default() -> Self {
        Self::new()
    }
}

impl Backend for TieredBackend {
    fn kind(&self) -> ComputeKind {
        ComputeKind::Tiered
    }

    fn matmul(
        &self,
        a: &[f32],
        b: &[f32],
        c: &mut [f32],
        m: usize,
        k: usize,
        n: usize,
        accumulate: bool,
    ) {
        self.matmul_src(a, &BSource::Dense { b, n }, c, m, k, n, accumulate);
    }

    fn matmul_at(
        &self,
        a: &[f32],
        b: &[f32],
        c: &mut [f32],
        m: usize,
        k: usize,
        n: usize,
        accumulate: bool,
    ) {
        self.matmul_at_impl(a, b, c, m, k, n, accumulate);
    }

    fn matmul_bt(
        &self,
        a: &[f32],
        b: &[f32],
        c: &mut [f32],
        m: usize,
        k: usize,
        n: usize,
        accumulate: bool,
    ) {
        self.matmul_bt_src(a, &BtSource::Dense { b, k }, c, m, k, n, accumulate);
    }

    fn conv2d_forward(
        &self,
        x: &[f32],
        w: &[f32],
        out: &mut [f32],
        g: &Conv2dGeom,
        batch: usize,
        _col: Option<&mut [f32]>,
    ) {
        let in_sz = g.in_c * g.in_h * g.in_w;
        let out_sz = g.out_c * g.col_cols();
        for s in 0..batch {
            let image = &x[s * in_sz..(s + 1) * in_sz];
            let o = &mut out[s * out_sz..(s + 1) * out_sz];
            let bsrc = BSource::Im2col { image, geom: g };
            self.matmul_src(w, &bsrc, o, g.out_c, g.col_rows(), g.col_cols(), false);
        }
    }

    fn conv2d_grad_w(
        &self,
        x: &[f32],
        dout: &[f32],
        gw: &mut [f32],
        g: &Conv2dGeom,
        batch: usize,
        _col: Option<&mut [f32]>,
    ) {
        let in_sz = g.in_c * g.in_h * g.in_w;
        let out_sz = g.out_c * g.col_cols();
        // Sequential over samples: gw accumulates in sample order, the
        // same cross-sample chain as the naive path.
        for s in 0..batch {
            let image = &x[s * in_sz..(s + 1) * in_sz];
            let d = &dout[s * out_sz..(s + 1) * out_sz];
            let bsrc = BtSource::Im2col { image, geom: g };
            self.matmul_bt_src(d, &bsrc, gw, g.out_c, g.col_cols(), g.col_rows(), true);
        }
    }

    fn flops(&self) -> u64 {
        self.flops.load(Ordering::Relaxed)
    }

    fn reset_flops(&self) {
        self.flops.store(0, Ordering::Relaxed)
    }
}
