//! Native CPU compute kernels — the default `Delegate` (paper §4).
//!
//! These are the raw numeric primitives every layer is built from:
//! a register-blocked matmul, im2col/col2im for convolutions, and
//! elementwise/reduction helpers. They are deliberately allocation-free:
//! all outputs and scratch space come from the caller (i.e. from pool
//! regions assigned by the Memory Planner), which keeps the training hot
//! loop malloc-free.

/// `k` at/above which `matmul` switches to the k-outer rank-1 path
/// (when the output also fits in cache per `CACHE_BLOCK_ELEMS`).
pub const TALL_K_MIN_K: usize = 2048;
/// "Fits in cache" element-count cutoff shared by the three matmul
/// regime switches. The regime choice fixes the FP accumulation chain
/// per output element, so the tiered backend mirrors these exact
/// conditions to stay bitwise identical.
pub const CACHE_BLOCK_ELEMS: usize = 64 * 1024;
/// Register microkernel tile shape (rows x cols).
pub const MR: usize = 4;
pub const NR: usize = 8;

/// C[m,n] (+)= A[m,k] * B[k,n].
///
/// Register-blocked (4x8 micro-kernel over a k-loop) single-threaded
/// matmul. On the 1-core container this reaches a few GFLOP/s, enough to
/// keep benchmark latencies realistic without an external BLAS (none is
/// available offline).
pub fn matmul(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize, accumulate: bool) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    if !accumulate {
        c.fill(0.0);
    }
    // Tall-K regime (fc layers on flattened images: K ~ 1e5, M,N small):
    // the tiled kernel would re-stream B per row-block. Switch to k-outer
    // rank-1 updates — A and B are each streamed exactly once and C stays
    // cache-resident. §Perf step 1: 2.7 -> ~6 GFLOP/s on 32x150528x128.
    if k >= TALL_K_MIN_K && m * n <= CACHE_BLOCK_ELEMS {
        for p in 0..k {
            let brow = &b[p * n..(p + 1) * n];
            for i in 0..m {
                let av = a[i * k + p];
                let crow = &mut c[i * n..(i + 1) * n];
                for (cv, &bv) in crow.iter_mut().zip(brow.iter()) {
                    *cv += av * bv;
                }
            }
        }
        return;
    }
    let mut i = 0;
    while i + MR <= m {
        let mut j = 0;
        while j + NR <= n {
            // 4x8 accumulator block.
            let mut acc = [[0f32; NR]; MR];
            for p in 0..k {
                let bp = &b[p * n + j..p * n + j + NR];
                for (r, accr) in acc.iter_mut().enumerate() {
                    let av = a[(i + r) * k + p];
                    for (s, accv) in accr.iter_mut().enumerate() {
                        *accv += av * bp[s];
                    }
                }
            }
            for (r, accr) in acc.iter().enumerate() {
                let crow = &mut c[(i + r) * n + j..(i + r) * n + j + NR];
                for (s, &v) in accr.iter().enumerate() {
                    crow[s] += v;
                }
            }
            j += NR;
        }
        // n remainder
        while j < n {
            for r in 0..MR {
                let mut acc = 0f32;
                for p in 0..k {
                    acc += a[(i + r) * k + p] * b[p * n + j];
                }
                c[(i + r) * n + j] += acc;
            }
            j += 1;
        }
        i += MR;
    }
    // m remainder
    while i < m {
        for j in 0..n {
            let mut acc = 0f32;
            for p in 0..k {
                acc += a[i * k + p] * b[p * n + j];
            }
            c[i * n + j] += acc;
        }
        i += 1;
    }
}

/// C[m,n] (+)= A^T[k,m] * B[k,n]  (A stored [k,m]).
pub fn matmul_at(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize, accumulate: bool) {
    debug_assert_eq!(a.len(), k * m);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    if !accumulate {
        c.fill(0.0);
    }
    // Weight-gradient regime (ΔW[f,u] += Xᵀ·ΔD with tiny batch k): when
    // B fits in cache, iterate output rows so the (often huge) C streams
    // exactly once instead of once per batch row. §Perf step 2:
    // 2.5 -> ~7 GFLOP/s on the fc0 gradient of Model A-Linear.
    if k * n <= CACHE_BLOCK_ELEMS {
        // §Perf step 5: branchless inner loop (the zero-skip guard costs
        // more in mispredicts than it saves on dense gradients).
        for i in 0..m {
            let crow = &mut c[i * n..(i + 1) * n];
            for p in 0..k {
                let av = a[p * m + i];
                let brow = &b[p * n..(p + 1) * n];
                for (cv, &bv) in crow.iter_mut().zip(brow.iter()) {
                    *cv += av * bv;
                }
            }
        }
        return;
    }
    // General: iterate p outer so both A-row and B-row are contiguous
    // streams; accumulate into C rows.
    for p in 0..k {
        let arow = &a[p * m..(p + 1) * m];
        let brow = &b[p * n..(p + 1) * n];
        for (i, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let crow = &mut c[i * n..(i + 1) * n];
            for (cv, &bv) in crow.iter_mut().zip(brow.iter()) {
                *cv += av * bv;
            }
        }
    }
}

/// C[m,n] (+)= A[m,k] * B^T[n,k]  (B stored [n,k]).
pub fn matmul_bt(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize, accumulate: bool) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    debug_assert_eq!(c.len(), m * n);
    if !accumulate {
        c.fill(0.0);
    }
    // Derivative regime (ΔD' = ΔD·Wᵀ with huge n = input features): when
    // A fits in cache, iterate B rows outer so W streams exactly once
    // instead of once per output row. §Perf step 3: 1.9 -> ~5 GFLOP/s on
    // the fc derivative of Model B-Linear.
    if m * k <= CACHE_BLOCK_ELEMS {
        for j in 0..n {
            let brow = &b[j * k..(j + 1) * k];
            for i in 0..m {
                let arow = &a[i * k..(i + 1) * k];
                // 4-way unrolled dot: independent accumulators break the
                // FP-add dependency chain (§Perf step 4).
                let mut acc = [0f32; 4];
                let chunks = k / 4;
                for t in 0..chunks {
                    let o = t * 4;
                    acc[0] += arow[o] * brow[o];
                    acc[1] += arow[o + 1] * brow[o + 1];
                    acc[2] += arow[o + 2] * brow[o + 2];
                    acc[3] += arow[o + 3] * brow[o + 3];
                }
                let mut s = (acc[0] + acc[1]) + (acc[2] + acc[3]);
                for t in chunks * 4..k {
                    s += arow[t] * brow[t];
                }
                c[i * n + j] += s;
            }
        }
        return;
    }
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        for j in 0..n {
            let brow = &b[j * k..(j + 1) * k];
            let mut acc = 0f32;
            // dot of two contiguous rows — vectorizes well.
            for (&av, &bv) in arow.iter().zip(brow.iter()) {
                acc += av * bv;
            }
            crow[j] += acc;
        }
    }
}

/// Add a row-vector bias[n] to every row of C[m,n].
pub fn add_bias(c: &mut [f32], bias: &[f32], m: usize, n: usize) {
    debug_assert_eq!(c.len(), m * n);
    debug_assert_eq!(bias.len(), n);
    for i in 0..m {
        let row = &mut c[i * n..(i + 1) * n];
        for (v, &b) in row.iter_mut().zip(bias.iter()) {
            *v += b;
        }
    }
}

/// bias_grad[n] (+)= column sums of D[m,n].
pub fn bias_grad(d: &[f32], g: &mut [f32], m: usize, n: usize, accumulate: bool) {
    debug_assert_eq!(d.len(), m * n);
    debug_assert_eq!(g.len(), n);
    if !accumulate {
        g.fill(0.0);
    }
    for i in 0..m {
        let row = &d[i * n..(i + 1) * n];
        for (gv, &dv) in g.iter_mut().zip(row.iter()) {
            *gv += dv;
        }
    }
}

/// Geometry of a 2-D convolution (single spatial config; shared by
/// forward / im2col / backward).
#[derive(Clone, Copy, Debug)]
pub struct Conv2dGeom {
    pub in_c: usize,
    pub in_h: usize,
    pub in_w: usize,
    pub out_c: usize,
    pub k_h: usize,
    pub k_w: usize,
    pub stride: usize,
    pub pad_h: usize,
    pub pad_w: usize,
}

impl Conv2dGeom {
    pub fn out_h(&self) -> usize {
        (self.in_h + 2 * self.pad_h - self.k_h) / self.stride + 1
    }
    pub fn out_w(&self) -> usize {
        (self.in_w + 2 * self.pad_w - self.k_w) / self.stride + 1
    }
    /// Rows of the im2col matrix: in_c*k_h*k_w; cols: out_h*out_w.
    pub fn col_rows(&self) -> usize {
        self.in_c * self.k_h * self.k_w
    }
    pub fn col_cols(&self) -> usize {
        self.out_h() * self.out_w()
    }
}

/// im2col for one image: input [in_c, in_h, in_w] → col [col_rows, col_cols].
///
/// "Image to Column" (paper §5.1 explicitly calls this buffer out as the
/// extra heap NNTrainer's Conv2D needs).
pub fn im2col(input: &[f32], g: &Conv2dGeom, col: &mut [f32]) {
    let (oh, ow) = (g.out_h(), g.out_w());
    debug_assert_eq!(input.len(), g.in_c * g.in_h * g.in_w);
    debug_assert_eq!(col.len(), g.col_rows() * g.col_cols());
    let mut r = 0usize;
    for c in 0..g.in_c {
        let plane = &input[c * g.in_h * g.in_w..(c + 1) * g.in_h * g.in_w];
        for kh in 0..g.k_h {
            for kw in 0..g.k_w {
                let dst = &mut col[r * oh * ow..(r + 1) * oh * ow];
                let mut d = 0usize;
                for y in 0..oh {
                    let iy = (y * g.stride + kh) as isize - g.pad_h as isize;
                    if iy < 0 || iy as usize >= g.in_h {
                        dst[d..d + ow].fill(0.0);
                        d += ow;
                        continue;
                    }
                    let iy = iy as usize;
                    for x in 0..ow {
                        let ix = (x * g.stride + kw) as isize - g.pad_w as isize;
                        dst[d] = if ix < 0 || ix as usize >= g.in_w {
                            0.0
                        } else {
                            plane[iy * g.in_w + ix as usize]
                        };
                        d += 1;
                    }
                }
                r += 1;
            }
        }
    }
}

/// Gather one row-segment of the im2col matrix without materializing
/// it: row `r`, columns `j0..j0+out.len()`, for the given image. This
/// is the implicit-GEMM primitive — the tiered backend packs conv
/// panels straight from the input, so no `col` scratch tensor exists
/// and the planner's peak shrinks by `col_rows * col_cols` floats.
///
/// Produces exactly the values `im2col` would place at
/// `col[r * col_cols + j0 ..][..out.len()]`.
pub fn im2col_cols(input: &[f32], g: &Conv2dGeom, r: usize, j0: usize, out: &mut [f32]) {
    let ow = g.out_w();
    let c = r / (g.k_h * g.k_w);
    let kh = (r / g.k_w) % g.k_h;
    let kw = r % g.k_w;
    let plane = &input[c * g.in_h * g.in_w..(c + 1) * g.in_h * g.in_w];
    for (d, o) in out.iter_mut().enumerate() {
        let j = j0 + d;
        let (y, x) = (j / ow, j % ow);
        let iy = (y * g.stride + kh) as isize - g.pad_h as isize;
        let ix = (x * g.stride + kw) as isize - g.pad_w as isize;
        *o = if iy < 0 || ix < 0 || iy as usize >= g.in_h || ix as usize >= g.in_w {
            0.0
        } else {
            plane[iy as usize * g.in_w + ix as usize]
        };
    }
}

/// col2im (scatter-add): col [col_rows, col_cols] → input-grad
/// [in_c, in_h, in_w]. Inverse of `im2col` for the backward pass.
pub fn col2im(col: &[f32], g: &Conv2dGeom, out: &mut [f32], accumulate: bool) {
    let (oh, ow) = (g.out_h(), g.out_w());
    debug_assert_eq!(out.len(), g.in_c * g.in_h * g.in_w);
    debug_assert_eq!(col.len(), g.col_rows() * g.col_cols());
    if !accumulate {
        out.fill(0.0);
    }
    let mut r = 0usize;
    for c in 0..g.in_c {
        for kh in 0..g.k_h {
            for kw in 0..g.k_w {
                let src = &col[r * oh * ow..(r + 1) * oh * ow];
                let mut s = 0usize;
                for y in 0..oh {
                    let iy = (y * g.stride + kh) as isize - g.pad_h as isize;
                    if iy < 0 || iy as usize >= g.in_h {
                        s += ow;
                        continue;
                    }
                    let iy = iy as usize;
                    for x in 0..ow {
                        let ix = (x * g.stride + kw) as isize - g.pad_w as isize;
                        if ix >= 0 && (ix as usize) < g.in_w {
                            out[c * g.in_h * g.in_w + iy * g.in_w + ix as usize] += src[s];
                        }
                        s += 1;
                    }
                }
                r += 1;
            }
        }
    }
}

// ---------------------------------------------------------------- elementwise

pub fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

pub fn map_sigmoid(x: &[f32], out: &mut [f32]) {
    for (o, &v) in out.iter_mut().zip(x.iter()) {
        *o = sigmoid(v);
    }
}

pub fn map_tanh(x: &[f32], out: &mut [f32]) {
    for (o, &v) in out.iter_mut().zip(x.iter()) {
        *o = v.tanh();
    }
}

pub fn map_relu(x: &[f32], out: &mut [f32]) {
    for (o, &v) in out.iter_mut().zip(x.iter()) {
        *o = v.max(0.0);
    }
}

/// Row-wise softmax over [rows, cols].
pub fn softmax_rows(x: &[f32], out: &mut [f32], rows: usize, cols: usize) {
    debug_assert_eq!(x.len(), rows * cols);
    debug_assert_eq!(out.len(), rows * cols);
    for r in 0..rows {
        let xi = &x[r * cols..(r + 1) * cols];
        let oi = &mut out[r * cols..(r + 1) * cols];
        let mx = xi.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0f32;
        for (o, &v) in oi.iter_mut().zip(xi.iter()) {
            let e = (v - mx).exp();
            *o = e;
            sum += e;
        }
        let inv = 1.0 / sum;
        for o in oi.iter_mut() {
            *o *= inv;
        }
    }
}

/// out = a + b (elementwise).
pub fn add(a: &[f32], b: &[f32], out: &mut [f32]) {
    for ((o, &x), &y) in out.iter_mut().zip(a.iter()).zip(b.iter()) {
        *o = x + y;
    }
}

/// out (+)= a * scale.
pub fn axpy(scale: f32, a: &[f32], out: &mut [f32]) {
    for (o, &x) in out.iter_mut().zip(a.iter()) {
        *o += scale * x;
    }
}

/// out = a * b (Hadamard).
pub fn mul(a: &[f32], b: &[f32], out: &mut [f32]) {
    for ((o, &x), &y) in out.iter_mut().zip(a.iter()).zip(b.iter()) {
        *o = x * y;
    }
}

pub fn sum_sq(a: &[f32]) -> f64 {
    a.iter().map(|&v| (v as f64) * (v as f64)).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn naive_matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut c = vec![0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                for p in 0..k {
                    c[i * n + j] += a[i * k + p] * b[p * n + j];
                }
            }
        }
        c
    }

    fn rand_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.uniform(-1.0, 1.0)).collect()
    }

    #[test]
    fn matmul_matches_naive_many_shapes() {
        let mut rng = Rng::new(1);
        for &(m, k, n) in &[(1, 1, 1), (4, 8, 8), (5, 7, 9), (13, 3, 17), (32, 64, 10), (3, 150, 2)] {
            let a = rand_vec(&mut rng, m * k);
            let b = rand_vec(&mut rng, k * n);
            let mut c = vec![0f32; m * n];
            matmul(&a, &b, &mut c, m, k, n, false);
            let want = naive_matmul(&a, &b, m, k, n);
            for (x, y) in c.iter().zip(want.iter()) {
                assert!((x - y).abs() < 1e-4, "{x} vs {y} at ({m},{k},{n})");
            }
        }
    }

    #[test]
    fn matmul_accumulate() {
        let a = [1f32, 2.0];
        let b = [3f32, 4.0];
        let mut c = [10f32];
        matmul(&a, &b, &mut c, 1, 2, 1, true);
        assert_eq!(c[0], 10.0 + 11.0);
    }

    #[test]
    fn matmul_at_matches() {
        let mut rng = Rng::new(2);
        let (m, k, n) = (6, 11, 5);
        // A stored [k, m]
        let at = rand_vec(&mut rng, k * m);
        let b = rand_vec(&mut rng, k * n);
        // Un-transpose A for the reference.
        let mut a = vec![0f32; m * k];
        for p in 0..k {
            for i in 0..m {
                a[i * k + p] = at[p * m + i];
            }
        }
        let mut c = vec![0f32; m * n];
        matmul_at(&at, &b, &mut c, m, k, n, false);
        let want = naive_matmul(&a, &b, m, k, n);
        for (x, y) in c.iter().zip(want.iter()) {
            assert!((x - y).abs() < 1e-4);
        }
    }

    #[test]
    fn matmul_bt_matches() {
        let mut rng = Rng::new(3);
        let (m, k, n) = (7, 9, 4);
        let a = rand_vec(&mut rng, m * k);
        // B stored [n, k]
        let bt = rand_vec(&mut rng, n * k);
        let mut b = vec![0f32; k * n];
        for j in 0..n {
            for p in 0..k {
                b[p * n + j] = bt[j * k + p];
            }
        }
        let mut c = vec![0f32; m * n];
        matmul_bt(&a, &bt, &mut c, m, k, n, false);
        let want = naive_matmul(&a, &b, m, k, n);
        for (x, y) in c.iter().zip(want.iter()) {
            assert!((x - y).abs() < 1e-4);
        }
    }

    #[test]
    fn im2col_identity_kernel() {
        // 1x1 kernel, stride 1, no pad: col == input.
        let g = Conv2dGeom { in_c: 2, in_h: 3, in_w: 3, out_c: 1, k_h: 1, k_w: 1, stride: 1, pad_h: 0, pad_w: 0 };
        let input: Vec<f32> = (0..18).map(|v| v as f32).collect();
        let mut col = vec![0f32; g.col_rows() * g.col_cols()];
        im2col(&input, &g, &mut col);
        assert_eq!(col, input);
    }

    #[test]
    fn im2col_3x3_same_padding() {
        let g = Conv2dGeom { in_c: 1, in_h: 3, in_w: 3, out_c: 1, k_h: 3, k_w: 3, stride: 1, pad_h: 1, pad_w: 1 };
        let input: Vec<f32> = (1..=9).map(|v| v as f32).collect();
        let mut col = vec![0f32; g.col_rows() * g.col_cols()];
        im2col(&input, &g, &mut col);
        // center tap (kh=1,kw=1) row must equal the input itself.
        let center = &col[4 * 9..5 * 9];
        assert_eq!(center, &input[..]);
        // top-left tap at output (0,0) looks at input (-1,-1) = 0 pad.
        assert_eq!(col[0], 0.0);
    }

    #[test]
    fn conv_via_im2col_matches_direct() {
        let mut rng = Rng::new(4);
        let g = Conv2dGeom { in_c: 3, in_h: 5, in_w: 5, out_c: 4, k_h: 3, k_w: 3, stride: 1, pad_h: 1, pad_w: 1 };
        let input = rand_vec(&mut rng, g.in_c * g.in_h * g.in_w);
        let w = rand_vec(&mut rng, g.out_c * g.col_rows());
        let mut col = vec![0f32; g.col_rows() * g.col_cols()];
        im2col(&input, &g, &mut col);
        let mut out = vec![0f32; g.out_c * g.col_cols()];
        matmul(&w, &col, &mut out, g.out_c, g.col_rows(), g.col_cols(), false);

        // direct convolution
        let (oh, ow) = (g.out_h(), g.out_w());
        for oc in 0..g.out_c {
            for y in 0..oh {
                for x in 0..ow {
                    let mut acc = 0f32;
                    for ic in 0..g.in_c {
                        for kh in 0..g.k_h {
                            for kw in 0..g.k_w {
                                let iy = (y * g.stride + kh) as isize - g.pad_h as isize;
                                let ix = (x * g.stride + kw) as isize - g.pad_w as isize;
                                if iy >= 0 && ix >= 0 && (iy as usize) < g.in_h && (ix as usize) < g.in_w {
                                    let iv = input[ic * 25 + iy as usize * 5 + ix as usize];
                                    let wv = w[oc * g.col_rows() + ic * 9 + kh * 3 + kw];
                                    acc += iv * wv;
                                }
                            }
                        }
                    }
                    let got = out[oc * oh * ow + y * ow + x];
                    assert!((got - acc).abs() < 1e-4, "{got} vs {acc}");
                }
            }
        }
    }

    #[test]
    fn im2col_cols_matches_materialized() {
        let mut rng = Rng::new(7);
        for g in [
            Conv2dGeom { in_c: 3, in_h: 5, in_w: 5, out_c: 2, k_h: 3, k_w: 3, stride: 1, pad_h: 1, pad_w: 1 },
            Conv2dGeom { in_c: 2, in_h: 7, in_w: 6, out_c: 2, k_h: 3, k_w: 2, stride: 2, pad_h: 0, pad_w: 1 },
            Conv2dGeom { in_c: 1, in_h: 1, in_w: 9, out_c: 2, k_h: 1, k_w: 3, stride: 1, pad_h: 0, pad_w: 1 },
        ] {
            let input = rand_vec(&mut rng, g.in_c * g.in_h * g.in_w);
            let cols = g.col_cols();
            let mut col = vec![0f32; g.col_rows() * cols];
            im2col(&input, &g, &mut col);
            for r in 0..g.col_rows() {
                // full row
                let mut got = vec![9f32; cols];
                im2col_cols(&input, &g, r, 0, &mut got);
                assert_eq!(got, col[r * cols..(r + 1) * cols].to_vec(), "row {r}");
                // interior segment
                if cols >= 4 {
                    let (j0, w) = (1, cols - 2);
                    let mut seg = vec![9f32; w];
                    im2col_cols(&input, &g, r, j0, &mut seg);
                    assert_eq!(seg, col[r * cols + j0..r * cols + j0 + w].to_vec());
                }
            }
        }
    }

    #[test]
    fn col2im_roundtrip_shape() {
        // col2im(im2col(x)) with 1x1 kernel is identity.
        let g = Conv2dGeom { in_c: 2, in_h: 4, in_w: 4, out_c: 1, k_h: 1, k_w: 1, stride: 1, pad_h: 0, pad_w: 0 };
        let input: Vec<f32> = (0..32).map(|v| v as f32).collect();
        let mut col = vec![0f32; g.col_rows() * g.col_cols()];
        im2col(&input, &g, &mut col);
        let mut back = vec![0f32; input.len()];
        col2im(&col, &g, &mut back, false);
        assert_eq!(back, input);
    }

    #[test]
    fn softmax_rows_normalized() {
        let x = [1f32, 2.0, 3.0, -1.0, 0.0, 1.0];
        let mut o = [0f32; 6];
        softmax_rows(&x, &mut o, 2, 3);
        for r in 0..2 {
            let s: f32 = o[r * 3..(r + 1) * 3].iter().sum();
            assert!((s - 1.0).abs() < 1e-6);
        }
        assert!(o[2] > o[1] && o[1] > o[0]);
    }

    #[test]
    fn bias_ops() {
        let mut c = vec![0f32; 6];
        add_bias(&mut c, &[1.0, 2.0, 3.0], 2, 3);
        assert_eq!(c, vec![1.0, 2.0, 3.0, 1.0, 2.0, 3.0]);
        let mut g = vec![0f32; 3];
        bias_grad(&c, &mut g, 2, 3, false);
        assert_eq!(g, vec![2.0, 4.0, 6.0]);
    }
}
