//! Tensor specifications and the tensor table (the paper's "Tensor Pool").
//!
//! Specification and data are managed independently (paper §4): a
//! `TensorSpec` records *what* a tensor is (dims, lifespan, create mode,
//! role); its storage is a `Region` into the `MemoryPool`, assigned later
//! by the Memory Planner. Placeholders never receive a region.

use std::collections::HashMap;
use std::fmt;

use super::dims::TensorDim;
use super::lifespan::{CreateMode, Lifespan, TensorId, TensorRole};
use crate::error::{Error, Result};
use crate::rng::Rng;

/// Weight initializer, applied at initialize time.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Initializer {
    Zeros,
    Ones,
    Constant(f32),
    /// Xavier/Glorot uniform over (fan_in, fan_out).
    XavierUniform { fan_in: usize, fan_out: usize },
    /// He normal over fan_in.
    HeNormal { fan_in: usize },
    /// Uniform in [-a, a].
    Uniform(f32),
    /// No initialization required (activations, derivs, temps).
    None,
}

impl Initializer {
    pub fn apply(&self, buf: &mut [f32], rng: &mut Rng) {
        match *self {
            Initializer::Zeros | Initializer::None => buf.fill(0.0),
            Initializer::Ones => buf.fill(1.0),
            Initializer::Constant(c) => buf.fill(c),
            Initializer::XavierUniform { fan_in, fan_out } => {
                let a = (6.0 / (fan_in + fan_out) as f32).sqrt();
                rng.fill_uniform(buf, -a, a);
            }
            Initializer::HeNormal { fan_in } => {
                let std = (2.0 / fan_in as f32).sqrt();
                rng.fill_normal(buf, std);
            }
            Initializer::Uniform(a) => rng.fill_uniform(buf, -a, a),
        }
    }
}

/// A contiguous span of the memory pool, in f32 elements.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Region {
    pub offset: usize,
    pub len: usize,
}

impl Region {
    pub fn end(&self) -> usize {
        self.offset + self.len
    }
    pub fn overlaps(&self, other: &Region) -> bool {
        self.offset < other.end() && other.offset < self.end()
    }
}

/// Full specification of one tensor request.
#[derive(Clone, Debug)]
pub struct TensorSpec {
    pub id: TensorId,
    pub name: String,
    pub dim: TensorDim,
    pub role: TensorRole,
    pub mode: CreateMode,
    pub init: Initializer,
    /// Cumulative lifespan over all requests (informational; the EOs are
    /// what the planner consumes).
    pub lifespan: Lifespan,
    /// Execution orders at which this tensor must hold valid data
    /// (Algorithm 1 output). Sorted ascending after `finish_orders`.
    pub eos: Vec<u32>,
    /// If merged into another tensor by MV/RV/E resolution, the target id.
    pub merged_into: Option<TensorId>,
    /// Pool placement (None for placeholders and merged tensors).
    pub region: Option<Region>,
    /// Weights of frozen (non-trainable) layers skip gradient allocation.
    pub trainable: bool,
    /// True first/last access EOs under per-layer apply, for persistent
    /// tensors whose recorded `eos` are a conservative full-iteration
    /// bracket (`{0, eo_apply}`). A weight's real accesses span its
    /// layer's forward EO through its layer's apply EO; optimizer state is
    /// touched only at the apply. The gap from `last` across the iteration
    /// boundary back to `first` is a genuine idle window the boundary
    /// offload pass (`advise_boundary`) can spill across. `None` when the
    /// true window is unknown (non-persistent tensors, or deferred-apply
    /// graphs where the bracket is the truth).
    pub boundary_window: Option<(u32, u32)>,
}

impl TensorSpec {
    pub fn min_eo(&self) -> Option<u32> {
        self.eos.iter().copied().min()
    }
    pub fn max_eo(&self) -> Option<u32> {
        self.eos.iter().copied().max()
    }
    pub fn is_placeholder(&self) -> bool {
        matches!(self.mode, CreateMode::Placeholder)
    }
}

/// Registry of all tensor requests of a compiled model.
///
/// Layers request tensors during `finalize`; Algorithm 1 assigns EOs;
/// MV/RV/E merging collapses views; the Memory Planner assigns regions.
#[derive(Default, Debug, Clone)]
pub struct TensorTable {
    specs: Vec<TensorSpec>,
    by_name: HashMap<String, TensorId>,
}

impl TensorTable {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register a new tensor request. Names must be unique; layers prefix
    /// requests with their own name (`"fc0:weight"`).
    pub fn request(
        &mut self,
        name: impl Into<String>,
        dim: TensorDim,
        role: TensorRole,
        mode: CreateMode,
        init: Initializer,
    ) -> Result<TensorId> {
        let name = name.into();
        if self.by_name.contains_key(&name) {
            return Err(Error::graph(format!("duplicate tensor `{name}`")));
        }
        // Views/extends must point at existing tensors.
        match mode {
            CreateMode::ModifyView(t) | CreateMode::ReadOnlyView(t) | CreateMode::Extend(t) => {
                if t >= self.specs.len() {
                    return Err(Error::graph(format!(
                        "tensor `{name}` views unknown target id {t}"
                    )));
                }
                if let CreateMode::Extend(t) = mode {
                    // E shares *everything*: spec must match.
                    if self.specs[t].dim != dim {
                        return Err(Error::shape(format!(
                            "extend `{name}`: dim {} != target dim {}",
                            dim, self.specs[t].dim
                        )));
                    }
                }
            }
            _ => {}
        }
        let id = self.specs.len();
        self.specs.push(TensorSpec {
            id,
            name: name.clone(),
            dim,
            role,
            mode,
            init,
            lifespan: Lifespan::FORWARD, // refined as EOs are added
            eos: vec![],
            merged_into: None,
            region: None,
            trainable: true,
            boundary_window: None,
        });
        self.by_name.insert(name, id);
        Ok(id)
    }

    pub fn len(&self) -> usize {
        self.specs.len()
    }
    pub fn is_empty(&self) -> bool {
        self.specs.is_empty()
    }

    pub fn get(&self, id: TensorId) -> &TensorSpec {
        &self.specs[id]
    }
    pub fn get_mut(&mut self, id: TensorId) -> &mut TensorSpec {
        &mut self.specs[id]
    }
    pub fn by_name(&self, name: &str) -> Option<TensorId> {
        self.by_name.get(name).copied()
    }
    pub fn iter(&self) -> impl Iterator<Item = &TensorSpec> {
        self.specs.iter()
    }
    pub fn iter_mut(&mut self) -> impl Iterator<Item = &mut TensorSpec> {
        self.specs.iter_mut()
    }

    /// Follow `merged_into` links to the storage root of a tensor.
    pub fn resolve(&self, id: TensorId) -> TensorId {
        let mut cur = id;
        while let Some(next) = self.specs[cur].merged_into {
            cur = next;
        }
        cur
    }

    /// Add an execution order to a tensor (Algorithm 1 line 10).
    pub fn add_eo(&mut self, id: TensorId, eo: u32, span: Lifespan) {
        let s = &mut self.specs[id];
        s.eos.push(eo);
        s.lifespan = s.lifespan.union(span);
    }

    /// Sort and dedup every tensor's EOs (end of Algorithm 1).
    pub fn finish_orders(&mut self) {
        for s in &mut self.specs {
            s.eos.sort_unstable();
            s.eos.dedup();
        }
    }

    /// Total bytes of every *allocated* root tensor — only meaningful after
    /// planning; used for reporting.
    pub fn allocated_bytes(&self) -> usize {
        self.specs
            .iter()
            .filter(|s| s.merged_into.is_none() && !s.is_placeholder())
            .map(|s| s.dim.bytes())
            .sum()
    }
}

impl fmt::Display for TensorTable {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for s in &self.specs {
            writeln!(
                f,
                "{:>4} {:<28} {:>16} {:<6} {:?} eos={:?} merged={:?} region={:?}",
                s.id,
                s.name,
                s.dim.to_string(),
                s.role.to_string(),
                s.lifespan,
                s.eos,
                s.merged_into,
                s.region
            )?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dim() -> TensorDim {
        TensorDim::vec(2, 4)
    }

    #[test]
    fn request_and_lookup() {
        let mut t = TensorTable::new();
        let id = t
            .request("a", dim(), TensorRole::Activation, CreateMode::Create, Initializer::None)
            .unwrap();
        assert_eq!(t.by_name("a"), Some(id));
        assert_eq!(t.get(id).dim, dim());
    }

    #[test]
    fn duplicate_name_rejected() {
        let mut t = TensorTable::new();
        t.request("a", dim(), TensorRole::Activation, CreateMode::Create, Initializer::None)
            .unwrap();
        assert!(t
            .request("a", dim(), TensorRole::Activation, CreateMode::Create, Initializer::None)
            .is_err());
    }

    #[test]
    fn view_of_unknown_target_rejected() {
        let mut t = TensorTable::new();
        assert!(t
            .request(
                "v",
                dim(),
                TensorRole::Activation,
                CreateMode::ModifyView(3),
                Initializer::None
            )
            .is_err());
    }

    #[test]
    fn extend_requires_same_dim() {
        let mut t = TensorTable::new();
        let a = t
            .request("w", dim(), TensorRole::Weight, CreateMode::Create, Initializer::Zeros)
            .unwrap();
        assert!(t
            .request(
                "w2",
                TensorDim::vec(2, 8),
                TensorRole::Weight,
                CreateMode::Extend(a),
                Initializer::Zeros
            )
            .is_err());
        assert!(t
            .request("w3", dim(), TensorRole::Weight, CreateMode::Extend(a), Initializer::Zeros)
            .is_ok());
    }

    #[test]
    fn resolve_follows_chain() {
        let mut t = TensorTable::new();
        let a = t
            .request("a", dim(), TensorRole::Activation, CreateMode::Create, Initializer::None)
            .unwrap();
        let b = t
            .request(
                "b",
                dim(),
                TensorRole::Activation,
                CreateMode::ModifyView(a),
                Initializer::None,
            )
            .unwrap();
        let c = t
            .request(
                "c",
                dim(),
                TensorRole::Activation,
                CreateMode::ReadOnlyView(b),
                Initializer::None,
            )
            .unwrap();
        t.get_mut(b).merged_into = Some(a);
        t.get_mut(c).merged_into = Some(b);
        assert_eq!(t.resolve(c), a);
        assert_eq!(t.resolve(a), a);
    }

    #[test]
    fn eo_bookkeeping() {
        let mut t = TensorTable::new();
        let a = t
            .request("a", dim(), TensorRole::Activation, CreateMode::Create, Initializer::None)
            .unwrap();
        t.add_eo(a, 7, Lifespan::CALC_GRAD);
        t.add_eo(a, 0, Lifespan::FORWARD);
        t.add_eo(a, 7, Lifespan::CALC_GRAD);
        t.finish_orders();
        assert_eq!(t.get(a).eos, vec![0, 7]);
        assert_eq!(t.get(a).min_eo(), Some(0));
        assert_eq!(t.get(a).max_eo(), Some(7));
        assert!(t.get(a).lifespan.calc_grad());
    }

    #[test]
    fn region_overlap() {
        let a = Region { offset: 0, len: 10 };
        let b = Region { offset: 10, len: 5 };
        let c = Region { offset: 9, len: 2 };
        assert!(!a.overlaps(&b));
        assert!(a.overlaps(&c));
        assert!(c.overlaps(&b));
    }
}
