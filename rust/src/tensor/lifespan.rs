//! Tensor lifespans (paper Table 2) and tensor create modes (paper Table 3).
//!
//! A lifespan says *during which execution phases of the requesting layer*
//! the tensor's data must be valid; Algorithm 1 turns `(lifespan, layer)`
//! pairs into concrete integer execution orders (EOs).

use std::fmt;

/// Bit flags over the three per-layer execution phases, plus the two
/// whole-training spans. Matches paper Table 2.
#[derive(Clone, Copy, PartialEq, Eq, Hash)]
pub struct Lifespan(u8);

impl Lifespan {
    /// Valid during the layer's forward step only.
    pub const FORWARD: Lifespan = Lifespan(0b001);
    /// Valid during the layer's compute-gradient step.
    pub const CALC_GRAD: Lifespan = Lifespan(0b010);
    /// Valid during the layer's compute-derivative step.
    pub const CALC_DERIV: Lifespan = Lifespan(0b100);
    /// Backward = gradient + derivative (paper's `B`).
    pub const BACKWARD: Lifespan = Lifespan(0b110);
    /// Valid for the whole iteration, reset afterwards (paper's `I`).
    pub const ITERATION: Lifespan = Lifespan(0b111);
    /// Valid for the entire training run (paper's `M`): weights,
    /// optimizer state.
    pub const MAX: Lifespan = Lifespan(0b1111);

    pub const fn union(self, other: Lifespan) -> Lifespan {
        Lifespan(self.0 | other.0)
    }

    pub const fn contains(self, other: Lifespan) -> bool {
        self.0 & other.0 == other.0
    }

    pub const fn is_max(self) -> bool {
        self.0 & 0b1000 != 0
    }

    pub const fn forward(self) -> bool {
        self.0 & 0b001 != 0
    }
    pub const fn calc_grad(self) -> bool {
        self.0 & 0b010 != 0
    }
    pub const fn calc_deriv(self) -> bool {
        self.0 & 0b100 != 0
    }
}

impl fmt::Debug for Lifespan {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.is_max() {
            return write!(f, "M");
        }
        let mut parts = vec![];
        if self.forward() {
            parts.push("F");
        }
        if self.calc_grad() {
            parts.push("CG");
        }
        if self.calc_deriv() {
            parts.push("CD");
        }
        write!(f, "{}", parts.join(","))
    }
}

/// How a tensor request binds to storage (paper Table 3).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum CreateMode {
    /// `P` — holds externally-owned memory (network inputs / labels fed by
    /// the Batch Queue). Tracked for EO analysis, not allocated in the pool.
    Placeholder,
    /// `C` — allocate a fresh tensor in the pool.
    Create,
    /// `MV(target)` — memory-sharing view of `target` whose data *changes*
    /// (in-place ops: activations, batch-norm). Merged only when the
    /// target's integrity is preserved (Alg. 1 line 17).
    ModifyView(TensorId),
    /// `RV(target)` — memory-sharing view whose data is guaranteed
    /// unchanged (flatten / reshape). Always merged.
    ReadOnlyView(TensorId),
    /// `E(target)` — tensor sharing: same spec *and* same data
    /// (time-unrolled weights). Always merged, EOs combined.
    Extend(TensorId),
}

/// Index of a tensor request within a `TensorTable`.
pub type TensorId = usize;

/// Primary-memory residency of a tensor under the swap runtime
/// (`runtime::swap`). Outside a memory-budgeted run every tensor is
/// `Resident` for its whole life; with an `OffloadPlan` active, offloaded
/// tensors cycle `Resident → Evicted → Fetching → Resident` across each
/// idle gap. Layers must only ever observe `Resident` tensors — the
/// executor's residency guard enforces this at every step.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Residency {
    /// Data is valid in the tensor's pool region.
    Resident,
    /// Data lives in the secondary store; the pool region may be reused
    /// by other tensors during the gap.
    Evicted,
    /// A background prefetch has been issued but not yet copied into the
    /// pool region.
    Fetching,
}

/// What role the tensor plays — used for reporting (Fig 9's breakdown),
/// optimizer hookup and transfer-learning freezes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TensorRole {
    /// Network input / label (usually `Placeholder`).
    Input,
    /// Intermediate activation (layer output).
    Activation,
    /// Back-propagated derivative buffer.
    Derivative,
    /// Trainable weight.
    Weight,
    /// Gradient of a weight.
    Gradient,
    /// Optimizer state (momentum, adam moments).
    OptState,
    /// Scratch/temporary (im2col buffers, lstm gate caches…).
    Temp,
}

impl fmt::Display for TensorRole {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            TensorRole::Input => "input",
            TensorRole::Activation => "act",
            TensorRole::Derivative => "deriv",
            TensorRole::Weight => "weight",
            TensorRole::Gradient => "grad",
            TensorRole::OptState => "opt",
            TensorRole::Temp => "temp",
        };
        f.write_str(s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lifespan_flags() {
        assert!(Lifespan::BACKWARD.calc_grad());
        assert!(Lifespan::BACKWARD.calc_deriv());
        assert!(!Lifespan::BACKWARD.forward());
        assert!(Lifespan::ITERATION.forward());
        assert!(Lifespan::MAX.is_max());
        assert!(!Lifespan::ITERATION.is_max());
    }

    #[test]
    fn union_contains() {
        let fs = Lifespan::FORWARD.union(Lifespan::CALC_GRAD);
        assert!(fs.contains(Lifespan::FORWARD));
        assert!(fs.contains(Lifespan::CALC_GRAD));
        assert!(!fs.contains(Lifespan::CALC_DERIV));
    }

    #[test]
    fn debug_format() {
        assert_eq!(format!("{:?}", Lifespan::FORWARD.union(Lifespan::CALC_GRAD)), "F,CG");
        assert_eq!(format!("{:?}", Lifespan::MAX), "M");
    }
}
