//! Tensor subsystem: dimensions, lifespans/create modes (paper Tables 2–3),
//! tensor specifications and the spec registry ("Tensor Pool").

pub mod dims;
pub mod lifespan;
pub mod spec;

pub use dims::TensorDim;
pub use lifespan::{CreateMode, Lifespan, Residency, TensorId, TensorRole};
pub use spec::{Initializer, Region, TensorSpec, TensorTable};
