//! Tensor dimensions in the paper's `B:C:H:W` notation (e.g. `64:3:224:224`).

use std::fmt;

use crate::error::{Error, Result};

/// 4-D tensor dimension, batch-major (NCHW), matching NNTrainer's notation.
///
/// Lower-rank tensors are represented with leading 1s, exactly like the
/// paper's component table writes a flat input as `64:1:1:150528`.
#[derive(Clone, Copy, PartialEq, Eq, Hash)]
pub struct TensorDim {
    pub b: usize,
    pub c: usize,
    pub h: usize,
    pub w: usize,
}

impl TensorDim {
    pub const fn new(b: usize, c: usize, h: usize, w: usize) -> Self {
        TensorDim { b, c, h, w }
    }

    /// A per-sample feature vector: `b:1:1:w`.
    pub const fn vec(b: usize, w: usize) -> Self {
        TensorDim::new(b, 1, 1, w)
    }

    /// Scalar-per-sample: `b:1:1:1`.
    pub const fn scalar(b: usize) -> Self {
        TensorDim::new(b, 1, 1, 1)
    }

    /// Total number of elements.
    pub const fn len(&self) -> usize {
        self.b * self.c * self.h * self.w
    }

    pub const fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Elements per sample (`c*h*w`), the paper's "feature size".
    pub const fn feature_len(&self) -> usize {
        self.c * self.h * self.w
    }

    /// Bytes when stored as f32.
    pub const fn bytes(&self) -> usize {
        self.len() * std::mem::size_of::<f32>()
    }

    /// Same dims with a different batch size (batch is a late-bound
    /// hyper-parameter in NNTrainer: specs are built per-sample and the
    /// batch is applied at initialize time).
    pub const fn with_batch(&self, b: usize) -> Self {
        TensorDim::new(b, self.c, self.h, self.w)
    }

    /// Flatten to `b:1:1:(c*h*w)` — what the Flatten realizer produces.
    pub const fn flattened(&self) -> Self {
        TensorDim::new(self.b, 1, 1, self.feature_len())
    }

    /// Parse `"b:c:h:w"` (or shorter forms, right-aligned: `"150528"` is
    /// `1:1:1:150528`, `"3:224:224"` is `1:3:224:224`).
    pub fn parse(s: &str) -> Result<Self> {
        let parts: Vec<&str> = s.split(':').collect();
        if parts.is_empty() || parts.len() > 4 {
            return Err(Error::shape(format!("bad dim string `{s}`")));
        }
        let mut v = [1usize; 4];
        let off = 4 - parts.len();
        for (i, p) in parts.iter().enumerate() {
            v[off + i] = p
                .trim()
                .parse::<usize>()
                .map_err(|e| Error::shape(format!("bad dim `{s}`: {e}")))?;
        }
        Ok(TensorDim::new(v[0], v[1], v[2], v[3]))
    }
}

impl fmt::Display for TensorDim {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}:{}:{}", self.b, self.c, self.h, self.w)
    }
}

impl fmt::Debug for TensorDim {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Display::fmt(self, f)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_full() {
        let d = TensorDim::parse("64:3:224:224").unwrap();
        assert_eq!(d, TensorDim::new(64, 3, 224, 224));
        assert_eq!(d.len(), 64 * 3 * 224 * 224);
        assert_eq!(d.feature_len(), 3 * 224 * 224);
    }

    #[test]
    fn parse_right_aligned() {
        assert_eq!(TensorDim::parse("150528").unwrap(), TensorDim::vec(1, 150528));
        assert_eq!(
            TensorDim::parse("3:224:224").unwrap(),
            TensorDim::new(1, 3, 224, 224)
        );
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(TensorDim::parse("a:b").is_err());
        assert!(TensorDim::parse("1:2:3:4:5").is_err());
        assert!(TensorDim::parse("").is_err());
    }

    #[test]
    fn bytes_and_batch() {
        let d = TensorDim::vec(64, 150528);
        assert_eq!(d.bytes(), 64 * 150528 * 4);
        assert_eq!(d.with_batch(1).bytes(), 150528 * 4);
        // Table 4, Linear input: 64:1:1:150528 = 37632 kiB
        assert_eq!(d.bytes() / 1024, 37632);
    }

    #[test]
    fn flatten() {
        let d = TensorDim::new(64, 3, 224, 224);
        assert_eq!(d.flattened(), TensorDim::vec(64, 3 * 224 * 224));
    }

    #[test]
    fn display_roundtrip() {
        let d = TensorDim::new(2, 3, 4, 5);
        assert_eq!(TensorDim::parse(&d.to_string()).unwrap(), d);
    }
}
