//! Execution-order machinery: Algorithm 1 (order assignment + view
//! merging) and the EO-driven executor.

pub mod executor;
pub mod order;

pub use executor::{Executor, StepOp};
pub use order::{
    eo_of, ideal_peak_bytes, init_graph, probe_init_graph, shape_analysis_count, EoTriple,
    InitGraph, InitNode, InitOptions, ShapeTemplate,
};
