//! Execution: runs the EO-ordered step list over the Memory Pool.
//!
//! The schedule is exactly the execution orders of Algorithm 1 — forward
//! steps 0..N, then alternating compute-gradient / compute-derivative
//! steps N..3N, then (optionally) a deferred apply step at 3N. The hot
//! loop is allocation-free: every buffer, including optimizer state, is a
//! planner-assigned pool region.

use std::collections::HashMap;
use std::sync::Arc;

use crate::backend::Backend;
use crate::error::{Error, Result};
use crate::layers::RunCtx;
use crate::optimizer::{clip_global_norm, Optimizer};
use crate::planner::offload::OffloadPlan;
use crate::planner::pool::MemoryPool;
use crate::rng::Rng;
use crate::runtime::swap::{SwapExec, SwapStats};
use crate::tensor::{CreateMode, TensorId, TensorRole};

use super::order::{eo_of, InitGraph};

/// One schedulable step.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StepOp {
    Forward(usize),
    CalcGrad(usize),
    CalcDeriv(usize),
    /// Deferred optimizer application over all gradients.
    Apply,
}

/// A compiled, planned, pool-backed model execution.
pub struct Executor {
    pub graph: InitGraph,
    /// Proactive swap runtime, present when the model was compiled under
    /// a primary-memory budget. Engaged around every training step and
    /// around forward steps in forward-only passes (the budgeted pool
    /// aliases regions across idle gaps, so eviction must run there
    /// too). Declared **before** `pool`: its drop joins the background
    /// evict worker, which may still hold raw spans into the pool —
    /// fields drop in declaration order, so the join must run while the
    /// pool is alive.
    swap: Option<SwapExec>,
    /// Compute backend every layer kernels through (selected by
    /// `CompileOpts::compute` / `DeviceProfile::compute`).
    backend: Arc<dyn Backend>,
    pub pool: MemoryPool,
    steps: Vec<(u32, StepOp)>,
    /// Gradient roots to zero right before the step at this EO (their
    /// first write of the iteration — regions may have been reused since
    /// last iteration, so zeroing must happen here, not after apply).
    zero_before: HashMap<u32, Vec<TensorId>>,
    pub optimizer: Box<dyn Optimizer>,
    pub clip_norm: Option<f32>,
    pub deferred_apply: bool,
    pub iter: u64,
    apply_count: u64,
    /// Loss captured at the loss layers' forward steps. The loss output
    /// tensor is only live at its forward EO — its pool region is
    /// (correctly) reused during backward, so it must be read *at* that
    /// step, not after the iteration.
    last_loss: f32,
}

impl Executor {
    /// Build the executor: derive the step schedule from the graph,
    /// allocate the pool, run weight initializers.
    pub fn new(
        graph: InitGraph,
        pool_len: usize,
        optimizer: Box<dyn Optimizer>,
        clip_norm: Option<f32>,
        training: bool,
        seed: u64,
        swap: Option<SwapExec>,
        backend: Arc<dyn Backend>,
    ) -> Result<Executor> {
        let n = graph.nodes.len();
        let mut steps: Vec<(u32, StepOp)> = Vec::with_capacity(3 * n + 1);
        for i in 0..n {
            let eo = eo_of(i, n);
            steps.push((eo.f, StepOp::Forward(i)));
            if training {
                steps.push((eo.cg, StepOp::CalcGrad(i)));
                if !graph.nodes[i].fused_backward {
                    steps.push((eo.cd, StepOp::CalcDeriv(i)));
                }
            }
        }
        let deferred = graph.deferred_apply || clip_norm.is_some();
        if training && deferred {
            steps.push((graph.eo_apply, StepOp::Apply));
        }
        steps.sort_by_key(|(eo, _)| *eo);

        // first-write EO per gradient root
        let mut zero_before: HashMap<u32, Vec<TensorId>> = HashMap::new();
        for s in graph.table.iter() {
            if s.role == TensorRole::Gradient && s.merged_into.is_none() && !s.eos.is_empty() {
                zero_before.entry(s.min_eo().unwrap()).or_default().push(s.id);
            }
        }

        let pool = MemoryPool::new(pool_len);
        let mut exec = Executor {
            graph,
            swap,
            backend,
            pool,
            steps,
            zero_before,
            optimizer,
            clip_norm,
            deferred_apply: deferred,
            iter: 0,
            apply_count: 0,
            last_loss: 0.0,
        };
        exec.init_weights(seed);
        Ok(exec)
    }

    /// Apply initializers to every root weight / opt-state / temp tensor.
    pub fn init_weights(&mut self, seed: u64) {
        let mut rng = Rng::new(seed);
        for s in self.graph.table.iter() {
            if s.merged_into.is_some() || s.eos.is_empty() {
                continue;
            }
            if matches!(s.role, TensorRole::Weight | TensorRole::OptState) {
                if let Some(r) = s.region {
                    s.init.apply(self.pool.view_mut(r), &mut rng);
                }
            }
        }
    }

    fn ctx<'a>(&'a self, node: usize) -> RunCtx<'a> {
        let nd = &self.graph.nodes[node];
        RunCtx {
            io: &nd.io,
            table: &self.graph.table,
            pool: &self.pool,
            in_dims: &nd.in_dims,
            out_dims: &nd.out_dims,
            training: true,
            iter: self.iter,
            backend: self.backend.as_ref(),
        }
    }

    /// The compute backend this executor runs on (FLOP counters feed
    /// the bench GFLOP/s columns).
    pub fn backend(&self) -> &dyn Backend {
        self.backend.as_ref()
    }

    fn ctx_infer<'a>(&'a self, node: usize) -> RunCtx<'a> {
        let mut c = self.ctx(node);
        c.training = false;
        c
    }

    /// Copy a batch into the input placeholder of input node `idx`
    /// (indices into `graph.input_nodes`).
    pub fn bind_input(&self, input_idx: usize, data: &[f32]) -> Result<()> {
        let node = *self
            .graph
            .input_nodes
            .get(input_idx)
            .ok_or_else(|| Error::graph(format!("no input node {input_idx}")))?;
        let id = self.graph.nodes[node].io.outputs[0];
        let root = self.graph.table.resolve(id);
        let r = self.graph.table.get(root).region.unwrap();
        if data.len() != self.graph.table.get(root).dim.len() {
            return Err(Error::shape(format!(
                "input size {} != expected {}",
                data.len(),
                self.graph.table.get(root).dim.len()
            )));
        }
        self.pool.view_mut(r)[..data.len()].copy_from_slice(data);
        Ok(())
    }

    /// Copy labels into the loss node's label placeholder.
    pub fn bind_label(&self, loss_idx: usize, data: &[f32]) -> Result<()> {
        let node = *self
            .graph
            .loss_nodes
            .get(loss_idx)
            .ok_or_else(|| Error::graph(format!("no loss node {loss_idx}")))?;
        let id = self.graph.nodes[node]
            .io
            .label
            .ok_or_else(|| Error::graph("loss node has no label"))?;
        let r = self.graph.table.get(id).region.unwrap();
        if data.len() != self.graph.table.get(id).dim.len() {
            return Err(Error::shape(format!(
                "label size {} != expected {}",
                data.len(),
                self.graph.table.get(id).dim.len()
            )));
        }
        self.pool.view_mut(r)[..data.len()].copy_from_slice(data);
        Ok(())
    }

    /// One full training iteration over the bound batch; returns the loss.
    /// Panics on swap-runtime failures — use [`Executor::try_train_iteration`]
    /// when running under a memory budget.
    pub fn train_iteration(&mut self) -> f32 {
        self.try_train_iteration().expect("train_iteration")
    }

    /// One full training iteration over the bound batch; returns the loss.
    ///
    /// With the swap runtime active, every step is bracketed by the
    /// evict/prefetch protocol: due prefetches are completed (and the
    /// residency guard run) before the step, and entries whose gap opens
    /// at this EO are evicted right after it.
    pub fn try_train_iteration(&mut self) -> Result<f32> {
        self.iter += 1;
        self.last_loss = 0.0;
        if let Some(sw) = self.swap.as_mut() {
            sw.begin_iteration(true, &self.pool)?;
        }
        for k in 0..self.steps.len() {
            let (eo, op) = self.steps[k];
            if let Some(sw) = self.swap.as_mut() {
                sw.pre_step(eo, &self.pool)?;
                sw.check_residency(eo)?;
            }
            if let Some(grads) = self.zero_before.get(&eo) {
                for &g in grads {
                    let r = self.graph.table.get(g).region.unwrap();
                    self.pool.view_mut(r).fill(0.0);
                }
            }
            match op {
                StepOp::Forward(i) => {
                    let ctx = self.ctx(i);
                    self.graph.nodes[i].layer.forward(&ctx);
                    if self.graph.nodes[i].is_loss {
                        // capture now: this region is reused in backward
                        let id = self.graph.nodes[i].io.outputs[0];
                        let r = self
                            .graph
                            .table
                            .get(self.graph.table.resolve(id))
                            .region
                            .unwrap();
                        self.last_loss += self.pool.view(r)[0];
                    }
                }
                StepOp::CalcGrad(i) => {
                    let ctx = self.ctx(i);
                    self.graph.nodes[i].layer.calc_gradient(&ctx);
                    // Per-layer apply happens only after the layer's whole
                    // backward: fused layers finish in CG, others in CD —
                    // the derivative must be computed with the *old* W.
                    if !self.deferred_apply
                        && self.graph.nodes[i].fused_backward
                        && self.graph.nodes[i].has_grads
                    {
                        self.apply_node(i);
                    }
                }
                StepOp::CalcDeriv(i) => {
                    let ctx = self.ctx(i);
                    self.graph.nodes[i].layer.calc_derivative(&ctx);
                    if !self.deferred_apply && self.graph.nodes[i].has_grads {
                        self.apply_node(i);
                    }
                }
                StepOp::Apply => {
                    self.apply_all();
                }
            }
            if let Some(sw) = self.swap.as_mut() {
                sw.post_step(eo, &self.pool)?;
            }
        }
        if let Some(sw) = self.swap.as_mut() {
            sw.end_iteration(&self.pool)?;
        }
        Ok(self.last_loss)
    }

    /// Forward-only pass (inference / feature extraction). Panics on
    /// swap-runtime failures — use [`Executor::try_forward_pass`] when
    /// running under a memory budget.
    pub fn forward_pass(&mut self) {
        self.try_forward_pass().expect("forward_pass")
    }

    /// Forward-only pass. The swap protocol runs over the forward steps
    /// too: a budget-compiled pool aliases regions across idle gaps, so
    /// skipping eviction here would let a gap tenant clobber a still-live
    /// tensor (e.g. a skip-connection activation read again later in
    /// forward). Entries whose prefetch EO lies in the (skipped) backward
    /// half are restored in the end-of-pass sweep.
    pub fn try_forward_pass(&mut self) -> Result<()> {
        self.forward_only(false).map(|_| ())
    }

    /// Forward-only pass that evaluates the loss on the bound batch
    /// without touching weights — the validation half of a train/val
    /// split. Runs in inference mode (dropout off) under the same swap
    /// protocol as [`Executor::try_forward_pass`]; the loss is captured
    /// at the loss layers' forward steps exactly as in training.
    pub fn try_eval_loss(&mut self) -> Result<f32> {
        self.forward_only(true).map(|l| l.unwrap_or(0.0))
    }

    fn forward_only(&mut self, capture_loss: bool) -> Result<Option<f32>> {
        self.iter += 1;
        let mut loss = 0f32;
        if let Some(sw) = self.swap.as_mut() {
            sw.begin_iteration(false, &self.pool)?;
        }
        for k in 0..self.steps.len() {
            if let (eo, StepOp::Forward(i)) = self.steps[k] {
                if let Some(sw) = self.swap.as_mut() {
                    sw.pre_step(eo, &self.pool)?;
                    sw.check_residency(eo)?;
                }
                let ctx = self.ctx_infer(i);
                self.graph.nodes[i].layer.forward(&ctx);
                if capture_loss && self.graph.nodes[i].is_loss {
                    // capture now: this region may be reused later on
                    let id = self.graph.nodes[i].io.outputs[0];
                    let r = self
                        .graph
                        .table
                        .get(self.graph.table.resolve(id))
                        .region
                        .unwrap();
                    loss += self.pool.view(r)[0];
                }
                if let Some(sw) = self.swap.as_mut() {
                    sw.post_step(eo, &self.pool)?;
                }
            }
        }
        if let Some(sw) = self.swap.as_mut() {
            sw.end_iteration(&self.pool)?;
        }
        Ok(capture_loss.then_some(loss))
    }

    fn apply_node(&mut self, i: usize) {
        self.apply_count += 1;
        let count = self.apply_count;
        let node = &self.graph.nodes[i];
        for (w_idx, gid) in node.io.grads.iter().enumerate() {
            let Some(gid) = gid else { continue };
            let wid = node.io.weights[w_idx];
            // E-shared weights are applied at their root only
            if matches!(self.graph.table.get(wid).mode, CreateMode::Extend(_)) {
                continue;
            }
            let wr = self.graph.table.get(self.graph.table.resolve(wid)).region.unwrap();
            let gr = self.graph.table.get(self.graph.table.resolve(*gid)).region.unwrap();
            let w = self.pool.view_mut(wr);
            let g = self.pool.view(gr);
            let mut states: Vec<&mut [f32]> = node.opt_states[w_idx]
                .iter()
                .map(|&sid| {
                    let r = self.graph.table.get(sid).region.unwrap();
                    self.pool.view_mut(r)
                })
                .collect();
            self.optimizer.apply(w, g, &mut states, count);
        }
    }

    fn apply_all(&mut self) {
        if let Some(max_norm) = self.clip_norm {
            let mut grads: Vec<&mut [f32]> = Vec::new();
            for s in self.graph.table.iter() {
                if s.role == TensorRole::Gradient && s.merged_into.is_none() && !s.eos.is_empty() {
                    grads.push(self.pool.view_mut(s.region.unwrap()));
                }
            }
            clip_global_norm(&mut grads, max_norm);
        }
        for i in 0..self.graph.nodes.len() {
            if self.graph.nodes[i].has_grads {
                self.apply_node(i);
            }
        }
    }

    /// Loss captured at the last iteration's loss-forward steps.
    pub fn loss(&self) -> f32 {
        self.last_loss
    }

    /// Copy out the activations of a named node's first output.
    pub fn read_output(&self, name: &str) -> Result<Vec<f32>> {
        let node = self
            .graph
            .nodes
            .iter()
            .find(|n| n.name == name)
            .ok_or_else(|| Error::graph(format!("unknown node `{name}`")))?;
        let id = node.io.outputs[0];
        let r = self.graph.table.get(self.graph.table.resolve(id)).region.unwrap();
        Ok(self.pool.view(r).to_vec())
    }

    /// Copy out a weight tensor by `layer:weight` name.
    pub fn read_weight(&self, name: &str) -> Result<Vec<f32>> {
        let id = self
            .graph
            .table
            .by_name(name)
            .ok_or_else(|| Error::graph(format!("unknown tensor `{name}`")))?;
        let root = self.graph.table.resolve(id);
        let r = self.graph.table.get(root).region.unwrap();
        Ok(self.pool.view(r).to_vec())
    }

    /// Overwrite a weight tensor (checkpoint load / oracle comparison).
    pub fn write_weight(&self, name: &str, data: &[f32]) -> Result<()> {
        let id = self
            .graph
            .table
            .by_name(name)
            .ok_or_else(|| Error::graph(format!("unknown tensor `{name}`")))?;
        let root = self.graph.table.resolve(id);
        let spec = self.graph.table.get(root);
        if data.len() != spec.dim.len() {
            return Err(Error::shape(format!(
                "weight `{name}` size {} != {}",
                data.len(),
                spec.dim.len()
            )));
        }
        self.pool.view_mut(spec.region.unwrap()).copy_from_slice(data);
        Ok(())
    }

    /// Names of all root trainable weights (for checkpointing).
    pub fn weight_names(&self) -> Vec<String> {
        self.graph
            .table
            .iter()
            .filter(|s| s.role == TensorRole::Weight && s.merged_into.is_none() && !s.eos.is_empty())
            .map(|s| s.name.clone())
            .collect()
    }

    /// Names of root weights belonging to frozen (non-trainable) layers —
    /// the set `personalize` must leave bitwise untouched.
    pub fn frozen_weight_names(&self) -> Vec<String> {
        self.graph
            .table
            .iter()
            .filter(|s| {
                s.role == TensorRole::Weight
                    && s.merged_into.is_none()
                    && !s.eos.is_empty()
                    && !s.trainable
            })
            .map(|s| s.name.clone())
            .collect()
    }

    /// Re-run the initializers of every weight and optimizer-state tensor
    /// whose *layer name* starts with one of `prefixes` (tensor names are
    /// `layer:weight`) — the head-swap half of personalization: the
    /// backbone keeps its checkpointed weights while the head restarts
    /// fresh, with its optimizer state re-zeroed alongside. A prefix
    /// matching no weight tensor is an error (mirroring the freeze API:
    /// a typoed head name must not silently keep the checkpoint's head),
    /// checked before anything is mutated. Returns the number of weight
    /// tensors reinitialized.
    pub fn reinit_weights_matching(&mut self, prefixes: &[String], seed: u64) -> Result<usize> {
        let eligible = |s: &crate::tensor::TensorSpec| {
            s.merged_into.is_none()
                && !s.eos.is_empty()
                && matches!(s.role, TensorRole::Weight | TensorRole::OptState)
        };
        let layer_of = |name: &str| name.split(':').next().unwrap_or("").to_string();
        // validate first so a bad prefix cannot leave a half-reinit head
        for p in prefixes {
            let hit = self
                .graph
                .table
                .iter()
                .any(|s| eligible(s) && layer_of(&s.name).starts_with(p.as_str()));
            if !hit {
                return Err(Error::graph(format!(
                    "reinit prefix `{p}` matches no weight tensor"
                )));
            }
        }
        let mut rng = Rng::new(seed);
        let mut count = 0usize;
        for s in self.graph.table.iter() {
            if !eligible(s) {
                continue;
            }
            let layer = layer_of(&s.name);
            if !prefixes.iter().any(|p| layer.starts_with(p.as_str())) {
                continue;
            }
            if let Some(r) = s.region {
                s.init.apply(self.pool.view_mut(r), &mut rng);
                if s.role == TensorRole::Weight {
                    count += 1;
                }
            }
        }
        Ok(count)
    }

    /// Pool layout of the *persistent trainable state* owned by the
    /// layers matching `prefixes` (tensor names are `layer:weight`):
    /// every root `Weight` and `OptState` region, in table order. This
    /// is exactly the set `reinit_weights_matching` re-initializes and
    /// the optimizer mutates across iterations — gradients are transient
    /// (zeroed at their first-write EO every iteration), so exporting
    /// these regions plus the step counters captures a complete training
    /// identity that can later be re-imported bitwise. A prefix matching
    /// no weight tensor is an error, checked before anything is returned.
    pub fn state_layout_matching(
        &self,
        prefixes: &[String],
    ) -> Result<Vec<(String, crate::tensor::Region)>> {
        let eligible = |s: &crate::tensor::TensorSpec| {
            s.merged_into.is_none()
                && !s.eos.is_empty()
                && matches!(s.role, TensorRole::Weight | TensorRole::OptState)
        };
        let layer_of = |name: &str| name.split(':').next().unwrap_or("").to_string();
        for p in prefixes {
            let hit = self
                .graph
                .table
                .iter()
                .any(|s| eligible(s) && layer_of(&s.name).starts_with(p.as_str()));
            if !hit {
                return Err(Error::graph(format!(
                    "state prefix `{p}` matches no weight tensor"
                )));
            }
        }
        let mut layout = Vec::new();
        for s in self.graph.table.iter() {
            if !eligible(s) {
                continue;
            }
            let layer = layer_of(&s.name);
            if !prefixes.iter().any(|p| layer.starts_with(p.as_str())) {
                continue;
            }
            if let Some(r) = s.region {
                layout.push((s.name.clone(), r));
            }
        }
        Ok(layout)
    }

    /// Concatenate the pool contents of `layout`'s regions into `out`
    /// (cleared first; capacity is reused, so steady-state exports are
    /// allocation-free once `out` has grown to the layout's size).
    pub fn export_state(&self, layout: &[(String, crate::tensor::Region)], out: &mut Vec<f32>) {
        out.clear();
        for (_, r) in layout {
            out.extend_from_slice(self.pool.view(*r));
        }
    }

    /// Write a previously exported concatenation back into `layout`'s
    /// regions. `data` must be exactly the layout's total length.
    pub fn import_state(
        &self,
        layout: &[(String, crate::tensor::Region)],
        data: &[f32],
    ) -> Result<()> {
        let total: usize = layout.iter().map(|(_, r)| r.len).sum();
        if data.len() != total {
            return Err(Error::shape(format!(
                "state import: {} f32s for a layout of {total}",
                data.len()
            )));
        }
        let mut off = 0usize;
        for (_, r) in layout {
            self.pool.view_mut(*r).copy_from_slice(&data[off..off + r.len]);
            off += r.len;
        }
        Ok(())
    }

    /// The training-step counters that feed the optimizer: iterations
    /// run (`RunCtx::iter`) and per-tensor apply calls (the `count`
    /// argument optimizers like Adam bias-correct on). Together with the
    /// `state_layout_matching` regions these make a tenant's training
    /// identity fully restorable.
    pub fn step_counters(&self) -> (u64, u64) {
        (self.iter, self.apply_count)
    }

    /// Restore previously captured step counters (see
    /// [`Executor::step_counters`]).
    pub fn set_step_counters(&mut self, iter: u64, apply_count: u64) {
        self.iter = iter;
        self.apply_count = apply_count;
    }

    pub fn steps(&self) -> &[(u32, StepOp)] {
        &self.steps
    }

    /// Whether this executor runs under a memory budget with the swap
    /// runtime engaged.
    pub fn swap_active(&self) -> bool {
        self.swap.is_some()
    }

    /// Cumulative swap-runtime counters (None when no budget was set).
    pub fn swap_stats(&self) -> Option<SwapStats> {
        self.swap.as_ref().map(|s| s.stats)
    }

    /// Per-epoch swap-stat deltas — one entry per epoch boundary the
    /// training loop marked (None when no budget was set). The
    /// cumulative whole-run counters stay in [`Executor::swap_stats`];
    /// this is the trajectory view the perf harness records.
    pub fn swap_epoch_stats(&self) -> Option<Vec<SwapStats>> {
        self.swap.as_ref().map(|s| s.epoch_stats())
    }

    /// Cumulative secondary-store I/O counters — rewrites, rotations,
    /// physical vs logical bytes, peak footprint (None when no budget
    /// was set).
    pub fn swap_store_stats(&self) -> Option<crate::runtime::store::StoreStats> {
        self.swap.as_ref().map(|s| s.store_stats())
    }

    /// Current in-flight prefetch depth (None when no budget was set).
    pub fn swap_depth(&self) -> Option<usize> {
        self.swap.as_ref().map(|s| s.depth())
    }

    /// Widest prefetch lead the runtime is currently using — tracks
    /// warmup recalibration, unlike the compile-time plan's leads
    /// (None when no budget was set).
    pub fn swap_max_lead(&self) -> Option<u32> {
        self.swap.as_ref().map(|s| s.max_lead())
    }

    /// The offload plan being executed (None when no budget was set).
    pub fn swap_plan(&self) -> Option<&OffloadPlan> {
        self.swap.as_ref().map(|s| s.plan())
    }

    /// Mutable access to the swap runtime (tests: plan-corruption hooks).
    pub fn swap_mut(&mut self) -> Option<&mut SwapExec> {
        self.swap.as_mut()
    }

    /// Number of cross-iteration (wrap) offload entries in the executing
    /// plan (None when no budget was set).
    pub fn swap_n_wrap_entries(&self) -> Option<usize> {
        self.swap.as_ref().map(|s| s.n_wrap_entries())
    }

    /// Fully drain the swap runtime: complete every carried boundary
    /// transfer and restore every cross-iteration (wrap) entry into the
    /// pool. Mandatory before reading weights out of a pipelined run,
    /// exporting/importing checkpoint state, or anything else that
    /// treats the pool bytes as the source of truth — under
    /// cross-iteration pipelining `end_iteration` deliberately leaves
    /// boundary transfers in flight. No-op without a swap runtime or
    /// when nothing is carried.
    pub fn quiesce_swap(&mut self) -> Result<()> {
        match self.swap.as_mut() {
            Some(sw) => sw.quiesce(&self.pool),
            None => Ok(()),
        }
    }

    /// Apply the parked pool-compaction plan, if any. Must be called at
    /// a swap-quiescent barrier (between iterations, after
    /// `end_iteration` has drained every transfer) — `rebind` refuses
    /// otherwise. Persistent tensors (weights, optimizer state,
    /// max-lifespan temps) have their bytes slid down in plan order —
    /// ascending destination, every move downward, so in-place memmove
    /// copies never clobber an unmoved source. Transient tensors carry
    /// no live data at the barrier and only have their table regions
    /// rewritten. The arena then truncates to the compacted peak and the
    /// swap runtime rebinds its entries to the relocated table. Returns
    /// `Ok(true)` when a plan was applied, `Ok(false)` when none was
    /// parked.
    pub fn compact_pool(&mut self) -> Result<bool> {
        let Some(sw) = self.swap.as_mut() else {
            return Ok(false);
        };
        if !sw.has_compaction() {
            return Ok(false);
        }
        // Relocation moves live bytes: the engine must be fully
        // quiescent, including carried cross-iteration transfers —
        // a wrap eviction writing the pool from the evict worker while
        // a region slides would race. Quiesce only when actually
        // compacting, so ordinary epoch boundaries keep the pipeline.
        sw.quiesce(&self.pool)?;
        let sw = self.swap.as_mut().unwrap();
        let Some(cp) = sw.take_compaction() else {
            return Ok(false);
        };
        for m in &cp.moves {
            if m.persistent {
                self.pool.move_region(m.from, m.to);
            }
            self.graph.table.get_mut(m.id).region = Some(m.to);
        }
        self.pool.shrink(cp.new_len);
        let sw = self.swap.as_mut().unwrap();
        sw.rebind(&self.graph.table)?;
        sw.refresh_frag(&self.graph.table, cp.new_len);
        Ok(true)
    }
}
