//! Graph initialization + Algorithm 1: execution-order assignment.
//!
//! This is the paper's core analysis (§4.1). Each layer `L_i` of an
//! `N`-layer model gets three execution orders:
//!
//! ```text
//! EO_F  = i                    (forward)
//! EO_CG = 3N − 2(i+1)          (compute gradient)
//! EO_CD = EO_CG + 1            (compute derivative)
//! ```
//!
//! Every tensor request accumulates the EOs implied by its lifespan at
//! each requesting layer; MV/RV/E create modes are then resolved by the
//! merge rules of Algorithm 1 (lines 13–23), collapsing in-place views
//! when the target tensor's integrity is preserved.

use crate::backend::ComputeKind;
use crate::error::{Error, Result};
use crate::layers::{loss::is_loss_kind, FinalizeOut, Layer, LayerFactory, LayerIo, Props};
use crate::graph::Graph;
use crate::tensor::{
    CreateMode, Initializer, Lifespan, TensorDim, TensorId, TensorRole, TensorTable,
};

use std::cell::Cell;
use std::collections::HashMap;

thread_local! {
    /// Per-thread count of per-layer shape analyses (factory + finalize)
    /// — the metric the auto-batch memoization test asserts on. Thread-
    /// local so parallel test threads cannot pollute each other's
    /// deltas.
    static SHAPE_ANALYSES: Cell<u64> = const { Cell::new(0) };
}

/// How many per-layer shape analyses this thread has run (monotone; take
/// deltas around the operation under test).
pub fn shape_analysis_count() -> u64 {
    SHAPE_ANALYSES.with(|c| c.get())
}

/// Options controlling initialization (the Fig 9 baseline and the
/// ablations toggle these).
#[derive(Clone, Debug)]
pub struct InitOptions {
    pub batch: usize,
    pub training: bool,
    /// Enable MV/RV in-place merging (paper default: on).
    pub inplace: bool,
    /// Emulate conventional frameworks: every activation/derivative/
    /// gradient/temp stays live for the whole iteration, no in-place.
    pub conventional: bool,
    /// Apply gradients once at iteration end (forced by gradient clipping
    /// and by E-shared weights / unrolled recurrence).
    pub deferred_apply: bool,
    /// Optimizer state tensors per trainable weight (SGD-momentum: 1,
    /// Adam: 2).
    pub opt_slots: usize,
    /// Compute backend the model will run on. Layers whose tensor
    /// declarations depend on it (conv's `col` temp) see this before
    /// finalize. Defaults to `Naive` here so raw `init_graph` callers
    /// keep the paper's exact tensor population; the compile pipeline
    /// threads the session's choice (default `Tiered`) through
    /// explicitly.
    pub compute: ComputeKind,
}

impl Default for InitOptions {
    fn default() -> Self {
        InitOptions {
            batch: 1,
            training: true,
            inplace: true,
            conventional: false,
            deferred_apply: false,
            opt_slots: 0,
            compute: ComputeKind::Naive,
        }
    }
}

/// An initialized node: instantiated layer + resolved tensor bindings.
pub struct InitNode {
    pub name: String,
    pub layer: Box<dyn Layer>,
    pub io: LayerIo,
    pub in_dims: Vec<TensorDim>,
    pub out_dims: Vec<TensorDim>,
    pub fused_backward: bool,
    pub trainable: bool,
    pub is_loss: bool,
    pub is_input: bool,
    /// This node has trainable weights with gradients.
    pub has_grads: bool,
    /// This node writes at least one input derivative.
    pub writes_derivs: bool,
    /// Optimizer state tensors, `[weight][slot]`.
    pub opt_states: Vec<Vec<TensorId>>,
}

/// Fully initialized graph, ready for planning and execution.
pub struct InitGraph {
    pub nodes: Vec<InitNode>,
    pub table: TensorTable,
    /// EO of the deferred apply step == 3N (training) or N (inference).
    pub eo_apply: u32,
    pub deferred_apply: bool,
    pub loss_nodes: Vec<usize>,
    pub input_nodes: Vec<usize>,
}

/// EO triple of one layer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct EoTriple {
    pub f: u32,
    pub cg: u32,
    pub cd: u32,
}

pub fn eo_of(i: usize, n: usize) -> EoTriple {
    EoTriple {
        f: i as u32,
        cg: (3 * n - 2 * (i + 1)) as u32,
        cd: (3 * n - 2 * (i + 1) + 1) as u32,
    }
}

/// Per-node result of pass 1 (instantiate + finalize), minus the layer
/// instance: everything the tensor-table passes consume. Cached by
/// [`ShapeTemplate`] so auto-batch probes can substitute batch-scaled
/// dims instead of re-running every layer's shape analysis.
#[derive(Clone)]
pub struct NodeShapes {
    pub fin: FinalizeOut,
    pub in_dims: Vec<TensorDim>,
    pub out_dims: Vec<TensorDim>,
    pub trainable: bool,
}

/// Pass 1: instantiate + finalize every layer in topological order —
/// the per-layer shape analysis ([`shape_analysis_count`] ticks once
/// per node).
fn pass1(
    graph: &Graph,
    factories: &HashMap<&'static str, LayerFactory>,
    batch: usize,
    compute: ComputeKind,
) -> Result<(Vec<Box<dyn Layer>>, Vec<NodeShapes>)> {
    let n = graph.nodes.len();
    let mut layers: Vec<Box<dyn Layer>> = Vec::with_capacity(n);
    let mut shapes: Vec<NodeShapes> = Vec::with_capacity(n);
    for (i, nd) in graph.nodes.iter().enumerate() {
        SHAPE_ANALYSES.with(|c| c.set(c.get() + 1));
        let factory = factories
            .get(nd.ltype.as_str())
            .ok_or_else(|| Error::model(format!("unknown layer type `{}`", nd.ltype)))?;
        let mut layer = factory(&nd.props)?;
        layer.set_compute(compute);
        let in_dims: Vec<TensorDim> = graph.inputs[i]
            .iter()
            .map(|ep| shapes[ep.node].out_dims[ep.slot])
            .collect();
        let mut fin = layer.finalize(&in_dims)?;
        // apply batch
        for d in fin.out_dims.iter_mut() {
            if nd.ltype == "input" {
                *d = d.with_batch(batch);
            } else if !is_loss_kind(&nd.ltype) {
                // keep the batch the layer derived from its input
                debug_assert!(d.b == batch || in_dims.is_empty() || d.b == in_dims[0].b);
            }
        }
        let out_dims = fin.out_dims.clone();
        shapes.push(NodeShapes {
            fin,
            in_dims,
            out_dims,
            trainable: nd.props.bool_or("trainable", true)?,
        });
        layers.push(layer);
    }
    Ok((layers, shapes))
}

/// Initialize a wired graph: instantiate layers, finalize shapes, create
/// every tensor spec with lifespans + create modes, run Algorithm 1.
pub fn init_graph(
    graph: &Graph,
    factories: &HashMap<&'static str, LayerFactory>,
    opts: &InitOptions,
) -> Result<InitGraph> {
    if graph.nodes.is_empty() {
        return Err(Error::graph("empty model"));
    }
    let (layers, shapes) = pass1(graph, factories, opts.batch, opts.compute)?;
    assemble(graph, layers, &shapes, opts)
}

/// Passes 2–3: derivative-need analysis, tensor creation, EO assignment
/// (Algorithm 1) and view merging, over precomputed pass-1 shapes.
fn assemble(
    graph: &Graph,
    mut layers: Vec<Box<dyn Layer>>,
    shapes: &[NodeShapes],
    opts: &InitOptions,
) -> Result<InitGraph> {
    let n = graph.nodes.len();
    let mut table = TensorTable::new();

    // ---- pass 2: derivative-need analysis (frozen-backbone pruning) -----
    // wants_deriv[i]: node i's output derivative will exist & be consumed.
    let mut wants_deriv = vec![false; n];
    let mut has_grads = vec![false; n];
    for i in 0..n {
        has_grads[i] = opts.training && shapes[i].trainable && !shapes[i].fin.weights.is_empty();
        let upstream = graph.inputs[i]
            .iter()
            .any(|ep| wants_deriv[ep.node] || has_grads[ep.node]);
        // a node's output deriv is wanted if it, or anything before it,
        // trains weights — and never for input or loss nodes.
        wants_deriv[i] = opts.training
            && !is_loss_kind(&graph.nodes[i].ltype)
            && graph.nodes[i].ltype != "input"
            && (has_grads[i] || upstream);
    }

    let eo_apply: u32 = if opts.training { (3 * n) as u32 } else { n as u32 };
    let mut deferred = opts.deferred_apply;
    // E-shared weights force deferred apply (gradient accumulation).
    for nd in &graph.nodes {
        if nd.props.contains("shared_from") {
            deferred = true;
        }
    }

    // ---- pass 3: tensor creation + EO assignment (Algorithm 1) ----------
    let consumers = graph.consumers();
    let mut nodes: Vec<InitNode> = Vec::with_capacity(n);
    // weight-name → id for shared_from lookups
    let mut weight_ids: HashMap<String, TensorId> = HashMap::new();
    let mut grad_ids: HashMap<String, TensorId> = HashMap::new();

    for i in 0..n {
        let nd = &graph.nodes[i];
        let fin = &shapes[i].fin;
        let eo = eo_of(i, n);
        let is_input = nd.ltype == "input";
        let is_loss = is_loss_kind(&nd.ltype);
        let fused = fin.fused_backward;
        let mut io = LayerIo::default();

        // -- inputs: resolve producer outputs, add consumer-side EOs.
        // `calc_derivative` runs (and reads *all* inputs) whenever any
        // producer edge carries a derivative — so a CD need on one input
        // keeps every input alive through the CD step (e.g. attention's
        // memory input, whose own edge has no derivative when it comes
        // straight from an input node).
        let will_run_cd =
            opts.training && graph.inputs[i].iter().any(|ep| wants_deriv[ep.node]);
        for ep in &graph.inputs[i] {
            let prod = &nodes[ep.node];
            let act = prod.io.outputs[ep.slot];
            table.add_eo(act, eo.f, Lifespan::FORWARD);
            if opts.training {
                if fin.need_input_cg && has_grads[i] {
                    table.add_eo(act, eo.cg, Lifespan::CALC_GRAD);
                }
                if fin.need_input_cd && (will_run_cd || has_grads[i]) {
                    table.add_eo(act, if fused { eo.cg } else { eo.cd }, Lifespan::CALC_DERIV);
                }
            }
            io.inputs.push(act);
        }

        // -- outputs + their derivative buffers
        let single_in_act = io.inputs.first().copied();
        for (k, od) in shapes[i].out_dims.iter().enumerate() {
            let mode = if is_input {
                CreateMode::Placeholder
            } else {
                match (fin.inplace, k, single_in_act, opts.inplace && !opts.conventional) {
                    (crate::layers::Inplace::Modify, 0, Some(t), true)
                        if !table.get(t).is_placeholder() =>
                    {
                        CreateMode::ModifyView(t)
                    }
                    (crate::layers::Inplace::ReadOnly, 0, Some(t), true) => {
                        CreateMode::ReadOnlyView(t)
                    }
                    _ => CreateMode::Create,
                }
            };
            let role = if is_input { TensorRole::Input } else { TensorRole::Activation };
            let act = table.request(
                format!("{}:out{}", nd.name, k),
                *od,
                role,
                mode,
                Initializer::None,
            )?;
            table.add_eo(act, eo.f, Lifespan::FORWARD);
            if is_input {
                // bound by the Batch Queue at iteration start
                table.add_eo(act, 0, Lifespan::FORWARD);
            }
            if opts.training && wants_deriv[i] {
                if fin.need_output_cg {
                    table.add_eo(act, eo.cg, Lifespan::CALC_GRAD);
                }
                if fin.need_output_cd {
                    table.add_eo(act, if fused { eo.cg } else { eo.cd }, Lifespan::CALC_DERIV);
                }
            }
            io.outputs.push(act);

            // derivative of this output
            if wants_deriv[i] {
                let d = table.request(
                    format!("{}:dout{}", nd.name, k),
                    *od,
                    TensorRole::Derivative,
                    CreateMode::Create,
                    Initializer::None,
                )?;
                // read by this node during its backward
                table.add_eo(d, eo.cg, Lifespan::CALC_GRAD);
                if !fused {
                    table.add_eo(d, eo.cd, Lifespan::CALC_DERIV);
                }
                io.out_derivs.push(Some(d));
            } else {
                io.out_derivs.push(None);
            }
        }

        // sanity: every non-multiout output must have <= 1 consumer
        if nd.ltype != "multiout" {
            for (slot_consumers, _) in [(consumers[i].iter().filter(|c| c.2 == 0).count(), 0)] {
                if shapes[i].out_dims.len() == 1 && slot_consumers > 1 {
                    return Err(Error::graph(format!(
                        "output of `{}` consumed {} times; the MultiOut realizer must fan it out",
                        nd.name, slot_consumers
                    )));
                }
            }
        }

        // -- input derivatives (this node WRITES them at CD / fused CG)
        for ep in &graph.inputs[i] {
            let pd = nodes[ep.node].io.out_derivs.get(ep.slot).copied().flatten();
            if let Some(d) = pd {
                table.add_eo(d, if fused { eo.cg } else { eo.cd }, Lifespan::CALC_DERIV);
            }
            io.in_derivs.push(pd);
        }
        // in-place derivative sharing (Fig 5): the producer's dout becomes
        // a view of this node's dout.
        if opts.inplace && !opts.conventional && opts.training {
            let my_dout = io.out_derivs.first().copied().flatten();
            let prod_dout = io.in_derivs.first().copied().flatten();
            if let (Some(my), Some(prod)) = (my_dout, prod_dout) {
                let share = match fin.inplace {
                    crate::layers::Inplace::Modify => Some(CreateMode::ModifyView(my)),
                    crate::layers::Inplace::ReadOnly => Some(CreateMode::ReadOnlyView(my)),
                    crate::layers::Inplace::None => None,
                };
                if let Some(m) = share {
                    let spec = table.get_mut(prod);
                    if matches!(spec.mode, CreateMode::Create) {
                        spec.mode = m;
                    }
                }
            }
        }

        // -- weights, gradients, optimizer state
        let shared_from = nd.props.string("shared_from");
        let mut opt_states: Vec<Vec<TensorId>> = Vec::new();
        for w in &fin.weights {
            let dim = w.dim; // weights are batch-independent
            let (mode, gmode) = match &shared_from {
                Some(src) => {
                    let wkey = format!("{src}:{}", w.name);
                    let wid = *weight_ids.get(&wkey).ok_or_else(|| {
                        Error::graph(format!("shared_from target weight `{wkey}` not found"))
                    })?;
                    let gid = grad_ids.get(&wkey).copied();
                    (CreateMode::Extend(wid), gid.map(CreateMode::Extend))
                }
                None => (CreateMode::Create, None),
            };
            let wid = table.request(
                format!("{}:{}", nd.name, w.name),
                dim,
                TensorRole::Weight,
                mode,
                w.init,
            )?;
            table.add_eo(wid, 0, Lifespan::MAX);
            table.add_eo(wid, eo_apply, Lifespan::MAX);
            table.get_mut(wid).trainable = shapes[i].trainable;
            if opts.training && !deferred && shared_from.is_none() {
                // Under per-layer apply the weight's real accesses span its
                // own forward read through its own apply (at CG for fused
                // backward, at CD otherwise); the recorded `{0, eo_apply}`
                // bracket stays in place for placement safety.
                let last = if fused { eo.cg } else { eo.cd };
                table.get_mut(wid).boundary_window = Some((eo.f, last));
            }
            io.weights.push(wid);

            if has_grads[i] {
                let gmode2 = match gmode {
                    Some(m) => m,
                    None => CreateMode::Create,
                };
                let gid = table.request(
                    format!("{}:{}:grad", nd.name, w.name),
                    dim,
                    TensorRole::Gradient,
                    gmode2,
                    Initializer::Zeros,
                )?;
                table.add_eo(gid, eo.cg, Lifespan::CALC_GRAD);
                if deferred {
                    table.add_eo(gid, eo_apply, Lifespan::MAX);
                } else if !fused {
                    // per-layer apply runs right after the layer's CD
                    // (the derivative must see the pre-update weight)
                    table.add_eo(gid, eo.cd, Lifespan::CALC_DERIV);
                }
                io.grads.push(Some(gid));
                if shared_from.is_none() {
                    weight_ids.insert(format!("{}:{}", nd.name, w.name), wid);
                    grad_ids.insert(format!("{}:{}", nd.name, w.name), gid);
                    // optimizer state (only for root weights)
                    let mut slots = Vec::new();
                    for s in 0..opts.opt_slots {
                        let sid = table.request(
                            format!("{}:{}:opt{}", nd.name, w.name, s),
                            dim,
                            TensorRole::OptState,
                            CreateMode::Create,
                            Initializer::Zeros,
                        )?;
                        table.add_eo(sid, 0, Lifespan::MAX);
                        table.add_eo(sid, eo_apply, Lifespan::MAX);
                        if opts.training && !deferred {
                            // optimizer state is touched only at its
                            // layer's apply step
                            let a = if fused { eo.cg } else { eo.cd };
                            table.get_mut(sid).boundary_window = Some((a, a));
                        }
                        slots.push(sid);
                    }
                    opt_states.push(slots);
                } else {
                    opt_states.push(vec![]);
                }
            } else {
                io.grads.push(None);
                opt_states.push(vec![]);
                if shared_from.is_none() {
                    weight_ids.insert(format!("{}:{}", nd.name, w.name), wid);
                }
            }
        }

        // -- temps
        for t in &fin.temps {
            // batch-dependent temps were declared with the input's batch
            let tid = table.request(
                format!("{}:{}", nd.name, t.name),
                t.dim,
                TensorRole::Temp,
                CreateMode::Create,
                Initializer::Zeros,
            )?;
            if t.span.is_max() {
                table.add_eo(tid, 0, Lifespan::MAX);
                table.add_eo(tid, eo_apply, Lifespan::MAX);
            } else {
                if t.span.forward() {
                    table.add_eo(tid, eo.f, Lifespan::FORWARD);
                }
                if opts.training {
                    if t.span.calc_grad() {
                        table.add_eo(tid, eo.cg, Lifespan::CALC_GRAD);
                    }
                    if t.span.calc_deriv() {
                        table.add_eo(tid, if fused { eo.cg } else { eo.cd }, Lifespan::CALC_DERIV);
                    }
                }
            }
            io.temps.push(tid);
        }

        // -- loss label placeholder
        if is_loss {
            let dim = shapes[i].in_dims[0];
            let lid = table.request(
                format!("{}:label", nd.name),
                dim,
                TensorRole::Input,
                CreateMode::Placeholder,
                Initializer::None,
            )?;
            table.add_eo(lid, 0, Lifespan::FORWARD);
            table.add_eo(lid, eo.f, Lifespan::FORWARD);
            if opts.training {
                table.add_eo(lid, eo.cd, Lifespan::CALC_DERIV);
            }
            io.label = Some(lid);
        }

        let writes_derivs = io.in_derivs.iter().any(|d| d.is_some());
        nodes.push(InitNode {
            name: nd.name.clone(),
            layer: std::mem::replace(
                &mut layers[i],
                crate::layers::input::InputLayer::create(&crate::layers::Props::from_pairs([(
                    "input_shape",
                    "1:1:1",
                )]))?,
            ),
            io,
            in_dims: shapes[i].in_dims.clone(),
            out_dims: shapes[i].out_dims.clone(),
            fused_backward: fused,
            trainable: shapes[i].trainable,
            is_loss,
            is_input,
            has_grads: has_grads[i],
            writes_derivs,
            opt_states,
        });
    }

    // ---- conventional-framework profile (Fig 9 baseline) ----------------
    if opts.conventional {
        for s in table.iter_mut() {
            if !s.eos.is_empty()
                && matches!(
                    s.role,
                    TensorRole::Activation | TensorRole::Derivative | TensorRole::Gradient | TensorRole::Temp
                )
            {
                s.eos.push(0);
                s.eos.push(eo_apply);
            }
        }
    }

    // ---- Algorithm 1 lines 13–23: MV/RV/E merge --------------------------
    table.finish_orders();
    merge_views(&mut table)?;
    table.finish_orders();

    let loss_nodes = nodes.iter().enumerate().filter(|(_, x)| x.is_loss).map(|(i, _)| i).collect();
    let input_nodes = nodes.iter().enumerate().filter(|(_, x)| x.is_input).map(|(i, _)| i).collect();
    Ok(InitGraph {
        nodes,
        table,
        eo_apply,
        deferred_apply: deferred,
        loss_nodes,
        input_nodes,
    })
}

/// Batch-scaling rule for one dim field, inferred from two reference
/// batches.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum DimRule {
    /// The field is batch-independent.
    Const(usize),
    /// The field is `k × batch`.
    PerBatch(usize),
}

impl DimRule {
    fn infer(va: usize, vb: usize, batch_a: usize, batch_b: usize) -> Option<DimRule> {
        if va == vb {
            Some(DimRule::Const(va))
        } else if va % batch_a == 0 && vb % batch_b == 0 && va / batch_a == vb / batch_b {
            Some(DimRule::PerBatch(va / batch_a))
        } else {
            None
        }
    }

    fn apply(&self, batch: usize) -> usize {
        match *self {
            DimRule::Const(v) => v,
            DimRule::PerBatch(k) => k * batch,
        }
    }
}

/// Reference batches the template is inferred from — coprime so a
/// `k × batch` field can never masquerade as a constant.
const TEMPLATE_REF_A: usize = 2;
const TEMPLATE_REF_B: usize = 3;

/// Memoized pass-1 shape analysis for auto-batch probes (ROADMAP:
/// "auto-batch currently re-plans per probe"). Per-layer finalize runs
/// at two reference batches; when every dim of every request differs
/// between them by at most a linear batch factor, further probe batches
/// are *substituted* ([`ShapeTemplate::instantiate`]) instead of
/// re-analyzed — the whole binary search costs two shape analyses
/// total. [`ShapeTemplate::build`] returns `None` when some layer's
/// shapes are not batch-linear; callers then fall back to a full
/// analysis per probe. (Two-point inference assumes dims are at most
/// linear in batch — true of every layer in this crate, where batch
/// never mixes into feature dims. A hypothetical layer crafted to
/// interpolate linearly at exactly batches 2 and 3 could fool the
/// template; the real compile at the selected batch and
/// `fits_budget()` still report the honest pool.)
pub struct ShapeTemplate {
    base: Vec<NodeShapes>,
    /// Per node, per collected dim (see [`collect_dims`] order), the
    /// four field rules `[b, c, h, w]`.
    rules: Vec<Vec<[DimRule; 4]>>,
}

/// Every TensorDim a `NodeShapes` carries, in a fixed order shared by
/// inference and substitution.
fn collect_dims(s: &NodeShapes) -> Vec<TensorDim> {
    let mut dims = Vec::new();
    dims.extend(s.in_dims.iter().copied());
    dims.extend(s.out_dims.iter().copied());
    dims.extend(s.fin.out_dims.iter().copied());
    dims.extend(s.fin.weights.iter().map(|w| w.dim));
    dims.extend(s.fin.temps.iter().map(|t| t.dim));
    dims
}

/// Write substituted dims back in [`collect_dims`] order.
fn apply_dims(s: &mut NodeShapes, dims: &[TensorDim]) {
    let mut it = dims.iter();
    for d in s.in_dims.iter_mut() {
        *d = *it.next().unwrap();
    }
    for d in s.out_dims.iter_mut() {
        *d = *it.next().unwrap();
    }
    for d in s.fin.out_dims.iter_mut() {
        *d = *it.next().unwrap();
    }
    for w in s.fin.weights.iter_mut() {
        w.dim = *it.next().unwrap();
    }
    for t in s.fin.temps.iter_mut() {
        t.dim = *it.next().unwrap();
    }
}

impl ShapeTemplate {
    /// Infer a template from two reference-batch analyses; `None` when
    /// any dim is not batch-linear **or** some layer cannot finalize at
    /// a reference batch at all (a custom layer rejecting, say, odd
    /// batches) — in both cases the honest fallback is a full analysis
    /// per probed batch, which only ever evaluates the batches actually
    /// probed.
    pub fn build(
        graph: &Graph,
        factories: &HashMap<&'static str, LayerFactory>,
        compute: ComputeKind,
    ) -> Option<ShapeTemplate> {
        let a = match pass1(graph, factories, TEMPLATE_REF_A, compute) {
            Ok((_, shapes)) => shapes,
            Err(_) => return None,
        };
        let b = match pass1(graph, factories, TEMPLATE_REF_B, compute) {
            Ok((_, shapes)) => shapes,
            Err(_) => return None,
        };
        let mut rules = Vec::with_capacity(a.len());
        for (sa, sb) in a.iter().zip(b.iter()) {
            let da = collect_dims(sa);
            let db = collect_dims(sb);
            if da.len() != db.len() || sa.trainable != sb.trainable {
                return None;
            }
            let mut node_rules = Vec::with_capacity(da.len());
            for (x, y) in da.iter().zip(db.iter()) {
                let r = [
                    DimRule::infer(x.b, y.b, TEMPLATE_REF_A, TEMPLATE_REF_B),
                    DimRule::infer(x.c, y.c, TEMPLATE_REF_A, TEMPLATE_REF_B),
                    DimRule::infer(x.h, y.h, TEMPLATE_REF_A, TEMPLATE_REF_B),
                    DimRule::infer(x.w, y.w, TEMPLATE_REF_A, TEMPLATE_REF_B),
                ];
                match r {
                    [Some(b_), Some(c), Some(h), Some(w)] => node_rules.push([b_, c, h, w]),
                    _ => return None,
                }
            }
            rules.push(node_rules);
        }
        Some(ShapeTemplate { base: a, rules })
    }

    /// Pass-1 shapes for `batch`, by rule substitution (no layer code
    /// runs).
    pub fn instantiate(&self, batch: usize) -> Vec<NodeShapes> {
        self.base
            .iter()
            .zip(self.rules.iter())
            .map(|(s, rules)| {
                let mut s = s.clone();
                let dims: Vec<TensorDim> = rules
                    .iter()
                    .map(|r| {
                        TensorDim::new(
                            r[0].apply(batch),
                            r[1].apply(batch),
                            r[2].apply(batch),
                            r[3].apply(batch),
                        )
                    })
                    .collect();
                apply_dims(&mut s, &dims);
                s
            })
            .collect()
    }
}

/// Probe-only initialization: assemble the tensor table for
/// `opts.batch` from a memoized shape template, with inert placeholder
/// layers standing in for the real ones — the result is planned, never
/// executed. The per-layer shape analysis count does not move.
pub fn probe_init_graph(
    graph: &Graph,
    template: &ShapeTemplate,
    opts: &InitOptions,
) -> Result<InitGraph> {
    if graph.nodes.is_empty() {
        return Err(Error::graph("empty model"));
    }
    let shapes = template.instantiate(opts.batch);
    let layers: Vec<Box<dyn Layer>> = (0..graph.nodes.len())
        .map(|_| {
            crate::layers::input::InputLayer::create(&Props::from_pairs([(
                "input_shape",
                "1:1:1",
            )]))
        })
        .collect::<Result<Vec<_>>>()?;
    assemble(graph, layers, &shapes, opts)
}

/// Algorithm 1, lines 13–23: resolve create modes in ascending-min-EO
/// order. `MV` merges only when the target's last use precedes (or
/// coincides with) the view's first use; `RV`/`E` always merge.
fn merge_views(table: &mut TensorTable) -> Result<()> {
    let mut ids: Vec<TensorId> = (0..table.len()).collect();
    ids.sort_by_key(|&id| table.get(id).min_eo().unwrap_or(u32::MAX));
    for id in ids {
        if table.get(id).eos.is_empty() {
            continue;
        }
        let mode = table.get(id).mode.clone();
        let (target, strict) = match mode {
            CreateMode::ModifyView(t) => (t, true),
            CreateMode::ReadOnlyView(t) | CreateMode::Extend(t) => (t, false),
            _ => continue,
        };
        let root = table.resolve(target);
        if root == id {
            return Err(Error::graph(format!(
                "tensor `{}` views itself",
                table.get(id).name
            )));
        }
        let root_max = table.get(root).max_eo().unwrap_or(0);
        let my_min = table.get(id).min_eo().unwrap_or(u32::MAX);
        let mergeable = !strict || root_max <= my_min;
        if mergeable {
            let eos = table.get(id).eos.clone();
            let span = table.get(id).lifespan;
            {
                let r = table.get_mut(root);
                r.eos.extend(eos);
                r.eos.sort_unstable();
                r.eos.dedup();
                r.lifespan = r.lifespan.union(span);
            }
            table.get_mut(id).merged_into = Some(root);
        } else {
            // integrity not guaranteed — demote to a fresh tensor
            table.get_mut(id).mode = CreateMode::Create;
        }
    }
    Ok(())
}

/// The analytic minimum peak (paper §3 "ideal memory"): the max over all
/// execution orders of the bytes of simultaneously-live root tensors.
/// This is the lower bound any planner can hope for, used as the "Ideal"
/// series of Table 4 / Fig 9.
pub fn ideal_peak_bytes(table: &TensorTable) -> usize {
    let mut events: Vec<(u32, i64)> = Vec::new();
    for s in table.iter() {
        if s.merged_into.is_some() || s.eos.is_empty() {
            continue;
        }
        let b = s.dim.bytes() as i64;
        events.push((s.min_eo().unwrap(), b));
        events.push((s.max_eo().unwrap() + 1, -b));
    }
    events.sort();
    let mut cur = 0i64;
    let mut peak = 0i64;
    for (_, d) in events {
        cur += d;
        peak = peak.max(cur);
    }
    peak as usize
}
