//! Plan + runtime metrics: the numbers every evaluation figure reports.

use std::collections::HashMap;
use std::time::Instant;

use crate::exec::ideal_peak_bytes;
use crate::tensor::{TensorRole, TensorTable};

pub const KIB: f64 = 1024.0;
pub const MIB: f64 = 1024.0 * 1024.0;

/// Constant we report as the framework's own footprint, mirroring the
/// paper's "baseline" series (NNTrainer: 12.3 MiB, TensorFlow: 337.8 MiB,
/// PyTorch: 105.4 MiB). Ours is the release binary + libxla runtime
/// resident set measured once; it is a *reported constant*, not part of
/// the pool accounting.
pub const BASELINE_NNTRAINER_MIB: f64 = 12.3;
pub const BASELINE_TENSORFLOW_MIB: f64 = 337.8;
pub const BASELINE_PYTORCH_MIB: f64 = 105.4;

/// Result of memory planning for one compiled model.
#[derive(Clone, Debug)]
pub struct PlanReport {
    pub planner: &'static str,
    /// Pool size = peak training memory, known before execution.
    pub pool_bytes: usize,
    /// Analytic lower bound (max simultaneous live bytes).
    pub ideal_bytes: usize,
    /// Sum of every root tensor (what a no-reuse allocator needs).
    pub total_bytes: usize,
    /// Per-role byte totals (root tensors).
    pub by_role: HashMap<String, usize>,
    pub n_tensors: usize,
    pub n_merged: usize,
}

impl PlanReport {
    pub fn from_table(table: &TensorTable, pool_len: usize, planner: &'static str) -> Self {
        let mut by_role: HashMap<String, usize> = HashMap::new();
        let mut total = 0usize;
        let mut n_tensors = 0usize;
        let mut n_merged = 0usize;
        for s in table.iter() {
            if s.eos.is_empty() {
                continue;
            }
            if s.merged_into.is_some() {
                n_merged += 1;
                continue;
            }
            n_tensors += 1;
            total += s.dim.bytes();
            *by_role.entry(s.role.to_string()).or_default() += s.dim.bytes();
        }
        PlanReport {
            planner,
            pool_bytes: pool_len * 4,
            ideal_bytes: ideal_peak_bytes(table),
            total_bytes: total,
            by_role,
            n_tensors,
            n_merged,
        }
    }

    pub fn pool_mib(&self) -> f64 {
        self.pool_bytes as f64 / MIB
    }
    pub fn ideal_mib(&self) -> f64 {
        self.ideal_bytes as f64 / MIB
    }
    pub fn pool_kib(&self) -> f64 {
        self.pool_bytes as f64 / KIB
    }
    pub fn ideal_kib(&self) -> f64 {
        self.ideal_bytes as f64 / KIB
    }
    /// Planner overhead over the analytic ideal.
    pub fn overhead(&self) -> f64 {
        if self.ideal_bytes == 0 {
            return 0.0;
        }
        self.pool_bytes as f64 / self.ideal_bytes as f64
    }
}

/// Simple wall-clock timer for latency rows.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Timer { start: Instant::now() }
    }
    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
    pub fn elapsed_ms(&self) -> f64 {
        self.start.elapsed().as_secs_f64() * 1e3
    }
}

/// Breakdown helper for reports.
pub fn role_bytes(table: &TensorTable, role: TensorRole) -> usize {
    table
        .iter()
        .filter(|s| s.merged_into.is_none() && !s.eos.is_empty() && s.role == role)
        .map(|s| s.dim.bytes())
        .sum()
}
