//! Model graph: description-level nodes and wiring.
//!
//! After *Load*, a model is a list of `[<Layer type>, <Properties>]`
//! tuples (paper §4). `Graph` resolves `input_layers` references into
//! edges, topologically sorts, and exposes producer/consumer relations to
//! the compiler.

use std::collections::HashMap;

use crate::error::{Error, Result};
use crate::layers::Props;

/// One layer description (pre-instantiation).
#[derive(Clone, Debug)]
pub struct NodeDesc {
    pub name: String,
    pub ltype: String,
    pub props: Props,
}

impl NodeDesc {
    pub fn new(name: impl Into<String>, ltype: impl Into<String>, props: Props) -> Self {
        NodeDesc { name: name.into(), ltype: ltype.into(), props }
    }

    /// Input references: `input_layers` property, with NNTrainer's INI
    /// convention that an omitted value chains from the previous layer.
    pub fn input_refs(&self) -> Vec<String> {
        self.props.list("input_layers")
    }
}

/// An edge endpoint: node index + output slot.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct OutRef {
    pub node: usize,
    pub slot: usize,
}

/// Wired graph over a node list.
#[derive(Debug)]
pub struct Graph {
    pub nodes: Vec<NodeDesc>,
    /// For each node, the producer endpoints of its inputs.
    pub inputs: Vec<Vec<OutRef>>,
    /// Topological order (indices into `nodes`).
    pub order: Vec<usize>,
}

impl Graph {
    /// Wire a node list. `input_layers = name` or `name(slot)` for
    /// multi-output producers; an omitted `input_layers` on a non-input
    /// layer chains from the previous node in the list.
    pub fn wire(nodes: Vec<NodeDesc>) -> Result<Graph> {
        let mut by_name: HashMap<&str, usize> = HashMap::new();
        for (i, n) in nodes.iter().enumerate() {
            if by_name.insert(n.name.as_str(), i).is_some() {
                return Err(Error::graph(format!("duplicate layer name `{}`", n.name)));
            }
        }
        // Track how many times each producer has been referenced so that
        // bare references to a multi-output node take successive slots.
        let mut next_slot: HashMap<usize, usize> = HashMap::new();
        let mut inputs: Vec<Vec<OutRef>> = Vec::with_capacity(nodes.len());
        for (i, n) in nodes.iter().enumerate() {
            let mut refs = n.input_refs();
            if refs.is_empty() && n.ltype != "input" {
                if i == 0 {
                    return Err(Error::graph(format!(
                        "layer `{}` has no input_layers and no predecessor",
                        n.name
                    )));
                }
                refs = vec![nodes[i - 1].name.clone()];
            }
            let mut eps = Vec::with_capacity(refs.len());
            for r in refs {
                let (name, slot) = parse_ref(&r)?;
                let &p = by_name
                    .get(name.as_str())
                    .ok_or_else(|| Error::graph(format!("unknown input `{name}` of `{}`", n.name)))?;
                if p >= i {
                    return Err(Error::graph(format!(
                        "layer `{}` consumes `{name}` which is not defined before it",
                        n.name
                    )));
                }
                let slot = match slot {
                    Some(s) => s,
                    None => {
                        // auto-advance slot for multiout producers
                        let e = next_slot.entry(p).or_insert(0);
                        let s = *e;
                        if nodes[p].ltype == "multiout" {
                            *e += 1;
                        }
                        s
                    }
                };
                eps.push(OutRef { node: p, slot });
            }
            inputs.push(eps);
        }
        // Node list is required to be topologically ordered already
        // (checked above: producers precede consumers).
        let order = (0..nodes.len()).collect();
        Ok(Graph { nodes, inputs, order })
    }

    /// consumers[p] = list of (consumer node, consumer input index, slot).
    pub fn consumers(&self) -> Vec<Vec<(usize, usize, usize)>> {
        let mut c: Vec<Vec<(usize, usize, usize)>> = vec![vec![]; self.nodes.len()];
        for (i, eps) in self.inputs.iter().enumerate() {
            for (k, ep) in eps.iter().enumerate() {
                c[ep.node].push((i, k, ep.slot));
            }
        }
        c
    }
}

fn parse_ref(r: &str) -> Result<(String, Option<usize>)> {
    if let Some(open) = r.find('(') {
        let close = r
            .rfind(')')
            .ok_or_else(|| Error::graph(format!("bad input ref `{r}`")))?;
        let slot: usize = r[open + 1..close]
            .trim()
            .parse()
            .map_err(|e| Error::graph(format!("bad slot in `{r}`: {e}")))?;
        Ok((r[..open].trim().to_string(), Some(slot)))
    } else {
        Ok((r.trim().to_string(), None))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn node(name: &str, ltype: &str, pairs: &[(&str, &str)]) -> NodeDesc {
        NodeDesc::new(name, ltype, Props::from_pairs(pairs.iter().copied()))
    }

    #[test]
    fn implicit_chaining() {
        let g = Graph::wire(vec![
            node("in", "input", &[("input_shape", "1:1:4")]),
            node("fc0", "fully_connected", &[("unit", "3")]),
            node("loss", "mse", &[]),
        ])
        .unwrap();
        assert_eq!(g.inputs[1], vec![OutRef { node: 0, slot: 0 }]);
        assert_eq!(g.inputs[2], vec![OutRef { node: 1, slot: 0 }]);
    }

    #[test]
    fn explicit_refs_and_slots() {
        let g = Graph::wire(vec![
            node("in", "input", &[("input_shape", "1:1:4")]),
            node("mo", "multiout", &[("outputs", "2")]),
            node("a", "fully_connected", &[("unit", "3"), ("input_layers", "mo(0)")]),
            node("b", "fully_connected", &[("unit", "3"), ("input_layers", "mo(1)")]),
            node("add", "addition", &[("input_layers", "a,b")]),
        ])
        .unwrap();
        assert_eq!(g.inputs[2], vec![OutRef { node: 1, slot: 0 }]);
        assert_eq!(g.inputs[3], vec![OutRef { node: 1, slot: 1 }]);
        assert_eq!(g.inputs[4].len(), 2);
        let cons = g.consumers();
        assert_eq!(cons[1].len(), 2);
    }

    #[test]
    fn bare_multiout_refs_auto_advance() {
        let g = Graph::wire(vec![
            node("in", "input", &[("input_shape", "1:1:4")]),
            node("mo", "multiout", &[("outputs", "2")]),
            node("a", "activation", &[("act", "relu"), ("input_layers", "mo")]),
            node("b", "activation", &[("act", "relu"), ("input_layers", "mo")]),
        ])
        .unwrap();
        assert_eq!(g.inputs[2][0].slot, 0);
        assert_eq!(g.inputs[3][0].slot, 1);
    }

    #[test]
    fn rejects_unknown_and_forward_refs() {
        assert!(Graph::wire(vec![
            node("in", "input", &[("input_shape", "1:1:4")]),
            node("fc", "fully_connected", &[("unit", "3"), ("input_layers", "nope")]),
        ])
        .is_err());
        assert!(Graph::wire(vec![
            node("a", "fully_connected", &[("unit", "3"), ("input_layers", "b")]),
            node("b", "input", &[("input_shape", "1:1:4")]),
        ])
        .is_err());
    }

    #[test]
    fn rejects_duplicate_names() {
        assert!(Graph::wire(vec![
            node("x", "input", &[("input_shape", "1:1:4")]),
            node("x", "fully_connected", &[("unit", "3")]),
        ])
        .is_err());
    }
}
