//! Small deterministic RNG (xorshift64*), used for weight init, synthetic
//! data producers and the in-repo property-test helper.
//!
//! The offline build has no `rand` crate; this is a self-contained,
//! reproducible generator — determinism is a feature for the paper's
//! "equivalence at 1e-4" correctness methodology.

/// xorshift64* PRNG. Not cryptographic; plenty for init + synthetic data.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // Avoid the all-zero fixed point.
        Rng {
            state: seed.wrapping_mul(0x9E3779B97F4A7C15).max(1),
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.next_f32()
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.next_f32().max(1e-7);
        let u2 = self.next_f32();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f32::consts::PI * u2).cos()
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        (self.next_u64() % n as u64) as usize
    }

    /// Fill a slice with uniform values in [lo, hi).
    pub fn fill_uniform(&mut self, buf: &mut [f32], lo: f32, hi: f32) {
        for v in buf {
            *v = self.uniform(lo, hi);
        }
    }

    /// Fill a slice with normal(0, std) values.
    pub fn fill_normal(&mut self, buf: &mut [f32], std: f32) {
        for v in buf {
            *v = self.normal() * std;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn f32_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let v = r.next_f32();
            assert!((0.0..1.0).contains(&v), "{v}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(9);
        let n = 50_000;
        let mut sum = 0.0f64;
        let mut sq = 0.0f64;
        for _ in 0..n {
            let v = r.normal() as f64;
            sum += v;
            sq += v * v;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn below_covers_range() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            seen[r.below(10)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }
}
