//! Shared harness for the benchmark binaries (`rust/benches/*`, built
//! with `harness = false` — criterion is unavailable offline).
//!
//! Every bench regenerates one table/figure of the paper and prints the
//! paper-reported values alongside, so `cargo bench | tee` *is* the
//! reproduction record (EXPERIMENTS.md).

use crate::compiler::{plan_only, CompileOpts};
use crate::dataset::{BatchQueue, DataProducer, RandomProducer};
use crate::error::Result;
use crate::graph::NodeDesc;
use crate::metrics::PlanReport;
use crate::model::{Model, ModelBuilder};
use crate::planner::PlannerKind;

/// Dataset size for latency benches; override with
/// `NNTRAINER_BENCH_DATASET` (the paper used 512 on an RPi4 — the
/// default here keeps a full `cargo bench` run in minutes on one core).
pub fn bench_dataset() -> usize {
    std::env::var("NNTRAINER_BENCH_DATASET")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(128)
}

/// Compile options for the two allocation profiles the evaluation
/// compares: NNTrainer (sorting planner, in-place on) and the
/// conventional-framework emulation (see DESIGN.md §Substitutions).
pub fn nntrainer_profile(batch: usize) -> CompileOpts {
    CompileOpts { batch, planner: PlannerKind::Sorting, ..Default::default() }
}

pub fn conventional_profile(batch: usize) -> CompileOpts {
    CompileOpts {
        batch,
        planner: PlannerKind::Naive,
        conventional: true,
        inplace: false,
        ..Default::default()
    }
}

/// NNTrainer profile under a primary-memory budget: the offload advisor
/// plans idle-gap swaps and the executor runs the proactive swap runtime
/// (`benches/swap_runtime.rs`).
pub fn budget_profile(batch: usize, budget_bytes: usize) -> CompileOpts {
    CompileOpts {
        batch,
        memory_budget_bytes: Some(budget_bytes),
        ..Default::default()
    }
}

/// Plan a model under a profile (no allocation).
pub fn plan(nodes: Vec<NodeDesc>, opts: &CompileOpts) -> Result<PlanReport> {
    plan_only(nodes, opts)
}

/// Compile + train `epochs` epochs on random data; returns (model,
/// wall-seconds, iterations).
pub fn train_random(
    nodes: Vec<NodeDesc>,
    opts: &CompileOpts,
    dataset: usize,
    epochs: usize,
    lr: f32,
) -> Result<(Model, f64, usize)> {
    train_random_swap(nodes, opts, dataset, epochs, lr, false)
}

/// [`train_random`] with the swap runtime's eviction mode pinned:
/// `sync_evictions = true` restores the synchronous-eviction (PR-1)
/// write path, the baseline the full-duplex write-stall columns of
/// `benches/swap_runtime.rs` compare against.
pub fn train_random_swap(
    nodes: Vec<NodeDesc>,
    opts: &CompileOpts,
    dataset: usize,
    epochs: usize,
    lr: f32,
    sync_evictions: bool,
) -> Result<(Model, f64, usize)> {
    let mut model = ModelBuilder::new()
        .add_nodes(nodes)
        .optimizer("sgd", &[("learning_rate", &format!("{lr}"))])
        .compile(opts)?;
    if sync_evictions {
        if let Some(sw) = model.exec.swap_mut() {
            sw.set_sync_evictions(true);
        }
    }
    let in_len: usize = model
        .exec
        .graph
        .input_nodes
        .iter()
        .map(|&n| model.exec.graph.nodes[n].out_dims[0].feature_len())
        .sum();
    let lb_len: usize = model
        .exec
        .graph
        .loss_nodes
        .iter()
        .map(|&n| model.exec.graph.nodes[n].in_dims[0].feature_len())
        .sum();
    let batch = opts.batch;
    let start = std::time::Instant::now();
    let mut iters = 0usize;
    for _ in 0..epochs {
        let make: Box<dyn DataProducer> = Box::new(RandomProducer::new(dataset, in_len, lb_len, 7));
        let queue = BatchQueue::spawn(make, batch, 2);
        while let Some(b) = queue.next() {
            model.bind_batch(&b.input, &b.label)?;
            model.exec.try_train_iteration()?;
            iters += 1;
        }
        // epoch boundary, as in session::run_training: calibrated swap
        // tuning reacts to the stall telemetry this epoch accrued
        if let Some(sw) = model.exec.swap_mut() {
            sw.adapt_depth();
        }
    }
    Ok((model, start.elapsed().as_secs_f64(), iters))
}

/// Markdown-ish table printer.
pub struct Table {
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }
    pub fn row(&mut self, cells: Vec<String>) {
        self.rows.push(cells);
    }
    pub fn print(&self) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                if i < widths.len() {
                    widths[i] = widths[i].max(c.len());
                }
            }
        }
        let line = |cells: &[String]| {
            let mut s = String::new();
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!("{:>w$}  ", c, w = widths[i.min(widths.len() - 1)]));
            }
            println!("{}", s.trim_end());
        };
        line(&self.headers);
        println!("{}", "-".repeat(widths.iter().sum::<usize>() + widths.len() * 2));
        for r in &self.rows {
            line(r);
        }
    }
}

pub fn fmt_mib(bytes: usize) -> String {
    format!("{:.1}", bytes as f64 / (1024.0 * 1024.0))
}

pub fn fmt_kib(bytes: usize) -> String {
    format!("{:.0}", bytes as f64 / 1024.0)
}
