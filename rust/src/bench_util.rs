//! Shared harness for the benchmark binaries (`rust/benches/*`, built
//! with `harness = false` — criterion is unavailable offline).
//!
//! Every bench regenerates one table/figure of the paper and prints the
//! paper-reported values alongside; the paper-figure benches additionally
//! feed their rows into a [`crate::bench_report::BenchReport`] and write
//! `BENCH_<name>.json` snapshots at the repo root, diffed against the
//! committed baseline with regression gates. EXPERIMENTS.md documents
//! how to run the harness, read the snapshots, and update a baseline.

use crate::compiler::{plan_only, CompileOpts};
use crate::dataset::{BatchQueue, DataProducer, RandomProducer};
use crate::error::Result;
use crate::graph::NodeDesc;
use crate::metrics::PlanReport;
use crate::model::{Model, ModelBuilder};
use crate::planner::PlannerKind;

/// Dataset size for latency benches; override with
/// `NNTRAINER_BENCH_DATASET` (the paper used 512 on an RPi4 — the
/// default here keeps a full `cargo bench` run in minutes on one core).
///
/// An unparseable override is a loud error: the CI perf-gate sizes its
/// smoke runs with this variable, and silently falling back to the full
/// default would both blow the job's time box and diff against a
/// baseline of the wrong size.
pub fn bench_dataset() -> usize {
    match std::env::var("NNTRAINER_BENCH_DATASET") {
        Ok(v) => match v.trim().parse::<usize>() {
            Ok(n) if n > 0 => n,
            Ok(_) => panic!("NNTRAINER_BENCH_DATASET must be > 0"),
            Err(e) => panic!("NNTRAINER_BENCH_DATASET={v:?} is not a usize: {e}"),
        },
        Err(std::env::VarError::NotPresent) => 128,
        Err(e) => panic!("NNTRAINER_BENCH_DATASET is set but unreadable: {e}"),
    }
}

/// Per-iteration training-thread sleep, microseconds, from
/// `NNTRAINER_BENCH_INJECT_STALL_US` (default 0). A deliberate
/// regression-injection hook: run a gated bench with this set and the
/// step-latency delta must trip the perf gate — the one-command proof
/// that the gate is live (EXPERIMENTS.md §Injecting a regression).
pub fn injected_stall_us() -> u64 {
    match std::env::var("NNTRAINER_BENCH_INJECT_STALL_US") {
        Ok(v) => v
            .trim()
            .parse()
            .unwrap_or_else(|e| panic!("NNTRAINER_BENCH_INJECT_STALL_US={v:?} is not a u64: {e}")),
        Err(std::env::VarError::NotPresent) => 0,
        Err(e) => panic!("NNTRAINER_BENCH_INJECT_STALL_US is set but unreadable: {e}"),
    }
}

/// Compile options for the two allocation profiles the evaluation
/// compares: NNTrainer (sorting planner, in-place on) and the
/// conventional-framework emulation (see DESIGN.md §Substitutions).
pub fn nntrainer_profile(batch: usize) -> CompileOpts {
    CompileOpts { batch, planner: PlannerKind::Sorting, ..Default::default() }
}

pub fn conventional_profile(batch: usize) -> CompileOpts {
    CompileOpts {
        batch,
        planner: PlannerKind::Naive,
        conventional: true,
        inplace: false,
        ..Default::default()
    }
}

/// Same options on the naive single-threaded compute backend — the
/// baseline the fig10/fig11 `tiered_speedup_x` columns divide by.
pub fn with_naive_compute(mut opts: CompileOpts) -> CompileOpts {
    opts.compute = crate::backend::ComputeKind::Naive;
    opts
}

/// NNTrainer profile under a primary-memory budget: the offload advisor
/// plans idle-gap swaps and the executor runs the proactive swap runtime
/// (`benches/swap_runtime.rs`).
pub fn budget_profile(batch: usize, budget_bytes: usize) -> CompileOpts {
    CompileOpts {
        batch,
        memory_budget_bytes: Some(budget_bytes),
        ..Default::default()
    }
}

/// Plan a model under a profile (no allocation).
pub fn plan(nodes: Vec<NodeDesc>, opts: &CompileOpts) -> Result<PlanReport> {
    plan_only(nodes, opts)
}

/// Deterministic per-epoch data seed. Every epoch must train on a
/// *different* batch sequence (the seed harness re-created the producer
/// with a constant seed, so each epoch silently replayed epoch 0 — the
/// regression `tests/bench_report.rs::epochs_see_distinct_batches`
/// guards), while the same epoch of the same run stays reproducible.
/// Epoch 0 keeps the historical seed 7, so single-epoch bench numbers
/// are comparable across the fix.
pub fn epoch_seed(epoch: usize) -> u64 {
    7u64 ^ (epoch as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
}

/// Compile + train `epochs` epochs on random data; returns (model,
/// wall-seconds, iterations).
pub fn train_random(
    nodes: Vec<NodeDesc>,
    opts: &CompileOpts,
    dataset: usize,
    epochs: usize,
    lr: f32,
) -> Result<(Model, f64, usize)> {
    train_random_swap(nodes, opts, dataset, epochs, lr, false)
}

/// [`train_random`] with the swap runtime's eviction mode pinned:
/// `sync_evictions = true` restores the synchronous-eviction (PR-1)
/// write path, the baseline the full-duplex write-stall columns of
/// `benches/swap_runtime.rs` compare against.
pub fn train_random_swap(
    nodes: Vec<NodeDesc>,
    opts: &CompileOpts,
    dataset: usize,
    epochs: usize,
    lr: f32,
    sync_evictions: bool,
) -> Result<(Model, f64, usize)> {
    let (model, secs, iters, _) = train_random_run(nodes, opts, dataset, epochs, lr, sync_evictions)?;
    Ok((model, secs, iters))
}

/// The full-fat runner behind [`train_random`]/[`train_random_swap`]:
/// additionally returns the per-epoch mean losses. With a zero learning
/// rate the weights never move, so equal epoch losses mean equal epoch
/// data — the hook the epoch-seed regression test keys on.
pub fn train_random_run(
    nodes: Vec<NodeDesc>,
    opts: &CompileOpts,
    dataset: usize,
    epochs: usize,
    lr: f32,
    sync_evictions: bool,
) -> Result<(Model, f64, usize, Vec<f32>)> {
    train_random_with(nodes, opts, dataset, epochs, lr, |model| {
        if sync_evictions {
            if let Some(sw) = model.exec.swap_mut() {
                sw.set_sync_evictions(true);
            }
        }
    })
}

/// [`train_random_run`] with an arbitrary post-compile hook: the bench
/// rows that pin a runtime mode the compiler doesn't expose (sync
/// evictions, drained boundary baseline) set it here, between compile
/// and the first iteration.
pub fn train_random_with(
    nodes: Vec<NodeDesc>,
    opts: &CompileOpts,
    dataset: usize,
    epochs: usize,
    lr: f32,
    setup: impl FnOnce(&mut Model),
) -> Result<(Model, f64, usize, Vec<f32>)> {
    let mut model = ModelBuilder::new()
        .add_nodes(nodes)
        .optimizer("sgd", &[("learning_rate", &format!("{lr}"))])
        .compile(opts)?;
    setup(&mut model);
    let in_len: usize = model
        .exec
        .graph
        .input_nodes
        .iter()
        .map(|&n| model.exec.graph.nodes[n].out_dims[0].feature_len())
        .sum();
    let lb_len: usize = model
        .exec
        .graph
        .loss_nodes
        .iter()
        .map(|&n| model.exec.graph.nodes[n].in_dims[0].feature_len())
        .sum();
    let batch = opts.batch;
    let inject_us = injected_stall_us();
    let start = std::time::Instant::now();
    let mut iters = 0usize;
    let mut epoch_losses = Vec::with_capacity(epochs);
    for epoch in 0..epochs {
        let make: Box<dyn DataProducer> =
            Box::new(RandomProducer::new(dataset, in_len, lb_len, epoch_seed(epoch)));
        let queue = BatchQueue::spawn(make, batch, 2);
        let mut loss_sum = 0f64;
        let mut in_epoch = 0usize;
        while let Some(b) = queue.next() {
            model.bind_batch(&b.input, &b.label)?;
            loss_sum += model.exec.try_train_iteration()? as f64;
            in_epoch += 1;
            if inject_us > 0 {
                std::thread::sleep(std::time::Duration::from_micros(inject_us));
            }
        }
        iters += in_epoch;
        epoch_losses.push(if in_epoch > 0 { (loss_sum / in_epoch as f64) as f32 } else { f32::NAN });
        // epoch boundary, as in session::run_training: apply any parked
        // pool compaction at the swap-quiescent barrier, snapshot the
        // swap counters for the per-epoch trajectory, then let
        // calibrated tuning react to the stall telemetry this epoch
        // accrued
        model.exec.compact_pool()?;
        if let Some(sw) = model.exec.swap_mut() {
            sw.mark_epoch();
            sw.adapt_depth();
        }
    }
    // run end is a mandatory full-drain point: with cross-iteration
    // pipelining the engine may still carry boundary transfers, and the
    // callers read weights out of the pool right after this returns
    model.exec.quiesce_swap()?;
    Ok((model, start.elapsed().as_secs_f64(), iters, epoch_losses))
}

/// Markdown-ish table printer.
pub struct Table {
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }
    pub fn row(&mut self, cells: Vec<String>) {
        self.rows.push(cells);
    }

    /// Render to a string (tested directly; `print` is the thin shell).
    /// Column widths cover the *widest* row, so a row longer than the
    /// header list gets its own columns instead of silently reusing the
    /// last header width, and an empty header list renders the rows
    /// without a header rule rather than underflowing.
    pub fn render(&self) -> String {
        let ncols = self.rows.iter().map(|r| r.len()).fold(self.headers.len(), usize::max);
        if ncols == 0 {
            return String::new();
        }
        let mut widths = vec![0usize; ncols];
        for (i, h) in self.headers.iter().enumerate() {
            widths[i] = h.len();
        }
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| -> String {
            let mut s = String::new();
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!("{:>w$}  ", c, w = widths[i]));
            }
            s.trim_end().to_string()
        };
        let mut out = String::new();
        if !self.headers.is_empty() {
            out.push_str(&line(&self.headers));
            out.push('\n');
            out.push_str(&"-".repeat(widths.iter().sum::<usize>() + widths.len() * 2));
            out.push('\n');
        }
        for r in &self.rows {
            out.push_str(&line(r));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

pub fn fmt_mib(bytes: usize) -> String {
    format!("{:.1}", bytes as f64 / (1024.0 * 1024.0))
}

pub fn fmt_kib(bytes: usize) -> String {
    format!("{:.0}", bytes as f64 / 1024.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::DataProducer;

    #[test]
    fn empty_table_renders_nothing() {
        // regression: `widths.len() - 1` underflowed on an empty header
        // list before rows were even considered
        assert_eq!(Table::new(&[]).render(), "");
    }

    #[test]
    fn headerless_rows_render_without_rule() {
        let mut t = Table::new(&[]);
        t.row(vec!["a".into(), "bb".into()]);
        t.row(vec!["ccc".into(), "d".into()]);
        let out = t.render();
        assert_eq!(out, "  a  bb\nccc   d\n");
    }

    #[test]
    fn overlong_row_gets_its_own_columns() {
        // regression: cells past the last header silently shared the
        // last header's width
        let mut t = Table::new(&["h"]);
        t.row(vec!["x".into(), "long-cell".into()]);
        let out = t.render();
        let lines: Vec<&str> = out.lines().collect();
        assert_eq!(lines[0], "h");
        // separator sized for both columns, not just the header's
        assert_eq!(lines[1].len(), 1 + "long-cell".len() + 2 * 2);
        assert_eq!(lines[2], "x  long-cell");
    }

    #[test]
    fn ragged_short_rows_render() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["1".into()]);
        t.row(vec!["2".into(), "3".into()]);
        let out = t.render();
        assert!(out.lines().count() == 4, "{out:?}");
    }

    #[test]
    fn epoch_seeds_are_distinct_and_anchored() {
        // epoch 0 keeps the historical seed (bench-number continuity)
        assert_eq!(epoch_seed(0), 7);
        let seeds: Vec<u64> = (0..16).map(epoch_seed).collect();
        for i in 0..seeds.len() {
            for j in (i + 1)..seeds.len() {
                assert_ne!(seeds[i], seeds[j], "epochs {i} and {j} share a data seed");
            }
        }
    }

    #[test]
    fn epoch_seeds_change_the_batch_stream() {
        let mut e0 = RandomProducer::new(8, 16, 4, epoch_seed(0));
        let mut e1 = RandomProducer::new(8, 16, 4, epoch_seed(1));
        let same = (0..8).all(|i| e0.sample(i).input == e1.sample(i).input);
        assert!(!same, "epoch 1 replays epoch 0's batches");
    }
}
