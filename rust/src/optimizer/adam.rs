//! Adam optimizer (two state slots: first/second moments).

use super::Optimizer;

pub struct Adam {
    lr: f32,
    b1: f32,
    b2: f32,
    eps: f32,
}

impl Adam {
    pub fn new(lr: f32, b1: f32, b2: f32, eps: f32) -> Self {
        Adam { lr, b1, b2, eps }
    }
}

impl Optimizer for Adam {
    fn name(&self) -> &'static str {
        "adam"
    }

    fn state_slots(&self) -> usize {
        2
    }

    fn apply(&self, w: &mut [f32], g: &[f32], states: &mut [&mut [f32]], iter: u64) {
        let t = iter.max(1) as i32;
        let bc1 = 1.0 - self.b1.powi(t);
        let bc2 = 1.0 - self.b2.powi(t);
        let (m, rest) = states.split_at_mut(1);
        let m = &mut m[0];
        let v = &mut rest[0];
        for i in 0..w.len() {
            m[i] = self.b1 * m[i] + (1.0 - self.b1) * g[i];
            v[i] = self.b2 * v[i] + (1.0 - self.b2) * g[i] * g[i];
            let mh = m[i] / bc1;
            let vh = v[i] / bc2;
            w[i] -= self.lr * mh / (vh.sqrt() + self.eps);
        }
    }

    fn learning_rate(&self) -> f32 {
        self.lr
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_step_magnitude() {
        // First Adam step moves by ~lr regardless of gradient scale.
        let o = Adam::new(0.001, 0.9, 0.999, 1e-8);
        for scale in [0.01f32, 1.0, 100.0] {
            let mut w = [0.0f32];
            let mut m = vec![0.0f32];
            let mut v = vec![0.0f32];
            o.apply(&mut w, &[scale], &mut [&mut m, &mut v], 1);
            assert!((w[0] + 0.001).abs() < 1e-5, "scale {scale}: {}", w[0]);
        }
    }

    #[test]
    fn converges_on_quadratic() {
        // minimize (w-3)^2
        let o = Adam::new(0.1, 0.9, 0.999, 1e-8);
        let mut w = [0.0f32];
        let mut m = vec![0.0f32];
        let mut v = vec![0.0f32];
        for t in 1..=500 {
            let g = 2.0 * (w[0] - 3.0);
            o.apply(&mut w, &[g], &mut [&mut m, &mut v], t);
        }
        assert!((w[0] - 3.0).abs() < 0.05, "{}", w[0]);
    }
}
