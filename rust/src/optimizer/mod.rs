//! Optimizers. Applied by the executor either per-layer (right after the
//! layer's compute-gradient step — the paper's default, which lets
//! gradient buffers die immediately) or deferred to iteration end (forced
//! by gradient clipping and by weight-shared/unrolled models, which need
//! gradient accumulation — paper §5.2, Tacotron2).

pub mod adam;
pub mod clip;
pub mod sgd;

pub use adam::Adam;
pub use clip::clip_global_norm;
pub use sgd::Sgd;

use crate::error::{Error, Result};
use crate::layers::Props;

/// An optimizer updates one weight from its gradient and per-weight state.
pub trait Optimizer: Send {
    fn name(&self) -> &'static str;
    /// Number of per-weight state tensors (same shape as the weight).
    fn state_slots(&self) -> usize;
    /// In-place update. `states` has exactly `state_slots()` entries.
    /// `iter` is the 1-based apply count (Adam bias correction).
    fn apply(&self, w: &mut [f32], g: &[f32], states: &mut [&mut [f32]], iter: u64);
    fn learning_rate(&self) -> f32;
}

/// Build an optimizer from properties (`optimizer = sgd|adam`).
pub fn create(kind: &str, props: &Props) -> Result<Box<dyn Optimizer>> {
    match kind.trim().to_ascii_lowercase().as_str() {
        "sgd" => Ok(Box::new(Sgd::new(
            props.f32_or("learning_rate", 1e-2)?,
            props.f32_or("momentum", 0.0)?,
        ))),
        "adam" => Ok(Box::new(Adam::new(
            props.f32_or("learning_rate", 1e-3)?,
            props.f32_or("beta1", 0.9)?,
            props.f32_or("beta2", 0.999)?,
            props.f32_or("epsilon", 1e-8)?,
        ))),
        other => Err(Error::model(format!("unknown optimizer `{other}`"))),
    }
}
