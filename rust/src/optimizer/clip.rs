//! Global-norm gradient clipping (paper §5.2: supported for the
//! Tacotron2 decoder; forces deferred gradient application because the
//! norm spans every gradient of the model).

/// Scale all gradients so their global L2 norm is at most `max_norm`.
/// Returns the pre-clip norm.
pub fn clip_global_norm(grads: &mut [&mut [f32]], max_norm: f32) -> f32 {
    let mut sq = 0f64;
    for g in grads.iter() {
        for &v in g.iter() {
            sq += (v as f64) * (v as f64);
        }
    }
    let norm = sq.sqrt() as f32;
    if norm > max_norm && norm > 0.0 {
        let scale = max_norm / norm;
        for g in grads.iter_mut() {
            for v in g.iter_mut() {
                *v *= scale;
            }
        }
    }
    norm
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clips_when_over() {
        let mut a = vec![3.0f32, 0.0];
        let mut b = vec![0.0f32, 4.0];
        let n = clip_global_norm(&mut [&mut a, &mut b], 1.0);
        assert!((n - 5.0).abs() < 1e-6);
        let new_norm: f32 = (a.iter().chain(b.iter()).map(|v| v * v).sum::<f32>()).sqrt();
        assert!((new_norm - 1.0).abs() < 1e-5);
    }

    #[test]
    fn no_clip_when_under() {
        let mut a = vec![0.3f32];
        clip_global_norm(&mut [&mut a], 1.0);
        assert_eq!(a[0], 0.3);
    }
}
