//! Stochastic gradient descent, optional momentum.

use super::Optimizer;

pub struct Sgd {
    lr: f32,
    momentum: f32,
}

impl Sgd {
    pub fn new(lr: f32, momentum: f32) -> Self {
        Sgd { lr, momentum }
    }
}

impl Optimizer for Sgd {
    fn name(&self) -> &'static str {
        "sgd"
    }

    fn state_slots(&self) -> usize {
        if self.momentum > 0.0 {
            1
        } else {
            0
        }
    }

    fn apply(&self, w: &mut [f32], g: &[f32], states: &mut [&mut [f32]], _iter: u64) {
        if self.momentum > 0.0 {
            let v = &mut states[0];
            for i in 0..w.len() {
                v[i] = self.momentum * v[i] + g[i];
                w[i] -= self.lr * v[i];
            }
        } else {
            for i in 0..w.len() {
                w[i] -= self.lr * g[i];
            }
        }
    }

    fn learning_rate(&self) -> f32 {
        self.lr
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plain_step() {
        let o = Sgd::new(0.1, 0.0);
        let mut w = [1.0f32, 2.0];
        o.apply(&mut w, &[1.0, -1.0], &mut [], 1);
        assert_eq!(w, [0.9, 2.1]);
        assert_eq!(o.state_slots(), 0);
    }

    #[test]
    fn momentum_accumulates() {
        let o = Sgd::new(0.1, 0.9);
        assert_eq!(o.state_slots(), 1);
        let mut w = [0.0f32];
        let mut v = vec![0.0f32];
        o.apply(&mut w, &[1.0], &mut [&mut v], 1);
        assert!((w[0] + 0.1).abs() < 1e-6);
        o.apply(&mut w, &[1.0], &mut [&mut v], 2);
        // v = 0.9*1 + 1 = 1.9; w = -0.1 - 0.19
        assert!((w[0] + 0.29).abs() < 1e-6);
    }
}
