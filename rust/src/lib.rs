//! # nntrainer-rs
//!
//! A Rust + JAX + Pallas reproduction of **NNTrainer** (Moon et al.,
//! Samsung Research): a light-weight on-device training framework whose
//! core contribution is execution-order-based memory planning — tensor
//! lifespans + create modes (Tables 2–3), EO assignment with in-place
//! view merging (Algorithm 1), and a pool planner (Algorithm 2) that
//! makes peak training memory known *before* execution.
//!
//! Architecture (see DESIGN.md):
//! * **L3** — this crate: the coordinator/framework (graph compiler,
//!   realizers, planners, executor, data pipeline, and the
//!   lifecycle-staged session API: `Session::describe → configure →
//!   compile_for → CompiledSession::{train, infer, personalize}`).
//! * **L2/L1** — `python/compile`: JAX train-step + Pallas kernels,
//!   AOT-lowered once to `artifacts/*.hlo.txt`.
//! * **runtime** — loads those artifacts via PJRT (`xla` crate); Python
//!   never runs on the training path.

// CI runs `cargo clippy -- -D warnings`. Structural/style lints that the
// paper-faithful layout trips wholesale (module named like its parent,
// EO-indexed step loops that also mutate `self`, arg-heavy constructors
// mirroring Algorithm-1 inputs) are opted out here once; correctness
// lints stay denying.
#![allow(
    clippy::module_inception,
    clippy::needless_range_loop,
    clippy::new_without_default,
    clippy::len_without_is_empty,
    clippy::type_complexity,
    clippy::too_many_arguments,
    clippy::comparison_chain,
    clippy::ptr_arg,
    clippy::manual_memcpy,
    clippy::collapsible_if,
    clippy::collapsible_else_if
)]

pub mod backend;
pub mod bench_report;
pub mod bench_util;
pub mod dataset;
pub mod compiler;
pub mod error;
pub mod exec;
pub mod fleet;
pub mod graph;
pub mod layers;
pub mod metrics;
pub mod model;
pub mod optimizer;
pub mod planner;
pub mod rng;
pub mod runtime;
pub mod tensor;

pub use error::{Error, Result};
