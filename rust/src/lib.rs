//! # nntrainer-rs
//!
//! A Rust + JAX + Pallas reproduction of **NNTrainer** (Moon et al.,
//! Samsung Research): a light-weight on-device training framework whose
//! core contribution is execution-order-based memory planning — tensor
//! lifespans + create modes (Tables 2–3), EO assignment with in-place
//! view merging (Algorithm 1), and a pool planner (Algorithm 2) that
//! makes peak training memory known *before* execution.
//!
//! Architecture (see DESIGN.md):
//! * **L3** — this crate: the coordinator/framework (graph compiler,
//!   realizers, planners, executor, data pipeline, model API).
//! * **L2/L1** — `python/compile`: JAX train-step + Pallas kernels,
//!   AOT-lowered once to `artifacts/*.hlo.txt`.
//! * **runtime** — loads those artifacts via PJRT (`xla` crate); Python
//!   never runs on the training path.

pub mod backend;
pub mod bench_util;
pub mod dataset;
pub mod compiler;
pub mod error;
pub mod exec;
pub mod graph;
pub mod layers;
pub mod metrics;
pub mod model;
pub mod optimizer;
pub mod planner;
pub mod rng;
pub mod runtime;
pub mod tensor;

pub use error::{Error, Result};
