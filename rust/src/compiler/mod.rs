//! Graph compiler: realizers (Table 1) + the compile pipeline that takes
//! a description-level node list to a planned, executable model.

pub mod realizer;
pub mod unroll;

use std::collections::HashMap;

use crate::error::Result;
use crate::exec::{init_graph, Executor, InitOptions};
use crate::graph::{Graph, NodeDesc};
use crate::layers::{builtin_factories, LayerFactory};
use crate::metrics::PlanReport;
use crate::optimizer::Optimizer;
use crate::planner::{validate::validate_merges, validate::validate_plan, PlannerKind};

/// Compile options — the knobs the evaluation sweeps.
#[derive(Clone, Debug)]
pub struct CompileOpts {
    pub batch: usize,
    pub training: bool,
    pub planner: PlannerKind,
    /// MV/RV in-place realization (ablation: `ablation_inplace`).
    pub inplace: bool,
    /// Conventional-framework allocation profile (Fig 9 baseline).
    pub conventional: bool,
    pub clip_norm: Option<f32>,
    pub seed: u64,
}

impl Default for CompileOpts {
    fn default() -> Self {
        CompileOpts {
            batch: 1,
            training: true,
            planner: PlannerKind::Sorting,
            inplace: true,
            conventional: false,
            clip_norm: None,
            seed: 42,
        }
    }
}

/// Run all default realizers, wire the graph, initialize (Algorithm 1),
/// plan memory (Algorithm 2 or selected planner), validate, and build the
/// executor.
pub fn compile(
    nodes: Vec<NodeDesc>,
    optimizer: Box<dyn Optimizer>,
    opts: &CompileOpts,
) -> Result<(Executor, PlanReport)> {
    compile_with(nodes, optimizer, opts, &builtin_factories())
}

/// Plan without allocating: run the full pipeline up to and including
/// memory planning and validation, but skip pool allocation and weight
/// init. Used by the memory benches (a conventional-profile VGG16 plan
/// describes gigabytes it never needs to touch).
pub fn plan_only(nodes: Vec<NodeDesc>, opts: &CompileOpts) -> Result<PlanReport> {
    let nodes = realizer::realize_all(nodes)?;
    let graph = Graph::wire(nodes)?;
    let init_opts = InitOptions {
        batch: opts.batch,
        training: opts.training,
        inplace: opts.inplace && !opts.conventional,
        conventional: opts.conventional,
        deferred_apply: opts.clip_norm.is_some(),
        opt_slots: 0,
    };
    let mut ig = init_graph(&graph, &builtin_factories(), &init_opts)?;
    let planner = opts.planner.instance();
    let pool_len = planner.plan(&mut ig.table)?;
    validate_plan(&ig.table, pool_len)?;
    validate_merges(&ig.table)?;
    Ok(PlanReport::from_table(&ig.table, pool_len, planner.name()))
}

/// `compile` with a custom layer registry (AppContext extensions).
pub fn compile_with(
    nodes: Vec<NodeDesc>,
    optimizer: Box<dyn Optimizer>,
    opts: &CompileOpts,
    factories: &HashMap<&'static str, LayerFactory>,
) -> Result<(Executor, PlanReport)> {
    let nodes = realizer::realize_all(nodes)?;
    let graph = Graph::wire(nodes)?;
    let init_opts = InitOptions {
        batch: opts.batch,
        training: opts.training,
        inplace: opts.inplace && !opts.conventional,
        conventional: opts.conventional,
        deferred_apply: opts.clip_norm.is_some(),
        opt_slots: optimizer.state_slots(),
    };
    let mut ig = init_graph(&graph, factories, &init_opts)?;
    let planner = opts.planner.instance();
    let pool_len = planner.plan(&mut ig.table)?;
    validate_plan(&ig.table, pool_len)?;
    validate_merges(&ig.table)?;
    let report = PlanReport::from_table(&ig.table, pool_len, planner.name());
    let exec = Executor::new(ig, pool_len, optimizer, opts.clip_norm, opts.training, opts.seed)?;
    Ok((exec, report))
}
