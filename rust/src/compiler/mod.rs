//! Graph compiler: realizers (Table 1) + the compile pipeline that takes
//! a description-level node list to a planned, executable model.

pub mod realizer;
pub mod unroll;

use std::collections::HashMap;

use crate::backend::ComputeKind;
use crate::error::Result;
use crate::exec::{init_graph, probe_init_graph, Executor, InitOptions, ShapeTemplate};
use crate::graph::{Graph, NodeDesc};
use crate::layers::{builtin_factories, LayerFactory};
use crate::metrics::PlanReport;
use crate::optimizer::Optimizer;
use crate::planner::{
    gapfit::{GapBestFitPlanner, GapFitPlanner, GapSkylinePlanner},
    offload, plan_compaction,
    validate::{validate_gap_plan, validate_merges, validate_plan},
    Planner, PlannerKind,
};
use crate::runtime::calibrate::{self, SwapCalibration, SwapTuning};
use crate::runtime::store::{SecondaryStore, StoreKind};
use crate::runtime::swap::SwapExec;
use crate::tensor::TensorTable;

/// Compile options — the knobs the evaluation sweeps.
#[derive(Clone, Debug)]
pub struct CompileOpts {
    pub batch: usize,
    pub training: bool,
    pub planner: PlannerKind,
    /// MV/RV in-place realization (ablation: `ablation_inplace`).
    pub inplace: bool,
    /// Conventional-framework allocation profile (Fig 9 baseline).
    pub conventional: bool,
    pub clip_norm: Option<f32>,
    pub seed: u64,
    /// Primary-memory budget in bytes. When set, the offload advisor
    /// plans idle-gap swaps, the gap-aware planner shrinks the pool
    /// accordingly, and the executor runs the proactive swap runtime.
    /// `planner` then selects the gap-aware *placement*: `BestFit` runs
    /// the best-fit hole search, anything else the first-fit default.
    ///
    /// The budget is a *target*, not a hard guarantee: when even maximal
    /// swapping cannot reach it, compile still succeeds with the best
    /// achievable pool — check `exec.swap_plan().unwrap().fits` and
    /// `Model::peak_pool_bytes()` (known before training) against the
    /// device limit, as `examples/batch_budget.rs` does.
    pub memory_budget_bytes: Option<usize>,
    /// Secondary store backing the swap runtime (host RAM or spill file).
    pub swap_store: StoreKind,
    /// How the swap runtime's prefetch leads/depth are chosen:
    /// `Fixed` keeps the PR-1 constants, `Calibrated` micro-benchmarks
    /// the store at compile time and derives per-entry leads
    /// (`runtime/calibrate.rs`). Only meaningful under a budget.
    pub swap_tuning: SwapTuning,
    /// Which compute backend executes the layer math. `Tiered` (the
    /// default) routes GEMMs through the cache-blocked, worker-pool
    /// backend and drops conv2d's materialized im2col temp; `Naive`
    /// keeps the original single-threaded free-function kernels as a
    /// bitwise regression baseline.
    pub compute: ComputeKind,
    /// Plan a one-shot pool compaction applied at the first epoch
    /// boundary (a swap-quiescent barrier): persistent tensors slide
    /// down into layout holes and the arena truncates to the compacted
    /// peak. Opt-in — callers that capture `Region` values at compile
    /// time (e.g. the fleet's weight-layout snapshots) must leave this
    /// off. Only meaningful under a memory budget.
    pub pool_compaction: bool,
    /// Cross-iteration swap pipelining: additionally spill persistent
    /// tensors (weights, optimizer state) across the iteration boundary.
    /// Their idle window wraps the schedule end — evicted after their
    /// last real access of iteration N, restored before their first of
    /// N+1 — so the boundary transfers overlap the adjacent iterations
    /// instead of draining at `end_iteration`. Only effective under
    /// per-layer apply (training without gradient clipping and without
    /// shared weights): deferred apply keeps every persistent tensor
    /// live to the schedule end, leaving no boundary window. Bitwise
    /// identical to the unswapped model either way. Opt-in; only
    /// meaningful under a memory budget.
    pub swap_pipeline: bool,
}

impl Default for CompileOpts {
    fn default() -> Self {
        CompileOpts {
            batch: 1,
            training: true,
            planner: PlannerKind::Sorting,
            inplace: true,
            conventional: false,
            clip_norm: None,
            seed: 42,
            memory_budget_bytes: None,
            swap_store: StoreKind::Host,
            swap_tuning: SwapTuning::Fixed,
            compute: ComputeKind::default(),
            pool_compaction: false,
            swap_pipeline: false,
        }
    }
}

/// Plan memory for an initialized table: either the selected plain
/// planner, or — under a memory budget — the offload advisor plus the
/// gap-aware planner. With `SwapTuning::Calibrated` and a store to
/// probe, the advisor's fixed leads are replaced by bandwidth-derived
/// per-entry leads *before* placement, so the pool layout reserves
/// exactly the residency the runtime will use. Returns the pool length
/// (f32 elements), the name of the planner that ran, the offload plan,
/// and the calibration state for the swap runtime.
///
/// Probe-only callers ([`plan_with`], the auto-batch search) pass no
/// store and plan with fixed leads: calibration is a measurement, so
/// budget probes stay cheap and deterministic. The realized pool of a
/// calibrated compile can therefore exceed a probe's estimate by the
/// widened-lead residency — the budget remains a target, not a bound.
fn plan_memory(
    table: &mut TensorTable,
    opts: &CompileOpts,
    store: Option<&mut dyn SecondaryStore>,
) -> Result<(usize, &'static str, Option<offload::OffloadPlan>, Option<SwapCalibration>)> {
    match opts.memory_budget_bytes {
        Some(budget) => {
            let mut plan = offload::advise(table, budget);
            if opts.swap_pipeline {
                // Boundary pass: wrap entries for persistent tensors
                // whose true access window the assembler annotated
                // (`boundary_window` — absent under deferred apply, so
                // this is a structural no-op there). Runs before
                // calibration so wrap leads get bandwidth-derived too.
                offload::advise_boundary(table, &mut plan, budget);
            }
            let calibration = match (opts.swap_tuning, store) {
                (SwapTuning::Calibrated, Some(store)) if !plan.entries.is_empty() => {
                    let probe_len =
                        plan.entries.iter().map(|e| e.bytes / 4).max().unwrap_or(1 << 12);
                    let store_cal = calibrate::probe_store(store, probe_len)?;
                    let cost =
                        calibrate::EoCostModel::from_table(table, &calibrate::probe_compute());
                    calibrate::derive_leads(&mut plan, table, budget, &store_cal, &cost);
                    Some(SwapCalibration::new(store_cal, cost))
                }
                _ => None,
            };
            let (pool_len, name) = match opts.planner {
                PlannerKind::Skyline => {
                    let placer = GapSkylinePlanner { plan: &plan };
                    (Planner::plan(&placer, table)?, "gapfit-skyline")
                }
                PlannerKind::BestFit => {
                    let placer = GapBestFitPlanner { plan: &plan };
                    (Planner::plan(&placer, table)?, "gapfit-bestfit")
                }
                _ => {
                    let placer = GapFitPlanner { plan: &plan };
                    (Planner::plan(&placer, table)?, "gapfit")
                }
            };
            validate_gap_plan(table, &plan, pool_len)?;
            validate_merges(table)?;
            Ok((pool_len, name, Some(plan), calibration))
        }
        None => {
            let planner = opts.planner.instance();
            let pool_len = planner.plan(table)?;
            validate_plan(table, pool_len)?;
            validate_merges(table)?;
            Ok((pool_len, planner.name(), None, None))
        }
    }
}

/// Run all default realizers, wire the graph, initialize (Algorithm 1),
/// plan memory (Algorithm 2 or selected planner), validate, and build the
/// executor.
pub fn compile(
    nodes: Vec<NodeDesc>,
    optimizer: Box<dyn Optimizer>,
    opts: &CompileOpts,
) -> Result<(Executor, PlanReport)> {
    compile_with(nodes, optimizer, opts, &builtin_factories())
}

/// Realize + wire once — the batch-independent half of compilation,
/// shared by every auto-batch probe and the final compile
/// ([`plan_graph`] / [`compile_graph`] consume the result).
pub fn analyze(nodes: Vec<NodeDesc>) -> Result<Graph> {
    Graph::wire(realizer::realize_all(nodes)?)
}

fn init_opts_of(opts: &CompileOpts, opt_slots: usize) -> InitOptions {
    InitOptions {
        batch: opts.batch,
        training: opts.training,
        inplace: opts.inplace && !opts.conventional,
        conventional: opts.conventional,
        deferred_apply: opts.clip_norm.is_some(),
        opt_slots,
        compute: opts.compute,
    }
}

/// Plan without allocating: run the full pipeline up to and including
/// memory planning and validation, but skip pool allocation and weight
/// init. Used by the memory benches (a conventional-profile VGG16 plan
/// describes gigabytes it never needs to touch).
pub fn plan_only(nodes: Vec<NodeDesc>, opts: &CompileOpts) -> Result<PlanReport> {
    plan_with(nodes, opts, &builtin_factories(), 0)
}

/// [`plan_only`] with a custom layer registry and an optimizer
/// state-slot count. The session auto-batch search probes candidate
/// batches with the *exact* tensor population the real compile will plan
/// — optimizer state included, which `plan_only` (kept bench-compatible)
/// omits.
pub fn plan_with(
    nodes: Vec<NodeDesc>,
    opts: &CompileOpts,
    factories: &HashMap<&'static str, LayerFactory>,
    opt_slots: usize,
) -> Result<PlanReport> {
    let graph = analyze(nodes)?;
    plan_graph(&graph, opts, factories, opt_slots, None)
}

/// [`plan_with`] over a pre-wired graph, optionally through a memoized
/// [`ShapeTemplate`]: the auto-batch search realizes/wires/finalizes
/// once and probes candidate batches by dim substitution.
pub fn plan_graph(
    graph: &Graph,
    opts: &CompileOpts,
    factories: &HashMap<&'static str, LayerFactory>,
    opt_slots: usize,
    template: Option<&ShapeTemplate>,
) -> Result<PlanReport> {
    let init_opts = init_opts_of(opts, opt_slots);
    let mut ig = match template {
        Some(t) => probe_init_graph(graph, t, &init_opts)?,
        None => init_graph(graph, factories, &init_opts)?,
    };
    let (pool_len, planner_name, _plan, _cal) = plan_memory(&mut ig.table, opts, None)?;
    Ok(PlanReport::from_table(&ig.table, pool_len, planner_name))
}

/// `compile` with a custom layer registry (AppContext extensions).
pub fn compile_with(
    nodes: Vec<NodeDesc>,
    optimizer: Box<dyn Optimizer>,
    opts: &CompileOpts,
    factories: &HashMap<&'static str, LayerFactory>,
) -> Result<(Executor, PlanReport)> {
    let graph = analyze(nodes)?;
    compile_graph(&graph, optimizer, opts, factories)
}

/// [`compile_with`] over a pre-wired graph (the session's auto-batch
/// path compiles the same graph it probed).
pub fn compile_graph(
    graph: &Graph,
    optimizer: Box<dyn Optimizer>,
    opts: &CompileOpts,
    factories: &HashMap<&'static str, LayerFactory>,
) -> Result<(Executor, PlanReport)> {
    let init_opts = init_opts_of(opts, optimizer.state_slots());
    let mut ig = init_graph(graph, factories, &init_opts)?;
    // the store is created before planning so Calibrated tuning can
    // probe the very instance the runtime will swap through
    let mut store = match opts.memory_budget_bytes {
        Some(_) => Some(opts.swap_store.instance()?),
        None => None,
    };
    let (pool_len, planner_name, plan, calibration) =
        plan_memory(&mut ig.table, opts, store.as_mut().map(|s| s.as_mut()))?;
    let report = PlanReport::from_table(&ig.table, pool_len, planner_name);
    let swap = match (plan, store) {
        (Some(plan), Some(store)) => {
            let mut sw = SwapExec::new(&ig.table, &plan, store, calibration)?;
            sw.refresh_frag(&ig.table, pool_len);
            if opts.pool_compaction {
                if let Some(cp) = plan_compaction(&ig.table, &plan, pool_len) {
                    sw.set_compaction(cp);
                }
            }
            Some(sw)
        }
        _ => None,
    };
    let exec = Executor::new(
        ig,
        pool_len,
        optimizer,
        opts.clip_norm,
        opts.training,
        opts.seed,
        swap,
        opts.compute.instance(),
    )?;
    Ok((exec, report))
}
