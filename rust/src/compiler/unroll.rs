//! Recurrent realizer (Table 1 "Recurrent: unroll the graph if there is
//! a loop").
//!
//! Time iteration (the Tacotron2 decoder, paper §5.2) is implemented by
//! unrolling: the step sub-graph is cloned once per timestep; clones
//! share weights *and* gradients with the step-0 instance via the `E`
//! (Extend) create mode (`shared_from` property), so unrolled weights add
//! no memory and gradients accumulate across timesteps (deferred apply).

use crate::error::{Error, Result};
use crate::graph::NodeDesc;

/// Description of a recurrence to unroll.
#[derive(Clone, Debug)]
pub struct UnrollSpec {
    /// Number of timesteps.
    pub t: usize,
    /// Edges fed back across timesteps: (producer-in-step, input-name) —
    /// a step-`k` reference to `input-name` becomes the step-`k−1` output
    /// of `producer-in-step`; at step 0 it stays wired to the original
    /// (initial-state) node outside the loop.
    pub recurrent: Vec<(String, String)>,
}

/// Clone `step` T times with `@t<k>` name suffixes, rewiring in-step
/// references, recurrent edges and collecting the final-step outputs.
///
/// Nodes in `step` must reference either other step nodes or external
/// nodes (left untouched).
pub fn unroll(step: &[NodeDesc], spec: &UnrollSpec) -> Result<Vec<NodeDesc>> {
    if spec.t == 0 {
        return Err(Error::graph("unroll with t=0"));
    }
    let step_names: Vec<&str> = step.iter().map(|n| n.name.as_str()).collect();
    let mut out = Vec::with_capacity(step.len() * spec.t);
    for k in 0..spec.t {
        for n in step {
            let mut c = n.clone();
            c.name = at(&n.name, k);
            if k > 0 {
                // share weights + gradients with step 0 (E mode)
                c.props.set("shared_from", at(&n.name, 0));
            }
            let refs = n.input_refs();
            if !refs.is_empty() {
                let rewired: Vec<String> = refs
                    .iter()
                    .map(|r| {
                        let (name, suffix) = split_ref(r);
                        // recurrent edge?
                        if let Some((prod, _)) =
                            spec.recurrent.iter().find(|(_, inp)| *inp == name)
                        {
                            if k == 0 {
                                // initial state: keep original reference
                                format!("{name}{suffix}")
                            } else {
                                format!("{}{suffix}", at(prod, k - 1))
                            }
                        } else if step_names.contains(&name.as_str()) {
                            format!("{}{suffix}", at(&name, k))
                        } else {
                            // external (encoder memory etc.) — BUT an
                            // external tensor consumed by every timestep
                            // would need a multiout fan-out; the caller's
                            // realizer chain handles that.
                            format!("{name}{suffix}")
                        }
                    })
                    .collect();
                c.props.set("input_layers", rewired.join(","));
            }
            out.push(c);
        }
    }
    Ok(out)
}

/// Name of node `base` at timestep `k`.
pub fn at(base: &str, k: usize) -> String {
    format!("{base}@t{k}")
}

fn split_ref(r: &str) -> (String, String) {
    match r.find('(') {
        Some(p) => (r[..p].trim().to_string(), r[p..].to_string()),
        None => (r.trim().to_string(), String::new()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::layers::Props;

    #[test]
    fn unrolls_and_shares() {
        let step = vec![
            NodeDesc::new(
                "cell",
                "fully_connected",
                Props::from_pairs([("unit", "4"), ("input_layers", "state")]),
            ),
            NodeDesc::new(
                "state",
                "activation",
                Props::from_pairs([("act", "tanh"), ("input_layers", "cell")]),
            ),
        ];
        let spec = UnrollSpec {
            t: 3,
            recurrent: vec![("state".into(), "state".into())],
        };
        let out = unroll(&step, &spec).unwrap();
        assert_eq!(out.len(), 6);
        // step 0 keeps initial-state reference
        assert_eq!(out[0].props.list("input_layers"), vec!["state"]);
        assert!(!out[0].props.contains("shared_from"));
        // step 1 cell consumes step 0 state, shares from step 0
        assert_eq!(out[2].name, "cell@t1");
        assert_eq!(out[2].props.list("input_layers"), vec!["state@t0"]);
        assert_eq!(out[2].props.string("shared_from").unwrap(), "cell@t0");
        // in-step (non-recurrent) edges rewired within the same step
        assert_eq!(out[3].props.list("input_layers"), vec!["cell@t1"]);
    }
}
