//! Realizers (paper Table 1): description-level graph lowerings that run
//! before wiring. Each realizer rewrites the node list — inserting,
//! removing or re-typing nodes — so the initializer only ever sees
//! primitive layers.

use std::collections::HashMap;

use crate::error::{Error, Result};
use crate::graph::NodeDesc;
use crate::layers::Props;

/// Run the default realizer chain in the canonical order.
pub fn realize_all(nodes: Vec<NodeDesc>) -> Result<Vec<NodeDesc>> {
    let nodes = input_realizer(nodes)?;
    let nodes = batchnorm_realizer(nodes)?;
    let nodes = activation_realizer(nodes)?;
    let nodes = flatten_realizer(nodes)?;
    let nodes = loss_realizer(nodes)?;
    let nodes = multiout_realizer(nodes)?;
    Ok(nodes)
}

/// Rewire every reference to `old` so it points at `new` (for nodes after
/// index `from`).
fn rewire(nodes: &mut [NodeDesc], from: usize, old: &str, new: &str) {
    for n in nodes[from..].iter_mut() {
        let refs = n.props.list("input_layers");
        if refs.is_empty() {
            continue;
        }
        let rewired: Vec<String> = refs
            .into_iter()
            .map(|r| {
                let (name, suffix) = match r.find('(') {
                    Some(p) => (r[..p].trim().to_string(), r[p..].to_string()),
                    None => (r.trim().to_string(), String::new()),
                };
                if name == old {
                    format!("{new}{suffix}")
                } else {
                    format!("{name}{suffix}")
                }
            })
            .collect();
        n.props.set("input_layers", rewired.join(","));
    }
}

/// Input realizer: a non-input first layer carrying `input_shape` gets an
/// explicit input node in front of it.
pub fn input_realizer(mut nodes: Vec<NodeDesc>) -> Result<Vec<NodeDesc>> {
    let mut out = Vec::with_capacity(nodes.len() + 1);
    for (i, mut n) in nodes.drain(..).enumerate() {
        if n.ltype != "input" && n.props.contains("input_shape") && n.input_refs().is_empty() {
            let iname = format!("{}/input", n.name);
            let mut p = Props::new();
            p.set("input_shape", n.props.get("input_shape").unwrap());
            out.push(NodeDesc::new(iname.clone(), "input", p));
            n.props.set("input_layers", iname);
            let _ = i;
        }
        out.push(n);
    }
    Ok(out)
}

/// Activation realizer: `activation = relu` on a compute layer splits
/// into a dedicated activation node right after it.
pub fn activation_realizer(nodes: Vec<NodeDesc>) -> Result<Vec<NodeDesc>> {
    insert_after_realizer(nodes, "activation", |orig, act| {
        let mut p = Props::new();
        p.set("act", act);
        p.set("input_layers", orig.to_string());
        ("activation", p)
    })
}

/// BatchNorm realizer: `batch_normalization = true` inserts a BN node
/// after the layer (before any activation split, which runs later).
pub fn batchnorm_realizer(nodes: Vec<NodeDesc>) -> Result<Vec<NodeDesc>> {
    let mut out: Vec<NodeDesc> = Vec::with_capacity(nodes.len());
    let mut pending_rewires: Vec<(usize, String, String)> = Vec::new();
    for mut n in nodes {
        if n.props.bool_or("batch_normalization", false)? {
            n.props.set("batch_normalization", "false");
            let bn_name = format!("{}/bn", n.name);
            let orig = n.name.clone();
            out.push(n);
            let at = out.len();
            let mut p = Props::new();
            p.set("input_layers", orig.clone());
            out.push(NodeDesc::new(bn_name.clone(), "batch_normalization", p));
            pending_rewires.push((at + 1, orig, bn_name));
        } else {
            out.push(n);
        }
    }
    for (from, old, new) in pending_rewires {
        if from <= out.len() {
            rewire(&mut out, from, &old, &new);
        }
    }
    Ok(out)
}

/// Flatten realizer: `flatten = true` inserts a flatten node after.
pub fn flatten_realizer(nodes: Vec<NodeDesc>) -> Result<Vec<NodeDesc>> {
    insert_after_realizer(nodes, "flatten", |orig, v| {
        let mut p = Props::new();
        p.set("input_layers", orig.to_string());
        let _ = v;
        ("flatten", p)
    })
}

fn insert_after_realizer(
    nodes: Vec<NodeDesc>,
    key: &str,
    make: impl Fn(&str, &str) -> (&'static str, Props),
) -> Result<Vec<NodeDesc>> {
    let mut out: Vec<NodeDesc> = Vec::with_capacity(nodes.len());
    let mut rewires: Vec<(usize, String, String)> = Vec::new();
    for mut n in nodes {
        let val = n.props.string(key);
        let insert = match (key, &val) {
            ("flatten", Some(v)) => v == "true" || v == "1",
            (_, Some(v)) => !v.is_empty() && v != "none",
            (_, None) => false,
        };
        if insert {
            let v = val.unwrap();
            n.props.set(key, "none");
            let orig = n.name.clone();
            let new_name = format!("{}/{}", orig, key);
            out.push(n);
            let at = out.len();
            let (ltype, props) = make(&orig, &v);
            out.push(NodeDesc::new(new_name.clone(), ltype, props));
            rewires.push((at + 1, orig, new_name));
        } else {
            out.push(n);
        }
    }
    for (from, old, new) in rewires {
        if from <= out.len() {
            rewire(&mut out, from, &old, &new);
        }
    }
    Ok(out)
}

/// Loss realizer (paper: "If loss is cross entropy, remove the
/// activation"): a `cross_entropy` loss preceded by a softmax activation
/// absorbs it into the fused `cross_entropy_softmax` layer. A plain
/// `cross_entropy` with no preceding softmax is promoted to the fused
/// layer as well.
pub fn loss_realizer(mut nodes: Vec<NodeDesc>) -> Result<Vec<NodeDesc>> {
    // find cross_entropy nodes
    let mut i = 0;
    while i < nodes.len() {
        if nodes[i].ltype == "cross_entropy" || nodes[i].ltype == "cross_entropy_softmax" {
            nodes[i].ltype = "cross_entropy_softmax".into();
            // producer of the loss
            let refs = resolved_inputs(&nodes, i)?;
            if let Some(pname) = refs.first() {
                if let Some(p) = nodes.iter().position(|n| &n.name == pname) {
                    let is_softmax = nodes[p].ltype == "activation"
                        && nodes[p].props.string("act").as_deref() == Some("softmax");
                    if is_softmax {
                        // rewire loss to softmax's producer, drop softmax
                        let grand = resolved_inputs(&nodes, p)?;
                        let g = grand
                            .first()
                            .ok_or_else(|| Error::graph("softmax with no producer"))?
                            .clone();
                        nodes[i].props.set("input_layers", g);
                        nodes.remove(p);
                        continue; // re-check same index (shifted)
                    }
                }
            }
        }
        i += 1;
    }
    Ok(nodes)
}

fn resolved_inputs(nodes: &[NodeDesc], i: usize) -> Result<Vec<String>> {
    let refs = nodes[i].input_refs();
    if !refs.is_empty() {
        return Ok(refs
            .into_iter()
            .map(|r| r.split('(').next().unwrap().trim().to_string())
            .collect());
    }
    if i == 0 {
        return Err(Error::graph(format!("`{}` has no inputs", nodes[i].name)));
    }
    Ok(vec![nodes[i - 1].name.clone()])
}

/// Multi-Out realizer: any output slot consumed by more than one layer
/// gets an explicit `multiout` fan-out node.
pub fn multiout_realizer(mut nodes: Vec<NodeDesc>) -> Result<Vec<NodeDesc>> {
    loop {
        // count consumers per (producer name, slot)
        let mut consumers: HashMap<String, Vec<usize>> = HashMap::new();
        for i in 0..nodes.len() {
            for r in resolved_inputs_full(&nodes, i) {
                consumers.entry(r).or_default().push(i);
            }
        }
        let mut victim: Option<(String, Vec<usize>)> = None;
        for (k, v) in &consumers {
            let pname = k.split('(').next().unwrap();
            let is_multiout = nodes
                .iter()
                .find(|n| n.name == pname)
                .map(|n| n.ltype == "multiout")
                .unwrap_or(false);
            if v.len() > 1 && !is_multiout {
                victim = Some((k.clone(), v.clone()));
                break;
            }
        }
        let Some((pref, users)) = victim else { break };
        let pname = pref.split('(').next().unwrap().to_string();
        let pidx = nodes
            .iter()
            .position(|n| n.name == pname)
            .ok_or_else(|| Error::graph(format!("unknown producer `{pname}`")))?;
        let mo_name = format!("{}/multiout", pname);
        let mut p = Props::new();
        p.set("outputs", users.len().to_string());
        p.set("input_layers", pref.clone());
        // insert right after producer; fix consumer refs with slots
        nodes.insert(pidx + 1, NodeDesc::new(mo_name.clone(), "multiout", p));
        let mut slot = 0usize;
        for i in 0..nodes.len() {
            if i == pidx + 1 {
                continue; // the multiout node itself
            }
            let refs = nodes[i].input_refs();
            if refs.is_empty() {
                // implicit chaining: materialize it so rewiring is explicit
                if i > 0 && nodes[i].ltype != "input" {
                    let prev = nodes[i - 1].name.clone();
                    nodes[i].props.set("input_layers", prev);
                } else {
                    continue;
                }
            }
            let refs = nodes[i].input_refs();
            let mut changed = false;
            let new_refs: Vec<String> = refs
                .into_iter()
                .map(|r| {
                    if r == pref || (r == pname && pref == pname) {
                        changed = true;
                        let s = format!("{mo_name}({slot})");
                        slot += 1;
                        s
                    } else {
                        r
                    }
                })
                .collect();
            if changed {
                nodes[i].props.set("input_layers", new_refs.join(","));
            }
        }
    }
    Ok(nodes)
}

fn resolved_inputs_full(nodes: &[NodeDesc], i: usize) -> Vec<String> {
    let refs = nodes[i].input_refs();
    if !refs.is_empty() {
        return refs;
    }
    if i == 0 || nodes[i].ltype == "input" {
        return vec![];
    }
    vec![nodes[i - 1].name.clone()]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn node(name: &str, ltype: &str, pairs: &[(&str, &str)]) -> NodeDesc {
        NodeDesc::new(name, ltype, Props::from_pairs(pairs.iter().copied()))
    }

    #[test]
    fn activation_split() {
        let out = activation_realizer(vec![
            node("in", "input", &[("input_shape", "1:1:4")]),
            node("fc", "fully_connected", &[("unit", "3"), ("activation", "relu")]),
            node("loss", "mse", &[("input_layers", "fc")]),
        ])
        .unwrap();
        assert_eq!(out.len(), 4);
        assert_eq!(out[2].ltype, "activation");
        assert_eq!(out[2].props.get("act"), Some("relu"));
        // loss rewired to the activation node
        assert_eq!(out[3].props.list("input_layers"), vec!["fc/activation"]);
    }

    #[test]
    fn input_materialization() {
        let out = input_realizer(vec![node(
            "fc",
            "fully_connected",
            &[("unit", "3"), ("input_shape", "1:1:8")],
        )])
        .unwrap();
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].ltype, "input");
        assert_eq!(out[1].props.list("input_layers"), vec!["fc/input"]);
    }

    #[test]
    fn loss_absorbs_softmax() {
        let out = loss_realizer(vec![
            node("in", "input", &[("input_shape", "1:1:4")]),
            node("fc", "fully_connected", &[("unit", "3")]),
            node("sm", "activation", &[("act", "softmax"), ("input_layers", "fc")]),
            node("loss", "cross_entropy", &[("input_layers", "sm")]),
        ])
        .unwrap();
        assert_eq!(out.len(), 3);
        assert_eq!(out[2].ltype, "cross_entropy_softmax");
        assert_eq!(out[2].props.list("input_layers"), vec!["fc"]);
    }

    #[test]
    fn multiout_fanout() {
        let out = multiout_realizer(vec![
            node("in", "input", &[("input_shape", "1:1:4")]),
            node("a", "fully_connected", &[("unit", "3"), ("input_layers", "in")]),
            node("b", "fully_connected", &[("unit", "3"), ("input_layers", "in")]),
            node("add", "addition", &[("input_layers", "a,b")]),
        ])
        .unwrap();
        assert_eq!(out[1].ltype, "multiout");
        assert_eq!(out[2].props.list("input_layers"), vec!["in/multiout(0)"]);
        assert_eq!(out[3].props.list("input_layers"), vec!["in/multiout(1)"]);
    }
}
