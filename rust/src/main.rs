//! `nntrainer` CLI — the leader entrypoint.
//!
//! ```text
//! nntrainer plan  <model.ini> [--batch N] [--planner sorting|naive|bestfit] [--conventional] [--table]
//! nntrainer train <model.ini> [--batch N] [--epochs N] [--save ckpt.bin] [--data digits|random]
//! nntrainer zoo                              # list built-in evaluation models
//! nntrainer artifacts [--dir artifacts]      # check + smoke the PJRT artifact catalog
//! ```

use std::process::ExitCode;

use nntrainer::compiler::CompileOpts;
use nntrainer::dataset::{DataProducer, DigitsProducer, RandomProducer};
use nntrainer::metrics::MIB;
use nntrainer::model::{ini, TrainConfig};
use nntrainer::planner::PlannerKind;
use nntrainer::runtime::catalog::ArtifactCatalog;
use nntrainer::runtime::XlaRuntime;

fn usage() -> ExitCode {
    eprintln!(
        "usage:\n  nntrainer plan  <model.ini> [--batch N] [--planner P] [--conventional] [--table]\n  \
         nntrainer train <model.ini> [--batch N] [--epochs N] [--save F] [--data digits|random]\n  \
         nntrainer zoo\n  nntrainer artifacts [--dir D]"
    );
    ExitCode::from(2)
}

struct Args {
    rest: Vec<String>,
}

impl Args {
    fn flag(&self, name: &str) -> bool {
        self.rest.iter().any(|a| a == name)
    }
    fn opt(&self, name: &str) -> Option<String> {
        self.rest
            .iter()
            .position(|a| a == name)
            .and_then(|p| self.rest.get(p + 1).cloned())
    }
}

fn main() -> ExitCode {
    let mut argv = std::env::args().skip(1);
    let Some(cmd) = argv.next() else { return usage() };
    let rest: Vec<String> = argv.collect();
    let args = Args { rest };
    let r = match cmd.as_str() {
        "plan" => cmd_plan(&args),
        "train" => cmd_train(&args),
        "zoo" => cmd_zoo(),
        "artifacts" => cmd_artifacts(&args),
        _ => return usage(),
    };
    match r {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}

fn compile_opts(args: &Args, default_batch: usize) -> nntrainer::Result<CompileOpts> {
    let planner = match args.opt("--planner") {
        Some(p) => PlannerKind::parse(&p)
            .ok_or_else(|| nntrainer::Error::model(format!("unknown planner `{p}`")))?,
        None => PlannerKind::Sorting,
    };
    let conventional = args.flag("--conventional");
    Ok(CompileOpts {
        batch: args
            .opt("--batch")
            .map(|b| b.parse().unwrap_or(default_batch))
            .unwrap_or(default_batch),
        planner,
        conventional,
        inplace: !conventional,
        ..Default::default()
    })
}

fn cmd_plan(args: &Args) -> nntrainer::Result<()> {
    let path = args
        .rest
        .first()
        .ok_or_else(|| nntrainer::Error::model("plan: missing model.ini"))?;
    let (builder, hyper) = ini::builder_from_file(path)?;
    let opts = compile_opts(args, hyper.batch)?;
    let model = builder.compile(&opts)?;
    let rep = &model.report;
    println!("model:        {path}");
    println!("planner:      {} (conventional profile: {})", rep.planner, opts.conventional);
    println!("batch:        {}", opts.batch);
    println!("peak pool:    {:.3} MiB  <- known before execution", rep.pool_mib());
    println!("ideal bound:  {:.3} MiB  (planner overhead x{:.3})", rep.ideal_mib(), rep.overhead());
    println!("no-reuse sum: {:.3} MiB", rep.total_bytes as f64 / MIB);
    println!("tensors:      {} allocated, {} merged (MV/RV/E)", rep.n_tensors, rep.n_merged);
    let mut roles: Vec<_> = rep.by_role.iter().collect();
    roles.sort();
    for (role, bytes) in roles {
        println!("  {role:<8} {:>10.3} MiB", *bytes as f64 / MIB);
    }
    if args.flag("--table") {
        println!("{}", model.exec.graph.table);
    }
    Ok(())
}

fn cmd_train(args: &Args) -> nntrainer::Result<()> {
    let path = args
        .rest
        .first()
        .ok_or_else(|| nntrainer::Error::model("train: missing model.ini"))?;
    let (builder, hyper) = ini::builder_from_file(path)?;
    let opts = compile_opts(args, hyper.batch)?;
    let epochs = args
        .opt("--epochs")
        .map(|e| e.parse().unwrap_or(hyper.epochs))
        .unwrap_or(hyper.epochs);
    let mut model = builder.compile(&opts)?;
    println!("peak pool {:.3} MiB; training {epochs} epochs @ batch {}", model.report.pool_mib(), opts.batch);

    // input/label sizes from the compiled graph
    let in_len: usize = model
        .exec
        .graph
        .input_nodes
        .iter()
        .map(|&n| model.exec.graph.nodes[n].out_dims[0].feature_len())
        .sum();
    let lb_len: usize = model
        .exec
        .graph
        .loss_nodes
        .iter()
        .map(|&n| model.exec.graph.nodes[n].in_dims[0].feature_len())
        .sum();
    let data = args.opt("--data").unwrap_or_else(|| "random".into());
    let n = 512usize;
    let make = move || -> Box<dyn DataProducer> {
        match data.as_str() {
            "digits" => {
                let side = (in_len as f64).sqrt() as usize;
                Box::new(DigitsProducer::new(n, side, 1, 42))
            }
            _ => Box::new(RandomProducer::new(n, in_len, lb_len, 42)),
        }
    };
    let summary = model.train(make, &TrainConfig { epochs, verbose: true, ..Default::default() })?;
    println!(
        "done: {} iterations, {:.2}s, final loss {:.5}",
        summary.iterations, summary.wall_s, summary.final_loss
    );
    if let Some(save) = args.opt("--save") {
        model.save(&save)?;
        println!("checkpoint written to {save}");
    }
    Ok(())
}

fn cmd_zoo() -> nntrainer::Result<()> {
    use nntrainer::model::zoo;
    println!("built-in evaluation models (rust/src/model/zoo.rs):");
    for (name, nodes, _) in zoo::table4_cases() {
        println!("  table4: {:<22} ({} layers)", name, nodes.len());
    }
    for (name, n) in [
        ("lenet5", zoo::lenet5().len()),
        ("vgg16", zoo::vgg16().len()),
        ("resnet18", zoo::resnet18().len()),
        ("resnet18_transfer", zoo::resnet18_transfer().len()),
        ("product_rating", zoo::product_rating().len()),
        ("tacotron_decoder(T=24)", zoo::tacotron_decoder(24, 80, 256).len()),
        ("postnet(T=24)", zoo::postnet(24, 80).len()),
        ("mlp_e2e", zoo::mlp_e2e().len()),
    ] {
        println!("  app:    {name:<22} ({n} layers)");
    }
    Ok(())
}

fn cmd_artifacts(args: &Args) -> nntrainer::Result<()> {
    let dir = args.opt("--dir").unwrap_or_else(|| {
        ArtifactCatalog::default_dir().to_string_lossy().into_owned()
    });
    ArtifactCatalog::open(&dir)?;
    let mut rt = XlaRuntime::new(&dir)?;
    println!("platform: {}", rt.platform());
    // smoke: run the linear oracle
    let (m, k, n) = nntrainer::runtime::catalog::ORACLE_LINEAR;
    let x = vec![0.5f32; m * k];
    let w = vec![0.1f32; k * n];
    let b = vec![0.0f32; n];
    let out = rt.run_f32(
        "oracle_linear_fwd",
        &[(&x[..], &[m, k][..]), (&w[..], &[k, n][..]), (&b[..], &[n][..])],
    )?;
    let got = out[0][0];
    let want = 0.5 * 0.1 * k as f32;
    if (got - want).abs() > 1e-4 {
        return Err(nntrainer::Error::Runtime(format!("smoke mismatch {got} vs {want}")));
    }
    println!("artifact catalog OK ({} artifacts, smoke passed)", nntrainer::runtime::catalog::ARTIFACTS.len());
    Ok(())
}
