//! `nntrainer` CLI — the leader entrypoint, driving the lifecycle-staged
//! session API (`Session::from_ini_file → configure → compile_for`).
//!
//! ```text
//! nntrainer plan  <model.ini> [--batch N] [--budget-mib M] [--planner sorting|naive|bestfit|skyline]
//!                 [--conventional] [--no-swap] [--calibrated] [--table]
//! nntrainer train <model.ini> [--batch N] [--budget-mib M] [--epochs N] [--early-stop P]
//!                 [--calibrated] [--save ckpt.bin] [--data digits|random]
//! nntrainer zoo                              # list built-in evaluation models
//! nntrainer artifacts [--dir artifacts]      # check + smoke the PJRT artifact catalog
//! nntrainer checkpoint diff <a.bin> <b.bin>  # manifest diff of two checkpoints (v1/v2)
//! ```
//!
//! With `--budget-mib` and no `--batch`, the largest batch whose planned
//! pool fits the budget is selected automatically.

// Same clippy posture as the library crate (see lib.rs); CI denies
// warnings.
#![allow(clippy::too_many_arguments, clippy::type_complexity)]

use std::process::ExitCode;

use nntrainer::dataset::{DataProducer, DigitsProducer, RandomProducer};
use nntrainer::metrics::MIB;
use nntrainer::model::{DeviceProfile, EarlyStop, Session, TrainCallback, TrainSpec};
use nntrainer::planner::PlannerKind;
use nntrainer::runtime::catalog::ArtifactCatalog;
use nntrainer::runtime::{SwapTuning, XlaRuntime};

fn usage() -> ExitCode {
    eprintln!(
        "usage:\n  nntrainer plan  <model.ini> [--batch N] [--budget-mib M] [--planner sorting|naive|bestfit|skyline] [--conventional] [--no-swap] [--calibrated] [--table]\n  \
         nntrainer train <model.ini> [--batch N] [--budget-mib M] [--epochs N] [--early-stop P] [--val-split F] [--calibrated] [--save F] [--data digits|random]\n  \
         nntrainer zoo\n  nntrainer artifacts [--dir D]\n  nntrainer checkpoint diff <a.bin> <b.bin>"
    );
    ExitCode::from(2)
}

struct Args {
    rest: Vec<String>,
}

impl Args {
    fn flag(&self, name: &str) -> bool {
        self.rest.iter().any(|a| a == name)
    }
    fn opt(&self, name: &str) -> Option<String> {
        self.rest
            .iter()
            .position(|a| a == name)
            .and_then(|p| self.rest.get(p + 1).cloned())
    }
}

fn main() -> ExitCode {
    let mut argv = std::env::args().skip(1);
    let Some(cmd) = argv.next() else { return usage() };
    let rest: Vec<String> = argv.collect();
    let args = Args { rest };
    let r = match cmd.as_str() {
        "plan" => cmd_plan(&args),
        "train" => cmd_train(&args),
        "zoo" => cmd_zoo(),
        "artifacts" => cmd_artifacts(&args),
        "checkpoint" => cmd_checkpoint(&args),
        _ => return usage(),
    };
    match r {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}

/// Parse a `--flag value` pair, erroring (like `--planner`) instead of
/// silently ignoring a malformed value.
fn parse_opt<T: std::str::FromStr>(args: &Args, name: &str) -> nntrainer::Result<Option<T>> {
    match args.opt(name) {
        None => Ok(None),
        Some(v) => v
            .parse::<T>()
            .map(Some)
            .map_err(|_| nntrainer::Error::model(format!("invalid value `{v}` for {name}"))),
    }
}

/// Resolve the two lifecycle contracts from INI defaults + CLI flags.
/// With `--budget-mib` and no explicit `--batch`, the batch is delegated
/// to the budget-aware auto-selection.
fn spec_and_profile(
    session: &Session,
    args: &Args,
) -> nntrainer::Result<(TrainSpec, DeviceProfile)> {
    let planner = match args.opt("--planner") {
        Some(p) => PlannerKind::parse(&p)
            .ok_or_else(|| nntrainer::Error::model(format!("unknown planner `{p}`")))?,
        None => PlannerKind::Sorting,
    };
    let conventional = args.flag("--conventional");
    let budget = parse_opt::<f64>(args, "--budget-mib")?.map(|m| (m * MIB) as usize);
    let mut spec = session.default_spec();
    if let Some(b) = parse_opt::<usize>(args, "--batch")? {
        spec.batch = Some(b);
    } else if budget.is_some() {
        spec.batch = None; // auto-select under the budget
    }
    if let Some(e) = parse_opt::<usize>(args, "--epochs")? {
        spec.epochs = e;
    }
    if let Some(v) = parse_opt::<f32>(args, "--val-split")? {
        spec.val_split = v;
    }
    let profile = DeviceProfile {
        memory_budget_bytes: budget,
        swap: !args.flag("--no-swap"),
        swap_tuning: if args.flag("--calibrated") {
            SwapTuning::Calibrated
        } else {
            SwapTuning::Fixed
        },
        planner,
        conventional,
        inplace: !conventional,
        ..DeviceProfile::default()
    };
    Ok((spec, profile))
}

fn cmd_plan(args: &Args) -> nntrainer::Result<()> {
    let path = args
        .rest
        .first()
        .ok_or_else(|| nntrainer::Error::model("plan: missing model.ini"))?;
    let session = Session::from_ini_file(path)?;
    let (spec, profile) = spec_and_profile(&session, args)?;
    let auto = spec.batch.is_none();
    let model = session.configure(spec).compile_for(profile)?;
    let rep = model.report();
    println!("model:        {path}");
    println!(
        "planner:      {} (conventional profile: {})",
        rep.planner,
        model.profile().conventional
    );
    println!(
        "batch:        {}{}",
        model.batch(),
        if auto { "  <- auto (largest fitting the budget)" } else { "" }
    );
    if let Some(fits) = model.fits_budget() {
        let b = model.profile().memory_budget_bytes.unwrap_or(0);
        println!(
            "budget:       {:.3} MiB ({})",
            b as f64 / MIB,
            if fits { "fits" } else { "EXCEEDED — best effort" }
        );
    }
    println!("peak pool:    {:.3} MiB  <- known before execution", rep.pool_mib());
    println!("ideal bound:  {:.3} MiB  (planner overhead x{:.3})", rep.ideal_mib(), rep.overhead());
    println!("no-reuse sum: {:.3} MiB", rep.total_bytes as f64 / MIB);
    println!("tensors:      {} allocated, {} merged (MV/RV/E)", rep.n_tensors, rep.n_merged);
    let mut roles: Vec<_> = rep.by_role.iter().collect();
    roles.sort();
    for (role, bytes) in roles {
        println!("  {role:<8} {:>10.3} MiB", *bytes as f64 / MIB);
    }
    if args.flag("--table") {
        println!("{}", model.model.exec.graph.table);
    }
    Ok(())
}

fn cmd_train(args: &Args) -> nntrainer::Result<()> {
    let path = args
        .rest
        .first()
        .ok_or_else(|| nntrainer::Error::model("train: missing model.ini"))?;
    let session = Session::from_ini_file(path)?;
    let (mut spec, profile) = spec_and_profile(&session, args)?;
    spec.verbose = true;
    let mut model = session.configure(spec).compile_for(profile)?;
    println!(
        "peak pool {:.3} MiB; training {} epochs @ batch {}",
        model.report().pool_mib(),
        model.spec().epochs,
        model.batch()
    );

    // input/label sizes from the compiled graph
    let exec = &model.model.exec;
    let in_len: usize = exec
        .graph
        .input_nodes
        .iter()
        .map(|&n| exec.graph.nodes[n].out_dims[0].feature_len())
        .sum();
    let lb_len: usize = exec
        .graph
        .loss_nodes
        .iter()
        .map(|&n| exec.graph.nodes[n].in_dims[0].feature_len())
        .sum();
    let data = args.opt("--data").unwrap_or_else(|| "random".into());
    let n = 512usize;
    let make = move || -> Box<dyn DataProducer> {
        match data.as_str() {
            "digits" => {
                let side = (in_len as f64).sqrt() as usize;
                Box::new(DigitsProducer::new(n, side, 1, 42))
            }
            _ => Box::new(RandomProducer::new(n, in_len, lb_len, 42)),
        }
    };
    let summary = match parse_opt::<usize>(args, "--early-stop")? {
        Some(patience) => {
            let mut es = EarlyStop::new(patience, 0.0);
            model.train_with(make, &mut [&mut es as &mut dyn TrainCallback])?
        }
        None => model.train(make)?,
    };
    println!(
        "done: {} iterations over {} epochs, {:.2}s, final loss {:.5}",
        summary.iterations, summary.epochs, summary.wall_s, summary.final_loss
    );
    if let Some(save) = args.opt("--save") {
        model.save(&save)?;
        println!("checkpoint written to {save}");
    }
    Ok(())
}

/// `checkpoint diff <a> <b>`: manifest-level diff of two checkpoint
/// files (v2 manifests read directly; v1 files are scanned). Exits
/// successfully whether or not the files differ — the diff itself is
/// the output.
fn cmd_checkpoint(args: &Args) -> nntrainer::Result<()> {
    match args.rest.first().map(|s| s.as_str()) {
        Some("diff") => {
            let a = args
                .rest
                .get(1)
                .ok_or_else(|| nntrainer::Error::model("checkpoint diff: missing <a.bin>"))?;
            let b = args
                .rest
                .get(2)
                .ok_or_else(|| nntrainer::Error::model("checkpoint diff: missing <b.bin>"))?;
            print!("{}", nntrainer::model::checkpoint::diff_files(a, b)?);
            Ok(())
        }
        _ => Err(nntrainer::Error::model(
            "usage: nntrainer checkpoint diff <a.bin> <b.bin>",
        )),
    }
}

fn cmd_zoo() -> nntrainer::Result<()> {
    use nntrainer::model::zoo;
    println!("built-in evaluation models (rust/src/model/zoo.rs):");
    for (name, nodes, _) in zoo::table4_cases() {
        println!("  table4: {:<22} ({} layers)", name, nodes.len());
    }
    for (name, n) in [
        ("lenet5", zoo::lenet5().len()),
        ("vgg16", zoo::vgg16().len()),
        ("resnet18", zoo::resnet18().len()),
        ("resnet18_transfer", zoo::resnet18_transfer().len()),
        ("product_rating", zoo::product_rating().len()),
        ("tacotron_decoder(T=24)", zoo::tacotron_decoder(24, 80, 256).len()),
        ("postnet(T=24)", zoo::postnet(24, 80).len()),
        ("mlp_e2e", zoo::mlp_e2e().len()),
    ] {
        println!("  app:    {name:<22} ({n} layers)");
    }
    Ok(())
}

fn cmd_artifacts(args: &Args) -> nntrainer::Result<()> {
    let dir = args.opt("--dir").unwrap_or_else(|| {
        ArtifactCatalog::default_dir().to_string_lossy().into_owned()
    });
    ArtifactCatalog::open(&dir)?;
    let mut rt = XlaRuntime::new(&dir)?;
    println!("platform: {}", rt.platform());
    // smoke: run the linear oracle
    let (m, k, n) = nntrainer::runtime::catalog::ORACLE_LINEAR;
    let x = vec![0.5f32; m * k];
    let w = vec![0.1f32; k * n];
    let b = vec![0.0f32; n];
    let out = rt.run_f32(
        "oracle_linear_fwd",
        &[(&x[..], &[m, k][..]), (&w[..], &[k, n][..]), (&b[..], &[n][..])],
    )?;
    let got = out[0][0];
    let want = 0.5 * 0.1 * k as f32;
    if (got - want).abs() > 1e-4 {
        return Err(nntrainer::Error::Runtime(format!("smoke mismatch {got} vs {want}")));
    }
    println!("artifact catalog OK ({} artifacts, smoke passed)", nntrainer::runtime::catalog::ARTIFACTS.len());
    Ok(())
}
