//! Error type shared across the crate.

use thiserror::Error;

/// Crate-wide error enumeration.
///
/// Most construction-time failures (bad model description, shape mismatch,
/// planner inconsistencies) are reported through this type; hot-path code
/// (forward / backward) is shape-checked at initialize time and does not
/// return `Result`.
#[derive(Debug, Error)]
pub enum Error {
    /// Model description (INI or API) is malformed.
    #[error("model description: {0}")]
    ModelDesc(String),
    /// A layer property had an unknown key or unparsable value.
    #[error("invalid property `{key}` = `{value}`: {reason}")]
    Property {
        key: String,
        value: String,
        reason: String,
    },
    /// Tensor shapes are inconsistent at graph-initialize time.
    #[error("shape mismatch: {0}")]
    Shape(String),
    /// Graph wiring error (unknown layer name, cycle outside recurrent scope…).
    #[error("graph: {0}")]
    Graph(String),
    /// Memory planner produced or detected an invalid plan.
    #[error("planner: {0}")]
    Planner(String),
    /// Data pipeline failure.
    #[error("dataset: {0}")]
    Dataset(String),
    /// Checkpoint serialization/deserialization failure.
    #[error("checkpoint: {0}")]
    Checkpoint(String),
    /// PJRT runtime failure (artifact missing, compile/execute error).
    #[error("runtime: {0}")]
    Runtime(String),
    /// I/O error.
    #[error(transparent)]
    Io(#[from] std::io::Error),
}

pub type Result<T> = std::result::Result<T, Error>;

impl Error {
    pub fn model<S: Into<String>>(s: S) -> Self {
        Error::ModelDesc(s.into())
    }
    pub fn shape<S: Into<String>>(s: S) -> Self {
        Error::Shape(s.into())
    }
    pub fn graph<S: Into<String>>(s: S) -> Self {
        Error::Graph(s.into())
    }
    pub fn planner<S: Into<String>>(s: S) -> Self {
        Error::Planner(s.into())
    }
}
