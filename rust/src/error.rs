//! Error type shared across the crate. Hand-rolled `Display`/`Error`
//! impls — the offline build carries no proc-macro dependencies.

use std::fmt;

/// Crate-wide error enumeration.
///
/// Most construction-time failures (bad model description, shape mismatch,
/// planner inconsistencies) are reported through this type; hot-path code
/// (forward / backward) is shape-checked at initialize time and does not
/// return `Result`.
#[derive(Debug)]
pub enum Error {
    /// Model description (INI or API) is malformed.
    ModelDesc(String),
    /// A layer property had an unknown key or unparsable value.
    Property {
        key: String,
        value: String,
        reason: String,
    },
    /// Tensor shapes are inconsistent at graph-initialize time.
    Shape(String),
    /// Graph wiring error (unknown layer name, cycle outside recurrent scope…).
    Graph(String),
    /// Memory planner produced or detected an invalid plan.
    Planner(String),
    /// Data pipeline failure.
    Dataset(String),
    /// Checkpoint serialization/deserialization failure.
    Checkpoint(String),
    /// Runtime failure (swap store I/O, PJRT artifact missing, compile/
    /// execute error, residency violation).
    Runtime(String),
    /// I/O error.
    Io(std::io::Error),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::ModelDesc(s) => write!(f, "model description: {s}"),
            Error::Property { key, value, reason } => {
                write!(f, "invalid property `{key}` = `{value}`: {reason}")
            }
            Error::Shape(s) => write!(f, "shape mismatch: {s}"),
            Error::Graph(s) => write!(f, "graph: {s}"),
            Error::Planner(s) => write!(f, "planner: {s}"),
            Error::Dataset(s) => write!(f, "dataset: {s}"),
            Error::Checkpoint(s) => write!(f, "checkpoint: {s}"),
            Error::Runtime(s) => write!(f, "runtime: {s}"),
            Error::Io(e) => write!(f, "{e}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

pub type Result<T> = std::result::Result<T, Error>;

impl Error {
    pub fn model<S: Into<String>>(s: S) -> Self {
        Error::ModelDesc(s.into())
    }
    pub fn shape<S: Into<String>>(s: S) -> Self {
        Error::Shape(s.into())
    }
    pub fn graph<S: Into<String>>(s: S) -> Self {
        Error::Graph(s.into())
    }
    pub fn planner<S: Into<String>>(s: S) -> Self {
        Error::Planner(s.into())
    }
}
