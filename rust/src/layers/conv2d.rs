//! 2-D convolution via im2col + matmul.
//!
//! The im2col buffer is the "additional heap" the paper attributes to
//! NNTrainer's Conv2D (§5.1) — but only the `Naive` compute backend
//! still materializes it. Under `Tiered` (the default) the forward and
//! weight-gradient GEMMs gather their column panels implicitly from the
//! input image, so the `col` temp is not even declared and the
//! planner's peak drops by one `col_rows * col_cols` buffer. The
//! backward `colgrad` scratch remains either way (col2im needs the
//! materialized column-gradient).

use crate::backend::native as nb;
use crate::backend::native::Conv2dGeom;
use crate::backend::ComputeKind;
use crate::error::{Error, Result};
use crate::tensor::{Initializer, Lifespan, TensorDim};

use super::{FinalizeOut, Layer, Props, RunCtx, TempReq, WeightReq};

pub struct Conv2d {
    filters: usize,
    k: usize,
    stride: usize,
    pad: usize,
    bias: bool,
    compute: ComputeKind,
    geom: Option<Conv2dGeom>,
}

impl Conv2d {
    pub fn create(props: &Props) -> Result<Box<dyn Layer>> {
        let k = props.usize_or("kernel_size", 3)?;
        // `padding = same | valid | <n>`
        let pad = match props.get("padding") {
            Some("same") => k / 2,
            Some("valid") | None => 0,
            Some(v) => v
                .trim()
                .parse::<usize>()
                .map_err(|e| Error::model(format!("bad padding `{v}`: {e}")))?,
        };
        Ok(Box::new(Conv2d {
            filters: props.usize_req("filters")?,
            k,
            stride: props.usize_or("stride", 1)?,
            pad,
            bias: props.bool_or("bias", true)?,
            compute: ComputeKind::default(),
            geom: None,
        }))
    }

    fn g(&self) -> &Conv2dGeom {
        self.geom.as_ref().expect("conv2d not finalized")
    }

    /// The materialized-col temp exists only under `Naive`; `colgrad`
    /// always exists. This maps "which temp slot is colgrad".
    fn colgrad_slot(&self) -> usize {
        match self.compute {
            ComputeKind::Naive => 1,
            ComputeKind::Tiered => 0,
        }
    }
}

impl Layer for Conv2d {
    fn kind(&self) -> &'static str {
        "conv2d"
    }

    fn set_compute(&mut self, kind: ComputeKind) {
        self.compute = kind;
    }

    fn finalize(&mut self, in_dims: &[TensorDim]) -> Result<FinalizeOut> {
        let d = *in_dims.first().ok_or_else(|| Error::graph("conv2d needs one input"))?;
        if d.h + 2 * self.pad < self.k || d.w + 2 * self.pad < self.k {
            return Err(Error::shape(format!(
                "conv2d kernel {} > padded input {}",
                self.k, d
            )));
        }
        let geom = Conv2dGeom {
            in_c: d.c,
            in_h: d.h,
            in_w: d.w,
            out_c: self.filters,
            k_h: self.k,
            k_w: self.k,
            stride: self.stride,
            pad_h: self.pad,
            pad_w: self.pad,
        };
        let (oh, ow) = (geom.out_h(), geom.out_w());
        let col_len = geom.col_rows() * geom.col_cols();
        let fan_in = geom.col_rows();
        let fan_out = self.filters * self.k * self.k;
        self.geom = Some(geom);

        let mut weights = vec![WeightReq {
            name: "kernel",
            dim: TensorDim::new(1, 1, self.filters, fan_in),
            init: Initializer::XavierUniform { fan_in, fan_out },
            need_cd: true,
        }];
        if self.bias {
            weights.push(WeightReq {
                name: "bias",
                dim: TensorDim::vec(1, self.filters),
                init: Initializer::Zeros,
                need_cd: false,
            });
        }
        let mut temps = vec![];
        if self.compute == ComputeKind::Naive {
            // one-image im2col buffer, reused across the batch and
            // re-materialized in backward (recompute-over-store). The
            // tiered backend gathers implicitly and never needs it.
            temps.push(TempReq {
                name: "col",
                dim: TensorDim::vec(1, col_len),
                span: Lifespan::ITERATION,
            });
        }
        // backward column-gradient scratch (CD only).
        temps.push(TempReq {
            name: "colgrad",
            dim: TensorDim::vec(1, col_len),
            span: Lifespan::CALC_DERIV,
        });
        Ok(FinalizeOut {
            out_dims: vec![TensorDim::new(d.b, self.filters, oh, ow)],
            weights,
            temps,
            need_input_cg: true,
            ..Default::default()
        })
    }

    fn forward(&self, ctx: &RunCtx) {
        let g = self.g();
        let b = ctx.batch();
        let x = ctx.input(0);
        let w = ctx.weight(0);
        let out = ctx.output(0);
        let col = match self.compute {
            ComputeKind::Naive => Some(ctx.temp(0)),
            ComputeKind::Tiered => None,
        };
        let out_sz = g.out_c * g.col_cols();
        ctx.backend.conv2d_forward(x, w, out, g, b, col);
        if self.bias {
            let bias = ctx.weight(1);
            let hw = g.col_cols();
            for s in 0..b {
                for c in 0..g.out_c {
                    let row = &mut out[s * out_sz + c * hw..s * out_sz + (c + 1) * hw];
                    for v in row.iter_mut() {
                        *v += bias[c];
                    }
                }
            }
        }
    }

    fn calc_gradient(&self, ctx: &RunCtx) {
        let g = self.g();
        let b = ctx.batch();
        let x = ctx.input(0);
        let dout = ctx.out_deriv(0);
        let out_sz = g.out_c * g.col_cols();
        if let Some(gw) = ctx.grad(0) {
            let col = match self.compute {
                ComputeKind::Naive => Some(ctx.temp(0)),
                ComputeKind::Tiered => None,
            };
            // ΔW[oc, R] += Σ_s ΔD[oc, C] · colᵀ[C, R]
            ctx.backend.conv2d_grad_w(x, dout, gw, g, b, col);
        }
        if self.bias {
            if let Some(gb) = ctx.grad(1) {
                let hw = g.col_cols();
                for s in 0..b {
                    for c in 0..g.out_c {
                        let row = &dout[s * out_sz + c * hw..s * out_sz + (c + 1) * hw];
                        gb[c] += row.iter().sum::<f32>();
                    }
                }
            }
        }
    }

    fn calc_derivative(&self, ctx: &RunCtx) {
        if !ctx.has_in_deriv(0) {
            return;
        }
        let g = self.g();
        let b = ctx.batch();
        let w = ctx.weight(0);
        let dout = ctx.out_deriv(0);
        let din = ctx.in_deriv(0);
        let colgrad = ctx.temp(self.colgrad_slot());
        let in_sz = g.in_c * g.in_h * g.in_w;
        let out_sz = g.out_c * g.col_cols();
        for s in 0..b {
            // colgrad[R, C] = Wᵀ[R, oc] · ΔD[oc, C]
            ctx.backend.matmul_at(
                w,
                &dout[s * out_sz..(s + 1) * out_sz],
                colgrad,
                g.col_rows(),
                g.out_c,
                g.col_cols(),
                false,
            );
            nb::col2im(colgrad, g, &mut din[s * in_sz..(s + 1) * in_sz], false);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::layers::Props;

    #[test]
    fn finalize_same_padding() {
        let p = Props::from_pairs([("filters", "64"), ("kernel_size", "3"), ("padding", "same")]);
        let mut l = Conv2d::create(&p).unwrap();
        let f = l.finalize(&[TensorDim::new(32, 3, 32, 32)]).unwrap();
        // paper §3's worked example: 32x32x3 -> 64 filters 3x3 same
        assert_eq!(f.out_dims[0], TensorDim::new(32, 64, 32, 32));
        // output buffer 8.3 MiB as in the paper
        let mib = f.out_dims[0].bytes() as f64 / (1024.0 * 1024.0);
        assert!((mib - 8.0).abs() < 0.5, "{mib}");
    }

    #[test]
    fn stride_two() {
        let p = Props::from_pairs([("filters", "3"), ("kernel_size", "3"), ("padding", "1"), ("stride", "2")]);
        let mut l = Conv2d::create(&p).unwrap();
        let f = l.finalize(&[TensorDim::new(64, 3, 224, 224)]).unwrap();
        // Table 4 Conv2D row: 64:3:224:224 -> 64:3:112:112
        assert_eq!(f.out_dims[0], TensorDim::new(64, 3, 112, 112));
    }

    #[test]
    fn kernel_too_big() {
        let p = Props::from_pairs([("filters", "4"), ("kernel_size", "5")]);
        let mut l = Conv2d::create(&p).unwrap();
        assert!(l.finalize(&[TensorDim::new(1, 1, 3, 3)]).is_err());
    }

    #[test]
    fn naive_compute_declares_col_temp_tiered_does_not() {
        let p = Props::from_pairs([("filters", "4"), ("kernel_size", "3"), ("padding", "same")]);
        let dims = [TensorDim::new(2, 2, 8, 8)];

        let mut tiered = Conv2d::create(&p).unwrap();
        tiered.set_compute(ComputeKind::Tiered);
        let ft = tiered.finalize(&dims).unwrap();
        assert_eq!(ft.temps.len(), 1);
        assert_eq!(ft.temps[0].name, "colgrad");

        let mut naive = Conv2d::create(&p).unwrap();
        naive.set_compute(ComputeKind::Naive);
        let fnv = naive.finalize(&dims).unwrap();
        assert_eq!(fnv.temps.len(), 2);
        assert_eq!(fnv.temps[0].name, "col");
    }
}
