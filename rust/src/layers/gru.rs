//! GRU layer over a full sequence, fused backward — extends the paper's
//! recurrent coverage (§1 "entire training features … recurrent
//! network") beyond LSTM.
//!
//! Gate order (r, z, n); the reset gate applies to the *hidden*
//! contribution of the candidate (`n = tanh(gx_n + r ∘ gh_n)`), matching
//! the common "v3" formulation. All step caches are iteration-lifespan
//! pool temps, exactly like the LSTM layer.

use crate::backend::native as nb;
use crate::error::{Error, Result};
use crate::tensor::{Initializer, Lifespan, TensorDim};

use super::{FinalizeOut, Layer, Props, RunCtx, TempReq, WeightReq};

pub struct Gru {
    unit: usize,
    return_sequences: bool,
    t: usize,
    input_feat: usize,
}

impl Gru {
    pub fn create(props: &Props) -> Result<Box<dyn Layer>> {
        Ok(Box::new(Gru {
            unit: props.usize_req("unit")?,
            return_sequences: props.bool_or("return_sequences", false)?,
            t: 0,
            input_feat: 0,
        }))
    }
}

// temp indices
const T_GATES: usize = 0; // [B,T,3H] post-activation (r,z,n)
const T_GHN: usize = 1; // [B,T,H] pre-reset hidden candidate gh_n
const T_HS: usize = 2; // [B,T,H]
const T_XT: usize = 3; // [B,I]
const T_GXBUF: usize = 4; // [B,3H]
const T_GHBUF: usize = 5; // [B,3H]
const T_HBUF: usize = 6; // [B,H]
const T_DH: usize = 7; // [B,H]
const T_DGX: usize = 8; // [B,3H]
const T_DGH: usize = 9; // [B,3H]
const T_DXBUF: usize = 10; // [B,I]

impl Layer for Gru {
    fn kind(&self) -> &'static str {
        "gru"
    }

    fn finalize(&mut self, in_dims: &[TensorDim]) -> Result<FinalizeOut> {
        let d = *in_dims.first().ok_or_else(|| Error::graph("gru needs one input"))?;
        if d.c != 1 {
            return Err(Error::shape(format!("gru expects b:1:T:I, got {d}")));
        }
        let (t, feat) = (d.h, d.w);
        self.t = t;
        self.input_feat = feat;
        let h = self.unit;
        let b = d.b;
        let out = if self.return_sequences {
            TensorDim::new(b, 1, t, h)
        } else {
            TensorDim::vec(b, h)
        };
        let iter = Lifespan::ITERATION;
        let back = Lifespan::BACKWARD;
        Ok(FinalizeOut {
            out_dims: vec![out],
            weights: vec![
                WeightReq {
                    name: "weight_xh",
                    dim: TensorDim::new(1, 1, feat, 3 * h),
                    init: Initializer::XavierUniform { fan_in: feat, fan_out: 3 * h },
                    need_cd: true,
                },
                WeightReq {
                    name: "weight_hh",
                    dim: TensorDim::new(1, 1, h, 3 * h),
                    init: Initializer::XavierUniform { fan_in: h, fan_out: 3 * h },
                    need_cd: true,
                },
                WeightReq {
                    name: "bias_x",
                    dim: TensorDim::vec(1, 3 * h),
                    init: Initializer::Zeros,
                    need_cd: false,
                },
                WeightReq {
                    name: "bias_h",
                    dim: TensorDim::vec(1, 3 * h),
                    init: Initializer::Zeros,
                    need_cd: false,
                },
            ],
            temps: vec![
                TempReq { name: "gates", dim: TensorDim::new(b, 1, t, 3 * h), span: iter },
                TempReq { name: "ghn", dim: TensorDim::new(b, 1, t, h), span: iter },
                TempReq { name: "hs", dim: TensorDim::new(b, 1, t, h), span: iter },
                TempReq { name: "xt", dim: TensorDim::vec(b, feat), span: iter },
                TempReq { name: "gxbuf", dim: TensorDim::vec(b, 3 * h), span: iter },
                TempReq { name: "ghbuf", dim: TensorDim::vec(b, 3 * h), span: iter },
                TempReq { name: "hbuf", dim: TensorDim::vec(b, h), span: iter },
                TempReq { name: "dh", dim: TensorDim::vec(b, h), span: back },
                TempReq { name: "dgx", dim: TensorDim::vec(b, 3 * h), span: back },
                TempReq { name: "dgh", dim: TensorDim::vec(b, 3 * h), span: back },
                TempReq { name: "dxbuf", dim: TensorDim::vec(b, feat), span: back },
            ],
            need_input_cg: true,
            fused_backward: true,
            ..Default::default()
        })
    }

    fn forward(&self, ctx: &RunCtx) {
        let (b, t, f, h) = (ctx.batch(), self.t, self.input_feat, self.unit);
        let x = ctx.input(0);
        let wx = ctx.weight(0);
        let wh = ctx.weight(1);
        let bx = ctx.weight(2);
        let bh = ctx.weight(3);
        let gates = ctx.temp(T_GATES);
        let ghn_c = ctx.temp(T_GHN);
        let hs = ctx.temp(T_HS);
        let xt = ctx.temp(T_XT);
        let gx = ctx.temp(T_GXBUF);
        let gh = ctx.temp(T_GHBUF);
        let hbuf = ctx.temp(T_HBUF);
        for step in 0..t {
            for s in 0..b {
                xt[s * f..(s + 1) * f]
                    .copy_from_slice(&x[s * t * f + step * f..s * t * f + (step + 1) * f]);
                if step == 0 {
                    hbuf[s * h..(s + 1) * h].fill(0.0);
                } else {
                    hbuf[s * h..(s + 1) * h].copy_from_slice(
                        &hs[s * t * h + (step - 1) * h..s * t * h + step * h],
                    );
                }
            }
            ctx.backend.matmul(xt, wx, gx, b, f, 3 * h, false);
            nb::add_bias(gx, bx, b, 3 * h);
            ctx.backend.matmul(hbuf, wh, gh, b, h, 3 * h, false);
            nb::add_bias(gh, bh, b, 3 * h);
            for s in 0..b {
                let gxs = &gx[s * 3 * h..(s + 1) * 3 * h];
                let ghs = &gh[s * 3 * h..(s + 1) * 3 * h];
                let gcache =
                    &mut gates[s * t * 3 * h + step * 3 * h..s * t * 3 * h + (step + 1) * 3 * h];
                for j in 0..h {
                    let r = nb::sigmoid(gxs[j] + ghs[j]);
                    let z = nb::sigmoid(gxs[h + j] + ghs[h + j]);
                    let ghn = ghs[2 * h + j];
                    let n = (gxs[2 * h + j] + r * ghn).tanh();
                    gcache[j] = r;
                    gcache[h + j] = z;
                    gcache[2 * h + j] = n;
                    ghn_c[s * t * h + step * h + j] = ghn;
                    let h_prev = hbuf[s * h + j];
                    hs[s * t * h + step * h + j] = (1.0 - z) * n + z * h_prev;
                }
            }
        }
        let out = ctx.output(0);
        if self.return_sequences {
            out.copy_from_slice(hs);
        } else {
            for s in 0..b {
                out[s * h..(s + 1) * h]
                    .copy_from_slice(&hs[s * t * h + (t - 1) * h..s * t * h + t * h]);
            }
        }
    }

    fn calc_gradient(&self, ctx: &RunCtx) {
        let (b, t, f, h) = (ctx.batch(), self.t, self.input_feat, self.unit);
        let x = ctx.input(0);
        let wx = ctx.weight(0);
        let wh = ctx.weight(1);
        let gates = ctx.temp(T_GATES);
        let ghn_c = ctx.temp(T_GHN);
        let hs = ctx.temp(T_HS);
        let xt = ctx.temp(T_XT);
        let hbuf = ctx.temp(T_HBUF);
        let dh = ctx.temp(T_DH);
        let dgx = ctx.temp(T_DGX);
        let dgh = ctx.temp(T_DGH);
        let dxbuf = ctx.temp(T_DXBUF);
        let dout = ctx.out_deriv(0);
        dh.fill(0.0);
        for step in (0..t).rev() {
            for s in 0..b {
                let dh_s = &mut dh[s * h..(s + 1) * h];
                if self.return_sequences {
                    for j in 0..h {
                        dh_s[j] += dout[s * t * h + step * h + j];
                    }
                } else if step == t - 1 {
                    for j in 0..h {
                        dh_s[j] += dout[s * h + j];
                    }
                }
            }
            for s in 0..b {
                let g = &gates[s * t * 3 * h + step * 3 * h..s * t * 3 * h + (step + 1) * 3 * h];
                let dgxs = &mut dgx[s * 3 * h..(s + 1) * 3 * h];
                let dghs = &mut dgh[s * 3 * h..(s + 1) * 3 * h];
                for j in 0..h {
                    let (r, z, n) = (g[j], g[h + j], g[2 * h + j]);
                    let ghn = ghn_c[s * t * h + step * h + j];
                    let h_prev =
                        if step == 0 { 0.0 } else { hs[s * t * h + (step - 1) * h + j] };
                    let dht = dh[s * h + j];
                    let dz = dht * (h_prev - n) * z * (1.0 - z);
                    let dn = dht * (1.0 - z) * (1.0 - n * n);
                    let dr = dn * ghn * r * (1.0 - r);
                    dgxs[j] = dr;
                    dgxs[h + j] = dz;
                    dgxs[2 * h + j] = dn;
                    dghs[j] = dr;
                    dghs[h + j] = dz;
                    dghs[2 * h + j] = dn * r;
                    // partial dh_prev: the z∘h_prev path (matmul part added below)
                    dh[s * h + j] = dht * z;
                }
            }
            for s in 0..b {
                xt[s * f..(s + 1) * f]
                    .copy_from_slice(&x[s * t * f + step * f..s * t * f + (step + 1) * f]);
                if step == 0 {
                    hbuf[s * h..(s + 1) * h].fill(0.0);
                } else {
                    hbuf[s * h..(s + 1) * h].copy_from_slice(
                        &hs[s * t * h + (step - 1) * h..s * t * h + step * h],
                    );
                }
            }
            if let Some(gwx) = ctx.grad(0) {
                ctx.backend.matmul_at(xt, dgx, gwx, f, b, 3 * h, true);
            }
            if let Some(gwh) = ctx.grad(1) {
                ctx.backend.matmul_at(hbuf, dgh, gwh, h, b, 3 * h, true);
            }
            if let Some(gbx) = ctx.grad(2) {
                nb::bias_grad(dgx, gbx, b, 3 * h, true);
            }
            if let Some(gbh) = ctx.grad(3) {
                nb::bias_grad(dgh, gbh, b, 3 * h, true);
            }
            if ctx.has_in_deriv(0) {
                ctx.backend.matmul_bt(dgx, wx, dxbuf, b, 3 * h, f, false);
                let din = ctx.in_deriv(0);
                for s in 0..b {
                    din[s * t * f + step * f..s * t * f + (step + 1) * f]
                        .copy_from_slice(&dxbuf[s * f..(s + 1) * f]);
                }
            }
            // dh_prev += dgh @ Wh^T  (on top of the z∘h_prev partial
            // already stored in dh above)
            ctx.backend.matmul_bt(dgh, wh, dh, b, 3 * h, h, true);
        }
    }

    fn calc_derivative(&self, _ctx: &RunCtx) {
        // fused into calc_gradient
    }
}
