//! Fully-connected (linear) layer: `out = X · W + b`.
//!
//! Tensor needs follow paper Fig 4 exactly: input is read at Forward and
//! at Compute-Gradient (`ΔW = Xᵀ·ΔD`); the weight is read at Forward and
//! Compute-Derivative (`ΔD' = ΔD·Wᵀ`).

use crate::backend::native as nb;
use crate::error::{Error, Result};
use crate::tensor::{Initializer, TensorDim};

use super::{FinalizeOut, Layer, Props, RunCtx, WeightReq};

pub struct FullyConnected {
    unit: usize,
    bias: bool,
    /// Apply per timestep over `b:1:T:F` (rows = b*T) instead of
    /// flattening the whole sample — Tacotron2's Prenet/heads.
    time_distributed: bool,
    feat: usize, // filled at finalize
    rows_per_sample: usize,
}

impl FullyConnected {
    pub fn create(props: &Props) -> Result<Box<dyn Layer>> {
        Ok(Box::new(FullyConnected {
            unit: props.usize_req("unit")?,
            bias: props.bool_or("bias", true)?,
            time_distributed: props.bool_or("time_distributed", false)?,
            feat: 0,
            rows_per_sample: 1,
        }))
    }
}

impl Layer for FullyConnected {
    fn kind(&self) -> &'static str {
        "fully_connected"
    }

    fn finalize(&mut self, in_dims: &[TensorDim]) -> Result<FinalizeOut> {
        let d = *in_dims
            .first()
            .ok_or_else(|| Error::graph("fully_connected needs one input"))?;
        if self.time_distributed {
            self.feat = d.w;
            self.rows_per_sample = d.c * d.h;
        } else {
            self.feat = d.feature_len();
            self.rows_per_sample = 1;
        }
        let mut weights = vec![WeightReq {
            name: "weight",
            dim: TensorDim::new(1, 1, self.feat, self.unit),
            init: Initializer::XavierUniform { fan_in: self.feat, fan_out: self.unit },
            need_cd: true,
        }];
        if self.bias {
            weights.push(WeightReq {
                name: "bias",
                dim: TensorDim::vec(1, self.unit),
                init: Initializer::Zeros,
                need_cd: false,
            });
        }
        let out_dim = if self.time_distributed {
            TensorDim::new(d.b, d.c, d.h, self.unit)
        } else {
            TensorDim::vec(d.b, self.unit)
        };
        Ok(FinalizeOut {
            out_dims: vec![out_dim],
            weights,
            need_input_cg: true,
            ..Default::default()
        })
    }

    fn forward(&self, ctx: &RunCtx) {
        let b = ctx.batch() * self.rows_per_sample;
        let x = ctx.input(0);
        let w = ctx.weight(0);
        let out = ctx.output(0);
        ctx.backend.matmul(x, w, out, b, self.feat, self.unit, false);
        if self.bias {
            nb::add_bias(out, ctx.weight(1), b, self.unit);
        }
    }

    fn calc_gradient(&self, ctx: &RunCtx) {
        let b = ctx.batch() * self.rows_per_sample;
        let d = ctx.out_deriv(0);
        if let Some(gw) = ctx.grad(0) {
            // ΔW[f,u] += Xᵀ[f,B] · ΔD[B,u]  (X stored [B,f])
            ctx.backend.matmul_at(ctx.input(0), d, gw, self.feat, b, self.unit, true);
        }
        if self.bias {
            if let Some(gb) = ctx.grad(1) {
                nb::bias_grad(d, gb, b, self.unit, true);
            }
        }
    }

    fn calc_derivative(&self, ctx: &RunCtx) {
        if !ctx.has_in_deriv(0) {
            return;
        }
        let b = ctx.batch() * self.rows_per_sample;
        // ΔD'[B,f] = ΔD[B,u] · Wᵀ  (W stored [f,u] == Bᵀ layout for matmul_bt)
        let (d, w, dx) = (ctx.out_deriv(0), ctx.weight(0), ctx.in_deriv(0));
        ctx.backend.matmul_bt(d, w, dx, b, self.unit, self.feat, false);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::layers::Props;

    #[test]
    fn finalize_shapes() {
        let p = Props::from_pairs([("unit", "10")]);
        let mut l = FullyConnected::create(&p).unwrap();
        let f = l.finalize(&[TensorDim::new(4, 3, 8, 8)]).unwrap();
        assert_eq!(f.out_dims, vec![TensorDim::vec(4, 10)]);
        assert_eq!(f.weights.len(), 2);
        assert_eq!(f.weights[0].dim.len(), 3 * 8 * 8 * 10);
        assert!(f.need_input_cg);
        assert!(f.weights[0].need_cd);
    }

    #[test]
    fn requires_unit() {
        assert!(FullyConnected::create(&Props::new()).is_err());
    }
}
