//! 1-D convolution over sequences (Tacotron2's Postnet). Input layout
//! `b:c:1:t` (channels × time); implemented as a degenerate 2-D conv.
//! Like conv2d, the materialized `col` temp exists only under the
//! `Naive` compute backend — `Tiered` gathers columns implicitly.

use crate::backend::native as nb;
use crate::backend::native::Conv2dGeom;
use crate::backend::ComputeKind;
use crate::error::{Error, Result};
use crate::tensor::{Initializer, Lifespan, TensorDim};

use super::{FinalizeOut, Layer, Props, RunCtx, TempReq, WeightReq};

pub struct Conv1d {
    filters: usize,
    k: usize,
    stride: usize,
    pad: usize,
    bias: bool,
    compute: ComputeKind,
    geom: Option<Conv2dGeom>,
}

impl Conv1d {
    pub fn create(props: &Props) -> Result<Box<dyn Layer>> {
        let k = props.usize_or("kernel_size", 5)?;
        let pad = match props.get("padding") {
            Some("same") => k / 2,
            Some("valid") | None => 0,
            Some(v) => v
                .trim()
                .parse::<usize>()
                .map_err(|e| Error::model(format!("bad padding `{v}`: {e}")))?,
        };
        Ok(Box::new(Conv1d {
            filters: props.usize_req("filters")?,
            k,
            stride: props.usize_or("stride", 1)?,
            pad,
            bias: props.bool_or("bias", true)?,
            compute: ComputeKind::default(),
            geom: None,
        }))
    }

    fn colgrad_slot(&self) -> usize {
        match self.compute {
            ComputeKind::Naive => 1,
            ComputeKind::Tiered => 0,
        }
    }
}

impl Layer for Conv1d {
    fn kind(&self) -> &'static str {
        "conv1d"
    }

    fn set_compute(&mut self, kind: ComputeKind) {
        self.compute = kind;
    }

    fn finalize(&mut self, in_dims: &[TensorDim]) -> Result<FinalizeOut> {
        let d = *in_dims.first().ok_or_else(|| Error::graph("conv1d needs one input"))?;
        // treat as 2-D conv with height 1, kernel 1 x k over `b:c:1:t`.
        let geom = Conv2dGeom {
            in_c: d.c,
            in_h: 1,
            in_w: d.w,
            out_c: self.filters,
            k_h: 1,
            k_w: self.k,
            stride: self.stride,
            pad_h: 0,
            pad_w: self.pad,
        };
        if d.w + 2 * self.pad < self.k {
            return Err(Error::shape(format!("conv1d kernel {} > padded input {}", self.k, d)));
        }
        let ow = geom.out_w();
        let col_len = geom.col_rows() * geom.col_cols();
        let fan_in = geom.col_rows();
        self.geom = Some(geom);
        let mut weights = vec![WeightReq {
            name: "kernel",
            dim: TensorDim::new(1, 1, self.filters, fan_in),
            init: Initializer::XavierUniform { fan_in, fan_out: self.filters * self.k },
            need_cd: true,
        }];
        if self.bias {
            weights.push(WeightReq {
                name: "bias",
                dim: TensorDim::vec(1, self.filters),
                init: Initializer::Zeros,
                need_cd: false,
            });
        }
        let mut temps = vec![];
        if self.compute == ComputeKind::Naive {
            temps.push(TempReq { name: "col", dim: TensorDim::vec(1, col_len), span: Lifespan::ITERATION });
        }
        temps.push(TempReq { name: "colgrad", dim: TensorDim::vec(1, col_len), span: Lifespan::CALC_DERIV });
        Ok(FinalizeOut {
            out_dims: vec![TensorDim::new(d.b, self.filters, 1, ow)],
            weights,
            temps,
            need_input_cg: true,
            ..Default::default()
        })
    }

    fn forward(&self, ctx: &RunCtx) {
        let g = self.geom.as_ref().unwrap();
        let b = ctx.batch();
        let x = ctx.input(0);
        let w = ctx.weight(0);
        let out = ctx.output(0);
        let col = match self.compute {
            ComputeKind::Naive => Some(ctx.temp(0)),
            ComputeKind::Tiered => None,
        };
        let out_sz = g.out_c * g.col_cols();
        ctx.backend.conv2d_forward(x, w, out, g, b, col);
        if self.bias {
            let bias = ctx.weight(1);
            let t = g.col_cols();
            for s in 0..b {
                for c in 0..g.out_c {
                    for v in out[s * out_sz + c * t..s * out_sz + (c + 1) * t].iter_mut() {
                        *v += bias[c];
                    }
                }
            }
        }
    }

    fn calc_gradient(&self, ctx: &RunCtx) {
        let g = self.geom.as_ref().unwrap();
        let b = ctx.batch();
        let x = ctx.input(0);
        let dout = ctx.out_deriv(0);
        let out_sz = g.out_c * g.col_cols();
        if let Some(gw) = ctx.grad(0) {
            let col = match self.compute {
                ComputeKind::Naive => Some(ctx.temp(0)),
                ComputeKind::Tiered => None,
            };
            ctx.backend.conv2d_grad_w(x, dout, gw, g, b, col);
        }
        if self.bias {
            if let Some(gb) = ctx.grad(1) {
                let t = g.col_cols();
                for s in 0..b {
                    for c in 0..g.out_c {
                        gb[c] += dout[s * out_sz + c * t..s * out_sz + (c + 1) * t]
                            .iter()
                            .sum::<f32>();
                    }
                }
            }
        }
    }

    fn calc_derivative(&self, ctx: &RunCtx) {
        if !ctx.has_in_deriv(0) {
            return;
        }
        let g = self.geom.as_ref().unwrap();
        let b = ctx.batch();
        let w = ctx.weight(0);
        let dout = ctx.out_deriv(0);
        let din = ctx.in_deriv(0);
        let colgrad = ctx.temp(self.colgrad_slot());
        let in_sz = g.in_c * g.in_w;
        let out_sz = g.out_c * g.col_cols();
        for s in 0..b {
            ctx.backend.matmul_at(
                w,
                &dout[s * out_sz..(s + 1) * out_sz],
                colgrad,
                g.col_rows(),
                g.out_c,
                g.col_cols(),
                false,
            );
            nb::col2im(colgrad, g, &mut din[s * in_sz..(s + 1) * in_sz], false);
        }
    }
}
