//! Activation layer — sigmoid / tanh / relu / softmax.
//!
//! The flagship in-place (`MV`) layer of the paper (§3, Fig 1c, Fig 5):
//! its output may share memory with its input because the *output* alone
//! is needed for the backward pass (`ΔD' = X'(1 − X')` for sigmoid), and
//! its input/output derivative buffers are likewise shared.

use crate::backend::native as nb;
use crate::error::{Error, Result};
use crate::tensor::TensorDim;

use super::{FinalizeOut, Inplace, Layer, Props, RunCtx};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ActKind {
    Sigmoid,
    Tanh,
    Relu,
    Softmax,
}

impl ActKind {
    pub fn parse(s: &str) -> Result<ActKind> {
        match s.trim().to_ascii_lowercase().as_str() {
            "sigmoid" => Ok(ActKind::Sigmoid),
            "tanh" => Ok(ActKind::Tanh),
            "relu" => Ok(ActKind::Relu),
            "softmax" => Ok(ActKind::Softmax),
            other => Err(Error::model(format!("unknown activation `{other}`"))),
        }
    }
}

pub struct ActivationLayer {
    pub kind_: ActKind,
    feat: usize,
}

impl ActivationLayer {
    pub fn create(props: &Props) -> Result<Box<dyn Layer>> {
        let kind = ActKind::parse(
            &props
                .string("act")
                .ok_or_else(|| Error::model("activation layer requires act="))?,
        )?;
        Ok(Box::new(ActivationLayer { kind_: kind, feat: 0 }))
    }

    pub fn new(kind: ActKind) -> Self {
        ActivationLayer { kind_: kind, feat: 0 }
    }
}

impl Layer for ActivationLayer {
    fn kind(&self) -> &'static str {
        "activation"
    }

    fn finalize(&mut self, in_dims: &[TensorDim]) -> Result<FinalizeOut> {
        let d = *in_dims
            .first()
            .ok_or_else(|| Error::graph("activation needs one input"))?;
        self.feat = d.feature_len();
        Ok(FinalizeOut {
            out_dims: vec![d],
            inplace: Inplace::Modify,
            need_output_cd: true,
            ..Default::default()
        })
    }

    fn forward(&self, ctx: &RunCtx) {
        let x = ctx.input(0);
        let out = ctx.output(0);
        // When merged in place, input and output are the same region:
        // operate on `out` only. Otherwise copy first.
        if x.as_ptr() != out.as_ptr() {
            out.copy_from_slice(x);
        }
        match self.kind_ {
            ActKind::Sigmoid => {
                for v in out.iter_mut() {
                    *v = nb::sigmoid(*v);
                }
            }
            ActKind::Tanh => {
                for v in out.iter_mut() {
                    *v = v.tanh();
                }
            }
            ActKind::Relu => {
                for v in out.iter_mut() {
                    *v = v.max(0.0);
                }
            }
            ActKind::Softmax => {
                let rows = out.len() / self.feat;
                // softmax_rows handles src == dst (row-local).
                let src = unsafe { std::slice::from_raw_parts(out.as_ptr(), out.len()) };
                nb::softmax_rows(src, out, rows, self.feat);
            }
        }
    }

    fn calc_derivative(&self, ctx: &RunCtx) {
        if !ctx.has_in_deriv(0) {
            return;
        }
        let y = ctx.output(0);
        let dout = ctx.out_deriv(0);
        let din = ctx.in_deriv(0);
        match self.kind_ {
            ActKind::Sigmoid => {
                for i in 0..din.len() {
                    din[i] = dout[i] * y[i] * (1.0 - y[i]);
                }
            }
            ActKind::Tanh => {
                for i in 0..din.len() {
                    din[i] = dout[i] * (1.0 - y[i] * y[i]);
                }
            }
            ActKind::Relu => {
                for i in 0..din.len() {
                    din[i] = if y[i] > 0.0 { dout[i] } else { 0.0 };
                }
            }
            ActKind::Softmax => {
                // din = y ∘ (dout − ⟨dout, y⟩) per row; element-sequential,
                // safe when din aliases dout.
                let rows = din.len() / self.feat;
                for r in 0..rows {
                    let o = r * self.feat;
                    let mut dot = 0f32;
                    for j in 0..self.feat {
                        dot += dout[o + j] * y[o + j];
                    }
                    for j in 0..self.feat {
                        din[o + j] = y[o + j] * (dout[o + j] - dot);
                    }
                }
            }
        }
    }
}
