//! Multi-Out layer (Table 1): fans one tensor out to K consumers.
//!
//! Forward is a copy per branch (outputs could be RV views, but branches
//! may be consumed at interleaved EOs, so the conservative choice is
//! fresh tensors); backward *sums* the branch derivatives — the reason
//! the realizer must materialize this node instead of letting two layers
//! read one output directly.

use crate::error::{Error, Result};
use crate::tensor::TensorDim;

use super::{FinalizeOut, Layer, Props, RunCtx};

pub struct MultiOut {
    n_out: usize,
}

impl MultiOut {
    pub fn create(props: &Props) -> Result<Box<dyn Layer>> {
        Ok(Box::new(MultiOut { n_out: props.usize_or("outputs", 2)? }))
    }

    pub fn with_outputs(n: usize) -> Self {
        MultiOut { n_out: n }
    }
}

impl Layer for MultiOut {
    fn kind(&self) -> &'static str {
        "multiout"
    }

    fn finalize(&mut self, in_dims: &[TensorDim]) -> Result<FinalizeOut> {
        let d = *in_dims.first().ok_or_else(|| Error::graph("multiout needs one input"))?;
        Ok(FinalizeOut {
            out_dims: vec![d; self.n_out],
            ..Default::default()
        })
    }

    fn forward(&self, ctx: &RunCtx) {
        let x = ctx.input(0);
        for k in 0..self.n_out {
            ctx.output(k).copy_from_slice(x);
        }
    }

    fn calc_derivative(&self, ctx: &RunCtx) {
        if !ctx.has_in_deriv(0) {
            return;
        }
        let din = ctx.in_deriv(0);
        din.fill(0.0);
        for k in 0..self.n_out {
            if ctx.has_out_deriv(k) {
                let d = ctx.out_deriv(k);
                for (o, &v) in din.iter_mut().zip(d.iter()) {
                    *o += v;
                }
            }
        }
    }
}
