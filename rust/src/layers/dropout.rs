//! Dropout — in-place (`MV`) capable; the mask is an iteration-lifespan
//! temp so backward can replay it without storing the input.

use crate::error::{Error, Result};
use crate::rng::Rng;
use crate::tensor::{Lifespan, TensorDim};

use super::{FinalizeOut, Inplace, Layer, Props, RunCtx, TempReq};

pub struct Dropout {
    rate: f32,
    seed: u64,
}

impl Dropout {
    pub fn create(props: &Props) -> Result<Box<dyn Layer>> {
        Ok(Box::new(Dropout {
            rate: props.f32_or("rate", 0.5)?,
            seed: props.usize_or("seed", 0x5EED)? as u64,
        }))
    }
}

impl Layer for Dropout {
    fn kind(&self) -> &'static str {
        "dropout"
    }

    fn finalize(&mut self, in_dims: &[TensorDim]) -> Result<FinalizeOut> {
        let d = *in_dims.first().ok_or_else(|| Error::graph("dropout needs one input"))?;
        Ok(FinalizeOut {
            out_dims: vec![d],
            inplace: Inplace::Modify,
            temps: vec![TempReq { name: "mask", dim: d, span: Lifespan::ITERATION }],
            ..Default::default()
        })
    }

    fn forward(&self, ctx: &RunCtx) {
        let x = ctx.input(0);
        let out = ctx.output(0);
        if x.as_ptr() != out.as_ptr() {
            out.copy_from_slice(x);
        }
        if !ctx.training || self.rate == 0.0 {
            return;
        }
        let mask = ctx.temp(0);
        let mut rng = Rng::new(self.seed ^ ctx.iter.wrapping_mul(0x9E37));
        let keep = 1.0 - self.rate;
        let scale = 1.0 / keep;
        for (m, o) in mask.iter_mut().zip(out.iter_mut()) {
            if rng.next_f32() < keep {
                *m = scale;
                *o *= scale;
            } else {
                *m = 0.0;
                *o = 0.0;
            }
        }
    }

    fn calc_derivative(&self, ctx: &RunCtx) {
        if !ctx.has_in_deriv(0) {
            return;
        }
        let dout = ctx.out_deriv(0);
        let din = ctx.in_deriv(0);
        if !ctx.training || self.rate == 0.0 {
            if dout.as_ptr() != din.as_ptr() {
                din.copy_from_slice(dout);
            }
            return;
        }
        let mask = ctx.temp(0);
        for i in 0..din.len() {
            din[i] = dout[i] * mask[i];
        }
    }
}
