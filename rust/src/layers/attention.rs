//! Scaled dot-product attention over a memory sequence — the simplified
//! stand-in for Tacotron2's location-sensitive attention (see DESIGN.md
//! §Substitutions). Inputs: `[query b:1:1:H, memory b:1:T:H]`; output:
//! context `b:1:1:H`. The post-softmax weights are an iteration temp.

use crate::error::{Error, Result};
use crate::tensor::{Lifespan, TensorDim};

use super::{FinalizeOut, Layer, Props, RunCtx, TempReq};

pub struct Attention {
    t: usize,
    h: usize,
}

impl Attention {
    pub fn create(_props: &Props) -> Result<Box<dyn Layer>> {
        Ok(Box::new(Attention { t: 0, h: 0 }))
    }
}

impl Layer for Attention {
    fn kind(&self) -> &'static str {
        "attention"
    }

    fn finalize(&mut self, in_dims: &[TensorDim]) -> Result<FinalizeOut> {
        if in_dims.len() != 2 {
            return Err(Error::graph("attention needs [query, memory]"));
        }
        let q = in_dims[0];
        let m = in_dims[1];
        if q.feature_len() != m.w || q.b != m.b {
            return Err(Error::shape(format!("attention dims: query {q} memory {m}")));
        }
        self.t = m.h;
        self.h = m.w;
        Ok(FinalizeOut {
            out_dims: vec![TensorDim::vec(q.b, self.h)],
            temps: vec![TempReq {
                name: "attw",
                dim: TensorDim::vec(q.b, self.t),
                span: Lifespan::ITERATION,
            }],
            need_input_cd: true,
            ..Default::default()
        })
    }

    fn forward(&self, ctx: &RunCtx) {
        let (b, t, h) = (ctx.batch(), self.t, self.h);
        let q = ctx.input(0);
        let mem = ctx.input(1);
        let out = ctx.output(0);
        let w = ctx.temp(0);
        let scale = 1.0 / (h as f32).sqrt();
        for s in 0..b {
            let qs = &q[s * h..(s + 1) * h];
            // scores
            let ws = &mut w[s * t..(s + 1) * t];
            let mut mx = f32::NEG_INFINITY;
            for step in 0..t {
                let ms = &mem[s * t * h + step * h..s * t * h + (step + 1) * h];
                let mut dot = 0f32;
                for j in 0..h {
                    dot += qs[j] * ms[j];
                }
                ws[step] = dot * scale;
                mx = mx.max(ws[step]);
            }
            // softmax
            let mut sum = 0f32;
            for v in ws.iter_mut() {
                *v = (*v - mx).exp();
                sum += *v;
            }
            let inv = 1.0 / sum;
            for v in ws.iter_mut() {
                *v *= inv;
            }
            // context
            let os = &mut out[s * h..(s + 1) * h];
            os.fill(0.0);
            for step in 0..t {
                let ms = &mem[s * t * h + step * h..s * t * h + (step + 1) * h];
                let wv = ws[step];
                for j in 0..h {
                    os[j] += wv * ms[j];
                }
            }
        }
    }

    fn calc_derivative(&self, ctx: &RunCtx) {
        let (b, t, h) = (ctx.batch(), self.t, self.h);
        let q = ctx.input(0);
        let mem = ctx.input(1);
        let w = ctx.temp(0);
        let dout = ctx.out_deriv(0);
        let scale = 1.0 / (h as f32).sqrt();
        for s in 0..b {
            let qs = &q[s * h..(s + 1) * h];
            let ws = &w[s * t..(s + 1) * t];
            let dos = &dout[s * h..(s + 1) * h];
            // dw[t] = <dout, mem_t>, then softmax jacobian
            let mut dw = vec![0f32; t]; // small (T) — on stack-ish; fine
            let mut dot_sum = 0f32;
            for step in 0..t {
                let ms = &mem[s * t * h + step * h..s * t * h + (step + 1) * h];
                let mut acc = 0f32;
                for j in 0..h {
                    acc += dos[j] * ms[j];
                }
                dw[step] = acc;
                dot_sum += acc * ws[step];
            }
            // d_scores
            for step in 0..t {
                dw[step] = ws[step] * (dw[step] - dot_sum);
            }
            if ctx.has_in_deriv(0) {
                let dq = &mut ctx.in_deriv(0)[s * h..(s + 1) * h];
                dq.fill(0.0);
                for step in 0..t {
                    let ms = &mem[s * t * h + step * h..s * t * h + (step + 1) * h];
                    for j in 0..h {
                        dq[j] += dw[step] * ms[j] * scale;
                    }
                }
            }
            if ctx.has_in_deriv(1) {
                let dm = ctx.in_deriv(1);
                let base = s * t * h;
                for step in 0..t {
                    for j in 0..h {
                        dm[base + step * h + j] =
                            ws[step] * dos[j] + dw[step] * qs[j] * scale;
                    }
                }
            }
        }
    }
}
