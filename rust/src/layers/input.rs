//! Input layer — the graph's data source. Its output tensor is a
//! `Placeholder` (Table 3 `P`): the Batch Queue binds user data into it
//! each iteration; no derivative buffer exists behind it (paper Fig 4 has
//! no `D_0`).

use crate::error::{Error, Result};
use crate::tensor::TensorDim;

use super::{FinalizeOut, Layer, Props, RunCtx};

pub struct InputLayer {
    shape: TensorDim, // per-sample (b ignored)
}

impl InputLayer {
    pub fn create(props: &Props) -> Result<Box<dyn Layer>> {
        let shape = props
            .dim("input_shape")?
            .ok_or_else(|| Error::model("input layer requires input_shape"))?;
        Ok(Box::new(InputLayer { shape }))
    }
}

impl Layer for InputLayer {
    fn kind(&self) -> &'static str {
        "input"
    }

    fn finalize(&mut self, in_dims: &[TensorDim]) -> Result<FinalizeOut> {
        if !in_dims.is_empty() {
            return Err(Error::graph("input layer cannot have inputs"));
        }
        Ok(FinalizeOut {
            // Batch is applied by the graph initializer.
            out_dims: vec![self.shape],
            ..Default::default()
        })
    }

    fn forward(&self, _ctx: &RunCtx) {
        // Data already bound into the placeholder by the Batch Queue.
    }

    fn calc_derivative(&self, _ctx: &RunCtx) {}
}
