//! Layer-operation-basis compute engine (paper §3, Fig 2b).
//!
//! Every layer implements three execution phases — `forward`,
//! `calc_gradient`, `calc_derivative` — and *declares* its tensor needs
//! at finalize time (which phase needs inputs/outputs/weights, whether it
//! can run in place, which scratch tensors it wants and for which
//! lifespan). The graph initializer turns those declarations into
//! `TensorSpec`s; Algorithm 1 turns them into execution orders; the
//! Memory Planner turns those into pool offsets. Layers never allocate.

pub mod activation;
pub mod addition;
pub mod attention;
pub mod batchnorm;
pub mod concat;
pub mod conv1d;
pub mod conv2d;
pub mod dropout;
pub mod embedding;
pub mod fc;
pub mod flatten;
pub mod gru;
pub mod input;
pub mod loss;
pub mod lstm;
pub mod multiout;
pub mod pooling;
pub mod props;

use std::collections::HashMap;

use crate::backend::{Backend, ComputeKind};
use crate::error::Result;
use crate::planner::pool::MemoryPool;
use crate::tensor::{Initializer, Lifespan, TensorDim, TensorId, TensorTable};

pub use props::Props;

/// Whether a layer's output may share memory with its input (Table 3).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Inplace {
    /// Output is a fresh tensor (`C`).
    None,
    /// Output is a data-modifying view of the input (`MV`) — activations,
    /// batch-norm, dropout. Derivative buffers are shared the same way.
    Modify,
    /// Output is a read-only view (`RV`) — flatten/reshape. Always
    /// mergeable regardless of execution orders (integrity is guaranteed).
    ReadOnly,
}

/// A trainable-parameter request.
#[derive(Clone, Debug)]
pub struct WeightReq {
    pub name: &'static str,
    pub dim: TensorDim,
    pub init: Initializer,
    /// Weight value is read during calc_derivative (true for almost every
    /// parametric layer: `ΔD' = ΔD · Wᵀ`).
    pub need_cd: bool,
}

/// A scratch-tensor request with an explicit lifespan.
#[derive(Clone, Debug)]
pub struct TempReq {
    pub name: &'static str,
    pub dim: TensorDim,
    pub span: Lifespan,
}

/// Everything a layer declares at finalize time.
#[derive(Clone, Debug)]
pub struct FinalizeOut {
    pub out_dims: Vec<TensorDim>,
    pub weights: Vec<WeightReq>,
    pub temps: Vec<TempReq>,
    pub inplace: Inplace,
    /// Input activation is read during compute-gradient (`ΔW = Xᵀ·ΔD`).
    pub need_input_cg: bool,
    /// Input activation is read during compute-derivative.
    pub need_input_cd: bool,
    /// Output activation is read during compute-derivative (sigmoid/tanh/
    /// softmax use their own outputs).
    pub need_output_cd: bool,
    /// Output activation is read during compute-gradient.
    pub need_output_cg: bool,
    /// Layer computes gradients and derivatives in one sweep
    /// (`calc_gradient` does both; `calc_derivative` is skipped). Used by
    /// recurrent layers where both phases share the BPTT recursion.
    pub fused_backward: bool,
}

impl Default for FinalizeOut {
    fn default() -> Self {
        FinalizeOut {
            out_dims: vec![],
            weights: vec![],
            temps: vec![],
            inplace: Inplace::None,
            need_input_cg: false,
            need_input_cd: false,
            need_output_cd: false,
            need_output_cg: false,
            fused_backward: false,
        }
    }
}

/// Tensor bindings of one graph node, filled in by the graph initializer.
#[derive(Clone, Debug, Default)]
pub struct LayerIo {
    /// Activation tensors read at forward (producers' outputs).
    pub inputs: Vec<TensorId>,
    /// Activation tensors written at forward.
    pub outputs: Vec<TensorId>,
    /// Derivative buffers this layer *writes* (d/d input). `None` when the
    /// producing edge has no derivative (network inputs).
    pub in_derivs: Vec<Option<TensorId>>,
    /// Derivative buffers this layer *reads* (d/d output), written by the
    /// consumer. `None` for terminal (loss) outputs.
    pub out_derivs: Vec<Option<TensorId>>,
    pub weights: Vec<TensorId>,
    /// Parallel to `weights`; `None` for frozen weights.
    pub grads: Vec<Option<TensorId>>,
    pub temps: Vec<TensorId>,
    /// Label placeholder (loss layers only).
    pub label: Option<TensorId>,
}

/// Per-step execution context handed to layers.
///
/// All accessors resolve a `TensorId` through the merge chain to its pool
/// region. Mutable and immutable views may alias only for tensors the
/// planner merged (in-place layers are written for that).
pub struct RunCtx<'a> {
    pub io: &'a LayerIo,
    pub table: &'a TensorTable,
    pub pool: &'a MemoryPool,
    pub in_dims: &'a [TensorDim],
    pub out_dims: &'a [TensorDim],
    pub training: bool,
    /// Iteration counter (dropout masks, schedules).
    pub iter: u64,
    /// Compute backend every matmul-consuming phase kernels through.
    pub backend: &'a dyn Backend,
}

impl<'a> RunCtx<'a> {
    fn slice(&self, id: TensorId) -> &'a [f32] {
        let root = self.table.resolve(id);
        let r = self.table.get(root).region.unwrap_or_else(|| {
            panic!("tensor `{}` has no region", self.table.get(root).name)
        });
        self.pool.view(r)
    }

    fn slice_mut(&self, id: TensorId) -> &'a mut [f32] {
        let root = self.table.resolve(id);
        let r = self.table.get(root).region.unwrap_or_else(|| {
            panic!("tensor `{}` has no region", self.table.get(root).name)
        });
        self.pool.view_mut(r)
    }

    pub fn input(&self, i: usize) -> &'a [f32] {
        self.slice(self.io.inputs[i])
    }
    pub fn output(&self, i: usize) -> &'a mut [f32] {
        self.slice_mut(self.io.outputs[i])
    }
    /// Derivative w.r.t. input `i` (this layer writes it). Panics if the
    /// edge has none — guarded by `has_in_deriv`.
    pub fn in_deriv(&self, i: usize) -> &'a mut [f32] {
        self.slice_mut(self.io.in_derivs[i].expect("no input derivative"))
    }
    pub fn has_in_deriv(&self, i: usize) -> bool {
        self.io.in_derivs[i].is_some()
    }
    /// Derivative w.r.t. output `i` (written by the consumer).
    pub fn out_deriv(&self, i: usize) -> &'a [f32] {
        self.slice(self.io.out_derivs[i].expect("no output derivative"))
    }
    pub fn has_out_deriv(&self, i: usize) -> bool {
        self.io.out_derivs[i].is_some()
    }
    pub fn weight(&self, i: usize) -> &'a [f32] {
        self.slice(self.io.weights[i])
    }
    pub fn weight_mut(&self, i: usize) -> &'a mut [f32] {
        self.slice_mut(self.io.weights[i])
    }
    /// Gradient buffer for weight `i`; `None` when the weight is frozen
    /// (transfer learning) — layers must skip the computation then.
    pub fn grad(&self, i: usize) -> Option<&'a mut [f32]> {
        self.io.grads[i].map(|id| self.slice_mut(id))
    }
    pub fn temp(&self, i: usize) -> &'a mut [f32] {
        self.slice_mut(self.io.temps[i])
    }
    pub fn label(&self) -> &'a [f32] {
        self.slice(self.io.label.expect("layer has no label"))
    }

    pub fn batch(&self) -> usize {
        self.in_dims.first().or(self.out_dims.first()).map(|d| d.b).unwrap_or(1)
    }
}

/// A neural-network layer, operating on pool tensors only.
pub trait Layer: Send {
    fn kind(&self) -> &'static str;

    /// Record which compute backend the model compiles for. Called once,
    /// before `finalize`, so layers whose tensor declarations depend on
    /// the backend (conv's `col` temp exists only for `Naive`) can adapt
    /// them. Default: ignore — most layers' declarations are
    /// backend-independent.
    fn set_compute(&mut self, _kind: ComputeKind) {}

    /// Shape inference + tensor declaration. Called once at initialize.
    fn finalize(&mut self, in_dims: &[TensorDim]) -> Result<FinalizeOut>;

    /// Forward phase (EO = i).
    fn forward(&self, ctx: &RunCtx);

    /// Compute-gradient phase (EO = 3N − 2(i+1)). Default: no weights.
    fn calc_gradient(&self, _ctx: &RunCtx) {}

    /// Compute-derivative phase (EO = CG + 1). Propagates `ΔD` to the
    /// producer. Layers with `fused_backward` do this inside
    /// `calc_gradient` instead.
    fn calc_derivative(&self, ctx: &RunCtx);
}

/// Layer constructor registry — the paper's `AppContext` lets applications
/// register custom layer types; `model::appctx` builds on this.
pub type LayerFactory = fn(&Props) -> Result<Box<dyn Layer>>;

/// Built-in layer types, keyed by their INI `Type=` string.
pub fn builtin_factories() -> HashMap<&'static str, LayerFactory> {
    let mut m: HashMap<&'static str, LayerFactory> = HashMap::new();
    m.insert("input", input::InputLayer::create as LayerFactory);
    m.insert("fully_connected", fc::FullyConnected::create as LayerFactory);
    m.insert("conv2d", conv2d::Conv2d::create as LayerFactory);
    m.insert("conv1d", conv1d::Conv1d::create as LayerFactory);
    m.insert("lstm", lstm::Lstm::create as LayerFactory);
    m.insert("gru", gru::Gru::create as LayerFactory);
    m.insert("activation", activation::ActivationLayer::create as LayerFactory);
    m.insert("batch_normalization", batchnorm::BatchNorm::create as LayerFactory);
    m.insert("flatten", flatten::Flatten::create as LayerFactory);
    m.insert("concat", concat::Concat::create as LayerFactory);
    m.insert("addition", addition::Addition::create as LayerFactory);
    m.insert("multiout", multiout::MultiOut::create as LayerFactory);
    m.insert("embedding", embedding::Embedding::create as LayerFactory);
    m.insert("pooling2d", pooling::Pooling2d::create as LayerFactory);
    m.insert("dropout", dropout::Dropout::create as LayerFactory);
    m.insert("attention", attention::Attention::create as LayerFactory);
    m.insert("mse", loss::MseLoss::create as LayerFactory);
    m.insert("cross_entropy_softmax", loss::CrossEntropySoftmax::create as LayerFactory);
    m
}
