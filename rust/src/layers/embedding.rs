//! Embedding layer (Product-Rating / recommendation case, Fig 12).
//!
//! Input: `b:1:1:L` of f32-encoded indices; output `b:1:L:E`. Backward is
//! a sparse scatter-add into the gradient rows; no input derivative
//! exists (indices are not differentiable).

use crate::error::{Error, Result};
use crate::tensor::{Initializer, TensorDim};

use super::{FinalizeOut, Layer, Props, RunCtx, WeightReq};

pub struct Embedding {
    vocab: usize,
    dim: usize,
    seq: usize,
}

impl Embedding {
    pub fn create(props: &Props) -> Result<Box<dyn Layer>> {
        Ok(Box::new(Embedding {
            vocab: props.usize_req("in_dim")?,
            dim: props.usize_req("out_dim")?,
            seq: 0,
        }))
    }
}

impl Layer for Embedding {
    fn kind(&self) -> &'static str {
        "embedding"
    }

    fn finalize(&mut self, in_dims: &[TensorDim]) -> Result<FinalizeOut> {
        let d = *in_dims.first().ok_or_else(|| Error::graph("embedding needs one input"))?;
        self.seq = d.feature_len();
        Ok(FinalizeOut {
            out_dims: vec![TensorDim::new(d.b, 1, self.seq, self.dim)],
            weights: vec![WeightReq {
                name: "table",
                dim: TensorDim::new(1, 1, self.vocab, self.dim),
                init: Initializer::Uniform(0.05),
                need_cd: false,
            }],
            // indices are re-read at CG for the scatter.
            need_input_cg: true,
            ..Default::default()
        })
    }

    fn forward(&self, ctx: &RunCtx) {
        let idx = ctx.input(0);
        let table = ctx.weight(0);
        let out = ctx.output(0);
        for (t, &ix) in idx.iter().enumerate() {
            let row = (ix as usize).min(self.vocab - 1);
            out[t * self.dim..(t + 1) * self.dim]
                .copy_from_slice(&table[row * self.dim..(row + 1) * self.dim]);
        }
    }

    fn calc_gradient(&self, ctx: &RunCtx) {
        let idx = ctx.input(0);
        let dout = ctx.out_deriv(0);
        if let Some(gt) = ctx.grad(0) {
            for (t, &ix) in idx.iter().enumerate() {
                let row = (ix as usize).min(self.vocab - 1);
                let g = &mut gt[row * self.dim..(row + 1) * self.dim];
                let d = &dout[t * self.dim..(t + 1) * self.dim];
                for (gv, &dv) in g.iter_mut().zip(d.iter()) {
                    *gv += dv;
                }
            }
        }
    }

    fn calc_derivative(&self, _ctx: &RunCtx) {
        // indices are not differentiable; nothing to propagate.
    }
}
