//! Addition layer: elementwise sum of N inputs (residual connections).
//! One of the paper's explicitly-called-out low OP/byte layers (§1
//! "Computation") — memory traffic dominated, so it must not allocate.

use crate::error::{Error, Result};
use crate::tensor::TensorDim;

use super::{FinalizeOut, Layer, Props, RunCtx};

pub struct Addition {
    n_in: usize,
}

impl Addition {
    pub fn create(_props: &Props) -> Result<Box<dyn Layer>> {
        Ok(Box::new(Addition { n_in: 0 }))
    }
}

impl Layer for Addition {
    fn kind(&self) -> &'static str {
        "addition"
    }

    fn finalize(&mut self, in_dims: &[TensorDim]) -> Result<FinalizeOut> {
        if in_dims.len() < 2 {
            return Err(Error::graph("addition needs >= 2 inputs"));
        }
        let d = in_dims[0];
        for other in &in_dims[1..] {
            if *other != d {
                return Err(Error::shape(format!("addition dims {} vs {}", d, other)));
            }
        }
        self.n_in = in_dims.len();
        Ok(FinalizeOut {
            out_dims: vec![d],
            ..Default::default()
        })
    }

    fn forward(&self, ctx: &RunCtx) {
        let out = ctx.output(0);
        out.copy_from_slice(ctx.input(0));
        for k in 1..self.n_in {
            let x = ctx.input(k);
            for (o, &v) in out.iter_mut().zip(x.iter()) {
                *o += v;
            }
        }
    }

    fn calc_derivative(&self, ctx: &RunCtx) {
        let dout = ctx.out_deriv(0);
        for k in 0..self.n_in {
            if ctx.has_in_deriv(k) {
                ctx.in_deriv(k).copy_from_slice(dout);
            }
        }
    }
}
