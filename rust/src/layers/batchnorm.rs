//! Batch normalization — in-place (`MV`) capable per the paper (§3: "This
//! is applied to batch normalization as well").
//!
//! Normalizes per channel for 4-D inputs (`b:c:h:w`, over b,h,w) or per
//! feature for flat inputs. Keeps `x̂` (normalized input) in an
//! iteration-lifespan temp so backward never needs the original input —
//! this is what makes the MV merge legal.

use crate::error::{Error, Result};
use crate::tensor::{Initializer, Lifespan, TensorDim};

use super::{FinalizeOut, Inplace, Layer, Props, RunCtx, TempReq, WeightReq};

pub struct BatchNorm {
    eps: f32,
    momentum: f32,
    ch: usize,      // channels (or features when flat)
    n_per: usize,   // reduction size per channel (b*h*w or b)
    spatial: usize, // h*w for 4-D, 1 for flat
}

impl BatchNorm {
    pub fn create(props: &Props) -> Result<Box<dyn Layer>> {
        Ok(Box::new(BatchNorm {
            eps: props.f32_or("epsilon", 1e-5)?,
            momentum: props.f32_or("momentum", 0.9)?,
            ch: 0,
            n_per: 0,
            spatial: 0,
        }))
    }

    #[inline]
    fn idx(&self, c: usize, r: usize) -> usize {
        // r enumerates the reduction set of channel c:
        // for 4-D, r = s * spatial + p, laid out [b][c][h*w]
        let b = r / self.spatial;
        let p = r % self.spatial;
        (b * self.ch + c) * self.spatial + p
    }
}

impl Layer for BatchNorm {
    fn kind(&self) -> &'static str {
        "batch_normalization"
    }

    fn finalize(&mut self, in_dims: &[TensorDim]) -> Result<FinalizeOut> {
        let d = *in_dims.first().ok_or_else(|| Error::graph("batchnorm needs one input"))?;
        let flat = d.c == 1 && d.h == 1;
        if flat {
            self.ch = d.w;
            self.spatial = 1;
            self.n_per = d.b;
        } else {
            self.ch = d.c;
            self.spatial = d.h * d.w;
            self.n_per = d.b * self.spatial;
        }
        let cdim = TensorDim::vec(1, self.ch);
        Ok(FinalizeOut {
            out_dims: vec![d],
            inplace: Inplace::Modify,
            weights: vec![
                WeightReq { name: "gamma", dim: cdim, init: Initializer::Ones, need_cd: true },
                WeightReq { name: "beta", dim: cdim, init: Initializer::Zeros, need_cd: false },
            ],
            temps: vec![
                // normalized input, needed by both CG and CD.
                TempReq { name: "xhat", dim: d, span: Lifespan::ITERATION },
                // 1/std per channel.
                TempReq { name: "inv_std", dim: cdim, span: Lifespan::ITERATION },
                // running stats — persist across iterations (inference).
                TempReq { name: "run_mean", dim: cdim, span: Lifespan::MAX },
                TempReq { name: "run_var", dim: cdim, span: Lifespan::MAX },
            ],
            ..Default::default()
        })
    }

    fn forward(&self, ctx: &RunCtx) {
        let x = ctx.input(0);
        let out = ctx.output(0);
        let gamma = ctx.weight(0);
        let beta = ctx.weight(1);
        let xhat = ctx.temp(0);
        let inv_std = ctx.temp(1);
        let n = self.n_per as f32;
        if ctx.training {
            let run_mean = ctx.temp(2);
            let run_var = ctx.temp(3);
            for c in 0..self.ch {
                let mut mean = 0f32;
                for r in 0..self.n_per {
                    mean += x[self.idx(c, r)];
                }
                mean /= n;
                let mut var = 0f32;
                for r in 0..self.n_per {
                    let dlt = x[self.idx(c, r)] - mean;
                    var += dlt * dlt;
                }
                var /= n;
                let istd = 1.0 / (var + self.eps).sqrt();
                inv_std[c] = istd;
                run_mean[c] = self.momentum * run_mean[c] + (1.0 - self.momentum) * mean;
                run_var[c] = self.momentum * run_var[c] + (1.0 - self.momentum) * var;
                for r in 0..self.n_per {
                    let i = self.idx(c, r);
                    let xh = (x[i] - mean) * istd;
                    xhat[i] = xh;
                    out[i] = gamma[c] * xh + beta[c];
                }
            }
        } else {
            let run_mean = ctx.temp(2);
            let run_var = ctx.temp(3);
            for c in 0..self.ch {
                let istd = 1.0 / (run_var[c] + self.eps).sqrt();
                for r in 0..self.n_per {
                    let i = self.idx(c, r);
                    out[i] = gamma[c] * (x[i] - run_mean[c]) * istd + beta[c];
                }
            }
        }
    }

    fn calc_gradient(&self, ctx: &RunCtx) {
        let dout = ctx.out_deriv(0);
        let xhat = ctx.temp(0);
        if let Some(gg) = ctx.grad(0) {
            for c in 0..self.ch {
                let mut acc = 0f32;
                for r in 0..self.n_per {
                    let i = self.idx(c, r);
                    acc += dout[i] * xhat[i];
                }
                gg[c] += acc;
            }
        }
        if let Some(gb) = ctx.grad(1) {
            for c in 0..self.ch {
                let mut acc = 0f32;
                for r in 0..self.n_per {
                    acc += dout[self.idx(c, r)];
                }
                gb[c] += acc;
            }
        }
    }

    fn calc_derivative(&self, ctx: &RunCtx) {
        if !ctx.has_in_deriv(0) {
            return;
        }
        let dout = ctx.out_deriv(0);
        let din = ctx.in_deriv(0);
        let gamma = ctx.weight(0);
        let xhat = ctx.temp(0);
        let inv_std = ctx.temp(1);
        let n = self.n_per as f32;
        // din = gamma*istd/n * (n*dout − Σdout − x̂·Σ(dout·x̂))
        for c in 0..self.ch {
            let mut sum_d = 0f32;
            let mut sum_dx = 0f32;
            for r in 0..self.n_per {
                let i = self.idx(c, r);
                sum_d += dout[i];
                sum_dx += dout[i] * xhat[i];
            }
            let k = gamma[c] * inv_std[c] / n;
            for r in 0..self.n_per {
                let i = self.idx(c, r);
                din[i] = k * (n * dout[i] - sum_d - xhat[i] * sum_dx);
            }
        }
    }
}
