//! Flatten / Reshape — pure-specification changes.
//!
//! The paper's Read-Only-View (`RV`) case (Fig 6): data is bit-identical,
//! so the view is merged with its target even when execution orders
//! interleave — integrity is guaranteed by the developer contract.

use crate::error::{Error, Result};
use crate::tensor::TensorDim;

use super::{FinalizeOut, Inplace, Layer, Props, RunCtx};

pub struct Flatten {
    /// Optional explicit target per-sample shape (reshape); default is
    /// `b:1:1:(c*h*w)`.
    target: Option<TensorDim>,
}

impl Flatten {
    pub fn create(props: &Props) -> Result<Box<dyn Layer>> {
        Ok(Box::new(Flatten { target: props.dim("target_shape")? }))
    }
}

impl Layer for Flatten {
    fn kind(&self) -> &'static str {
        "flatten"
    }

    fn finalize(&mut self, in_dims: &[TensorDim]) -> Result<FinalizeOut> {
        let d = *in_dims.first().ok_or_else(|| Error::graph("flatten needs one input"))?;
        let out = match self.target {
            Some(t) => {
                let t = t.with_batch(d.b);
                if t.len() != d.len() {
                    return Err(Error::shape(format!(
                        "reshape {} -> {} changes element count",
                        d, t
                    )));
                }
                t
            }
            None => d.flattened(),
        };
        Ok(FinalizeOut {
            out_dims: vec![out],
            inplace: Inplace::ReadOnly,
            ..Default::default()
        })
    }

    fn forward(&self, ctx: &RunCtx) {
        let x = ctx.input(0);
        let out = ctx.output(0);
        if x.as_ptr() != out.as_ptr() {
            out.copy_from_slice(x);
        }
    }

    fn calc_derivative(&self, ctx: &RunCtx) {
        if !ctx.has_in_deriv(0) {
            return;
        }
        let dout = ctx.out_deriv(0);
        let din = ctx.in_deriv(0);
        if dout.as_ptr() != din.as_ptr() {
            din.copy_from_slice(dout);
        }
    }
}
