//! Concatenate layer: joins N inputs along the channel axis (or the
//! feature axis for flat tensors). Table 1's Concat realizer materializes
//! this node whenever a layer lists multiple `input_layers` but does not
//! reduce them itself.

use crate::error::{Error, Result};
use crate::tensor::TensorDim;

use super::{FinalizeOut, Layer, Props, RunCtx};

pub struct Concat {
    in_dims: Vec<TensorDim>,
}

impl Concat {
    pub fn create(_props: &Props) -> Result<Box<dyn Layer>> {
        Ok(Box::new(Concat { in_dims: vec![] }))
    }
}

impl Layer for Concat {
    fn kind(&self) -> &'static str {
        "concat"
    }

    fn finalize(&mut self, in_dims: &[TensorDim]) -> Result<FinalizeOut> {
        if in_dims.len() < 2 {
            return Err(Error::graph("concat needs >= 2 inputs"));
        }
        let d0 = in_dims[0];
        // Concatenate along the flattened per-sample feature axis; all
        // inputs must share the batch.
        for d in in_dims {
            if d.b != d0.b {
                return Err(Error::shape("concat inputs must share batch"));
            }
        }
        self.in_dims = in_dims.to_vec();
        let total: usize = in_dims.iter().map(|d| d.feature_len()).sum();
        Ok(FinalizeOut {
            out_dims: vec![TensorDim::vec(d0.b, total)],
            ..Default::default()
        })
    }

    fn forward(&self, ctx: &RunCtx) {
        let out = ctx.output(0);
        let b = ctx.batch();
        let total: usize = self.in_dims.iter().map(|d| d.feature_len()).sum();
        let mut off = 0usize;
        for (k, d) in self.in_dims.iter().enumerate() {
            let f = d.feature_len();
            let x = ctx.input(k);
            for s in 0..b {
                out[s * total + off..s * total + off + f].copy_from_slice(&x[s * f..(s + 1) * f]);
            }
            off += f;
        }
    }

    fn calc_derivative(&self, ctx: &RunCtx) {
        let dout = ctx.out_deriv(0);
        let b = ctx.batch();
        let total: usize = self.in_dims.iter().map(|d| d.feature_len()).sum();
        let mut off = 0usize;
        for (k, d) in self.in_dims.iter().enumerate() {
            let f = d.feature_len();
            if ctx.has_in_deriv(k) {
                let din = ctx.in_deriv(k);
                for s in 0..b {
                    din[s * f..(s + 1) * f]
                        .copy_from_slice(&dout[s * total + off..s * total + off + f]);
                }
            }
            off += f;
        }
    }
}
