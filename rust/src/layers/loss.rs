//! Loss layers: Mean Squared Error and fused Softmax-Cross-Entropy.
//!
//! A loss layer terminates the graph: its single output is the scalar
//! loss, it owns a `label` placeholder, and its compute-derivative phase
//! *starts* back-propagation. The Loss realizer (Table 1) removes a
//! preceding softmax activation and swaps in the fused layer — both for
//! numerical stability and to save one intermediate activation.

use crate::backend::native as nb;
use crate::error::{Error, Result};
use crate::tensor::{Lifespan, TensorDim};

use super::{FinalizeOut, Layer, Props, RunCtx, TempReq};

/// Marker trait helper: the graph initializer identifies loss layers via
/// `Layer::kind()` strings listed here.
pub fn is_loss_kind(kind: &str) -> bool {
    matches!(kind, "mse" | "cross_entropy_softmax")
}

pub struct MseLoss {
    n: usize, // total elements (batch * feat), for the mean
}

impl MseLoss {
    pub fn create(_props: &Props) -> Result<Box<dyn Layer>> {
        Ok(Box::new(MseLoss { n: 0 }))
    }
}

impl Layer for MseLoss {
    fn kind(&self) -> &'static str {
        "mse"
    }

    fn finalize(&mut self, in_dims: &[TensorDim]) -> Result<FinalizeOut> {
        let d = *in_dims.first().ok_or_else(|| Error::graph("mse needs one input"))?;
        self.n = d.len();
        Ok(FinalizeOut {
            out_dims: vec![TensorDim::scalar(1)],
            need_input_cd: true,
            ..Default::default()
        })
    }

    fn forward(&self, ctx: &RunCtx) {
        let pred = ctx.input(0);
        let label = ctx.label();
        let mut acc = 0f64;
        for (&p, &l) in pred.iter().zip(label.iter()) {
            let e = (p - l) as f64;
            acc += e * e;
        }
        ctx.output(0)[0] = (acc / self.n as f64) as f32;
    }

    fn calc_derivative(&self, ctx: &RunCtx) {
        if !ctx.has_in_deriv(0) {
            return;
        }
        let pred = ctx.input(0);
        let label = ctx.label();
        let din = ctx.in_deriv(0);
        let scale = 2.0 / self.n as f32;
        for i in 0..din.len() {
            din[i] = scale * (pred[i] - label[i]);
        }
    }
}

/// Softmax + cross-entropy fused: `loss = −Σ label·log softmax(x) / B`.
/// The derivative handles unnormalized (soft) labels exactly:
/// `ΔD' = ((Σ_j label_j)·softmax(x) − label) / B` — which reduces to the
/// textbook `(softmax − label)/B` when labels are one-hot.
pub struct CrossEntropySoftmax {
    feat: usize,
    batch: usize,
}

impl CrossEntropySoftmax {
    pub fn create(_props: &Props) -> Result<Box<dyn Layer>> {
        Ok(Box::new(CrossEntropySoftmax { feat: 0, batch: 0 }))
    }
}

impl Layer for CrossEntropySoftmax {
    fn kind(&self) -> &'static str {
        "cross_entropy_softmax"
    }

    fn finalize(&mut self, in_dims: &[TensorDim]) -> Result<FinalizeOut> {
        let d = *in_dims
            .first()
            .ok_or_else(|| Error::graph("cross_entropy_softmax needs one input"))?;
        self.feat = d.feature_len();
        self.batch = d.b;
        Ok(FinalizeOut {
            out_dims: vec![TensorDim::scalar(1)],
            // softmax probabilities, computed at forward and re-used at CD.
            temps: vec![TempReq {
                name: "probs",
                dim: d,
                span: Lifespan::FORWARD.union(Lifespan::CALC_DERIV),
            }],
            ..Default::default()
        })
    }

    fn forward(&self, ctx: &RunCtx) {
        let x = ctx.input(0);
        let label = ctx.label();
        let probs = ctx.temp(0);
        let rows = x.len() / self.feat;
        nb::softmax_rows(x, probs, rows, self.feat);
        let mut acc = 0f64;
        for (&p, &l) in probs.iter().zip(label.iter()) {
            if l != 0.0 {
                acc -= (l as f64) * (p.max(1e-12) as f64).ln();
            }
        }
        ctx.output(0)[0] = (acc / rows as f64) as f32;
    }

    fn calc_derivative(&self, ctx: &RunCtx) {
        if !ctx.has_in_deriv(0) {
            return;
        }
        let probs = ctx.temp(0);
        let label = ctx.label();
        let din = ctx.in_deriv(0);
        let rows = din.len() / self.feat;
        let scale = 1.0 / rows as f32;
        for r in 0..rows {
            let o = r * self.feat;
            let lsum: f32 = label[o..o + self.feat].iter().sum();
            for j in 0..self.feat {
                din[o + j] = scale * (lsum * probs[o + j] - label[o + j]);
            }
        }
    }
}
