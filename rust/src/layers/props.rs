//! Layer property bag: `key = value` pairs from the INI model description
//! or the builder API (paper §4: layers are stored as tuples of
//! `[<Layer type>, <Properties (key, value)>]` after *Load*).

use std::collections::HashMap;

use crate::error::{Error, Result};
use crate::tensor::TensorDim;

/// Case-insensitive `key → value` property map.
#[derive(Clone, Debug, Default)]
pub struct Props {
    map: HashMap<String, String>,
}

impl Props {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn from_pairs<I, K, V>(pairs: I) -> Self
    where
        I: IntoIterator<Item = (K, V)>,
        K: Into<String>,
        V: Into<String>,
    {
        let mut p = Props::new();
        for (k, v) in pairs {
            p.set(k, v);
        }
        p
    }

    pub fn set(&mut self, key: impl Into<String>, value: impl Into<String>) {
        self.map.insert(key.into().to_ascii_lowercase(), value.into());
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.map.get(&key.to_ascii_lowercase()).map(|s| s.as_str())
    }

    pub fn contains(&self, key: &str) -> bool {
        self.map.contains_key(&key.to_ascii_lowercase())
    }

    fn parse_err(key: &str, value: &str, reason: impl ToString) -> Error {
        Error::Property {
            key: key.to_string(),
            value: value.to_string(),
            reason: reason.to_string(),
        }
    }

    pub fn usize(&self, key: &str) -> Result<Option<usize>> {
        match self.get(key) {
            None => Ok(None),
            Some(v) => v
                .trim()
                .parse::<usize>()
                .map(Some)
                .map_err(|e| Self::parse_err(key, v, e)),
        }
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        Ok(self.usize(key)?.unwrap_or(default))
    }

    pub fn usize_req(&self, key: &str) -> Result<usize> {
        self.usize(key)?
            .ok_or_else(|| Self::parse_err(key, "", "required property missing"))
    }

    pub fn f32(&self, key: &str) -> Result<Option<f32>> {
        match self.get(key) {
            None => Ok(None),
            Some(v) => v
                .trim()
                .parse::<f32>()
                .map(Some)
                .map_err(|e| Self::parse_err(key, v, e)),
        }
    }

    pub fn f32_or(&self, key: &str, default: f32) -> Result<f32> {
        Ok(self.f32(key)?.unwrap_or(default))
    }

    pub fn bool_or(&self, key: &str, default: bool) -> Result<bool> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => match v.trim().to_ascii_lowercase().as_str() {
                "true" | "1" | "yes" => Ok(true),
                "false" | "0" | "no" => Ok(false),
                other => Err(Self::parse_err(key, other, "expected bool")),
            },
        }
    }

    pub fn string(&self, key: &str) -> Option<String> {
        self.get(key).map(|s| s.trim().to_string())
    }

    pub fn dim(&self, key: &str) -> Result<Option<TensorDim>> {
        match self.get(key) {
            None => Ok(None),
            Some(v) => TensorDim::parse(v).map(Some),
        }
    }

    /// Comma-separated list value (`input_layers = a, b`).
    pub fn list(&self, key: &str) -> Vec<String> {
        self.get(key)
            .map(|v| {
                v.split(',')
                    .map(|s| s.trim().to_string())
                    .filter(|s| !s.is_empty())
                    .collect()
            })
            .unwrap_or_default()
    }

    pub fn keys(&self) -> impl Iterator<Item = &String> {
        self.map.keys()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn case_insensitive() {
        let mut p = Props::new();
        p.set("Unit", "10");
        assert_eq!(p.usize("unit").unwrap(), Some(10));
        assert_eq!(p.usize_req("UNIT").unwrap(), 10);
    }

    #[test]
    fn defaults_and_errors() {
        let p = Props::from_pairs([("stride", "2"), ("bad", "x")]);
        assert_eq!(p.usize_or("stride", 1).unwrap(), 2);
        assert_eq!(p.usize_or("missing", 7).unwrap(), 7);
        assert!(p.usize("bad").is_err());
        assert!(p.usize_req("missing").is_err());
    }

    #[test]
    fn lists_and_bools() {
        let p = Props::from_pairs([("input_layers", "a, b ,c"), ("flag", "true")]);
        assert_eq!(p.list("input_layers"), vec!["a", "b", "c"]);
        assert!(p.bool_or("flag", false).unwrap());
        assert!(!p.bool_or("missing", false).unwrap());
    }

    #[test]
    fn dims() {
        let p = Props::from_pairs([("input_shape", "3:32:32")]);
        assert_eq!(
            p.dim("input_shape").unwrap().unwrap(),
            TensorDim::new(1, 3, 32, 32)
        );
    }
}
