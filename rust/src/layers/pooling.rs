//! 2-D pooling (max / average). Max pooling stores flat argmax indices in
//! an iteration-lifespan temp so backward can scatter without the input.

use crate::error::{Error, Result};
use crate::tensor::{Lifespan, TensorDim};

use super::{FinalizeOut, Layer, Props, RunCtx, TempReq};

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum PoolKind {
    Max,
    Average,
    /// Global average pooling (`h:w -> 1:1`).
    GlobalAverage,
}

pub struct Pooling2d {
    kind_: PoolKind,
    k: usize,
    stride: usize,
    in_dim: TensorDim,
    out_hw: (usize, usize),
}

impl Pooling2d {
    pub fn create(props: &Props) -> Result<Box<dyn Layer>> {
        let kind_ = match props.get("pooling").unwrap_or("max") {
            "max" => PoolKind::Max,
            "average" | "avg" => PoolKind::Average,
            "global_average" => PoolKind::GlobalAverage,
            other => return Err(Error::model(format!("unknown pooling `{other}`"))),
        };
        let k = props.usize_or("pool_size", 2)?;
        Ok(Box::new(Pooling2d {
            kind_,
            k,
            stride: props.usize_or("stride", k)?,
            in_dim: TensorDim::scalar(1),
            out_hw: (0, 0),
        }))
    }
}

impl Layer for Pooling2d {
    fn kind(&self) -> &'static str {
        "pooling2d"
    }

    fn finalize(&mut self, in_dims: &[TensorDim]) -> Result<FinalizeOut> {
        let d = *in_dims.first().ok_or_else(|| Error::graph("pooling2d needs one input"))?;
        self.in_dim = d;
        let (oh, ow) = match self.kind_ {
            PoolKind::GlobalAverage => (1, 1),
            _ => {
                if d.h < self.k || d.w < self.k {
                    return Err(Error::shape(format!("pool {} > input {}", self.k, d)));
                }
                ((d.h - self.k) / self.stride + 1, (d.w - self.k) / self.stride + 1)
            }
        };
        self.out_hw = (oh, ow);
        let out = TensorDim::new(d.b, d.c, oh, ow);
        let temps = if self.kind_ == PoolKind::Max {
            vec![TempReq { name: "argmax", dim: out, span: Lifespan::ITERATION }]
        } else {
            vec![]
        };
        Ok(FinalizeOut {
            out_dims: vec![out],
            temps,
            ..Default::default()
        })
    }

    fn forward(&self, ctx: &RunCtx) {
        let d = self.in_dim;
        let (oh, ow) = self.out_hw;
        let x = ctx.input(0);
        let out = ctx.output(0);
        let planes = d.b * d.c;
        match self.kind_ {
            PoolKind::GlobalAverage => {
                let hw = d.h * d.w;
                for p in 0..planes {
                    out[p] = x[p * hw..(p + 1) * hw].iter().sum::<f32>() / hw as f32;
                }
            }
            PoolKind::Average => {
                let inv = 1.0 / (self.k * self.k) as f32;
                for p in 0..planes {
                    let plane = &x[p * d.h * d.w..(p + 1) * d.h * d.w];
                    for y in 0..oh {
                        for xx in 0..ow {
                            let mut acc = 0f32;
                            for ky in 0..self.k {
                                for kx in 0..self.k {
                                    acc += plane[(y * self.stride + ky) * d.w + xx * self.stride + kx];
                                }
                            }
                            out[p * oh * ow + y * ow + xx] = acc * inv;
                        }
                    }
                }
            }
            PoolKind::Max => {
                let arg = ctx.temp(0);
                for p in 0..planes {
                    let plane = &x[p * d.h * d.w..(p + 1) * d.h * d.w];
                    for y in 0..oh {
                        for xx in 0..ow {
                            let mut best = f32::NEG_INFINITY;
                            let mut bidx = 0usize;
                            for ky in 0..self.k {
                                for kx in 0..self.k {
                                    let idx = (y * self.stride + ky) * d.w + xx * self.stride + kx;
                                    if plane[idx] > best {
                                        best = plane[idx];
                                        bidx = idx;
                                    }
                                }
                            }
                            out[p * oh * ow + y * ow + xx] = best;
                            arg[p * oh * ow + y * ow + xx] = bidx as f32;
                        }
                    }
                }
            }
        }
    }

    fn calc_derivative(&self, ctx: &RunCtx) {
        if !ctx.has_in_deriv(0) {
            return;
        }
        let d = self.in_dim;
        let (oh, ow) = self.out_hw;
        let dout = ctx.out_deriv(0);
        let din = ctx.in_deriv(0);
        din.fill(0.0);
        let planes = d.b * d.c;
        match self.kind_ {
            PoolKind::GlobalAverage => {
                let hw = d.h * d.w;
                let inv = 1.0 / hw as f32;
                for p in 0..planes {
                    let g = dout[p] * inv;
                    for v in din[p * hw..(p + 1) * hw].iter_mut() {
                        *v += g;
                    }
                }
            }
            PoolKind::Average => {
                let inv = 1.0 / (self.k * self.k) as f32;
                for p in 0..planes {
                    for y in 0..oh {
                        for xx in 0..ow {
                            let g = dout[p * oh * ow + y * ow + xx] * inv;
                            for ky in 0..self.k {
                                for kx in 0..self.k {
                                    din[p * d.h * d.w
                                        + (y * self.stride + ky) * d.w
                                        + xx * self.stride
                                        + kx] += g;
                                }
                            }
                        }
                    }
                }
            }
            PoolKind::Max => {
                let arg = ctx.temp(0);
                for p in 0..planes {
                    for o in 0..oh * ow {
                        let idx = arg[p * oh * ow + o] as usize;
                        din[p * d.h * d.w + idx] += dout[p * oh * ow + o];
                    }
                }
            }
        }
    }
}
