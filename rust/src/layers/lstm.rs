//! LSTM layer over a full sequence, with fused backward (BPTT).
//!
//! Layout: input `b:1:T:I`, output `b:1:T:H` (`return_sequences`) or
//! `b:1:1:H` (last step only). Gate caches (`i,f,g,o` post-activation),
//! cell and hidden sequences are iteration-lifespan temps: they are the
//! ">90% of training memory is intermediate activation" the paper
//! optimizes, and they die at the end of the layer's backward, letting
//! the planner reuse their space.
//!
//! Both backward phases share the single reverse-time recursion, so the
//! layer declares `fused_backward` and performs gradient + derivative in
//! one sweep (the paper's Backward/`B` lifespan).

use crate::backend::native as nb;
use crate::error::{Error, Result};
use crate::tensor::{Initializer, Lifespan, TensorDim};

use super::{FinalizeOut, Layer, Props, RunCtx, TempReq, WeightReq};

pub struct Lstm {
    unit: usize,
    return_sequences: bool,
    t: usize,
    input_feat: usize,
}

impl Lstm {
    pub fn create(props: &Props) -> Result<Box<dyn Layer>> {
        Ok(Box::new(Lstm {
            unit: props.usize_req("unit")?,
            return_sequences: props.bool_or("return_sequences", false)?,
            t: 0,
            input_feat: 0,
        }))
    }
}

// temp indices
const T_GATES: usize = 0; // [B,T,4H] post-activation gates (i,f,g,o)
const T_CS: usize = 1; // [B,T,H] cell states
const T_HS: usize = 2; // [B,T,H] hidden states
const T_XT: usize = 3; // [B,I] gathered x_t
const T_GBUF: usize = 4; // [B,4H] contiguous gate workspace
const T_HBUF: usize = 5; // [B,H] gathered h_{t-1}
const T_DH: usize = 6; // [B,H]
const T_DC: usize = 7; // [B,H]
const T_DGATES: usize = 8; // [B,4H]
const T_DXBUF: usize = 9; // [B,I]

impl Layer for Lstm {
    fn kind(&self) -> &'static str {
        "lstm"
    }

    fn finalize(&mut self, in_dims: &[TensorDim]) -> Result<FinalizeOut> {
        let d = *in_dims.first().ok_or_else(|| Error::graph("lstm needs one input"))?;
        // sequence along h: `b:1:T:I`
        if d.c != 1 {
            return Err(Error::shape(format!("lstm expects b:1:T:I, got {d}")));
        }
        let (t, feat) = (d.h, d.w);
        self.t = t;
        self.input_feat = feat;
        let h = self.unit;
        let b = d.b;
        let out = if self.return_sequences {
            TensorDim::new(b, 1, t, h)
        } else {
            TensorDim::vec(b, h)
        };
        let iter = Lifespan::ITERATION;
        let back = Lifespan::BACKWARD;
        Ok(FinalizeOut {
            out_dims: vec![out],
            weights: vec![
                WeightReq {
                    name: "weight_xh",
                    dim: TensorDim::new(1, 1, feat, 4 * h),
                    init: Initializer::XavierUniform { fan_in: feat, fan_out: 4 * h },
                    need_cd: true,
                },
                WeightReq {
                    name: "weight_hh",
                    dim: TensorDim::new(1, 1, h, 4 * h),
                    init: Initializer::XavierUniform { fan_in: h, fan_out: 4 * h },
                    need_cd: true,
                },
                WeightReq {
                    name: "bias",
                    dim: TensorDim::vec(1, 4 * h),
                    init: Initializer::Zeros,
                    need_cd: false,
                },
            ],
            temps: vec![
                TempReq { name: "gates", dim: TensorDim::new(b, 1, t, 4 * h), span: iter },
                TempReq { name: "cs", dim: TensorDim::new(b, 1, t, h), span: iter },
                TempReq { name: "hs", dim: TensorDim::new(b, 1, t, h), span: iter },
                TempReq { name: "xt", dim: TensorDim::vec(b, feat), span: iter },
                TempReq { name: "gbuf", dim: TensorDim::vec(b, 4 * h), span: iter },
                TempReq { name: "hbuf", dim: TensorDim::vec(b, h), span: iter },
                TempReq { name: "dh", dim: TensorDim::vec(b, h), span: back },
                TempReq { name: "dc", dim: TensorDim::vec(b, h), span: back },
                TempReq { name: "dgates", dim: TensorDim::vec(b, 4 * h), span: back },
                TempReq { name: "dxbuf", dim: TensorDim::vec(b, feat), span: back },
            ],
            need_input_cg: true,
            fused_backward: true,
            ..Default::default()
        })
    }

    fn forward(&self, ctx: &RunCtx) {
        let (b, t, f, h) = (ctx.batch(), self.t, self.input_feat, self.unit);
        let x = ctx.input(0);
        let wx = ctx.weight(0);
        let wh = ctx.weight(1);
        let bias = ctx.weight(2);
        let gates = ctx.temp(T_GATES);
        let cs = ctx.temp(T_CS);
        let hs = ctx.temp(T_HS);
        let xt = ctx.temp(T_XT);
        let gbuf = ctx.temp(T_GBUF);
        let hbuf = ctx.temp(T_HBUF);
        for step in 0..t {
            // gather x_t and h_{t-1} into contiguous [B, ...] matrices
            for s in 0..b {
                xt[s * f..(s + 1) * f]
                    .copy_from_slice(&x[s * t * f + step * f..s * t * f + (step + 1) * f]);
                if step == 0 {
                    hbuf[s * h..(s + 1) * h].fill(0.0);
                } else {
                    hbuf[s * h..(s + 1) * h].copy_from_slice(
                        &hs[s * t * h + (step - 1) * h..s * t * h + step * h],
                    );
                }
            }
            ctx.backend.matmul(xt, wx, gbuf, b, f, 4 * h, false);
            ctx.backend.matmul(hbuf, wh, gbuf, b, h, 4 * h, true);
            nb::add_bias(gbuf, bias, b, 4 * h);
            for s in 0..b {
                let g = &mut gbuf[s * 4 * h..(s + 1) * 4 * h];
                for j in 0..h {
                    g[j] = nb::sigmoid(g[j]); // i
                    g[h + j] = nb::sigmoid(g[h + j]); // f
                    g[2 * h + j] = g[2 * h + j].tanh(); // g
                    g[3 * h + j] = nb::sigmoid(g[3 * h + j]); // o
                }
                for j in 0..h {
                    let c_prev =
                        if step == 0 { 0.0 } else { cs[s * t * h + (step - 1) * h + j] };
                    let c = g[h + j] * c_prev + g[j] * g[2 * h + j];
                    cs[s * t * h + step * h + j] = c;
                    hs[s * t * h + step * h + j] = g[3 * h + j] * c.tanh();
                }
                gates[s * t * 4 * h + step * 4 * h..s * t * 4 * h + (step + 1) * 4 * h]
                    .copy_from_slice(g);
            }
        }
        // emit output
        let out = ctx.output(0);
        if self.return_sequences {
            out.copy_from_slice(hs);
        } else {
            for s in 0..b {
                out[s * h..(s + 1) * h]
                    .copy_from_slice(&hs[s * t * h + (t - 1) * h..s * t * h + t * h]);
            }
        }
    }

    /// Fused backward: gradients *and* input derivative in one BPTT sweep.
    fn calc_gradient(&self, ctx: &RunCtx) {
        let (b, t, f, h) = (ctx.batch(), self.t, self.input_feat, self.unit);
        let x = ctx.input(0);
        let wx = ctx.weight(0);
        let wh = ctx.weight(1);
        let gates = ctx.temp(T_GATES);
        let cs = ctx.temp(T_CS);
        let hs = ctx.temp(T_HS);
        let xt = ctx.temp(T_XT);
        let hbuf = ctx.temp(T_HBUF);
        let dh = ctx.temp(T_DH);
        let dc = ctx.temp(T_DC);
        let dgates = ctx.temp(T_DGATES);
        let dxbuf = ctx.temp(T_DXBUF);
        let dout = ctx.out_deriv(0);
        dh.fill(0.0);
        dc.fill(0.0);
        for step in (0..t).rev() {
            // dh_total = dh (recurrent) + dout contribution at this step
            for s in 0..b {
                let dh_s = &mut dh[s * h..(s + 1) * h];
                if self.return_sequences {
                    for j in 0..h {
                        dh_s[j] += dout[s * t * h + step * h + j];
                    }
                } else if step == t - 1 {
                    for j in 0..h {
                        dh_s[j] += dout[s * h + j];
                    }
                }
            }
            // per-element gate gradients
            for s in 0..b {
                let g = &gates[s * t * 4 * h + step * 4 * h..s * t * 4 * h + (step + 1) * 4 * h];
                let dgs = &mut dgates[s * 4 * h..(s + 1) * 4 * h];
                for j in 0..h {
                    let c = cs[s * t * h + step * h + j];
                    let tc = c.tanh();
                    let (gi, gf, gg, go) = (g[j], g[h + j], g[2 * h + j], g[3 * h + j]);
                    let dht = dh[s * h + j];
                    let dct = dht * go * (1.0 - tc * tc) + dc[s * h + j];
                    let c_prev =
                        if step == 0 { 0.0 } else { cs[s * t * h + (step - 1) * h + j] };
                    // pre-activation gradients
                    dgs[j] = dct * gg * gi * (1.0 - gi); // i
                    dgs[h + j] = dct * c_prev * gf * (1.0 - gf); // f
                    dgs[2 * h + j] = dct * gi * (1.0 - gg * gg); // g
                    dgs[3 * h + j] = dht * tc * go * (1.0 - go); // o
                    dc[s * h + j] = dct * gf;
                }
            }
            // gather x_t and h_{t-1}
            for s in 0..b {
                xt[s * f..(s + 1) * f]
                    .copy_from_slice(&x[s * t * f + step * f..s * t * f + (step + 1) * f]);
                if step == 0 {
                    hbuf[s * h..(s + 1) * h].fill(0.0);
                } else {
                    hbuf[s * h..(s + 1) * h].copy_from_slice(
                        &hs[s * t * h + (step - 1) * h..s * t * h + step * h],
                    );
                }
            }
            // weight gradients
            if let Some(gwx) = ctx.grad(0) {
                ctx.backend.matmul_at(xt, dgates, gwx, f, b, 4 * h, true);
            }
            if let Some(gwh) = ctx.grad(1) {
                ctx.backend.matmul_at(hbuf, dgates, gwh, h, b, 4 * h, true);
            }
            if let Some(gb) = ctx.grad(2) {
                nb::bias_grad(dgates, gb, b, 4 * h, true);
            }
            // input derivative
            if ctx.has_in_deriv(0) {
                ctx.backend.matmul_bt(dgates, wx, dxbuf, b, 4 * h, f, false);
                let din = ctx.in_deriv(0);
                for s in 0..b {
                    din[s * t * f + step * f..s * t * f + (step + 1) * f]
                        .copy_from_slice(&dxbuf[s * f..(s + 1) * f]);
                }
            }
            // dh for previous step
            ctx.backend.matmul_bt(dgates, wh, dh, b, 4 * h, h, false);
        }
    }

    fn calc_derivative(&self, _ctx: &RunCtx) {
        // fused into calc_gradient (see finalize: fused_backward).
    }
}
