//! Machine-readable perf snapshots and regression gates (EXPERIMENTS.md).
//!
//! The paper's headline claims are quantitative — memory down to 1/20,
//! optimizations transparent to accuracy — so the benches must leave a
//! *recorded trajectory*, not just a terminal table. Each paper-figure
//! bench feeds its `Table` rows into a [`BenchReport`] and calls
//! [`finish`], which:
//!
//! 1. reads the committed `BENCH_<name>.json` baseline at the repo root
//!    (tolerating a missing one — the first run seeds it),
//! 2. writes the fresh snapshot over it (commit to update the baseline,
//!    `git checkout` to discard),
//! 3. prints a delta table of every metric shared with the baseline, and
//! 4. under `NNTRAINER_BENCH_GATE=1`, exits nonzero when any *gated*
//!    metric regressed past `NNTRAINER_BENCH_GATE_PCT` percent
//!    (default 10) — the CI `perf-gate` job.
//!
//! Gates only apply against a baseline whose `source` is `"measured"`
//! and whose `dataset` matches the current run: a hand-seeded baseline
//! or a differently-sized smoke run diffs informationally instead of
//! failing on numbers that were never comparable.
//!
//! Everything here is hand-rolled (JSON emitter *and* parser) because
//! the workspace builds with zero crates.io dependencies.

use std::path::{Path, PathBuf};

use crate::bench_util::Table;

// --------------------------------------------------------------- model

/// Regression-gate direction of one metric.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Gate {
    /// Lower is better (peak MiB, stall ms, step latency): gated, a
    /// `+threshold%` increase over the baseline regresses.
    Lower,
    /// Higher is better (iters/s, samples/s): gated, a `-threshold%`
    /// drop under the baseline regresses.
    Higher,
    /// Recorded for the trajectory but never gated (ratios against
    /// emulated baselines, counters without a "better" direction).
    Info,
}

impl Gate {
    pub fn as_str(self) -> &'static str {
        match self {
            Gate::Lower => "lower",
            Gate::Higher => "higher",
            Gate::Info => "info",
        }
    }

    fn from_str(s: &str) -> Result<Gate, String> {
        match s {
            "lower" => Ok(Gate::Lower),
            "higher" => Ok(Gate::Higher),
            "info" => Ok(Gate::Info),
            other => Err(format!("unknown gate {other:?} (lower|higher|info)")),
        }
    }
}

/// One named measurement of a bench row.
#[derive(Clone, Debug)]
pub struct Metric {
    pub name: String,
    pub value: f64,
    pub gate: Gate,
}

impl Metric {
    pub fn lower(name: &str, value: f64) -> Metric {
        Metric { name: name.into(), value, gate: Gate::Lower }
    }
    pub fn higher(name: &str, value: f64) -> Metric {
        Metric { name: name.into(), value, gate: Gate::Higher }
    }
    pub fn info(name: &str, value: f64) -> Metric {
        Metric { name: name.into(), value, gate: Gate::Info }
    }
}

/// One bench case (a `Table` row): a stable id plus its metrics.
#[derive(Clone, Debug)]
pub struct BenchRow {
    pub id: String,
    pub metrics: Vec<Metric>,
}

/// Whether a snapshot's numbers were actually measured on a machine or
/// hand-seeded to bootstrap the trajectory (seeded baselines never gate).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Source {
    Seeded,
    Measured,
}

impl Source {
    pub fn as_str(self) -> &'static str {
        match self {
            Source::Seeded => "seeded",
            Source::Measured => "measured",
        }
    }

    fn from_str(s: &str) -> Result<Source, String> {
        match s {
            "seeded" => Ok(Source::Seeded),
            "measured" => Ok(Source::Measured),
            other => Err(format!("unknown source {other:?} (seeded|measured)")),
        }
    }
}

/// One bench binary's full snapshot — serialized as `BENCH_<name>.json`.
#[derive(Clone, Debug)]
pub struct BenchReport {
    /// Snapshot name: `fig9`, `fig10`, `fig11`, `swap_runtime`.
    pub name: String,
    /// The `NNTRAINER_BENCH_DATASET` the run used (0 for plan-only
    /// benches that never touch data). Gates require an exact match.
    pub dataset: usize,
    pub source: Source,
    pub rows: Vec<BenchRow>,
}

impl BenchReport {
    /// A fresh measured report (what the benches emit).
    pub fn new(name: &str, dataset: usize) -> BenchReport {
        BenchReport { name: name.into(), dataset, source: Source::Measured, rows: vec![] }
    }

    pub fn push(&mut self, id: &str, metrics: Vec<Metric>) {
        self.rows.push(BenchRow { id: id.into(), metrics });
    }

    // ------------------------------------------------------------ emit

    pub fn to_json(&self) -> String {
        let mut s = String::new();
        s.push_str("{\n");
        s.push_str(&format!("  \"name\": {},\n", json_str(&self.name)));
        s.push_str(&format!("  \"dataset\": {},\n", self.dataset));
        s.push_str(&format!("  \"source\": \"{}\",\n", self.source.as_str()));
        s.push_str("  \"rows\": [\n");
        for (ri, row) in self.rows.iter().enumerate() {
            s.push_str(&format!("    {{ \"id\": {}, \"metrics\": [\n", json_str(&row.id)));
            for (mi, m) in row.metrics.iter().enumerate() {
                let comma = if mi + 1 < row.metrics.len() { "," } else { "" };
                s.push_str(&format!(
                    "      {{ \"name\": {}, \"value\": {}, \"gate\": \"{}\" }}{comma}\n",
                    json_str(&m.name),
                    json_num(m.value),
                    m.gate.as_str()
                ));
            }
            let comma = if ri + 1 < self.rows.len() { "," } else { "" };
            s.push_str(&format!("    ] }}{comma}\n"));
        }
        s.push_str("  ]\n}\n");
        s
    }

    // ----------------------------------------------------------- parse

    pub fn from_json(text: &str) -> Result<BenchReport, String> {
        let root = parse_json(text)?;
        let name = root
            .get("name")
            .and_then(Json::as_str)
            .ok_or("missing string field \"name\"")?
            .to_string();
        let dataset = root
            .get("dataset")
            .and_then(Json::as_usize)
            .ok_or("missing integer field \"dataset\"")?;
        let source = Source::from_str(
            root.get("source").and_then(Json::as_str).ok_or("missing string field \"source\"")?,
        )?;
        let mut rows = Vec::new();
        let jrows = root.get("rows").and_then(Json::as_arr).ok_or("missing array field \"rows\"")?;
        for (ri, jrow) in jrows.iter().enumerate() {
            let id = jrow
                .get("id")
                .and_then(Json::as_str)
                .ok_or_else(|| format!("rows[{ri}]: missing string field \"id\""))?
                .to_string();
            let mut metrics = Vec::new();
            let jms = jrow
                .get("metrics")
                .and_then(Json::as_arr)
                .ok_or_else(|| format!("rows[{ri}]: missing array field \"metrics\""))?;
            for (mi, jm) in jms.iter().enumerate() {
                let ctx = || format!("rows[{ri}].metrics[{mi}]");
                let name = jm
                    .get("name")
                    .and_then(Json::as_str)
                    .ok_or_else(|| format!("{}: missing string field \"name\"", ctx()))?
                    .to_string();
                let value = jm
                    .get("value")
                    .and_then(Json::as_f64)
                    .ok_or_else(|| format!("{}: missing numeric field \"value\"", ctx()))?;
                let gate = Gate::from_str(
                    jm.get("gate")
                        .and_then(Json::as_str)
                        .ok_or_else(|| format!("{}: missing string field \"gate\"", ctx()))?,
                )
                .map_err(|e| format!("{}: {e}", ctx()))?;
                metrics.push(Metric { name, value, gate });
            }
            rows.push(BenchRow { id, metrics });
        }
        Ok(BenchReport { name, dataset, source, rows })
    }
}

fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// Non-finite values have no JSON literal; they round-trip through
/// `null` (parsed back as NaN, which the diff skips).
fn json_num(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "null".into()
    }
}

// --------------------------------------------------- minimal JSON parse

enum Json {
    Null,
    Bool(#[allow(dead_code)] bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(kv) => kv.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }
    fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s.as_str()),
            _ => None,
        }
    }
    fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            Json::Null => Some(f64::NAN),
            _ => None,
        }
    }
    fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as usize),
            _ => None,
        }
    }
    fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v.as_slice()),
            _ => None,
        }
    }
}

fn parse_json(text: &str) -> Result<Json, String> {
    let mut p = P { s: text.as_bytes(), i: 0 };
    let v = p.value()?;
    p.ws();
    if p.i != p.s.len() {
        return Err(p.err("trailing content after the JSON value"));
    }
    Ok(v)
}

struct P<'a> {
    s: &'a [u8],
    i: usize,
}

impl<'a> P<'a> {
    fn err(&self, msg: &str) -> String {
        format!("{msg} at byte {}", self.i)
    }
    fn peek(&self) -> Option<u8> {
        self.s.get(self.i).copied()
    }
    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.i += 1;
        }
        c
    }
    fn ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }
    fn expect(&mut self, want: u8) -> Result<(), String> {
        if self.bump() == Some(want) {
            Ok(())
        } else {
            self.i = self.i.saturating_sub(1);
            Err(self.err(&format!("expected '{}'", want as char)))
        }
    }
    fn lit(&mut self, word: &str) -> Result<(), String> {
        if self.s[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(())
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => {
                self.lit("true")?;
                Ok(Json::Bool(true))
            }
            Some(b'f') => {
                self.lit("false")?;
                Ok(Json::Bool(false))
            }
            Some(b'n') => {
                self.lit("null")?;
                Ok(Json::Null)
            }
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.expect(b':')?;
            let val = self.value()?;
            out.push((key, val));
            self.ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(out)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            let val = self.value()?;
            out.push(val);
            self.ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(out)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.ws();
        self.expect(b'"')?;
        // bytes, not chars: multi-byte UTF-8 passes through untouched
        let mut out: Vec<u8> = Vec::new();
        let push_char = |out: &mut Vec<u8>, c: char| {
            let mut buf = [0u8; 4];
            out.extend_from_slice(c.encode_utf8(&mut buf).as_bytes());
        };
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    return String::from_utf8(out).map_err(|_| self.err("invalid UTF-8 in string"))
                }
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push(b'"'),
                    Some(b'\\') => out.push(b'\\'),
                    Some(b'/') => out.push(b'/'),
                    Some(b'n') => out.push(b'\n'),
                    Some(b't') => out.push(b'\t'),
                    Some(b'r') => out.push(b'\r'),
                    Some(b'b') => out.push(0x08),
                    Some(b'f') => out.push(0x0C),
                    Some(b'u') => {
                        let mut cp: u32 = 0;
                        for _ in 0..4 {
                            let d = self.bump().ok_or_else(|| self.err("truncated \\u escape"))?;
                            let digit = (d as char)
                                .to_digit(16)
                                .ok_or_else(|| self.err("bad \\u escape digit"))?;
                            cp = cp * 16 + digit;
                        }
                        push_char(&mut out, char::from_u32(cp).unwrap_or('\u{FFFD}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) => out.push(c),
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let txt =
            std::str::from_utf8(&self.s[start..self.i]).map_err(|_| self.err("bad number"))?;
        txt.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }
}

// ---------------------------------------------------------------- diff

/// One metric compared between baseline and current run.
#[derive(Clone, Debug)]
pub struct MetricDelta {
    pub row: String,
    pub metric: String,
    pub gate: Gate,
    pub base: f64,
    pub cur: f64,
    /// Signed percent change relative to `|base|` (NaN when either side
    /// is non-finite or the baseline is ~0 — such pairs never gate).
    pub change_pct: f64,
}

impl MetricDelta {
    pub fn regressed(&self, threshold_pct: f64) -> bool {
        if !self.change_pct.is_finite() {
            return false;
        }
        match self.gate {
            Gate::Lower => self.change_pct > threshold_pct,
            Gate::Higher => self.change_pct < -threshold_pct,
            Gate::Info => false,
        }
    }

    pub fn improved(&self, threshold_pct: f64) -> bool {
        if !self.change_pct.is_finite() {
            return false;
        }
        match self.gate {
            Gate::Lower => self.change_pct < -threshold_pct,
            Gate::Higher => self.change_pct > threshold_pct,
            Gate::Info => false,
        }
    }
}

/// Full baseline-vs-current comparison.
#[derive(Clone, Debug)]
pub struct DiffReport {
    pub deltas: Vec<MetricDelta>,
    /// Baseline rows the current run no longer produces (warned, not
    /// gated — the bench suite is allowed to evolve).
    pub missing_rows: Vec<String>,
    /// Current rows the baseline has never seen.
    pub new_rows: Vec<String>,
    /// Gates apply only to a measured baseline of the same dataset size.
    pub gate_applies: bool,
    pub gate_note: Option<String>,
    pub threshold_pct: f64,
}

impl DiffReport {
    /// The deltas that fail the gate (empty when gates don't apply).
    pub fn regressions(&self) -> Vec<&MetricDelta> {
        if !self.gate_applies {
            return vec![];
        }
        self.deltas.iter().filter(|d| d.regressed(self.threshold_pct)).collect()
    }

    pub fn render(&self) -> String {
        let fmt = |v: f64| {
            if v.is_finite() {
                format!("{v:.3}")
            } else {
                "-".into()
            }
        };
        let mut t = Table::new(&["row", "metric", "gate", "baseline", "current", "delta%", "status"]);
        for d in &self.deltas {
            let status = if !d.change_pct.is_finite() {
                "-"
            } else if d.regressed(self.threshold_pct) {
                "REGRESSED"
            } else if d.improved(self.threshold_pct) {
                "improved"
            } else {
                "ok"
            };
            let pct = if d.change_pct.is_finite() {
                format!("{:+.1}", d.change_pct)
            } else {
                "-".into()
            };
            t.row(vec![
                d.row.clone(),
                d.metric.clone(),
                d.gate.as_str().into(),
                fmt(d.base),
                fmt(d.cur),
                pct,
                status.into(),
            ]);
        }
        let mut s = format!(
            "\n== perf diff vs committed baseline (threshold {:.0}%) ==\n\n",
            self.threshold_pct
        );
        s.push_str(&t.render());
        if let Some(note) = &self.gate_note {
            s.push_str(&format!("\ngate: informational only — {note}\n"));
        }
        for r in &self.missing_rows {
            s.push_str(&format!("note: baseline row {r:?} not produced by this run\n"));
        }
        for r in &self.new_rows {
            s.push_str(&format!("note: new row {r:?} (no baseline yet)\n"));
        }
        s
    }
}

/// Compare `current` against `baseline`, metric by metric.
pub fn diff(baseline: &BenchReport, current: &BenchReport, threshold_pct: f64) -> DiffReport {
    let mut deltas = Vec::new();
    let mut missing_rows = Vec::new();
    for brow in &baseline.rows {
        let Some(crow) = current.rows.iter().find(|r| r.id == brow.id) else {
            missing_rows.push(brow.id.clone());
            continue;
        };
        for bm in &brow.metrics {
            let Some(cm) = crow.metrics.iter().find(|m| m.name == bm.name) else { continue };
            let change_pct =
                if bm.value.is_finite() && cm.value.is_finite() && bm.value.abs() > 1e-9 {
                    (cm.value - bm.value) / bm.value.abs() * 100.0
                } else {
                    f64::NAN
                };
            deltas.push(MetricDelta {
                row: brow.id.clone(),
                metric: bm.name.clone(),
                // the current code's gate class wins: a metric can be
                // reclassified without resnapshotting the baseline
                gate: cm.gate,
                base: bm.value,
                cur: cm.value,
                change_pct,
            });
        }
    }
    let new_rows = current
        .rows
        .iter()
        .filter(|r| !baseline.rows.iter().any(|b| b.id == r.id))
        .map(|r| r.id.clone())
        .collect();
    let (gate_applies, gate_note) = if baseline.source != Source::Measured {
        (false, Some("baseline is hand-seeded; re-run the bench and commit the snapshot to arm the gate".to_string()))
    } else if baseline.dataset != current.dataset {
        (
            false,
            Some(format!(
                "baseline dataset {} != current dataset {} — numbers are not comparable",
                baseline.dataset, current.dataset
            )),
        )
    } else {
        (true, None)
    };
    DiffReport { deltas, missing_rows, new_rows, gate_applies, gate_note, threshold_pct }
}

// --------------------------------------------------------------- driver

/// Repo root: the workspace directory holding the committed
/// `BENCH_*.json` baselines (the crate lives in `<root>/rust`).
pub fn repo_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("crate directory has a parent")
        .to_path_buf()
}

/// `"1"`/`"true"`/`"yes"` arm, `"0"`/`"false"`/`"no"`/unset/empty
/// disarm; anything else is a loud error (no swallow-and-default).
fn env_flag(name: &str) -> bool {
    match std::env::var(name) {
        Ok(v) => match v.trim() {
            "1" | "true" | "yes" => true,
            "0" | "false" | "no" | "" => false,
            other => panic!("{name}={other:?} is not a boolean (use 1 or 0)"),
        },
        Err(std::env::VarError::NotPresent) => false,
        Err(e) => panic!("{name} is set but unreadable: {e}"),
    }
}

fn env_f64(name: &str, default: f64) -> f64 {
    match std::env::var(name) {
        Ok(v) => {
            let parsed: f64 = v
                .trim()
                .parse()
                .unwrap_or_else(|e| panic!("{name}={v:?} is not a number: {e}"));
            if !parsed.is_finite() || parsed < 0.0 {
                panic!("{name}={v:?} must be a finite non-negative percent");
            }
            parsed
        }
        Err(std::env::VarError::NotPresent) => default,
        Err(e) => panic!("{name} is set but unreadable: {e}"),
    }
}

/// Snapshot + diff + gate against the repo-root baselines; every bench
/// binary's last call. See the module docs for the exact contract.
pub fn finish(report: &BenchReport) {
    finish_in(report, &repo_root());
}

/// [`finish`] against an explicit baseline directory (tests).
pub fn finish_in(report: &BenchReport, dir: &Path) {
    let gate = env_flag("NNTRAINER_BENCH_GATE");
    let threshold = env_f64("NNTRAINER_BENCH_GATE_PCT", 10.0);
    let path = dir.join(format!("BENCH_{}.json", report.name));

    let baseline = match std::fs::read_to_string(&path) {
        Ok(text) => match BenchReport::from_json(&text) {
            Ok(b) => Some(b),
            Err(e) => {
                eprintln!("perf-gate: baseline {} is unreadable: {e}", path.display());
                if gate {
                    std::process::exit(2);
                }
                None
            }
        },
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => None,
        Err(e) => panic!("perf-gate: cannot read {}: {e}", path.display()),
    };

    // write the fresh snapshot first so it survives a gate failure
    std::fs::write(&path, report.to_json())
        .unwrap_or_else(|e| panic!("perf-gate: cannot write {}: {e}", path.display()));
    // shape self-check: the emitted snapshot must round-trip
    let back = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("perf-gate: cannot re-read {}: {e}", path.display()));
    BenchReport::from_json(&back)
        .unwrap_or_else(|e| panic!("perf-gate: snapshot {} does not round-trip: {e}", path.display()));
    println!("\nsnapshot: {} ({} rows)", path.display(), report.rows.len());

    match baseline {
        None => println!(
            "perf-gate: no baseline for {:?} — first run; commit the snapshot to start the trajectory",
            report.name
        ),
        Some(base) => {
            let d = diff(&base, report, threshold);
            print!("{}", d.render());
            let regs = d.regressions();
            if regs.is_empty() {
                if d.gate_applies {
                    println!("perf-gate: ok — no gated metric regressed past {threshold:.0}%");
                }
            } else {
                eprintln!("\nperf-gate: {} metric(s) regressed past {threshold:.0}%:", regs.len());
                for r in &regs {
                    eprintln!(
                        "  {} / {}: {:.3} -> {:.3} ({:+.1}%)",
                        r.row, r.metric, r.base, r.cur, r.change_pct
                    );
                }
                if gate {
                    std::process::exit(1);
                }
                println!("(informational — set NNTRAINER_BENCH_GATE=1 to fail on this)");
            }
        }
    }
}
