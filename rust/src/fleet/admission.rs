//! Admission control and parked-state storage for the fleet.
//!
//! * [`AdmissionPlan`] prices a tenant *before* the fleet runs it, by
//!   reusing the planner's probe path (`plan_with`) — the same exact
//!   tensor population the auto-batch search uses. It answers two
//!   questions: what would the naive one-session-per-user design cost
//!   (the bench's comparison baseline), and how many tenant state
//!   copies fit under the global budget alongside the shared pool.
//! * [`ParkingLot`] is the fleet's slice of the
//!   [`SecondaryStore`](crate::runtime::SecondaryStore) machinery:
//!   named per-tenant slots (keyed by `TenantId`), synchronous park
//!   (the training thread owns the export anyway), and a background
//!   unpark worker mirroring the swap engine's fetch worker so the
//!   scheduler can overlap a cold tenant's store read with other
//!   tenants' compute.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::compiler::plan_with;
use crate::error::{Error, Result};
use crate::graph::NodeDesc;
use crate::layers::{builtin_factories, Props};
use crate::model::{DeviceProfile, TrainSpec};
use crate::optimizer;
use crate::runtime::store::{SecondaryStore, StoreKind, StoreStats};

/// The fleet's memory arithmetic, derived once at build.
#[derive(Clone, Debug)]
pub struct AdmissionPlan {
    /// The global budget the fleet was built with.
    pub budget_bytes: usize,
    /// Pool bytes of the one shared session (backbone + activations +
    /// head gradients/optstate) — paid once regardless of tenant count.
    pub shared_pool_bytes: usize,
    /// Bytes one tenant adds while RAM-resident: its head Weight +
    /// OptState regions.
    pub tenant_state_bytes: usize,
    /// Pool bytes ONE standalone session would plan for this model —
    /// what every additional user costs in the naive design.
    pub naive_session_bytes: usize,
    /// How many tenants may hold RAM state at once (the active tenant's
    /// pool copy plus `max_resident - 1` parked-in-RAM buffers).
    pub max_resident: usize,
}

impl AdmissionPlan {
    /// Probe the model's marginal footprint and size the fleet.
    ///
    /// `shared_pool_bytes` comes from the already-compiled shared
    /// session; the probe independently re-plans the same node set to
    /// price the naive design, so the two are directly comparable.
    #[allow(clippy::too_many_arguments)]
    pub fn probe(
        mut nodes: Vec<NodeDesc>,
        optimizer_kind: &str,
        optimizer_pairs: &[(&str, &str)],
        spec: &TrainSpec,
        profile: &DeviceProfile,
        batch: usize,
        shared_pool_bytes: usize,
        state_len: usize,
        budget_bytes: usize,
    ) -> Result<AdmissionPlan> {
        crate::model::session::apply_freeze(&mut nodes, &spec.freeze)?;
        let opt = optimizer::create(
            optimizer_kind,
            &Props::from_pairs(optimizer_pairs.iter().copied()),
        )?;
        let opts = crate::model::session::resolve_opts(batch, spec, profile);
        let naive_session_bytes =
            plan_with(nodes, &opts, &builtin_factories(), opt.state_slots())?.pool_bytes;

        let tenant_state_bytes = state_len * std::mem::size_of::<f32>();
        if budget_bytes < shared_pool_bytes + tenant_state_bytes {
            return Err(Error::Runtime(format!(
                "fleet budget {budget_bytes} B too small: shared pool is \
                 {shared_pool_bytes} B + one tenant state is {tenant_state_bytes} B"
            )));
        }
        // The active tenant's state lives inside the shared pool (it IS
        // the head regions), so the first resident tenant is free; every
        // further one costs a full state buffer.
        let max_resident = 1 + (budget_bytes - shared_pool_bytes) / tenant_state_bytes;
        Ok(AdmissionPlan {
            budget_bytes,
            shared_pool_bytes,
            tenant_state_bytes,
            naive_session_bytes,
            max_resident,
        })
    }

    /// What the naive one-session-per-user design would hold for
    /// `users` concurrent users.
    pub fn naive_total(&self, users: usize) -> usize {
        self.naive_session_bytes.saturating_mul(users)
    }
}

enum Req {
    Fetch { id: usize, buf: Vec<f32> },
    Stop,
}

/// A completed async unpark.
pub struct UnparkDone {
    pub id: usize,
    /// The tenant's state vector, or the store error.
    pub data: Result<Vec<f32>>,
    /// Wall time the store read took, for the scheduler's lookahead EWMA.
    pub ns: u64,
}

/// Per-tenant parked-state storage with an async unpark worker.
pub struct ParkingLot {
    store: Arc<Mutex<Box<dyn SecondaryStore>>>,
    kind: &'static str,
    state_len: usize,
    req_tx: Sender<Req>,
    done_rx: Receiver<UnparkDone>,
    worker: Option<JoinHandle<()>>,
}

impl ParkingLot {
    pub fn new(kind: StoreKind, state_len: usize) -> Result<ParkingLot> {
        let store = Arc::new(Mutex::new(kind.instance()?));
        let kind_name = store.lock().unwrap().kind();
        let (req_tx, req_rx) = channel::<Req>();
        let (done_tx, done_rx) = channel::<UnparkDone>();
        let wstore = Arc::clone(&store);
        let worker = std::thread::Builder::new()
            .name("nntrainer-fleet-unpark".into())
            .spawn(move || {
                while let Ok(Req::Fetch { id, mut buf }) = req_rx.recv() {
                    buf.resize(state_len, 0.0);
                    let t0 = Instant::now();
                    let data = wstore.lock().unwrap().get(id, &mut buf).map(|()| buf);
                    let ns = t0.elapsed().as_nanos() as u64;
                    if done_tx.send(UnparkDone { id, data, ns }).is_err() {
                        break;
                    }
                }
            })
            .map_err(|e| Error::Runtime(format!("spawn fleet unpark thread: {e}")))?;
        Ok(ParkingLot {
            store,
            kind: kind_name,
            state_len,
            req_tx,
            done_rx,
            worker: Some(worker),
        })
    }

    /// Synchronously write a tenant's state into its slot.
    pub fn park(&self, id: usize, data: &[f32]) -> Result<()> {
        debug_assert_eq!(data.len(), self.state_len);
        self.store.lock().unwrap().put(id, data)
    }

    /// Synchronously read a tenant's slot (retrieval path, not hot).
    pub fn fetch_sync(&self, id: usize, out: &mut [f32]) -> Result<()> {
        self.store.lock().unwrap().get(id, out)
    }

    /// Hand `buf` to the worker to fill from `id`'s slot; the result
    /// arrives via [`try_done`](Self::try_done)/[`wait_done`](Self::wait_done).
    pub fn request_unpark(&self, id: usize, buf: Vec<f32>) -> Result<()> {
        self.req_tx
            .send(Req::Fetch { id, buf })
            .map_err(|_| Error::Runtime("fleet unpark thread died".into()))
    }

    /// Non-blocking poll for a completed unpark.
    pub fn try_done(&self) -> Option<UnparkDone> {
        self.done_rx.try_recv().ok()
    }

    /// Block for the next completed unpark.
    pub fn wait_done(&self) -> Result<UnparkDone> {
        self.done_rx
            .recv()
            .map_err(|_| Error::Runtime("fleet unpark thread died".into()))
    }

    /// Release a tenant's slot (departure).
    pub fn free(&self, id: usize) -> Result<()> {
        self.store.lock().unwrap().free(id);
        Ok(())
    }

    /// Live store slots — every parked or finished tenant holds one.
    pub fn slot_count(&self) -> usize {
        self.store.lock().unwrap().slot_count()
    }

    /// Snapshot of the backing store's cumulative I/O counters
    /// (`StoreStats::peak_bytes` is the bench's peak-store-footprint
    /// column; compressing stores report physical < logical bytes).
    pub fn store_stats(&self) -> StoreStats {
        self.store.lock().unwrap().stats()
    }

    pub fn kind(&self) -> &'static str {
        self.kind
    }
}

impl Drop for ParkingLot {
    fn drop(&mut self) {
        let _ = self.req_tx.send(Req::Stop);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}
