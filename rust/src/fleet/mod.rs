//! Fleet: a multi-tenant personalization service over one
//! [`CompiledSession`].
//!
//! The paper's personalization story is one device, one user. A fleet
//! simulation (or an edge gateway serving many users) inverts that:
//! thousands of tenants, each wanting to fine-tune the same frozen
//! backbone with a private head, under one global memory budget. Naively
//! that is one `CompiledSession` per user — the backbone weights, the
//! activation pool, and the optimizer state replicated N times.
//!
//! `FleetService` exploits what the freeze/personalize machinery already
//! guarantees: with the backbone frozen, the *only* state that
//! distinguishes tenant A from tenant B is
//!
//! * the head's `Weight` regions,
//! * the head's `OptState` regions,
//! * the step counters (`iter`, optimizer apply count).
//!
//! Everything else — frozen weights, activations, gradients — is either
//! shared read-only or scratch that each training step fully rewrites
//! (gradients are zeroed at their first-write EO each iteration). So the
//! service keeps ONE compiled session and context-switches tenants by
//! swapping a contiguous per-tenant state vector in and out of the pool
//! via [`CompiledSession::export_head_state`] /
//! [`CompiledSession::import_head_state`]. Idle tenants park that vector
//! into a [`SecondaryStore`](crate::runtime::SecondaryStore); a
//! background worker unparks it ahead of the tenant's next turn
//! (see `scheduler.rs` for the swap-aware round-robin).
//!
//! Admission control (`admission.rs`) prices a tenant before letting it
//! run: the shared pool is a one-off cost, each resident tenant adds
//! exactly `state_len * 4` bytes, and the budget caps how many state
//! copies may be RAM-resident at once. Arrivals beyond that wait in a
//! queue; tenants beyond the *resident* cap get parked LRU-first.
//!
//! Bitwise contract: a tenant trained through the fleet produces weights
//! identical to the same seed trained via a standalone
//! `CompiledSession::personalize` (proven by `rust/tests/fleet_service.rs`).
//! The service replicates `personalize()`'s pipeline exactly — same
//! checkpoint load, same `reinit_weights_matching(head, seed)`, same
//! batch assembly semantics as `BatchQueue` (fresh producer per epoch,
//! sequential full batches, tail dropped, sample-major packing) — and
//! saves/restores `(iter, apply_count)` across context switches so
//! iteration-indexed optimizers see an uninterrupted step sequence.
//! One obligation falls on the caller: producers must be
//! index-deterministic (`sample(idx)` a pure function of `idx`), because
//! a tenant may be parked mid-epoch and its producer rebuilt later.

mod admission;
mod scheduler;

pub use admission::{AdmissionPlan, ParkingLot, UnparkDone};
pub use scheduler::Tick;

use std::collections::VecDeque;
use std::time::Instant;

use crate::dataset::DataProducer;
use crate::error::{Error, Result};
use crate::graph::NodeDesc;
use crate::model::{checkpoint, CompiledSession, DeviceProfile, Session, TrainSpec};
use crate::runtime::store::StoreKind;
use crate::runtime::swap::ewma_update;
use crate::tensor::Region;

/// Tenants are addressed by their admission index.
pub type TenantId = usize;

/// EWMA smoothing for step-time and unpark-time estimates, matching the
/// calibration style in `runtime/swap.rs`.
const FLEET_EWMA_ALPHA: f64 = 0.2;

/// Upper bound on how many queue positions ahead the scheduler will
/// issue speculative unparks for.
const MAX_LOOKAHEAD: usize = 8;

/// Default retention for the per-step latency ring. Generous — at a
/// 10 ms step this is ~10 minutes of history — but bounded, so a
/// long-lived service doesn't grow its latency log without limit.
/// Tune with [`FleetService::set_step_latency_cap`].
pub const STEP_LATENCY_CAP: usize = 65_536;

/// Global configuration for a fleet.
pub struct FleetConfig {
    /// Total RAM budget in bytes: shared pool + resident tenant states.
    pub budget_bytes: usize,
    /// Layer-name prefixes forming the per-tenant head. Must cover every
    /// trainable layer (enforced at build).
    pub head: Vec<String>,
    /// Optional vendor checkpoint loaded once into the shared session
    /// (head regions excluded, exactly as `personalize()` does).
    pub checkpoint: Option<String>,
    /// Where idle tenants' state vectors park.
    pub park_store: StoreKind,
    /// Training steps a tenant runs per scheduler slot.
    pub quantum: usize,
    /// Cap on tenants admitted into the run queue at once; the rest
    /// wait. Defaults to `4 * max_resident`, at least 8.
    pub max_active: Option<usize>,
}

impl FleetConfig {
    pub fn new(budget_bytes: usize, head: Vec<String>) -> Self {
        FleetConfig {
            budget_bytes,
            head,
            checkpoint: None,
            park_store: StoreKind::Host,
            quantum: 4,
            max_active: None,
        }
    }
}

/// Per-tenant training request.
pub struct TenantSpec {
    /// Head reinit seed — the tenant's identity for reproducibility.
    pub seed: u64,
    /// Epochs to train before the tenant is finished.
    pub epochs: usize,
    /// Builds the tenant's data producer. Called once per epoch (the
    /// same lifecycle `run_training` gives `BatchQueue`), and again if
    /// the tenant was parked mid-epoch — hence the
    /// index-determinism requirement.
    pub make_producer: Box<dyn Fn() -> Box<dyn DataProducer>>,
}

/// Where a tenant's state lives right now.
pub(crate) enum Phase {
    /// Admitted, never activated; state materializes lazily via head
    /// reinit at first activation.
    Fresh,
    /// State is live in the shared session's pool.
    Active,
    /// State held in a RAM-resident buffer, ready to import.
    Resident(Vec<f32>),
    /// State lives only in the parking store.
    Parked,
    /// An async unpark is in flight for this tenant.
    Unparking,
    /// Trained to completion; final state parked for retrieval.
    Finished,
    /// Gone; store slot freed.
    Departed,
}

/// Public snapshot of a tenant's lifecycle phase.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TenantState {
    Fresh,
    Active,
    Resident,
    Parked,
    Unparking,
    Finished,
    Departed,
}

pub(crate) struct Tenant {
    spec: TenantSpec,
    phase: Phase,
    /// Saved executor counters — restored on activation so the step
    /// sequence is indistinguishable from an uninterrupted run.
    iter: u64,
    apply_count: u64,
    epoch: usize,
    /// Sample cursor within the current epoch.
    cursor: usize,
    /// Live producer for the current epoch (dropped at epoch end and
    /// whenever the tenant is parked).
    producer: Option<Box<dyn DataProducer>>,
    steps_done: u64,
    /// Logical clock of the tenant's last slot — LRU key for parking.
    last_ran: u64,
    last_loss: f32,
}

/// Aggregate fleet telemetry.
#[derive(Clone, Debug, Default)]
pub struct FleetStats {
    pub admitted: usize,
    pub completed: usize,
    pub departed: usize,
    pub steps: u64,
    pub parks: u64,
    pub unparks: u64,
    /// Unparks the scheduler had to block on (lookahead missed).
    pub stalled_unparks: u64,
    /// Compute slots yielded because the tenant's state wasn't resident.
    pub yields: u64,
    pub read_stall_ns: u64,
    pub bytes_out: u64,
    pub bytes_in: u64,
    pub context_switches: u64,
    /// Peak of shared pool + resident state copies, in bytes.
    pub peak_resident_bytes: usize,
    /// Peak tenants simultaneously admitted-and-not-departed.
    pub peak_live_tenants: usize,
}

/// The multi-tenant personalization service. See the module docs for
/// the design; see `scheduler.rs` for the step loop.
pub struct FleetService {
    pub(crate) session: CompiledSession,
    pub(crate) head: Vec<String>,
    pub(crate) layout: Vec<(String, Region)>,
    /// Total f32 length of one tenant's state vector.
    pub(crate) state_len: usize,
    pub(crate) plan: AdmissionPlan,
    pub(crate) parking: ParkingLot,
    pub(crate) tenants: Vec<Tenant>,
    /// Round-robin order of admitted tenants.
    pub(crate) run_queue: VecDeque<usize>,
    /// Admitted but beyond `max_active` — waiting to enter the queue.
    pub(crate) waiting: VecDeque<usize>,
    /// Tenant whose state currently occupies the pool's head regions.
    pub(crate) active: Option<usize>,
    /// Recycled state buffers (capacity `state_len`).
    pub(crate) spare: Vec<Vec<f32>>,
    /// `Resident` + `Unparking` state copies currently holding RAM.
    pub(crate) ram_copies: usize,
    pub(crate) unparks_in_flight: usize,
    /// Distinct state buffers ever allocated — drives peak RSS.
    pub(crate) allocated_bufs: usize,
    /// Budget-derived cap on `ram_copies` (`max_resident - 1`: the
    /// active tenant's copy lives in the pool, not in a buffer).
    pub(crate) max_ram_copies: usize,
    pub(crate) max_active: usize,
    pub(crate) quantum: usize,
    /// Logical clock, bumped once per slot.
    pub(crate) clock: u64,
    /// Admitted and not departed.
    pub(crate) live: usize,
    pub(crate) ewma_step_ns: f64,
    pub(crate) ewma_unpark_ns: f64,
    pub(crate) stats: FleetStats,
    /// Per-step wall latencies (ns), most recent last. Ring-capped at
    /// `step_latency_cap`: once full, each push drops the oldest sample
    /// so memory stays bounded over a service's lifetime.
    pub(crate) step_latencies: VecDeque<u64>,
    pub(crate) step_latency_cap: usize,
    /// Reused batch-assembly buffers.
    pub(crate) in_buf: Vec<f32>,
    pub(crate) lb_buf: Vec<f32>,
}

impl FleetService {
    /// Compile the shared session and size the fleet against `cfg`.
    ///
    /// `nodes`/`optimizer_*`/`spec`/`profile` describe the model exactly
    /// as a standalone `Session::describe(...).optimizer(...)
    /// .configure(spec).compile_for(profile)` would; `spec.freeze` must
    /// freeze the backbone and `cfg.head` must cover every remaining
    /// trainable layer, or tenants would share mutable state.
    pub fn build(
        nodes: Vec<NodeDesc>,
        optimizer_kind: &str,
        optimizer_pairs: &[(&str, &str)],
        spec: TrainSpec,
        profile: DeviceProfile,
        cfg: FleetConfig,
    ) -> Result<FleetService> {
        if cfg.head.is_empty() {
            return Err(Error::graph("fleet: FleetConfig::head is empty"));
        }
        if spec.freeze.is_empty() {
            return Err(Error::graph(
                "fleet: TrainSpec::freeze is empty — without a frozen backbone every \
                 weight is per-tenant state and sharing a session saves nothing",
            ));
        }
        if cfg.quantum == 0 {
            return Err(Error::graph("fleet: quantum must be >= 1"));
        }

        let session = Session::describe(nodes.clone())
            .optimizer(optimizer_kind, optimizer_pairs)
            .configure(spec.clone())
            .compile_for(profile.clone())?;
        if let Some(path) = &cfg.checkpoint {
            // Same load as personalize(): backbone from the vendor
            // checkpoint, head regions skipped (reinit owns them).
            checkpoint::load_matching(&session.model.exec, path, &cfg.head)?;
        }

        let layout = session.head_state_layout(&cfg.head)?;

        // Isolation invariant: every trainable root weight must be under
        // a head prefix, otherwise its updates leak across tenants.
        for s in session.model.exec.graph.table.iter() {
            if s.merged_into.is_some() || s.eos.is_empty() || !s.trainable {
                continue;
            }
            if !matches!(s.role, crate::tensor::TensorRole::Weight) {
                continue;
            }
            let layer = s.name.split(':').next().unwrap_or(&s.name);
            if !cfg.head.iter().any(|p| layer.starts_with(p.as_str())) {
                return Err(Error::graph(format!(
                    "fleet: trainable layer `{layer}` is outside the head set — \
                     tenants would share mutable state; freeze it or add it to \
                     FleetConfig::head"
                )));
            }
        }

        let state_len: usize = layout.iter().map(|(_, r)| r.len).sum();
        let shared_pool_bytes = session.model.report.pool_bytes;
        let plan = AdmissionPlan::probe(
            nodes,
            optimizer_kind,
            optimizer_pairs,
            &spec,
            &profile,
            session.batch(),
            shared_pool_bytes,
            state_len,
            cfg.budget_bytes,
        )?;
        let parking = ParkingLot::new(cfg.park_store, state_len)?;

        let max_ram_copies = plan.max_resident - 1;
        let max_active = cfg
            .max_active
            .unwrap_or_else(|| plan.max_resident.saturating_mul(4).max(8));

        let mut svc = FleetService {
            session,
            head: cfg.head,
            layout,
            state_len,
            plan,
            parking,
            tenants: Vec::new(),
            run_queue: VecDeque::new(),
            waiting: VecDeque::new(),
            active: None,
            spare: Vec::new(),
            ram_copies: 0,
            unparks_in_flight: 0,
            allocated_bufs: 0,
            max_ram_copies,
            max_active,
            quantum: cfg.quantum,
            clock: 0,
            live: 0,
            ewma_step_ns: 0.0,
            ewma_unpark_ns: 0.0,
            stats: FleetStats::default(),
            step_latencies: VecDeque::new(),
            step_latency_cap: STEP_LATENCY_CAP,
            in_buf: Vec::new(),
            lb_buf: Vec::new(),
        };
        svc.stats.peak_resident_bytes = svc.plan.shared_pool_bytes;
        Ok(svc)
    }

    /// Admit a tenant. It enters the waiting queue and will be pulled
    /// into the run queue as slots free up.
    pub fn admit(&mut self, spec: TenantSpec) -> TenantId {
        let id = self.tenants.len();
        self.tenants.push(Tenant {
            spec,
            phase: Phase::Fresh,
            iter: 0,
            apply_count: 0,
            epoch: 0,
            cursor: 0,
            producer: None,
            steps_done: 0,
            last_ran: 0,
            last_loss: f32::NAN,
        });
        self.waiting.push_back(id);
        self.stats.admitted += 1;
        self.live += 1;
        if self.live > self.stats.peak_live_tenants {
            self.stats.peak_live_tenants = self.live;
        }
        id
    }

    /// Remove a tenant, releasing whatever its state occupies. Safe in
    /// any phase; in-flight unparks are discarded on completion.
    pub fn depart(&mut self, id: TenantId) -> Result<()> {
        if matches!(self.tenants[id].phase, Phase::Departed) {
            return Ok(());
        }
        let was_finished = matches!(self.tenants[id].phase, Phase::Finished);
        let prev = std::mem::replace(&mut self.tenants[id].phase, Phase::Departed);
        match prev {
            Phase::Fresh => {}
            Phase::Active => {
                // Pool contents are garbage to everyone else; next
                // activation overwrites them.
                self.active = None;
            }
            Phase::Resident(buf) => {
                self.recycle_buf(buf);
                self.ram_copies -= 1;
            }
            Phase::Parked | Phase::Finished => self.parking.free(id)?,
            // handle_done sees Departed and cleans up.
            Phase::Unparking => {}
            Phase::Departed => unreachable!(),
        }
        if !was_finished {
            self.live -= 1;
        }
        self.stats.departed += 1;
        Ok(())
    }

    /// Public phase snapshot.
    pub fn tenant_state(&self, id: TenantId) -> TenantState {
        match self.tenants[id].phase {
            Phase::Fresh => TenantState::Fresh,
            Phase::Active => TenantState::Active,
            Phase::Resident(_) => TenantState::Resident,
            Phase::Parked => TenantState::Parked,
            Phase::Unparking => TenantState::Unparking,
            Phase::Finished => TenantState::Finished,
            Phase::Departed => TenantState::Departed,
        }
    }

    /// Make `id` the tenant whose state occupies the pool. Exports the
    /// previous occupant to a resident buffer, then either reinitializes
    /// (first activation — this IS `personalize()`'s head reinit) or
    /// imports the tenant's saved state.
    pub(crate) fn activate(&mut self, id: TenantId) -> Result<()> {
        if self.active == Some(id) {
            return Ok(());
        }
        // Context switches read and rewrite head regions straight out of
        // the pool: under cross-iteration swap pipelining the previous
        // tenant's last step may have left boundary transfers in flight
        // over exactly those regions, so drain them first.
        self.session.model.exec.quiesce_swap()?;
        if let Some(prev) = self.active.take() {
            if !matches!(self.tenants[prev].phase, Phase::Departed) {
                let mut buf = self.take_buf();
                self.session.export_head_state(&self.layout, &mut buf);
                let (iter, applies) = self.session.model.exec.step_counters();
                self.tenants[prev].iter = iter;
                self.tenants[prev].apply_count = applies;
                self.tenants[prev].phase = Phase::Resident(buf);
                self.ram_copies += 1;
                self.stats.context_switches += 1;
            }
        }
        let prev = std::mem::replace(&mut self.tenants[id].phase, Phase::Active);
        match prev {
            Phase::Fresh => {
                let seed = self.tenants[id].spec.seed;
                self.session
                    .model
                    .exec
                    .reinit_weights_matching(&self.head, seed)?;
                self.session.model.exec.set_step_counters(0, 0);
            }
            Phase::Resident(buf) => {
                self.session.import_head_state(&self.layout, &buf)?;
                let (iter, applies) = (self.tenants[id].iter, self.tenants[id].apply_count);
                self.session.model.exec.set_step_counters(iter, applies);
                self.recycle_buf(buf);
                self.ram_copies -= 1;
            }
            other => {
                self.tenants[id].phase = other;
                return Err(Error::Runtime(format!(
                    "fleet internal: activate({id}) on a non-runnable tenant"
                )));
            }
        }
        self.active = Some(id);
        // Enforce the residency budget: evict coldest copies to store.
        while self.ram_copies > self.max_ram_copies {
            if !self.park_lru_resident()? {
                break;
            }
        }
        Ok(())
    }

    /// Park the least-recently-run `Resident` tenant. Returns false if
    /// none exists (remaining RAM copies are all mid-unpark).
    pub(crate) fn park_lru_resident(&mut self) -> Result<bool> {
        let mut victim: Option<(usize, u64)> = None;
        for (i, t) in self.tenants.iter().enumerate() {
            if matches!(t.phase, Phase::Resident(_)) {
                match victim {
                    Some((_, best)) if t.last_ran >= best => {}
                    _ => victim = Some((i, t.last_ran)),
                }
            }
        }
        let Some((i, _)) = victim else {
            return Ok(false);
        };
        // Write to the store while the buffer is still owned by the
        // phase, so an I/O error leaves the tenant intact.
        if let Phase::Resident(buf) = &self.tenants[i].phase {
            self.parking.park(i, buf)?;
        }
        let prev = std::mem::replace(&mut self.tenants[i].phase, Phase::Parked);
        if let Phase::Resident(buf) = prev {
            self.stats.parks += 1;
            self.stats.bytes_out += (buf.len() * 4) as u64;
            self.recycle_buf(buf);
            self.ram_copies -= 1;
        }
        // A parked tenant mustn't hold a live producer (it may be
        // rebuilt after unpark; index-determinism makes that safe).
        self.tenants[i].producer = None;
        Ok(true)
    }

    /// Issue an async unpark for a `Parked` tenant if a RAM slot is
    /// available (optionally making room by parking an LRU resident).
    /// Returns whether the unpark was issued.
    pub(crate) fn try_issue_unpark(&mut self, id: TenantId, allow_park: bool) -> Result<bool> {
        if !matches!(self.tenants[id].phase, Phase::Parked) {
            return Ok(false);
        }
        if self.ram_copies >= self.max_ram_copies {
            if !(allow_park && self.park_lru_resident()?) {
                return Ok(false);
            }
        }
        let buf = self.take_buf();
        self.parking.request_unpark(id, buf)?;
        self.tenants[id].phase = Phase::Unparking;
        self.ram_copies += 1;
        self.unparks_in_flight += 1;
        self.stats.unparks += 1;
        self.stats.bytes_in += (self.state_len * 4) as u64;
        Ok(true)
    }

    /// Fold a completed unpark back into tenant state.
    pub(crate) fn handle_done(&mut self, done: UnparkDone) -> Result<()> {
        self.unparks_in_flight -= 1;
        let buf = done.data?;
        ewma_update(&mut self.ewma_unpark_ns, done.ns as f64, FLEET_EWMA_ALPHA);
        match self.tenants[done.id].phase {
            Phase::Unparking => {
                self.tenants[done.id].phase = Phase::Resident(buf);
                Ok(())
            }
            Phase::Departed => {
                // Departed mid-flight; the store slot still needs freeing.
                self.recycle_buf(buf);
                self.ram_copies -= 1;
                self.parking.free(done.id)
            }
            _ => Err(Error::Runtime(format!(
                "fleet internal: unpark completed for tenant {} in an unexpected phase",
                done.id
            ))),
        }
    }

    /// Run one compute slot (up to `quantum` training steps) for `id`.
    /// Returns `(steps_taken, finished)`.
    pub(crate) fn run_slot(&mut self, id: TenantId) -> Result<(u32, bool)> {
        self.activate(id)?;
        let batch = self.session.batch();
        let (in_len, lb_len) = {
            let g = &self.session.model.exec.graph;
            let in_len: usize = g
                .input_nodes
                .iter()
                .map(|&n| g.nodes[n].out_dims[0].feature_len())
                .sum();
            let lb_len: usize = g
                .loss_nodes
                .iter()
                .map(|&n| g.nodes[n].in_dims[0].feature_len())
                .sum();
            (in_len, lb_len)
        };
        let mut steps: u32 = 0;
        let mut finished = false;
        while (steps as usize) < self.quantum {
            {
                let t = &mut self.tenants[id];
                if t.epoch >= t.spec.epochs {
                    finished = true;
                    break;
                }
                if t.producer.is_none() {
                    // Fresh producer per epoch — the lifecycle
                    // BatchQueue::spawn gives run_training. The cursor
                    // is NOT reset here: parking drops the producer
                    // mid-epoch, and the rebuilt one must resume at the
                    // saved cursor (index-determinism makes that exact) —
                    // resetting would replay the epoch's first batches,
                    // breaking the bitwise contract and, under frequent
                    // parking, never reaching the epoch boundary at all.
                    t.producer = Some((t.spec.make_producer)());
                }
                let producer = t.producer.as_mut().unwrap();
                let n = producer.len();
                if n < batch {
                    return Err(Error::Runtime(format!(
                        "fleet tenant {id}: no full batch produced \
                         (producer len {n} < batch {batch})"
                    )));
                }
                if t.cursor + batch > n {
                    // Epoch boundary: tail dropped, exactly as
                    // BatchQueue's `while i + batch <= n` loop.
                    t.epoch += 1;
                    t.producer = None;
                    t.cursor = 0;
                    if t.epoch >= t.spec.epochs {
                        finished = true;
                        break;
                    }
                    continue;
                }
                self.in_buf.resize(batch * in_len, 0.0);
                self.lb_buf.resize(batch * lb_len, 0.0);
                for k in 0..batch {
                    let s = producer.sample(t.cursor + k);
                    self.in_buf[k * in_len..(k + 1) * in_len].copy_from_slice(&s.input);
                    self.lb_buf[k * lb_len..(k + 1) * lb_len].copy_from_slice(&s.label);
                }
                t.cursor += batch;
            }
            let t0 = Instant::now();
            self.session.model.bind_batch(&self.in_buf, &self.lb_buf)?;
            let loss = self.session.model.exec.try_train_iteration()?;
            let ns = t0.elapsed().as_nanos() as u64;
            self.step_latencies.push_back(ns);
            while self.step_latencies.len() > self.step_latency_cap {
                self.step_latencies.pop_front();
            }
            ewma_update(&mut self.ewma_step_ns, ns as f64, FLEET_EWMA_ALPHA);
            self.stats.steps += 1;
            let t = &mut self.tenants[id];
            t.steps_done += 1;
            t.last_loss = loss;
            steps += 1;
        }
        self.clock += 1;
        self.tenants[id].last_ran = self.clock;
        if finished {
            self.finish_tenant(id)?;
        }
        Ok((steps, finished))
    }

    /// Export a completed tenant's final state straight to the store
    /// and free its compute slot.
    fn finish_tenant(&mut self, id: TenantId) -> Result<()> {
        // the export reads head regions out of the pool — drain any
        // carried boundary transfers over them first
        self.session.model.exec.quiesce_swap()?;
        let mut buf = self.take_buf();
        self.session.export_head_state(&self.layout, &mut buf);
        let (iter, applies) = self.session.model.exec.step_counters();
        self.tenants[id].iter = iter;
        self.tenants[id].apply_count = applies;
        self.parking.park(id, &buf)?;
        self.stats.parks += 1;
        self.stats.bytes_out += (buf.len() * 4) as u64;
        self.recycle_buf(buf);
        self.tenants[id].phase = Phase::Finished;
        self.tenants[id].producer = None;
        // The pool no longer holds anyone's state worth exporting.
        self.active = None;
        self.stats.completed += 1;
        self.live -= 1;
        Ok(())
    }

    /// Fetch a tenant's current head-state vector (weights + optimizer
    /// state, in layout order), wherever it lives. Blocks on an
    /// in-flight unpark if necessary.
    pub fn tenant_head_state(&mut self, id: TenantId) -> Result<Vec<f32>> {
        loop {
            match self.tenant_state(id) {
                TenantState::Active => {
                    self.session.model.exec.quiesce_swap()?;
                    let mut out = Vec::new();
                    self.session.export_head_state(&self.layout, &mut out);
                    return Ok(out);
                }
                TenantState::Resident => {
                    if let Phase::Resident(buf) = &self.tenants[id].phase {
                        return Ok(buf.clone());
                    }
                    unreachable!();
                }
                TenantState::Parked | TenantState::Finished => {
                    let mut out = vec![0f32; self.state_len];
                    self.parking.fetch_sync(id, &mut out)?;
                    return Ok(out);
                }
                TenantState::Unparking => {
                    let done = self.parking.wait_done()?;
                    self.handle_done(done)?;
                }
                TenantState::Fresh => {
                    return Err(Error::Runtime(format!(
                        "fleet tenant {id}: no state yet (never activated)"
                    )));
                }
                TenantState::Departed => {
                    return Err(Error::Runtime(format!("fleet tenant {id}: departed")));
                }
            }
        }
    }

    pub(crate) fn take_buf(&mut self) -> Vec<f32> {
        self.spare.pop().unwrap_or_else(|| {
            self.allocated_bufs += 1;
            let peak =
                self.plan.shared_pool_bytes + self.allocated_bufs * self.plan.tenant_state_bytes;
            if peak > self.stats.peak_resident_bytes {
                self.stats.peak_resident_bytes = peak;
            }
            Vec::with_capacity(self.state_len)
        })
    }

    pub(crate) fn recycle_buf(&mut self, buf: Vec<f32>) {
        self.spare.push(buf);
    }

    /// Is any queued tenant runnable right now (no store round-trip)?
    pub(crate) fn queue_has_runnable(&self) -> bool {
        self.run_queue.iter().any(|&i| {
            matches!(
                self.tenants[i].phase,
                Phase::Fresh | Phase::Active | Phase::Resident(_)
            )
        })
    }

    // ---- accessors -----------------------------------------------------

    pub fn session(&self) -> &CompiledSession {
        &self.session
    }

    pub fn admission(&self) -> &AdmissionPlan {
        &self.plan
    }

    pub fn stats(&self) -> &FleetStats {
        &self.stats
    }

    /// Recorded per-step latencies (ns), oldest first. Holds at most
    /// the last [`step_latency_cap`](Self::step_latency_cap) samples.
    pub fn step_latencies_ns(&self) -> Vec<u64> {
        self.step_latencies.iter().copied().collect()
    }

    /// Current retention cap on the step-latency ring.
    pub fn step_latency_cap(&self) -> usize {
        self.step_latency_cap
    }

    /// Resize the step-latency ring (minimum 1). Shrinking drops the
    /// oldest samples immediately.
    pub fn set_step_latency_cap(&mut self, cap: usize) {
        self.step_latency_cap = cap.max(1);
        while self.step_latencies.len() > self.step_latency_cap {
            self.step_latencies.pop_front();
        }
    }

    /// Latency percentile (q in 0..=100) over the retained steps (the
    /// ring keeps the most recent `step_latency_cap` samples).
    pub fn step_latency_percentile(&self, q: f64) -> u64 {
        if self.step_latencies.is_empty() {
            return 0;
        }
        let mut sorted: Vec<u64> = self.step_latencies.iter().copied().collect();
        sorted.sort_unstable();
        let idx = ((q / 100.0) * (sorted.len() - 1) as f64).round() as usize;
        sorted[idx.min(sorted.len() - 1)]
    }

    /// Last observed training loss for a tenant, if it has stepped.
    pub fn tenant_loss(&self, id: TenantId) -> Option<f32> {
        let l = self.tenants[id].last_loss;
        if l.is_nan() {
            None
        } else {
            Some(l)
        }
    }

    pub fn live_tenants(&self) -> usize {
        self.live
    }

    pub fn parked_slot_count(&self) -> usize {
        self.parking.slot_count()
    }

    /// Cumulative I/O counters of the parking store — peak store
    /// footprint, rewrites, physical-vs-logical bytes (the compressed
    /// store's saving shows up here).
    pub fn park_store_stats(&self) -> crate::runtime::store::StoreStats {
        self.parking.store_stats()
    }
}
