//! Swap-aware round-robin scheduling for the fleet.
//!
//! The scheduler's job is to keep the compute slot busy while tenant
//! state shuttles to and from the parking store. The core moves:
//!
//! * **Yield, don't stall.** A tenant at the queue head whose state is
//!   parked (or mid-unpark) gives up its turn: the scheduler issues the
//!   unpark, rotates the tenant to the back, and runs whoever is
//!   resident instead. It only blocks when *nobody* in the queue is
//!   runnable — and that block is accounted as a stalled unpark in
//!   [`FleetStats`](super::FleetStats).
//! * **Calibrated lookahead.** After every compute slot the scheduler
//!   issues speculative unparks for parked tenants near the queue head.
//!   How far ahead is the ratio of the unpark-time EWMA to the
//!   slot-time EWMA (step EWMA × quantum) — the same
//!   smoothing-and-ratio trick the swap engine uses to derive prefetch
//!   lead from measured store bandwidth (`runtime/swap.rs`, shared via
//!   [`ewma_update`](crate::runtime::swap::ewma_update)). A slow store
//!   pulls more tenants forward; a fast one keeps speculation minimal.

use crate::error::{Error, Result};

use super::{FleetService, FleetStats, TenantId, MAX_LOOKAHEAD};

/// What one scheduler tick did.
#[derive(Debug)]
pub enum Tick {
    /// Ran a compute slot for `tenant`.
    Stepped {
        tenant: TenantId,
        steps: u32,
        finished: bool,
    },
    /// `tenant` was at the head but not resident; its unpark is in
    /// flight and its turn was forfeited.
    Yielded { tenant: TenantId },
    /// Nothing left to run — every admitted tenant finished or departed.
    Idle,
}

impl FleetService {
    /// One scheduling decision: drain finished unparks, top up the run
    /// queue from the waiting line, then give the queue head its turn
    /// (or rotate past it if its state isn't here yet).
    pub fn tick(&mut self) -> Result<Tick> {
        while let Some(done) = self.parking.try_done() {
            self.handle_done(done)?;
        }
        while self.run_queue.len() < self.max_active {
            match self.waiting.pop_front() {
                Some(id) => self.run_queue.push_back(id),
                None => break,
            }
        }
        loop {
            let Some(id) = self.run_queue.pop_front() else {
                return Ok(Tick::Idle);
            };
            match self.tenant_state(id) {
                // Drop out of rotation silently.
                super::TenantState::Finished | super::TenantState::Departed => continue,
                super::TenantState::Fresh
                | super::TenantState::Active
                | super::TenantState::Resident => {
                    let (steps, finished) = self.run_slot(id)?;
                    if !finished {
                        self.run_queue.push_back(id);
                    }
                    self.lookahead_unparks()?;
                    return Ok(Tick::Stepped {
                        tenant: id,
                        steps,
                        finished,
                    });
                }
                super::TenantState::Parked => {
                    // Evicting a resident to fetch the head is only safe
                    // when nobody is runnable (then no resident exists —
                    // residents always sit in this queue). With a
                    // runnable tenant present it would LIVELOCK at
                    // max_ram_copies == 1: two parked tenants would take
                    // turns evicting each other's freshly-unparked state
                    // without ever running a slot.
                    let runnable = self.queue_has_runnable();
                    self.try_issue_unpark(id, !runnable)?;
                    self.run_queue.push_back(id);
                    self.stats.yields += 1;
                    if !runnable {
                        self.block_on_unpark()?;
                    }
                    return Ok(Tick::Yielded { tenant: id });
                }
                super::TenantState::Unparking => {
                    self.run_queue.push_back(id);
                    self.stats.yields += 1;
                    if !self.queue_has_runnable() {
                        self.block_on_unpark()?;
                    }
                    return Ok(Tick::Yielded { tenant: id });
                }
            }
        }
    }

    /// Drive the fleet until every admitted tenant has finished (or
    /// departed). Returns a snapshot of the stats.
    pub fn run(&mut self) -> Result<FleetStats> {
        while !matches!(self.tick()?, Tick::Idle) {}
        Ok(self.stats.clone())
    }

    /// Block for one in-flight unpark — the no-runnable-tenant path.
    /// Safety: both callers guarantee an unpark is in flight (the
    /// `Parked` branch either issued one or found RAM full of
    /// `Unparking` buffers; the `Unparking` branch is one itself).
    fn block_on_unpark(&mut self) -> Result<()> {
        if self.unparks_in_flight == 0 {
            return Err(Error::Runtime(
                "fleet internal: blocking with no unpark in flight".into(),
            ));
        }
        let t0 = std::time::Instant::now();
        let done = self.parking.wait_done()?;
        self.stats.read_stall_ns += t0.elapsed().as_nanos() as u64;
        self.stats.stalled_unparks += 1;
        self.handle_done(done)
    }

    /// Issue speculative unparks for parked tenants within the
    /// lookahead window at the front of the run queue. Never evicts a
    /// resident tenant to make room (speculation must not thrash).
    fn lookahead_unparks(&mut self) -> Result<()> {
        let l = self.lookahead();
        let ids: Vec<usize> = self.run_queue.iter().take(l).copied().collect();
        for id in ids {
            if matches!(self.tenant_state(id), super::TenantState::Parked)
                && !self.try_issue_unpark(id, false)?
            {
                break;
            }
        }
        Ok(())
    }

    /// How many queue positions a store read spans, per the EWMAs.
    fn lookahead(&self) -> usize {
        if self.ewma_step_ns <= 0.0 || self.ewma_unpark_ns <= 0.0 {
            return 1;
        }
        let slot_ns = (self.ewma_step_ns * self.quantum as f64).max(1.0);
        let l = (self.ewma_unpark_ns / slot_ns).ceil() as usize;
        l.clamp(1, MAX_LOOKAHEAD)
    }
}
