//! Bandwidth-calibrated swap tuning.
//!
//! The swap runtime's two scheduling knobs — how far ahead of a use EO a
//! prefetch must *complete* (the per-entry lead) and how many background
//! fetches ride in flight (the depth) — were fixed constants in PR 1
//! (`PREFETCH_LEAD = 1`, `PREFETCH_DEPTH = 2`). That is only correct
//! when the store moves one tensor per EO of compute: on a slow store
//! every barrier becomes a counted stall, on a fast one residency is
//! held longer than needed. This module derives both knobs from
//! *measurement*:
//!
//! 1. **Store probe** ([`probe_store`]) — micro-benchmarks the actual
//!    [`SecondaryStore`] instance the compile will hand to the runtime:
//!    streaming write/read bandwidth over a representative buffer plus a
//!    tiny-op round trip for per-op latency.
//! 2. **Compute probe** ([`probe_compute`]) — times an FMA sweep to get
//!    host compute throughput in bytes/ns, the scale that converts
//!    "bytes touched at an EO" (known exactly from the planner table)
//!    into estimated nanoseconds of compute ([`EoCostModel`]).
//! 3. **Lead derivation** ([`derive_leads`]) — for each offload entry,
//!    widen the read lead until the estimated fetch time
//!    (`latency + bytes / read bandwidth`) fits inside the compute time
//!    of the EO window `[prefetch_before − lead, prefetch_before)`,
//!    capped so the lead never swallows the idle gap; then derive the
//!    *write* lead the same way on the eviction side
//!    ([`write_lead_for_ns`]: extend the region reservation past
//!    `evict_after` until the estimated store write fits, capped so the
//!    two extensions never meet). Both feed straight into the gap-aware
//!    planner's reservation model (`OffloadPlan::lead_map`), so the
//!    pool layout and the runtime barriers agree by construction.
//! 4. **Depth derivation** ([`derive_depth`]) — total fetch time over
//!    total compute time, clamped to `[2, entries]`: if the store needs
//!    3× the compute time to move one iteration's traffic, three
//!    fetches must overlap to hide it.
//!
//! The cost model is an *estimate* until training starts; the swap
//! runtime re-times whole iterations (warmup rescale, then a running
//! EWMA) and records per-entry *observed* fetch/evict wall times from
//! the background workers, re-deriving leads within each entry's safe
//! bound every iteration — the model keeps tracking the store as it
//! behaves under real load, not just the compile-time probe. Depth
//! also keeps adapting from stall telemetry at epoch boundaries
//! (`SwapExec::adapt_depth`). Selected via `SwapTuning::Calibrated` on
//! `DeviceProfile`/`CompileOpts`; `Fixed` preserves the PR-1 constants.

use std::time::Instant;

use crate::error::Result;
use crate::planner::offload::{peak_of_plan, OffloadPlan, PREFETCH_DEPTH, PREFETCH_LEAD};
use crate::tensor::TensorTable;

use super::store::SecondaryStore;

/// How the swap runtime's prefetch lead/depth are chosen under a memory
/// budget.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum SwapTuning {
    /// PR-1 constants: global 1-EO lead, depth 2. Deterministic plans;
    /// stalls on stores slower than one tensor per EO of compute.
    #[default]
    Fixed,
    /// Micro-benchmark the store and host compute at compile time,
    /// derive per-entry leads and the initial depth, then keep adapting
    /// at runtime (warmup iteration timing rescales the cost model,
    /// stall telemetry grows the depth at epoch boundaries).
    Calibrated,
}

/// Measured secondary-store speed.
#[derive(Clone, Copy, Debug)]
pub struct StoreCalibration {
    /// Streaming write bandwidth, bytes/second.
    pub write_bps: f64,
    /// Streaming read bandwidth, bytes/second.
    pub read_bps: f64,
    /// Fixed per-operation overhead (seek + syscall + lock), ns.
    pub per_op_ns: f64,
}

impl StoreCalibration {
    /// Estimated time to fetch `bytes` back from the store, ns.
    pub fn fetch_ns(&self, bytes: usize) -> f64 {
        self.per_op_ns + bytes as f64 / self.read_bps.max(1.0) * 1e9
    }

    /// Estimated time to evict `bytes` to the store, ns (the write-side
    /// twin of [`StoreCalibration::fetch_ns`], feeding the write-lead
    /// model).
    pub fn evict_ns(&self, bytes: usize) -> f64 {
        self.per_op_ns + bytes as f64 / self.write_bps.max(1.0) * 1e9
    }

    /// A synthetic calibration for tests: `mbps` both ways, no latency.
    pub fn synthetic(mbps: f64) -> Self {
        StoreCalibration {
            write_bps: mbps * 1e6,
            read_bps: mbps * 1e6,
            per_op_ns: 0.0,
        }
    }
}

/// Probe keys far above any offload-entry index, so calibration slots
/// never collide with scheduled evictions.
const PROBE_KEY_BULK: usize = usize::MAX;
const PROBE_KEY_TINY: usize = usize::MAX - 1;
const PROBE_REPS: u32 = 4;

/// Micro-benchmark a store: timed slot writes for the eviction-overlap
/// (write-lead) model, a few timed reads of a `probe_len`-f32 buffer
/// for the fetch bandwidth the read-lead model runs on, and a
/// tiny-buffer round trip for per-op latency. `probe_len` should be
/// representative of the plan's entry sizes (the caller passes the
/// largest entry, clamped to keep the probe cheap).
pub fn probe_store(
    store: &mut dyn SecondaryStore,
    probe_len: usize,
) -> Result<StoreCalibration> {
    let len = probe_len.clamp(1 << 10, 1 << 18);
    // mixed-mantissa probe values in [1, 2): a constant buffer would let
    // a compressing store (file-compressed) report its best-case RLE
    // bandwidth instead of a representative one
    let mut lcg = 0x9E37_79B9_7F4A_7C15u64;
    let buf: Vec<f32> = (0..len)
        .map(|_| {
            lcg = lcg.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            f32::from_bits(0x3F80_0000 | ((lcg >> 40) as u32 & 0x007F_FFFF))
        })
        .collect();
    let mut out = vec![0f32; len];
    // allocate the slot first, then time steady-state overwrites — the
    // write path the eviction pipeline runs every iteration
    store.put(PROBE_KEY_BULK, &buf)?;
    let t0 = Instant::now();
    for _ in 0..PROBE_REPS {
        store.put(PROBE_KEY_BULK, &buf)?;
    }
    let w_ns = (t0.elapsed().as_nanos() as f64 / PROBE_REPS as f64).max(1.0);
    // warm one read, then time steady-state reps — reads are what the
    // prefetch lead model is calibrated against
    store.get(PROBE_KEY_BULK, &mut out)?;
    let t0 = Instant::now();
    for _ in 0..PROBE_REPS {
        store.get(PROBE_KEY_BULK, &mut out)?;
    }
    let r_ns = (t0.elapsed().as_nanos() as f64 / PROBE_REPS as f64).max(1.0);

    let tiny = vec![0f32; 16];
    let mut tiny_out = vec![0f32; 16];
    store.put(PROBE_KEY_TINY, &tiny)?;
    let t0 = Instant::now();
    for _ in 0..PROBE_REPS {
        store.get(PROBE_KEY_TINY, &mut tiny_out)?;
    }
    let per_op_ns = (t0.elapsed().as_nanos() as f64 / PROBE_REPS as f64).max(1.0);

    // release the probe slots: the same store instance backs the whole
    // training session, and dead probe data must not pin budgeted
    // memory (newest-first so FileStore can roll its end offset back)
    store.free(PROBE_KEY_TINY);
    store.free(PROBE_KEY_BULK);

    let bytes = (len * 4) as f64;
    Ok(StoreCalibration {
        write_bps: bytes / w_ns * 1e9,
        read_bps: bytes / r_ns * 1e9,
        per_op_ns,
    })
}

/// Measured host compute throughput: the scale turning per-EO touched
/// bytes into estimated compute time.
#[derive(Clone, Copy, Debug)]
pub struct ComputeCalibration {
    pub bytes_per_ns: f64,
}

/// Time an FMA sweep over a ~1 MiB buffer. Deliberately crude — the
/// absolute scale is replaced by measured iteration time after warmup;
/// what matters at compile time is the order of magnitude relating
/// store bandwidth to compute speed.
pub fn probe_compute() -> ComputeCalibration {
    let n = 1usize << 18; // 1 MiB of f32
    let mut v = vec![1.0f32; n];
    let t0 = Instant::now();
    for r in 0..PROBE_REPS {
        let c = 1.0 + (r as f32) * 1e-7;
        for x in v.iter_mut() {
            *x = x.mul_add(c, 1e-9);
        }
    }
    let ns = (t0.elapsed().as_nanos() as f64 / PROBE_REPS as f64).max(1.0);
    std::hint::black_box(&v);
    ComputeCalibration { bytes_per_ns: (n * 4) as f64 / ns }
}

/// Per-EO compute-cost model: estimated nanoseconds per execution order.
/// The *relative* shape comes from exact planner-table analysis (bytes
/// touched by the tensors using each EO); the *absolute* scale starts
/// from the compute probe and is rescaled once real iteration timing
/// exists ([`EoCostModel::rescale_to_iteration_ns`]).
#[derive(Clone, Debug)]
pub struct EoCostModel {
    cost_ns: Vec<f64>,
    /// `prefix[i] = Σ cost_ns[..i]`, kept in sync with `cost_ns` so
    /// window sums are O(1) — lead derivation sweeps a window per
    /// candidate lead per entry, every iteration under observed
    /// feedback, so per-EO summation would cost O(gap²) per entry on
    /// the training thread.
    prefix: Vec<f64>,
}

fn prefix_of(cost_ns: &[f64]) -> Vec<f64> {
    let mut prefix = Vec::with_capacity(cost_ns.len() + 1);
    let mut acc = 0.0;
    prefix.push(0.0);
    for &c in cost_ns {
        acc += c;
        prefix.push(acc);
    }
    prefix
}

impl EoCostModel {
    /// Build from a planned table: each EO's cost is the bytes of every
    /// per-iteration tensor using it, over measured compute throughput.
    /// Whole-training (MAX-lifespan) tensors are excluded — their EO set
    /// does not reflect per-step accesses. Every EO gets a small floor
    /// so windows over quiet EOs are never estimated as free.
    pub fn from_table(table: &TensorTable, compute: &ComputeCalibration) -> Self {
        let max_eo = table
            .iter()
            .filter(|s| s.merged_into.is_none())
            .filter_map(|s| s.max_eo())
            .max()
            .unwrap_or(0);
        let mut bytes = vec![0f64; max_eo as usize + 1];
        for s in table.iter() {
            if s.merged_into.is_some() || s.lifespan.is_max() {
                continue;
            }
            for &e in &s.eos {
                bytes[e as usize] += s.dim.bytes() as f64;
            }
        }
        let floor = 64.0; // bytes; keeps empty EOs from being "free"
        let scale = 1.0 / compute.bytes_per_ns.max(f64::MIN_POSITIVE);
        let cost_ns: Vec<f64> = bytes.iter().map(|b| b.max(floor) * scale).collect();
        let prefix = prefix_of(&cost_ns);
        EoCostModel { cost_ns, prefix }
    }

    /// A uniform model for tests: `n_eos` EOs of `ns_per_eo` each.
    pub fn uniform(n_eos: usize, ns_per_eo: f64) -> Self {
        let cost_ns = vec![ns_per_eo; n_eos];
        let prefix = prefix_of(&cost_ns);
        EoCostModel { cost_ns, prefix }
    }

    /// Σ estimated cost over EOs `[from, to]` inclusive, in O(1). EOs
    /// beyond the model (e.g. a deferred apply step) cost the model's
    /// mean.
    pub fn window_ns(&self, from: u32, to: u32) -> f64 {
        if to < from || self.cost_ns.is_empty() {
            return 0.0;
        }
        let n = self.cost_ns.len();
        let lo = (from as usize).min(n);
        let hi = (to as usize + 1).min(n);
        let inside = self.prefix[hi] - self.prefix[lo];
        let overhang = (to - from + 1) as usize - (hi - lo);
        inside + self.total_ns() / n as f64 * overhang as f64
    }

    /// Whole-schedule estimated cost, ns.
    pub fn total_ns(&self) -> f64 {
        *self.prefix.last().unwrap_or(&0.0)
    }

    /// Number of modeled EOs (the schedule length).
    pub fn n_eos(&self) -> usize {
        self.cost_ns.len()
    }

    /// Compute window available to a *boundary-crossing* fetch: the
    /// schedule tail after the eviction write lands (`(evict_after,
    /// end]`) plus the next iteration's head up to the use EO
    /// (`[prefetch_before − lead, prefetch_before)`). This is the window
    /// a wrap entry's background fetch genuinely overlaps — iteration
    /// N's tail, the boundary, and N+1's head.
    pub fn boundary_window_ns(&self, evict_after: u32, prefetch_before: u32, lead: u32) -> f64 {
        let end = self.n_eos().saturating_sub(1) as u32;
        let tail = if evict_after < end { self.window_ns(evict_after + 1, end) } else { 0.0 };
        let head = if lead > 0 && prefetch_before > 0 {
            self.window_ns(prefetch_before.saturating_sub(lead), prefetch_before - 1)
        } else {
            0.0
        };
        tail + head
    }

    /// Replace the absolute scale with a measured per-iteration wall
    /// time, keeping the relative per-EO shape (warmup refinement).
    pub fn rescale_to_iteration_ns(&mut self, measured_iter_ns: f64) {
        let total = self.total_ns();
        if total <= 0.0 || measured_iter_ns <= 0.0 {
            return;
        }
        let k = measured_iter_ns / total;
        for c in &mut self.cost_ns {
            *c *= k;
        }
        for p in &mut self.prefix {
            *p *= k;
        }
    }
}

/// Widest admissible lead for an entry: one less than the idle gap (a
/// lead that swallows the gap would put the completion barrier at or
/// before the eviction — the schedule-head edge the runtime rejects).
pub fn lead_cap(evict_after: u32, prefetch_before: u32) -> u32 {
    prefetch_before
        .saturating_sub(evict_after)
        .saturating_sub(1)
        .max(1)
}

/// Derive one entry's lead from an estimated (or *observed*) fetch
/// time: widen from 1 EO until the fetch fits in the compute window
/// before the use EO, capped by the gap. The runtime's observed-fetch
/// feedback calls this directly with per-entry EWMA wall times.
pub fn lead_for_ns(
    fetch_ns: f64,
    evict_after: u32,
    prefetch_before: u32,
    cost: &EoCostModel,
) -> u32 {
    if prefetch_before == 0 {
        return PREFETCH_LEAD; // degenerate entry; the runtime rejects it
    }
    let cap = lead_cap(evict_after, prefetch_before);
    let mut lead = PREFETCH_LEAD;
    while lead < cap
        && cost.window_ns(prefetch_before.saturating_sub(lead), prefetch_before - 1) < fetch_ns
    {
        lead += 1;
    }
    lead
}

/// Derive one entry's lead: widen from 1 EO until the fetch fits in the
/// compute window before the use EO, capped by the gap.
pub fn lead_for(
    entry_bytes: usize,
    evict_after: u32,
    prefetch_before: u32,
    store: &StoreCalibration,
    cost: &EoCostModel,
) -> u32 {
    lead_for_ns(store.fetch_ns(entry_bytes), evict_after, prefetch_before, cost)
}

/// Widest admissible write lead for an entry: the write extension and
/// the next segment's read widening must never meet inside the gap
/// (`evict_after + write_lead < prefetch_before − read_lead`).
pub fn write_lead_cap(evict_after: u32, prefetch_before: u32, read_lead: u32) -> u32 {
    prefetch_before
        .saturating_sub(evict_after)
        .saturating_sub(read_lead)
        .saturating_sub(1)
}

/// Derive one entry's write lead from an estimated (or observed) evict
/// time: extend the reservation past the eviction until the copy fits
/// in the covered compute window (`(evict_after, evict_after + w]`),
/// within the gap budget left by the read lead. Zero only when the gap
/// leaves no room at all (cap 0) — any in-flight write wants at least
/// one EO of guaranteed cover before a tenant may reclaim the range.
pub fn write_lead_for_ns(
    evict_ns: f64,
    evict_after: u32,
    prefetch_before: u32,
    read_lead: u32,
    cost: &EoCostModel,
) -> u32 {
    let cap = write_lead_cap(evict_after, prefetch_before, read_lead);
    let mut w = 0u32;
    while w < cap && cost.window_ns(evict_after + 1, evict_after + w) < evict_ns {
        w += 1;
    }
    w
}

/// Widest admissible lead for a *wrap* (boundary) entry: the restore
/// barrier `due = prefetch_before − lead` must stay inside the schedule
/// head (`due ≥ 0`), so the lead may grow up to the first real access EO
/// itself — the fetch window behind it extends into the previous
/// iteration's tail, which [`wrap_lead_for_ns`] accounts for.
pub fn wrap_lead_cap(prefetch_before: u32) -> u32 {
    prefetch_before.max(1)
}

/// Derive a wrap entry's lead from an estimated (or observed) fetch
/// time. The available compute window crosses the schedule end
/// ([`EoCostModel::boundary_window_ns`]): the tail after the eviction is
/// always part of it, so a fetch that fits there needs only the minimum
/// head lead; slower fetches widen into the head up to `prefetch_before`.
pub fn wrap_lead_for_ns(
    fetch_ns: f64,
    evict_after: u32,
    prefetch_before: u32,
    cost: &EoCostModel,
) -> u32 {
    let cap = wrap_lead_cap(prefetch_before);
    let mut lead = PREFETCH_LEAD.min(cap);
    while lead < cap && cost.boundary_window_ns(evict_after, prefetch_before, lead) < fetch_ns {
        lead += 1;
    }
    lead
}

/// Widest admissible write lead for a wrap entry: the reservation may
/// extend to the schedule end but not past it (`evict_after + w ≤ end`)
/// — past the end, the carried-state barriers of the next iteration
/// cover the still-draining write, so reserving more buys nothing.
pub fn wrap_write_lead_cap(evict_after: u32, schedule_end: u32) -> u32 {
    schedule_end.saturating_sub(evict_after)
}

/// Derive a wrap entry's write lead: extend the in-schedule reservation
/// past the eviction until the estimated store write fits, capped at the
/// schedule end.
pub fn wrap_write_lead_for_ns(evict_ns: f64, evict_after: u32, cost: &EoCostModel) -> u32 {
    let end = cost.n_eos().saturating_sub(1) as u32;
    let cap = wrap_write_lead_cap(evict_after, end);
    let mut w = 0u32;
    while w < cap && cost.window_ns(evict_after + 1, evict_after + w) < evict_ns {
        w += 1;
    }
    w
}

/// Write calibrated per-entry read *and* write leads and the initial
/// depth into the plan, then refresh its peak/fits for the widened
/// residency (both ends of every gap).
pub fn derive_leads(
    plan: &mut OffloadPlan,
    table: &TensorTable,
    budget_bytes: usize,
    store: &StoreCalibration,
    cost: &EoCostModel,
) {
    for e in &mut plan.entries {
        if e.wrap {
            e.lead =
                wrap_lead_for_ns(store.fetch_ns(e.bytes), e.evict_after, e.prefetch_before, cost);
            e.write_lead = wrap_write_lead_for_ns(store.evict_ns(e.bytes), e.evict_after, cost);
        } else {
            e.lead = lead_for(e.bytes, e.evict_after, e.prefetch_before, store, cost);
            e.write_lead = write_lead_for_ns(
                store.evict_ns(e.bytes),
                e.evict_after,
                e.prefetch_before,
                e.lead,
                cost,
            );
        }
    }
    plan.prefetch_depth = derive_depth(plan, store, cost);
    plan.primary_peak_bytes = peak_of_plan(table, plan);
    plan.fits = plan.primary_peak_bytes <= budget_bytes;
}

/// Initial in-flight depth: the ratio of total fetch time to total
/// compute time per iteration, clamped to `[PREFETCH_DEPTH, entries]` —
/// a store that needs N× the compute time to move one iteration's
/// swap-in traffic needs ~N overlapping fetches to hide it.
pub fn derive_depth(
    plan: &OffloadPlan,
    store: &StoreCalibration,
    cost: &EoCostModel,
) -> usize {
    if plan.entries.is_empty() {
        return PREFETCH_DEPTH;
    }
    let fetch_total: f64 = plan.entries.iter().map(|e| store.fetch_ns(e.bytes)).sum();
    let ratio = (fetch_total / cost.total_ns().max(1.0)).ceil() as usize;
    ratio.clamp(PREFETCH_DEPTH, plan.entries.len().max(PREFETCH_DEPTH))
}

/// Everything the swap runtime needs to keep calibrating after compile:
/// the store speeds, the (rescalable) cost model, how many warmup
/// iterations to time before the first lead re-derivation, and the
/// smoothing factor for the per-entry observed fetch/evict wall times
/// the runtime records every iteration thereafter.
#[derive(Clone, Debug)]
pub struct SwapCalibration {
    pub store: StoreCalibration,
    pub cost: EoCostModel,
    /// Iterations to time before the first cost-model rescale and lead
    /// re-derivation; after warmup both keep updating every iteration
    /// from observed-EWMA feedback.
    pub warmup_iters: u64,
    /// EWMA smoothing factor for observed per-entry transfer times and
    /// the per-iteration compute estimate, in `(0, 1]` (1 = use only
    /// the latest sample).
    pub ewma_alpha: f64,
}

impl SwapCalibration {
    pub fn new(store: StoreCalibration, cost: EoCostModel) -> Self {
        SwapCalibration { store, cost, warmup_iters: 2, ewma_alpha: 0.25 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::store::{HostStore, SecondaryStore};

    #[test]
    fn store_probe_reports_positive_speeds() {
        let mut s = HostStore::new();
        let cal = probe_store(&mut s, 1 << 14).unwrap();
        assert!(cal.write_bps > 0.0 && cal.read_bps > 0.0 && cal.per_op_ns > 0.0);
        // probe slots must not collide with entry keys (0..n)
        let mut out = vec![0f32; 4];
        assert!(s.get(0, &mut out).is_err(), "probe wrote an entry slot");
    }

    #[test]
    fn compute_probe_is_positive() {
        assert!(probe_compute().bytes_per_ns > 0.0);
    }

    #[test]
    fn window_and_rescale() {
        let mut m = EoCostModel::uniform(10, 100.0);
        assert_eq!(m.window_ns(2, 4), 300.0);
        assert_eq!(m.window_ns(4, 2), 0.0);
        // EOs past the model cost the mean
        assert_eq!(m.window_ns(9, 10), 200.0);
        m.rescale_to_iteration_ns(2000.0);
        assert_eq!(m.window_ns(0, 9), 2000.0);
    }

    #[test]
    fn lead_widens_until_fetch_fits() {
        let cost = EoCostModel::uniform(64, 100.0);
        // 1000-byte entry at 1 byte/ns needs 1000 ns = 10 EOs of lead
        let store = StoreCalibration { write_bps: 1e9, read_bps: 1e9, per_op_ns: 0.0 };
        assert_eq!(lead_for(1000, 0, 40, &store, &cost), 10);
        // fast store: the default 1-EO lead suffices
        let fast = StoreCalibration { write_bps: 1e12, read_bps: 1e12, per_op_ns: 0.0 };
        assert_eq!(lead_for(1000, 0, 40, &fast, &cost), 1);
        // cap: the lead never swallows the gap
        assert_eq!(lead_for(1_000_000, 30, 40, &store, &cost), 9);
    }

    #[test]
    fn wrap_lead_uses_boundary_window() {
        let cost = EoCostModel::uniform(64, 100.0);
        // eviction at EO 60 leaves a 3-EO tail (61..=63) = 300 ns of
        // always-available cover; a 1000 ns fetch widens the head lead
        // until tail + head ≥ fetch (300 + 7×100)
        assert_eq!(wrap_lead_for_ns(1000.0, 60, 20, &cost), 7);
        // a fetch that fits in the tail + minimum head keeps lead 1
        assert_eq!(wrap_lead_for_ns(250.0, 60, 20, &cost), 1);
        // cap: the restore barrier never leaves the schedule head
        assert_eq!(wrap_lead_for_ns(1e12, 60, 20, &cost), 20);
    }

    #[test]
    fn wrap_write_lead_capped_at_schedule_end() {
        let cost = EoCostModel::uniform(64, 100.0);
        // one EO of cover suffices for a 50 ns write
        assert_eq!(wrap_write_lead_for_ns(50.0, 60, &cost), 1);
        // the reservation never runs past the schedule end (EO 63)
        assert_eq!(wrap_write_lead_cap(60, 63), 3);
        assert_eq!(wrap_write_lead_for_ns(1e12, 60, &cost), 3);
    }

    #[test]
    fn write_lead_widens_until_evict_fits() {
        let cost = EoCostModel::uniform(64, 100.0);
        let store = StoreCalibration { write_bps: 1e9, read_bps: 1e9, per_op_ns: 0.0 };
        // 1000-byte eviction at 1 byte/ns needs 1000 ns = 10 EOs of cover
        assert_eq!(write_lead_for_ns(store.evict_ns(1000), 0, 40, 1, &cost), 10);
        // a write covered by one EO of compute needs exactly that one
        assert_eq!(write_lead_for_ns(store.evict_ns(50), 0, 40, 1, &cost), 1);
        // cap: write extension + read widening never meet inside the gap
        assert_eq!(write_lead_cap(30, 40, 3), 6);
        assert_eq!(write_lead_for_ns(store.evict_ns(1 << 20), 30, 40, 3, &cost), 6);
        // degenerate gap: no room at all
        assert_eq!(write_lead_cap(0, 2, 1), 0);
    }
}
