//! Runtime services.
//!
//! * `store` / `swap` / `calibrate` — the proactive swap runtime:
//!   secondary-memory stores, the EO-scheduled evict/prefetch engine
//!   that executes an `OffloadPlan` during training, and the
//!   bandwidth-calibration subsystem that derives per-entry prefetch
//!   leads and in-flight depth from measured store speed (see DESIGN.md
//!   §Swap runtime).
//! * `client` / `catalog` — PJRT runtime: loads the AOT-compiled HLO
//!   artifacts produced by `python/compile/aot.py` and executes them on
//!   the request path. Python is never involved at runtime — the binary
//!   is self-contained once `make artifacts` has run. The real client
//!   needs the `xla` crate and is gated behind the `pjrt` feature; the
//!   default (offline) build uses a stub that errors at construction.

pub mod alloc_audit;
pub mod calibrate;
pub mod catalog;
pub mod store;
pub mod swap;

#[cfg(feature = "pjrt")]
pub mod client;
#[cfg(not(feature = "pjrt"))]
#[path = "client_stub.rs"]
pub mod client;

pub use calibrate::{
    ComputeCalibration, EoCostModel, StoreCalibration, SwapCalibration, SwapTuning,
};
pub use catalog::ArtifactCatalog;
pub use client::XlaRuntime;
pub use store::{DelayStore, FileStore, HostStore, SecondaryStore, StoreKind, StoreStats};
pub use swap::{SwapExec, SwapStats};
