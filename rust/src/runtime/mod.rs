//! PJRT runtime: loads the AOT-compiled HLO artifacts produced by
//! `python/compile/aot.py` and executes them on the request path.
//! Python is never involved at runtime — the binary is self-contained
//! once `make artifacts` has run.

pub mod catalog;
pub mod client;

pub use catalog::ArtifactCatalog;
pub use client::XlaRuntime;
