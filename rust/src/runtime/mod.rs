//! Runtime services.
//!
//! * `store` / `swap` — the proactive swap runtime: secondary-memory
//!   stores and the EO-scheduled evict/prefetch engine that executes an
//!   `OffloadPlan` during training (see DESIGN.md §Swap runtime).
//! * `client` / `catalog` — PJRT runtime: loads the AOT-compiled HLO
//!   artifacts produced by `python/compile/aot.py` and executes them on
//!   the request path. Python is never involved at runtime — the binary
//!   is self-contained once `make artifacts` has run. The real client
//!   needs the `xla` crate and is gated behind the `pjrt` feature; the
//!   default (offline) build uses a stub that errors at construction.

pub mod catalog;
pub mod store;
pub mod swap;

#[cfg(feature = "pjrt")]
pub mod client;
#[cfg(not(feature = "pjrt"))]
#[path = "client_stub.rs"]
pub mod client;

pub use catalog::ArtifactCatalog;
pub use client::XlaRuntime;
pub use store::{FileStore, HostStore, SecondaryStore, StoreKind};
pub use swap::{SwapExec, SwapStats};
