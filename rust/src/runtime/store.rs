//! Secondary-memory stores backing the swap runtime (paper §6 future
//! work: "dynamic off-loading using secondary memory").
//!
//! A store holds the bytes of evicted tensors between their idle-gap
//! endpoints. Keys are offload-entry indices (stable for the life of a
//! compiled model), so a tensor with several idle gaps per iteration uses
//! one slot per gap. Two backends:
//!
//! * [`HostStore`] — an in-memory buffer pool; models swapping from a
//!   fast primary arena (e.g. a device/TPU pool) to host RAM.
//! * [`FileStore`] — a spill file in the OS temp directory; models
//!   swapping to flash, the on-device case the paper targets.

use std::collections::HashMap;
use std::fs::{File, OpenOptions};
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};

use crate::error::{Error, Result};

/// Byte sink/source for evicted tensors. Implementations must be cheap to
/// call from the executor's hot loop (no allocation on the `put` path
/// after warm-up) and `Send` so the prefetcher thread can own a handle.
pub trait SecondaryStore: Send {
    fn kind(&self) -> &'static str;
    /// Store `data` under `key`, overwriting any previous contents.
    fn put(&mut self, key: usize, data: &[f32]) -> Result<()>;
    /// Read `key` back into `out` (exactly the length that was `put`).
    fn get(&mut self, key: usize, out: &mut [f32]) -> Result<()>;
    /// Release `key`'s slot (calibration probes free theirs so a
    /// session-long store never pins dead probe data). Freeing an
    /// absent key is a no-op.
    fn free(&mut self, _key: usize) {}
    /// Number of live slots — the teardown audit metric: the swap
    /// runtime frees every entry slot on drop, so a store must count 0
    /// after its engine is gone (no leaked eviction data).
    fn slot_count(&self) -> usize {
        0
    }
}

/// Which secondary store a memory-budgeted compile should use.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum StoreKind {
    /// In-memory host buffers (default).
    #[default]
    Host,
    /// File-backed spill in the OS temp directory.
    File,
}

impl StoreKind {
    pub fn instance(&self) -> Result<Box<dyn SecondaryStore>> {
        Ok(match self {
            StoreKind::Host => Box::new(HostStore::new()),
            StoreKind::File => Box::new(FileStore::in_temp_dir()?),
        })
    }
}

/// In-memory secondary store: one buffer per offload entry, reused across
/// iterations so steady-state swapping is allocation-free.
#[derive(Default)]
pub struct HostStore {
    slots: HashMap<usize, Vec<f32>>,
}

impl HostStore {
    pub fn new() -> Self {
        HostStore::default()
    }
}

impl SecondaryStore for HostStore {
    fn kind(&self) -> &'static str {
        "host"
    }

    fn put(&mut self, key: usize, data: &[f32]) -> Result<()> {
        let slot = self.slots.entry(key).or_default();
        slot.clear();
        slot.extend_from_slice(data);
        Ok(())
    }

    fn get(&mut self, key: usize, out: &mut [f32]) -> Result<()> {
        let slot = self
            .slots
            .get(&key)
            .ok_or_else(|| Error::Runtime(format!("swap store: key {key} was never put")))?;
        if slot.len() != out.len() {
            return Err(Error::Runtime(format!(
                "swap store: key {key} holds {} f32s, asked for {}",
                slot.len(),
                out.len()
            )));
        }
        out.copy_from_slice(slot);
        Ok(())
    }

    fn free(&mut self, key: usize) {
        self.slots.remove(&key);
    }

    fn slot_count(&self) -> usize {
        self.slots.len()
    }
}

static FILE_STORE_SEQ: AtomicU64 = AtomicU64::new(0);

/// File-backed secondary store. Slots are allocated append-only on first
/// `put` and overwritten in place afterwards; the file is removed on drop.
pub struct FileStore {
    file: File,
    path: PathBuf,
    /// key → (byte offset, f32 length)
    slots: HashMap<usize, (u64, usize)>,
    end: u64,
    scratch: Vec<u8>,
}

impl FileStore {
    pub fn in_temp_dir() -> Result<Self> {
        let seq = FILE_STORE_SEQ.fetch_add(1, Ordering::Relaxed);
        let path = std::env::temp_dir().join(format!(
            "nntrainer-swap-{}-{}.bin",
            std::process::id(),
            seq
        ));
        Self::create(path)
    }

    pub fn create(path: PathBuf) -> Result<Self> {
        let file = OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .truncate(true)
            .open(&path)?;
        Ok(FileStore { file, path, slots: HashMap::new(), end: 0, scratch: Vec::new() })
    }

    pub fn path(&self) -> &std::path::Path {
        &self.path
    }
}

impl Drop for FileStore {
    fn drop(&mut self) {
        let _ = std::fs::remove_file(&self.path);
    }
}

impl SecondaryStore for FileStore {
    fn kind(&self) -> &'static str {
        "file"
    }

    fn put(&mut self, key: usize, data: &[f32]) -> Result<()> {
        let offset = match self.slots.get(&key) {
            Some(&(off, len)) if len == data.len() => off,
            _ => {
                let off = self.end;
                self.end += (data.len() * 4) as u64;
                self.slots.insert(key, (off, data.len()));
                off
            }
        };
        self.scratch.clear();
        self.scratch.reserve(data.len() * 4);
        for v in data {
            self.scratch.extend_from_slice(&v.to_le_bytes());
        }
        self.file.seek(SeekFrom::Start(offset))?;
        self.file.write_all(&self.scratch)?;
        Ok(())
    }

    fn free(&mut self, key: usize) {
        // reclaim the file space too when the slot is the trailing one
        // (calibration probes are written before any eviction, so
        // freeing them newest-first rolls `end` back to zero)
        if let Some((off, len)) = self.slots.remove(&key) {
            if off + (len * 4) as u64 == self.end {
                self.end = off;
            }
        }
    }

    fn slot_count(&self) -> usize {
        self.slots.len()
    }

    fn get(&mut self, key: usize, out: &mut [f32]) -> Result<()> {
        let &(offset, len) = self
            .slots
            .get(&key)
            .ok_or_else(|| Error::Runtime(format!("swap store: key {key} was never put")))?;
        if len != out.len() {
            return Err(Error::Runtime(format!(
                "swap store: key {key} holds {len} f32s, asked for {}",
                out.len()
            )));
        }
        self.scratch.clear();
        self.scratch.resize(len * 4, 0);
        self.file.seek(SeekFrom::Start(offset))?;
        self.file.read_exact(&mut self.scratch)?;
        for (i, v) in out.iter_mut().enumerate() {
            *v = f32::from_le_bytes([
                self.scratch[4 * i],
                self.scratch[4 * i + 1],
                self.scratch[4 * i + 2],
                self.scratch[4 * i + 3],
            ]);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(store: &mut dyn SecondaryStore) {
        let a = vec![1.0f32, -2.5, 3.25, f32::MIN_POSITIVE, -0.0];
        let b = vec![9.0f32; 7];
        store.put(0, &a).unwrap();
        store.put(1, &b).unwrap();
        let mut out = vec![0f32; a.len()];
        store.get(0, &mut out).unwrap();
        // bitwise: swap must preserve exact representations (incl. -0.0)
        for (x, y) in out.iter().zip(a.iter()) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        // overwrite in place
        let a2 = vec![7.0f32; 5];
        store.put(0, &a2).unwrap();
        store.get(0, &mut out).unwrap();
        assert_eq!(out, a2);
        let mut out_b = vec![0f32; b.len()];
        store.get(1, &mut out_b).unwrap();
        assert_eq!(out_b, b);
        // wrong length and missing key are errors
        let mut wrong = vec![0f32; 3];
        assert!(store.get(0, &mut wrong).is_err());
        assert!(store.get(99, &mut out).is_err());
        // freed slots are gone; freeing an absent key is a no-op
        assert_eq!(store.slot_count(), 2);
        store.free(1);
        store.free(1);
        assert!(store.get(1, &mut out_b).is_err());
        assert_eq!(store.slot_count(), 1);
        store.free(0);
        assert_eq!(store.slot_count(), 0);
    }

    #[test]
    fn host_roundtrip() {
        roundtrip(&mut HostStore::new());
    }

    #[test]
    fn file_roundtrip() {
        let mut s = FileStore::in_temp_dir().unwrap();
        let path = s.path().to_path_buf();
        roundtrip(&mut s);
        assert!(path.exists());
        drop(s);
        assert!(!path.exists(), "spill file removed on drop");
    }
}
