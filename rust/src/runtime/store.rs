//! Secondary-memory stores backing the swap runtime (paper §6 future
//! work: "dynamic off-loading using secondary memory").
//!
//! A store holds the bytes of evicted tensors between their idle-gap
//! endpoints. Keys are offload-entry indices (stable for the life of a
//! compiled model), so a tensor with several idle gaps per iteration uses
//! one slot per gap. Backends:
//!
//! * [`HostStore`] — an in-memory buffer pool; models swapping from a
//!   fast primary arena (e.g. a device/TPU pool) to host RAM.
//! * [`FileStore`] — a spill file in the OS temp directory; models
//!   swapping to flash, the on-device case the paper targets. The file
//!   store is written for device storage, not just correctness:
//!   - **extents** — slots own byte extents sized for the *raw* payload
//!     (so a re-put always fits regardless of how well it compressed),
//!     recycled through a free list with trailing-extent rollback;
//!   - **compression** ([`StoreKind::FileCompressed`]) — f32 payloads
//!     are byte-shuffled into four per-byte planes and PackBits-RLE
//!     coded per plane (exponent/sign planes of real tensors are highly
//!     repetitive), with a raw fallback whenever the coded form isn't
//!     smaller; recovery is bitwise, including `-0.0` and NaN payloads;
//!   - **write coalescing** — adjacent/near-adjacent slot writes merge
//!     into one buffered file write (small gaps are bridged with the
//!     file's current bytes, so untouched extents inside a gap survive
//!     the flush), turning an eviction burst into a single sequential
//!     flush;
//!   - **wear rotation** — per-extent write counters; a slot that keeps
//!     rewriting a hot extent is rotated onto the coolest adequate free
//!     extent, spreading flash program/erase cycles.
//!
//! Every store reports cumulative [`StoreStats`]; the swap runtime and
//! fleet surface them (bench columns `store_rewrites`, peak store bytes).

use std::collections::HashMap;
use std::fs::{File, OpenOptions};
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};

use crate::error::{Error, Result};

/// Cumulative store I/O counters. All monotone except `live_bytes`
/// (current reservation; `peak_bytes` is its high-water mark).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StoreStats {
    /// Completed `put` calls.
    pub puts: u64,
    /// Completed `get` calls.
    pub gets: u64,
    /// Puts that overwrote an already-written backing range in place —
    /// the flash-wear proxy the `store_rewrites` bench column gates.
    pub rewrites: u64,
    /// Wear-leveling relocations (hot slot moved to a cooler extent).
    pub rotations: u64,
    /// Puts whose bytes merged into a buffered neighbouring write
    /// instead of issuing their own file write.
    pub coalesced_puts: u64,
    /// Caller payload bytes across all puts (pre-codec).
    pub logical_bytes: u64,
    /// Bytes actually written to the backing medium (post-codec,
    /// including coalescing gap bridges).
    pub physical_bytes: u64,
    /// Backing bytes currently reserved by live slots.
    pub live_bytes: u64,
    /// High-water mark of `live_bytes`.
    pub peak_bytes: u64,
    /// Write count of the hottest backing extent (wear skew gauge).
    pub max_slot_writes: u64,
}

/// Byte sink/source for evicted tensors. Implementations must be cheap to
/// call from the executor's hot loop (no allocation on the `put` path
/// after warm-up) and `Send` so the prefetcher thread can own a handle.
pub trait SecondaryStore: Send {
    fn kind(&self) -> &'static str;
    /// Store `data` under `key`, overwriting any previous contents.
    fn put(&mut self, key: usize, data: &[f32]) -> Result<()>;
    /// Read `key` back into `out` (exactly the length that was `put`).
    fn get(&mut self, key: usize, out: &mut [f32]) -> Result<()>;
    /// Release `key`'s slot (calibration probes free theirs so a
    /// session-long store never pins dead probe data). Freeing an
    /// absent key is a no-op.
    fn free(&mut self, _key: usize) {}
    /// Number of live slots — the teardown audit metric: the swap
    /// runtime frees every entry slot on drop, so a store must count 0
    /// after its engine is gone (no leaked eviction data).
    fn slot_count(&self) -> usize {
        0
    }
    /// Cumulative I/O counters. Stores that don't track report zeros.
    fn stats(&self) -> StoreStats {
        StoreStats::default()
    }
}

/// Which secondary store a memory-budgeted compile should use.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum StoreKind {
    /// In-memory host buffers (default).
    #[default]
    Host,
    /// File-backed spill in the OS temp directory.
    File,
    /// File-backed spill with byte-shuffle + RLE compression.
    FileCompressed,
}

impl StoreKind {
    pub fn instance(&self) -> Result<Box<dyn SecondaryStore>> {
        let store: Box<dyn SecondaryStore> = match self {
            StoreKind::Host => Box::new(HostStore::new()),
            StoreKind::File => Box::new(FileStore::in_temp_dir()?),
            StoreKind::FileCompressed => Box::new(FileStore::in_temp_dir_compressed()?),
        };
        Ok(match injected_store_delay_us()? {
            0 => store,
            us => Box::new(DelayStore::new(store, std::time::Duration::from_micros(us))),
        })
    }

    /// Parse a store name (CLI/env): `host`, `file`, `file-compressed`.
    pub fn parse(s: &str) -> Option<Self> {
        match s.trim().to_ascii_lowercase().as_str() {
            "host" => Some(StoreKind::Host),
            "file" => Some(StoreKind::File),
            "file-compressed" | "file_compressed" | "filecompressed" => {
                Some(StoreKind::FileCompressed)
            }
            _ => None,
        }
    }
}

/// Per-operation store latency from `NNTRAINER_STORE_DELAY_US`
/// (default 0 = off). A latency-injection hook for benches and CI: on a
/// fast development disk the spill store barely stalls, so the
/// swap-runtime bench's stall columns (and the pipelined-vs-drained
/// boundary comparison) inject a deterministic delay to make overlap
/// effects measurable. An unparseable value is a loud error, matching
/// the other bench env knobs.
fn injected_store_delay_us() -> Result<u64> {
    match std::env::var("NNTRAINER_STORE_DELAY_US") {
        Ok(v) => v.trim().parse().map_err(|e| {
            Error::Runtime(format!("NNTRAINER_STORE_DELAY_US={v:?} is not a u64: {e}"))
        }),
        Err(std::env::VarError::NotPresent) => Ok(0),
        Err(e) => Err(Error::Runtime(format!(
            "NNTRAINER_STORE_DELAY_US is set but unreadable: {e}"
        ))),
    }
}

/// Latency-injection wrapper: every `put`/`get` sleeps a fixed delay
/// before delegating to the wrapped store. Never constructed on a
/// production path — [`StoreKind::instance`] wraps with it only when
/// `NNTRAINER_STORE_DELAY_US` is set.
pub struct DelayStore {
    inner: Box<dyn SecondaryStore>,
    delay: std::time::Duration,
}

impl DelayStore {
    pub fn new(inner: Box<dyn SecondaryStore>, delay: std::time::Duration) -> Self {
        DelayStore { inner, delay }
    }
}

impl SecondaryStore for DelayStore {
    fn kind(&self) -> &'static str {
        self.inner.kind()
    }
    fn put(&mut self, key: usize, data: &[f32]) -> Result<()> {
        std::thread::sleep(self.delay);
        self.inner.put(key, data)
    }
    fn get(&mut self, key: usize, out: &mut [f32]) -> Result<()> {
        std::thread::sleep(self.delay);
        self.inner.get(key, out)
    }
    fn free(&mut self, key: usize) {
        self.inner.free(key);
    }
    fn slot_count(&self) -> usize {
        self.inner.slot_count()
    }
    fn stats(&self) -> StoreStats {
        self.inner.stats()
    }
}

/// In-memory secondary store: one buffer per offload entry, reused across
/// iterations so steady-state swapping is allocation-free.
#[derive(Default)]
pub struct HostStore {
    slots: HashMap<usize, Vec<f32>>,
    stats: StoreStats,
}

impl HostStore {
    pub fn new() -> Self {
        HostStore::default()
    }

    fn live_bytes(&self) -> u64 {
        self.slots.values().map(|v| (v.len() * 4) as u64).sum()
    }
}

impl SecondaryStore for HostStore {
    fn kind(&self) -> &'static str {
        "host"
    }

    fn put(&mut self, key: usize, data: &[f32]) -> Result<()> {
        let slot = self.slots.entry(key).or_default();
        if !slot.is_empty() {
            self.stats.rewrites += 1;
        }
        slot.clear();
        slot.extend_from_slice(data);
        self.stats.puts += 1;
        let bytes = (data.len() * 4) as u64;
        self.stats.logical_bytes += bytes;
        self.stats.physical_bytes += bytes;
        self.stats.live_bytes = self.live_bytes();
        self.stats.peak_bytes = self.stats.peak_bytes.max(self.stats.live_bytes);
        Ok(())
    }

    fn get(&mut self, key: usize, out: &mut [f32]) -> Result<()> {
        let slot = self
            .slots
            .get(&key)
            .ok_or_else(|| Error::Runtime(format!("swap store: key {key} was never put")))?;
        if slot.len() != out.len() {
            return Err(Error::Runtime(format!(
                "swap store: key {key} holds {} f32s, asked for {}",
                slot.len(),
                out.len()
            )));
        }
        out.copy_from_slice(slot);
        self.stats.gets += 1;
        Ok(())
    }

    fn free(&mut self, key: usize) {
        self.slots.remove(&key);
        self.stats.live_bytes = self.live_bytes();
    }

    fn slot_count(&self) -> usize {
        self.slots.len()
    }

    fn stats(&self) -> StoreStats {
        self.stats
    }
}

// ---------------------------------------------------------------------
// Byte-shuffle + PackBits codec (zero-dep, bitwise-exact)
// ---------------------------------------------------------------------

/// Per-plane stream format: `[u32 LE coded length][PackBits stream]` × 4
/// planes (LE byte 0..=3 of every f32). PackBits control byte `c`:
/// `c < 128` → literal run of `c + 1` bytes follows; `c >= 128` → the
/// next byte repeats `(c - 128) + 2` times.
fn packbits(src: &[u8], out: &mut Vec<u8>) {
    let mut i = 0;
    while i < src.len() {
        let b = src[i];
        let mut run = 1;
        while i + run < src.len() && src[i + run] == b && run < 129 {
            run += 1;
        }
        if run >= 3 {
            out.push(128 + (run - 2) as u8);
            out.push(b);
            i += run;
        } else {
            // literal: absorb short runs until a run of >= 3 starts
            let start = i;
            i += run;
            while i < src.len() && i - start < 128 {
                let c = src[i];
                let mut r = 1;
                while i + r < src.len() && src[i + r] == c && r < 3 {
                    r += 1;
                }
                if r >= 3 {
                    break;
                }
                i += r;
            }
            let mut len = i - start;
            if len > 128 {
                len = 128;
                i = start + len;
            }
            out.push((len - 1) as u8);
            out.extend_from_slice(&src[start..start + len]);
        }
    }
}

fn unpackbits(src: &[u8], out: &mut Vec<u8>) -> Result<()> {
    let mut i = 0;
    while i < src.len() {
        let c = src[i] as usize;
        i += 1;
        if c < 128 {
            let len = c + 1;
            if i + len > src.len() {
                return Err(Error::Runtime("swap store: corrupt RLE literal run".into()));
            }
            out.extend_from_slice(&src[i..i + len]);
            i += len;
        } else {
            let len = (c - 128) + 2;
            if i >= src.len() {
                return Err(Error::Runtime("swap store: corrupt RLE repeat run".into()));
            }
            out.extend(std::iter::repeat(src[i]).take(len));
            i += 1;
        }
    }
    Ok(())
}

/// Shuffle `data`'s LE bytes into 4 planes and PackBits each into `out`.
/// `plane` is caller-provided scratch (reused across calls).
fn shuffle_rle_encode(data: &[f32], out: &mut Vec<u8>, plane: &mut Vec<u8>) {
    out.clear();
    for p in 0..4 {
        plane.clear();
        plane.extend(data.iter().map(|v| v.to_le_bytes()[p]));
        let hdr = out.len();
        out.extend_from_slice(&[0u8; 4]);
        packbits(plane, out);
        let coded = (out.len() - hdr - 4) as u32;
        out[hdr..hdr + 4].copy_from_slice(&coded.to_le_bytes());
    }
}

/// Inverse of [`shuffle_rle_encode`]: decode `enc` into `out` bitwise.
/// `shuf` is caller-provided scratch holding the concatenated planes.
fn shuffle_rle_decode(enc: &[u8], out: &mut [f32], shuf: &mut Vec<u8>) -> Result<()> {
    let n = out.len();
    shuf.clear();
    let mut cur = 0usize;
    for p in 0..4 {
        if cur + 4 > enc.len() {
            return Err(Error::Runtime("swap store: truncated RLE plane header".into()));
        }
        let coded =
            u32::from_le_bytes([enc[cur], enc[cur + 1], enc[cur + 2], enc[cur + 3]]) as usize;
        cur += 4;
        if cur + coded > enc.len() {
            return Err(Error::Runtime("swap store: truncated RLE plane stream".into()));
        }
        unpackbits(&enc[cur..cur + coded], shuf)?;
        cur += coded;
        if shuf.len() != (p + 1) * n {
            return Err(Error::Runtime(format!(
                "swap store: RLE plane {p} decoded {} bytes, expected {n}",
                shuf.len() - p * n
            )));
        }
    }
    for (i, v) in out.iter_mut().enumerate() {
        *v = f32::from_le_bytes([shuf[i], shuf[n + i], shuf[2 * n + i], shuf[3 * n + i]]);
    }
    Ok(())
}

// ---------------------------------------------------------------------
// FileStore
// ---------------------------------------------------------------------

static FILE_STORE_SEQ: AtomicU64 = AtomicU64::new(0);

/// Rewrites of one extent before the store tries to rotate its slot onto
/// a cooler free extent (flash wear leveling).
const ROTATE_WRITES: u64 = 64;
/// Largest hole the write coalescer bridges between two buffered
/// writes (filled from the file's current bytes — see `queue_write`).
const COALESCE_MAX_GAP: usize = 256;
/// Pending-buffer flush threshold; a single oversized write may exceed
/// it (it becomes its own flush).
const COALESCE_MAX_PENDING: usize = 4 << 20;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Encoding {
    Raw,
    ShuffleRle,
}

/// One byte range of the spill file. Extents are append-allocated (the
/// vector stays sorted by offset), recycled through the `free` flag, and
/// popped from the tail when freed trailing space can roll `end` back.
#[derive(Clone, Copy, Debug)]
struct Extent {
    off: u64,
    cap: usize,
    writes: u64,
    free: bool,
}

#[derive(Clone, Copy, Debug)]
struct Slot {
    extent: usize,
    f32_len: usize,
    enc: Encoding,
    enc_len: usize,
}

/// File-backed secondary store (see module docs for the device-grade
/// behaviors: extents, compression, coalescing, wear rotation). The
/// encoding of each slot lives in memory only — the file is not
/// self-describing, matching its lifetime (removed on drop).
pub struct FileStore {
    file: File,
    path: PathBuf,
    compress: bool,
    slots: HashMap<usize, Slot>,
    extents: Vec<Extent>,
    end: u64,
    /// Encode/read scratch.
    scratch: Vec<u8>,
    /// Codec plane scratch.
    plane: Vec<u8>,
    /// Decode shuffle scratch.
    shuf: Vec<u8>,
    /// Coalescing write buffer covering `[pending_off, pending_off +
    /// pending.len())` of the file.
    pending: Vec<u8>,
    pending_off: u64,
    stats: StoreStats,
}

impl FileStore {
    pub fn in_temp_dir() -> Result<Self> {
        Self::create(Self::temp_path())
    }

    /// A temp-dir store with byte-shuffle + RLE compression.
    pub fn in_temp_dir_compressed() -> Result<Self> {
        Self::create_compressed(Self::temp_path())
    }

    fn temp_path() -> PathBuf {
        let seq = FILE_STORE_SEQ.fetch_add(1, Ordering::Relaxed);
        std::env::temp_dir().join(format!(
            "nntrainer-swap-{}-{}.bin",
            std::process::id(),
            seq
        ))
    }

    pub fn create(path: PathBuf) -> Result<Self> {
        Self::open(path, false)
    }

    pub fn create_compressed(path: PathBuf) -> Result<Self> {
        Self::open(path, true)
    }

    fn open(path: PathBuf, compress: bool) -> Result<Self> {
        let file = OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .truncate(true)
            .open(&path)
            .map_err(|e| {
                Error::Runtime(format!(
                    "swap store: create spill file {}: {e}",
                    path.display()
                ))
            })?;
        Ok(FileStore {
            file,
            path,
            compress,
            slots: HashMap::new(),
            extents: Vec::new(),
            end: 0,
            scratch: Vec::new(),
            plane: Vec::new(),
            shuf: Vec::new(),
            pending: Vec::new(),
            pending_off: 0,
            stats: StoreStats::default(),
        })
    }

    pub fn path(&self) -> &std::path::Path {
        &self.path
    }

    /// Encode `data` into `self.scratch`; returns the slot encoding.
    /// Compression falls back to raw whenever the coded form isn't
    /// strictly smaller, so an extent sized for the raw payload always
    /// fits any future re-put of the same tensor.
    fn encode(&mut self, data: &[f32]) -> Encoding {
        if self.compress {
            shuffle_rle_encode(data, &mut self.scratch, &mut self.plane);
            if self.scratch.len() < data.len() * 4 {
                return Encoding::ShuffleRle;
            }
        }
        self.scratch.clear();
        self.scratch.reserve(data.len() * 4);
        for v in data {
            self.scratch.extend_from_slice(&v.to_le_bytes());
        }
        Encoding::Raw
    }

    /// Claim a free extent with `cap >= need`, preferring the coolest
    /// (fewest writes), then the tightest fit; `None` if none qualifies
    /// (or none is strictly cooler than `cooler_than`, when given).
    fn pick_free(&self, need: usize, cooler_than: Option<u64>) -> Option<usize> {
        self.extents
            .iter()
            .enumerate()
            .filter(|(_, e)| e.free && e.cap >= need)
            .filter(|(_, e)| cooler_than.map_or(true, |w| e.writes < w))
            .min_by_key(|(i, e)| (e.writes, e.cap, *i))
            .map(|(i, _)| i)
    }

    fn claim(&mut self, idx: usize) {
        debug_assert!(self.extents[idx].free);
        self.extents[idx].free = false;
        self.stats.live_bytes += self.extents[idx].cap as u64;
        self.stats.peak_bytes = self.stats.peak_bytes.max(self.stats.live_bytes);
    }

    /// Allocate an extent of `cap` bytes: recycle the best free extent
    /// or append at the end of the file.
    fn alloc(&mut self, cap: usize) -> usize {
        if let Some(i) = self.pick_free(cap, None) {
            self.claim(i);
            return i;
        }
        let off = self.end;
        self.end += cap as u64;
        self.extents.push(Extent { off, cap, writes: 0, free: true });
        let i = self.extents.len() - 1;
        self.claim(i);
        i
    }

    /// Return an extent to the free list; trailing free extents are
    /// absorbed so `end` (and the file's logical footprint) rolls back —
    /// calibration probes freed newest-first roll it to zero.
    fn release(&mut self, idx: usize) {
        self.extents[idx].free = true;
        self.stats.live_bytes -= self.extents[idx].cap as u64;
        while let Some(last) = self.extents.last() {
            if last.free && last.off + last.cap as u64 == self.end {
                self.end = last.off;
                self.extents.pop();
            } else {
                break;
            }
        }
    }

    /// Queue `self.scratch` for writing at file offset `off`, merging
    /// with the pending buffer when the ranges touch (a bounded hole is
    /// bridged with the file's *current* bytes — it may cover a live
    /// extent that is not part of this batch, so zero-filling would
    /// clobber it at flush time). All writes flow through here, so
    /// overlapping writes land in program order.
    fn queue_write(&mut self, off: u64) -> Result<()> {
        if self.pending.is_empty() {
            self.pending_off = off;
            std::mem::swap(&mut self.pending, &mut self.scratch);
            return Ok(());
        }
        let pend_end = self.pending_off + self.pending.len() as u64;
        let mergeable = off >= self.pending_off
            && off <= pend_end + COALESCE_MAX_GAP as u64
            && self.pending.len() + self.scratch.len() <= COALESCE_MAX_PENDING;
        if mergeable {
            if off + self.scratch.len() as u64 <= pend_end {
                // fully inside: overwrite in place
                let s = (off - self.pending_off) as usize;
                self.pending[s..s + self.scratch.len()].copy_from_slice(&self.scratch);
            } else if off >= pend_end {
                // forward extension, bridging the (bounded) hole with
                // the bytes the file holds there; past EOF the zero
                // fill stands (nothing lives above the logical end)
                let start = self.pending.len();
                self.pending.resize((off - self.pending_off) as usize, 0);
                if start < self.pending.len() {
                    let mut filled = 0usize;
                    self.file.seek(SeekFrom::Start(pend_end)).map_err(|e| {
                        Error::Runtime(format!(
                            "swap store: seek to {pend_end} in {}: {e}",
                            self.path.display()
                        ))
                    })?;
                    while start + filled < self.pending.len() {
                        match self.file.read(&mut self.pending[start + filled..]) {
                            Ok(0) => break,
                            Ok(n) => filled += n,
                            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                            Err(e) => {
                                return Err(Error::Runtime(format!(
                                    "swap store: read hole at {pend_end} from {}: {e}",
                                    self.path.display()
                                )))
                            }
                        }
                    }
                }
                self.pending.extend_from_slice(&self.scratch);
            } else {
                // tail overlap: truncate then extend
                self.pending.truncate((off - self.pending_off) as usize);
                self.pending.extend_from_slice(&self.scratch);
            }
            self.stats.coalesced_puts += 1;
            return Ok(());
        }
        self.flush_pending()?;
        self.pending_off = off;
        std::mem::swap(&mut self.pending, &mut self.scratch);
        Ok(())
    }

    fn flush_pending(&mut self) -> Result<()> {
        if self.pending.is_empty() {
            return Ok(());
        }
        self.file
            .seek(SeekFrom::Start(self.pending_off))
            .and_then(|_| self.file.write_all(&self.pending))
            .map_err(|e| {
                Error::Runtime(format!(
                    "swap store: write {} bytes at {} to {}: {e}",
                    self.pending.len(),
                    self.pending_off,
                    self.path.display()
                ))
            })?;
        self.stats.physical_bytes += self.pending.len() as u64;
        self.pending.clear();
        Ok(())
    }
}

impl Drop for FileStore {
    fn drop(&mut self) {
        let _ = std::fs::remove_file(&self.path);
    }
}

impl SecondaryStore for FileStore {
    fn kind(&self) -> &'static str {
        if self.compress {
            "file-compressed"
        } else {
            "file"
        }
    }

    fn put(&mut self, key: usize, data: &[f32]) -> Result<()> {
        let raw_len = data.len() * 4;
        let enc = self.encode(data);
        let enc_len = self.scratch.len();
        let extent = match self.slots.get(&key).copied() {
            Some(s) if s.f32_len == data.len() => {
                let ei = s.extent;
                // wear rotation: a hot extent hands its slot to the
                // coolest adequate free extent
                if self.extents[ei].writes >= ROTATE_WRITES {
                    match self.pick_free(raw_len, Some(self.extents[ei].writes)) {
                        Some(ni) => {
                            self.claim(ni);
                            self.release(ei);
                            self.stats.rotations += 1;
                            ni
                        }
                        None => ei,
                    }
                } else {
                    ei
                }
            }
            Some(s) => {
                // length changed: the old extent can't be trusted to fit
                self.release(s.extent);
                self.alloc(raw_len)
            }
            None => self.alloc(raw_len),
        };
        if self.extents[extent].writes > 0 {
            self.stats.rewrites += 1;
        }
        self.extents[extent].writes += 1;
        let off = self.extents[extent].off;
        self.slots
            .insert(key, Slot { extent, f32_len: data.len(), enc, enc_len });
        self.queue_write(off)?;
        self.stats.puts += 1;
        self.stats.logical_bytes += raw_len as u64;
        Ok(())
    }

    fn get(&mut self, key: usize, out: &mut [f32]) -> Result<()> {
        self.flush_pending()?;
        let slot = *self
            .slots
            .get(&key)
            .ok_or_else(|| Error::Runtime(format!("swap store: key {key} was never put")))?;
        if slot.f32_len != out.len() {
            return Err(Error::Runtime(format!(
                "swap store: key {key} holds {} f32s, asked for {}",
                slot.f32_len,
                out.len()
            )));
        }
        let off = self.extents[slot.extent].off;
        self.scratch.clear();
        self.scratch.resize(slot.enc_len, 0);
        self.file
            .seek(SeekFrom::Start(off))
            .and_then(|_| self.file.read_exact(&mut self.scratch))
            .map_err(|e| {
                Error::Runtime(format!(
                    "swap store: read slot {key} ({} bytes at {off}) from {}: {e}",
                    slot.enc_len,
                    self.path.display()
                ))
            })?;
        match slot.enc {
            Encoding::Raw => {
                for (i, v) in out.iter_mut().enumerate() {
                    *v = f32::from_le_bytes([
                        self.scratch[4 * i],
                        self.scratch[4 * i + 1],
                        self.scratch[4 * i + 2],
                        self.scratch[4 * i + 3],
                    ]);
                }
            }
            Encoding::ShuffleRle => {
                // scratch holds enc; decode through the shuffle scratch
                let enc = std::mem::take(&mut self.scratch);
                let r = shuffle_rle_decode(&enc, out, &mut self.shuf);
                self.scratch = enc;
                r.map_err(|e| {
                    Error::Runtime(format!("swap store: decode slot {key}: {e}"))
                })?;
            }
        }
        self.stats.gets += 1;
        Ok(())
    }

    fn free(&mut self, key: usize) {
        if let Some(slot) = self.slots.remove(&key) {
            self.release(slot.extent);
        }
    }

    fn slot_count(&self) -> usize {
        self.slots.len()
    }

    fn stats(&self) -> StoreStats {
        let mut s = self.stats;
        s.max_slot_writes = self.extents.iter().map(|e| e.writes).max().unwrap_or(0);
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(store: &mut dyn SecondaryStore) {
        let a = vec![1.0f32, -2.5, 3.25, f32::MIN_POSITIVE, -0.0];
        let b = vec![9.0f32; 7];
        store.put(0, &a).unwrap();
        store.put(1, &b).unwrap();
        let mut out = vec![0f32; a.len()];
        store.get(0, &mut out).unwrap();
        // bitwise: swap must preserve exact representations (incl. -0.0)
        for (x, y) in out.iter().zip(a.iter()) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        // overwrite in place
        let a2 = vec![7.0f32; 5];
        store.put(0, &a2).unwrap();
        store.get(0, &mut out).unwrap();
        assert_eq!(out, a2);
        let mut out_b = vec![0f32; b.len()];
        store.get(1, &mut out_b).unwrap();
        assert_eq!(out_b, b);
        // wrong length and missing key are errors
        let mut wrong = vec![0f32; 3];
        assert!(store.get(0, &mut wrong).is_err());
        assert!(store.get(99, &mut out).is_err());
        // freed slots are gone; freeing an absent key is a no-op
        assert_eq!(store.slot_count(), 2);
        store.free(1);
        store.free(1);
        assert!(store.get(1, &mut out_b).is_err());
        assert_eq!(store.slot_count(), 1);
        store.free(0);
        assert_eq!(store.slot_count(), 0);
    }

    #[test]
    fn host_roundtrip() {
        roundtrip(&mut HostStore::new());
    }

    #[test]
    fn file_roundtrip() {
        let mut s = FileStore::in_temp_dir().unwrap();
        let path = s.path().to_path_buf();
        roundtrip(&mut s);
        assert!(path.exists());
        drop(s);
        assert!(!path.exists(), "spill file removed on drop");
    }

    #[test]
    fn file_compressed_roundtrip() {
        let mut s = FileStore::in_temp_dir_compressed().unwrap();
        assert_eq!(s.kind(), "file-compressed");
        roundtrip(&mut s);
    }

    /// Adversarial payloads through the codec itself: bitwise recovery
    /// for NaN payloads, ±0.0, denormals, and raw-fallback inputs.
    #[test]
    fn codec_roundtrip_bitwise() {
        let mut lcg = 0x1234_5678_9abc_def0u64;
        let mut cases: Vec<Vec<f32>> = vec![
            vec![],
            vec![0.0; 257],
            vec![-0.0; 4],
            vec![1.0; 1000],
            (0..300).map(|i| i as f32 * 0.25 - 40.0).collect(),
            vec![f32::NAN, f32::INFINITY, f32::NEG_INFINITY, f32::MIN_POSITIVE, -0.0, 1e-42],
        ];
        // incompressible-ish random bits (raw fallback exercises too)
        cases.push(
            (0..777)
                .map(|_| {
                    lcg = lcg.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                    f32::from_bits((lcg >> 32) as u32)
                })
                .collect(),
        );
        let (mut enc, mut plane, mut shuf) = (Vec::new(), Vec::new(), Vec::new());
        for case in &cases {
            shuffle_rle_encode(case, &mut enc, &mut plane);
            let mut out = vec![0f32; case.len()];
            shuffle_rle_decode(&enc, &mut out, &mut shuf).unwrap();
            for (x, y) in out.iter().zip(case.iter()) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
        // a constant tensor must actually compress
        shuffle_rle_encode(&vec![1.0f32; 1000], &mut enc, &mut plane);
        assert!(enc.len() < 4000, "constant plane should RLE well: {} bytes", enc.len());
    }

    #[test]
    fn compressed_store_writes_fewer_physical_bytes() {
        let mut s = FileStore::in_temp_dir_compressed().unwrap();
        let data = vec![1.5f32; 4096];
        s.put(0, &data).unwrap();
        let mut out = vec![0f32; data.len()];
        s.get(0, &mut out).unwrap(); // get flushes the pending write
        assert_eq!(out, data);
        let st = s.stats();
        assert_eq!(st.logical_bytes, 4096 * 4);
        assert!(
            st.physical_bytes < st.logical_bytes / 4,
            "constant tensor barely compressed: {} physical vs {} logical",
            st.physical_bytes,
            st.logical_bytes
        );
    }

    #[test]
    fn adjacent_puts_coalesce_into_one_write() {
        let mut s = FileStore::in_temp_dir().unwrap();
        s.put(0, &[1.0f32; 64]).unwrap();
        s.put(1, &[2.0f32; 64]).unwrap();
        s.put(2, &[3.0f32; 64]).unwrap();
        assert_eq!(s.stats().coalesced_puts, 2, "appended extents are adjacent");
        assert_eq!(s.stats().physical_bytes, 0, "nothing flushed yet");
        let mut out = vec![0f32; 64];
        for (k, want) in [(0usize, 1.0f32), (1, 2.0), (2, 3.0)] {
            s.get(k, &mut out).unwrap();
            assert!(out.iter().all(|v| *v == want), "slot {k}");
        }
        assert_eq!(s.stats().physical_bytes, 3 * 64 * 4, "one coalesced flush");
    }

    /// A bridged coalescing hole must carry the file's *current* bytes:
    /// a live extent inside the gap that is not part of the write burst
    /// has to survive the merged flush (zero-filling the hole clobbered
    /// it — caught by the behavioral-sim fuzz before commit).
    #[test]
    fn coalesced_gap_preserves_live_extent_between_writes() {
        let mut s = FileStore::in_temp_dir().unwrap();
        s.put(0, &[1.0f32; 16]).unwrap();
        s.put(1, &[2.0f32; 16]).unwrap();
        s.put(2, &[3.0f32; 16]).unwrap();
        let mut out = vec![0f32; 16];
        s.get(1, &mut out).unwrap(); // flush the burst
        // rewrite only the outer slots — slot 1's extent sits inside
        // the hole the coalescer bridges
        s.put(0, &[4.0f32; 16]).unwrap();
        s.put(2, &[5.0f32; 16]).unwrap();
        assert_eq!(s.stats().coalesced_puts, 3, "the gap write must merge");
        s.get(1, &mut out).unwrap();
        assert!(out.iter().all(|v| *v == 2.0), "bridged hole clobbered slot 1: {out:?}");
        s.get(0, &mut out).unwrap();
        assert!(out.iter().all(|v| *v == 4.0));
        s.get(2, &mut out).unwrap();
        assert!(out.iter().all(|v| *v == 5.0));
    }

    /// Write-counter monotonicity + wear rotation: a hot slot rotates
    /// onto a cooler free extent, capping the hottest extent's writes.
    #[test]
    fn wear_rotation_spreads_writes() {
        let mut s = FileStore::in_temp_dir().unwrap();
        let len = 32usize;
        s.put(0, &vec![0.5f32; len]).unwrap();
        s.put(1, &vec![1.5f32; len]).unwrap();
        s.put(2, &vec![2.5f32; len]).unwrap(); // keeps extent 1 non-trailing
        s.free(1); // mid-file free extent, 1 write on the clock
        let mut prev_writes = 0u64;
        for i in 0..(2 * ROTATE_WRITES) {
            s.put(0, &vec![i as f32; len]).unwrap();
            let st = s.stats();
            assert!(st.max_slot_writes >= prev_writes, "write counters went backwards");
            prev_writes = st.max_slot_writes;
        }
        let st = s.stats();
        assert!(st.rotations >= 1, "hot slot never rotated: {st:?}");
        assert!(
            st.max_slot_writes < st.puts,
            "rotation should spread writes below the total put count"
        );
        // data still intact after rotating
        let mut out = vec![0f32; len];
        s.get(0, &mut out).unwrap();
        assert!(out.iter().all(|v| *v == (2 * ROTATE_WRITES - 1) as f32));
        s.get(2, &mut out).unwrap();
        assert!(out.iter().all(|v| *v == 2.5));
    }

    #[test]
    fn create_error_names_the_path() {
        let bad = PathBuf::from("/nonexistent-dir-nntrainer/spill.bin");
        let err = FileStore::create(bad.clone()).unwrap_err();
        let msg = format!("{err}");
        assert!(
            msg.contains("/nonexistent-dir-nntrainer/spill.bin"),
            "error must name the offending path: {msg}"
        );
    }

    /// A backing file that vanishes (truncated to zero behind the
    /// store's back) must fail a fetch with an error naming the slot —
    /// not garbage data, not a bare io error.
    #[test]
    fn vanished_backing_file_names_the_slot() {
        let mut s = FileStore::in_temp_dir().unwrap();
        s.put(7, &[1.0f32; 128]).unwrap();
        let mut out = vec![0f32; 128];
        s.get(7, &mut out).unwrap(); // flushed + verified readable
        // unlinking keeps an open fd readable on unix; shrink instead
        std::fs::OpenOptions::new()
            .write(true)
            .open(s.path())
            .unwrap()
            .set_len(0)
            .unwrap();
        let err = s.get(7, &mut out).unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("slot 7"), "error must name the slot: {msg}");
        assert!(msg.contains("nntrainer-swap"), "error must name the file: {msg}");
    }

    #[test]
    fn freed_trailing_extents_roll_the_file_back() {
        let mut s = FileStore::in_temp_dir().unwrap();
        s.put(0, &[1.0f32; 16]).unwrap();
        s.put(1, &[2.0f32; 16]).unwrap();
        assert_eq!(s.stats().live_bytes, 2 * 16 * 4);
        s.free(1);
        s.free(0);
        assert_eq!(s.end, 0, "newest-first frees roll the end back to zero");
        assert_eq!(s.stats().live_bytes, 0);
        assert_eq!(s.stats().peak_bytes, 2 * 16 * 4);
        // space is recycled, not leaked
        s.put(2, &[3.0f32; 16]).unwrap();
        assert_eq!(s.end, 16 * 4);
    }

    #[test]
    fn length_change_reallocates() {
        let mut s = FileStore::in_temp_dir().unwrap();
        s.put(0, &[1.0f32; 16]).unwrap();
        s.put(0, &[2.0f32; 32]).unwrap();
        let mut out = vec![0f32; 32];
        s.get(0, &mut out).unwrap();
        assert!(out.iter().all(|v| *v == 2.0));
        let mut short = vec![0f32; 16];
        assert!(s.get(0, &mut short).is_err());
    }
}
