//! Stub PJRT runtime, compiled when the `pjrt` feature is off (the
//! default: the offline build cannot vendor the `xla` crate). Mirrors the
//! real `client.rs` API; construction fails with a clear message, so
//! artifact-dependent paths (`nntrainer artifacts`, the XLA oracle tests)
//! degrade to a skip/error instead of breaking the build.

use std::path::Path;

use crate::error::{Error, Result};

/// A loaded + compiled executable with its input arity (stub).
pub struct LoadedExec {
    pub name: String,
}

/// PJRT CPU runtime holding compiled artifacts by name (stub).
pub struct XlaRuntime {
    _private: (),
}

fn unavailable() -> Error {
    Error::Runtime(
        "PJRT runtime unavailable: built without the `pjrt` feature \
         (requires the `xla` crate; see DESIGN.md §Substitutions)"
            .into(),
    )
}

impl XlaRuntime {
    pub fn new(_artifact_dir: impl AsRef<Path>) -> Result<Self> {
        Err(unavailable())
    }

    pub fn platform(&self) -> String {
        "unavailable".into()
    }

    pub fn load(&mut self, _name: &str) -> Result<()> {
        Err(unavailable())
    }

    pub fn run_f32(
        &mut self,
        _name: &str,
        _inputs: &[(&[f32], &[usize])],
    ) -> Result<Vec<Vec<f32>>> {
        Err(unavailable())
    }

    pub fn loaded(&self) -> Vec<&str> {
        Vec::new()
    }
}
