//! Thin wrapper over the `xla` crate: one PJRT CPU client, HLO-text
//! loading, compile caching, f32-buffer execution.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::error::{Error, Result};

/// A loaded + compiled executable with its input arity.
pub struct LoadedExec {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
}

/// PJRT CPU runtime holding compiled artifacts by name.
pub struct XlaRuntime {
    client: xla::PjRtClient,
    dir: PathBuf,
    cache: HashMap<String, LoadedExec>,
}

impl XlaRuntime {
    /// Create a CPU PJRT client rooted at an artifact directory.
    pub fn new(artifact_dir: impl AsRef<Path>) -> Result<Self> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| Error::Runtime(format!("PjRtClient::cpu: {e}")))?;
        Ok(XlaRuntime {
            client,
            dir: artifact_dir.as_ref().to_path_buf(),
            cache: HashMap::new(),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load `<dir>/<name>.hlo.txt`, compile, and cache.
    pub fn load(&mut self, name: &str) -> Result<()> {
        if self.cache.contains_key(name) {
            return Ok(());
        }
        let path = self.dir.join(format!("{name}.hlo.txt"));
        if !path.exists() {
            return Err(Error::Runtime(format!(
                "artifact `{}` not found — run `make artifacts`",
                path.display()
            )));
        }
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| Error::Runtime("non-utf8 path".into()))?,
        )
        .map_err(|e| Error::Runtime(format!("parse {}: {e}", path.display())))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| Error::Runtime(format!("compile {name}: {e}")))?;
        self.cache.insert(name.to_string(), LoadedExec { exe, name: name.to_string() });
        Ok(())
    }

    /// Execute a loaded artifact on f32 inputs (shape given per input),
    /// returning every output flattened to `Vec<f32>`.
    ///
    /// All aot.py artifacts are lowered with `return_tuple=True`, so the
    /// single result is a tuple we unpack.
    pub fn run_f32(&mut self, name: &str, inputs: &[(&[f32], &[usize])]) -> Result<Vec<Vec<f32>>> {
        self.load(name)?;
        let exec = self.cache.get(name).unwrap();
        let mut lits = Vec::with_capacity(inputs.len());
        for (data, shape) in inputs {
            let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
            let lit = xla::Literal::vec1(data)
                .reshape(&dims)
                .map_err(|e| Error::Runtime(format!("reshape input: {e}")))?;
            lits.push(lit);
        }
        let mut result = exec
            .exe
            .execute::<xla::Literal>(&lits)
            .map_err(|e| Error::Runtime(format!("execute {name}: {e}")))?[0][0]
            .to_literal_sync()
            .map_err(|e| Error::Runtime(format!("fetch {name}: {e}")))?;
        let tuple = result
            .decompose_tuple()
            .map_err(|e| Error::Runtime(format!("untuple {name}: {e}")))?;
        let mut out = Vec::with_capacity(tuple.len());
        for t in tuple {
            out.push(
                t.to_vec::<f32>()
                    .map_err(|e| Error::Runtime(format!("to_vec {name}: {e}")))?,
            );
        }
        Ok(out)
    }

    pub fn loaded(&self) -> Vec<&str> {
        self.cache.keys().map(|s| s.as_str()).collect()
    }
}
