//! Artifact catalog: the shape contract between `python/compile/aot.py`
//! and the Rust runtime. Shapes are duplicated here as constants (and
//! asserted against `manifest.json` at load) so the Rust side type-checks
//! buffer sizes without parsing JSON on the hot path.

use std::path::Path;

use crate::error::{Error, Result};

/// Demo-MLP spec — keep in sync with `python/compile/model.py` and
/// `zoo::mlp_e2e`.
pub const MLP_IN: usize = 256;
pub const MLP_HIDDEN: usize = 64;
pub const MLP_OUT: usize = 10;
pub const MLP_BATCH: usize = 32;

/// Oracle shapes (`model.py::ORACLE_*`).
pub const ORACLE_LINEAR: (usize, usize, usize) = (8, 32, 16); // m,k,n
pub const ORACLE_CONV: (usize, usize, usize, usize, usize, usize) = (2, 3, 8, 8, 4, 3); // b,c,h,w,oc,k
pub const ORACLE_LSTM: (usize, usize, usize, usize) = (2, 5, 4, 6); // b,t,i,h
pub const ORACLE_XENT: (usize, usize) = (8, 10); // r,c

/// Lightweight manifest check: every expected artifact file exists.
pub struct ArtifactCatalog {
    pub dir: std::path::PathBuf,
}

pub const ARTIFACTS: &[&str] = &[
    "mlp_train_step",
    "mlp_forward",
    "oracle_linear_fwd",
    "oracle_linear_sigmoid_fwd",
    "oracle_conv2d_fwd",
    "oracle_lstm_fwd",
    "oracle_softmax_xent",
];

impl ArtifactCatalog {
    pub fn open(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        for name in ARTIFACTS {
            let p = dir.join(format!("{name}.hlo.txt"));
            if !p.exists() {
                return Err(Error::Runtime(format!(
                    "missing artifact `{}` — run `make artifacts`",
                    p.display()
                )));
            }
        }
        Ok(ArtifactCatalog { dir })
    }

    /// Default location relative to the repo root / binary cwd.
    pub fn default_dir() -> std::path::PathBuf {
        // honour NNTRAINER_ARTIFACTS, else ./artifacts
        std::env::var("NNTRAINER_ARTIFACTS")
            .map(Into::into)
            .unwrap_or_else(|_| "artifacts".into())
    }
}
