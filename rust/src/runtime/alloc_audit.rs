//! Steady-state allocation audit for the swap workers.
//!
//! The swap engine's contract (DESIGN.md §Swap runtime) is that
//! steady-state swapping is allocation-free: staging buffers recycle
//! through the fetch worker, store slots are reused across iterations,
//! and the evict worker reads pool spans in place. This module makes
//! that contract *testable* without taking a dependency: a counting
//! [`std::alloc::GlobalAlloc`] wrapper that a test binary installs as
//! its `#[global_allocator]`, plus a thread-local mark the swap workers
//! set on themselves so the audit counts only their allocations.
//!
//! Two deliberate scope cuts keep the signal clean:
//!
//! * Only allocations of at least [`TRACK_MIN_BYTES`] are counted. The
//!   std `mpsc` channels the engine communicates over allocate small
//!   per-send packet nodes (tens of bytes, amortized blocks ~2 KiB) that
//!   are outside the engine's control; tensor staging buffers are the
//!   thing the contract is about, and any model worth auditing moves
//!   tensors well past 4 KiB. An audit model must therefore size its
//!   offloadable tensors above the threshold — a staging realloc then
//!   cannot hide under it.
//! * Only threads that called [`mark_thread_tracked`] are counted — the
//!   training thread legitimately allocates (batch assembly, epoch
//!   bookkeeping); the workers must not.
//!
//! The counter is process-global and armed explicitly ([`arm`] /
//! [`disarm`]), so a test can warm the engine up first (first-touch
//! buffer growth is expected) and pin the *post-warmup* window to zero.
//! `rust/tests/swap_alloc_audit.rs` is the consumer, including a
//! negative control proving the hook observes the warmup allocations.

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};

/// Allocations below this size are not counted (std channel packet
/// nodes and other harness noise); tensor staging traffic in any
/// realistic audit model is far above it.
pub const TRACK_MIN_BYTES: usize = 4096;

static ARMED: AtomicBool = AtomicBool::new(false);
static TRACKED: AtomicU64 = AtomicU64::new(0);

thread_local! {
    // const-initialized: reading the flag inside the allocator cannot
    // itself allocate (a lazily-initialized TLS would recurse).
    static TRACKED_THREAD: Cell<bool> = const { Cell::new(false) };
}

/// Opt the calling thread into the audit. The swap engine's fetch and
/// evict workers call this unconditionally on startup; it is a
/// thread-local store, free when no audit is armed.
pub fn mark_thread_tracked() {
    let _ = TRACKED_THREAD.try_with(|f| f.set(true));
}

/// Zero the counter and start counting tracked-thread allocations.
pub fn arm() {
    TRACKED.store(0, Ordering::SeqCst);
    ARMED.store(true, Ordering::SeqCst);
}

/// Stop counting; returns the allocations observed while armed.
pub fn disarm() -> u64 {
    ARMED.store(false, Ordering::SeqCst);
    TRACKED.load(Ordering::SeqCst)
}

/// Current count (armed or not).
pub fn tracked_allocations() -> u64 {
    TRACKED.load(Ordering::SeqCst)
}

#[inline]
fn record(size: usize) {
    if size >= TRACK_MIN_BYTES
        && ARMED.load(Ordering::Relaxed)
        && TRACKED_THREAD.try_with(|f| f.get()).unwrap_or(false)
    {
        TRACKED.fetch_add(1, Ordering::Relaxed);
    }
}

/// Counting wrapper over the [`System`] allocator. Install in an audit
/// binary as:
///
/// ```ignore
/// #[global_allocator]
/// static ALLOC: nntrainer::runtime::alloc_audit::CountingAlloc = CountingAlloc;
/// ```
pub struct CountingAlloc;

// Safety: defers every operation to `System`; the bookkeeping around it
// touches only atomics and a const-initialized thread-local.
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        record(layout.size());
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        record(layout.size());
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        // a grow is a fresh reservation from the audit's point of view
        if new_size > layout.size() {
            record(new_size);
        }
        System.realloc(ptr, layout, new_size)
    }
}
